//! Spectral-norm vs communication-budget trade-off (paper Figure 3) on
//! the three evaluation topologies, printed as a table. Planning-only:
//! every point is an `experiment::Plan`, no run needed.
//!
//! Run: `cargo run --release --example spectral_tradeoff`

use matcha::experiment::{Plan, Strategy};
use matcha::graph::{
    find_er_with_max_degree, find_geometric_with_max_degree, paper_figure1_graph, Graph,
};

fn curve(name: &str, g: &Graph) {
    let van = Plan::for_graph(g.clone(), Strategy::Vanilla).unwrap();
    println!(
        "\n{name}: m={}, Δ={}, M={}, vanilla ρ = {:.4}",
        g.num_nodes(),
        g.max_degree(),
        van.decomposition.len(),
        van.rho
    );
    println!("  CB    ρ(MATCHA)  ρ(P-DecenSGD)  λ₂(E[L])");
    for i in 1..=10 {
        let cb = i as f64 / 10.0;
        let matcha = Plan::for_graph(g.clone(), Strategy::Matcha { budget: cb }).unwrap();
        let per = Plan::for_graph(g.clone(), Strategy::Periodic { budget: cb }).unwrap();
        let marker = if matcha.rho < van.rho { "  <- beats vanilla" } else { "" };
        println!(
            "  {cb:.1}   {:.4}     {:.4}         {:.4}{marker}",
            matcha.rho, per.rho, matcha.lambda2
        );
    }
}

fn main() {
    // Fig 3a: the 8-node graph of Figure 1 (Δ = 5).
    curve("fig3a: 8-node base graph", &paper_figure1_graph());
    // Fig 3b: 16-node geometric graph with Δ = 10.
    curve(
        "fig3b: 16-node geometric (Δ=10)",
        &find_geometric_with_max_degree(16, 10, 202),
    );
    // Fig 3c: 16-node Erdős–Rényi with Δ = 8.
    curve("fig3c: 16-node Erdős–Rényi (Δ=8)", &find_er_with_max_degree(16, 8, 303));

    println!(
        "\nreading: MATCHA needs far less budget than P-DecenSGD for the same ρ, \
         and with CB around 0.4–0.6 can even beat vanilla's ρ (paper §4.2)."
    );
}
