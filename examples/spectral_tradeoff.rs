//! Spectral-norm vs communication-budget trade-off (paper Figure 3) on
//! the three evaluation topologies, printed as a table.
//!
//! Run: `cargo run --release --example spectral_tradeoff`

use matcha::budget::optimize_activation_probabilities;
use matcha::graph::{
    find_er_with_max_degree, find_geometric_with_max_degree, paper_figure1_graph, Graph,
};
use matcha::matching::decompose;
use matcha::mixing::{optimize_alpha, optimize_alpha_periodic, vanilla_design};

fn curve(name: &str, g: &Graph) {
    let d = decompose(g);
    let van = vanilla_design(&g.laplacian());
    println!(
        "\n{name}: m={}, Δ={}, M={}, vanilla ρ = {:.4}",
        g.num_nodes(),
        g.max_degree(),
        d.len(),
        van.rho
    );
    println!("  CB    ρ(MATCHA)  ρ(P-DecenSGD)  λ₂(E[L])");
    for i in 1..=10 {
        let cb = i as f64 / 10.0;
        let probs = optimize_activation_probabilities(&d, cb);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let per = optimize_alpha_periodic(&g.laplacian(), cb);
        let marker = if mix.rho < van.rho { "  <- beats vanilla" } else { "" };
        println!(
            "  {cb:.1}   {:.4}     {:.4}         {:.4}{marker}",
            mix.rho, per.rho, probs.lambda2
        );
    }
}

fn main() {
    // Fig 3a: the 8-node graph of Figure 1 (Δ = 5).
    curve("fig3a: 8-node base graph", &paper_figure1_graph());
    // Fig 3b: 16-node geometric graph with Δ = 10.
    curve(
        "fig3b: 16-node geometric (Δ=10)",
        &find_geometric_with_max_degree(16, 10, 202),
    );
    // Fig 3c: 16-node Erdős–Rényi with Δ = 8.
    curve("fig3c: 16-node Erdős–Rényi (Δ=8)", &find_er_with_max_degree(16, 8, 303));

    println!(
        "\nreading: MATCHA needs far less budget than P-DecenSGD for the same ρ, \
         and with CB around 0.4–0.6 can even beat vanilla's ρ (paper §4.2)."
    );
}
