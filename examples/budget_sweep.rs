//! Error-vs-wallclock sweep on the fast simulator (paper Figures 4/5/6
//! in miniature): MATCHA at several budgets vs vanilla and P-DecenSGD on
//! a non-IID logistic-regression task over the Figure-1 topology. Every
//! run is one `ExperimentSpec` with the strategy swapped.
//!
//! Run: `cargo run --release --example budget_sweep`

use matcha::experiment::{self, ExperimentSpec, ProblemSpec, Strategy};

fn spec(strategy: Strategy) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(strategy)
        .problem(ProblemSpec::Logistic { non_iid: 0.8, separation: 1.5, seed: Some(13) })
        .lr(0.1)
        .iterations(2000)
        .record_every(25)
        .compute_units(1.0) // communication-heavy regime, like CIFAR-100/WRN
        .seed(3)
        .sampler_seed(11)
}

struct Row {
    name: String,
    final_loss: f64,
    acc: f64,
    time: f64,
    time_to_04: Option<f64>,
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();
    let mut run = |name: String, strategy: Strategy| {
        let res = experiment::run(&spec(strategy)).expect("sweep run");
        rows.push(Row {
            name,
            final_loss: res.final_loss(),
            acc: res.metrics.last("test_acc_vs_iter").unwrap_or(f64::NAN),
            time: res.total_time,
            time_to_04: res.metrics.first_x_below("loss_vs_time", 0.4),
        });
    };

    run("vanilla".into(), Strategy::Vanilla);
    for cb in [0.5, 0.25, 0.1] {
        run(format!("matcha CB={cb}"), Strategy::Matcha { budget: cb });
        run(format!("periodic CB={cb}"), Strategy::Periodic { budget: cb });
    }

    println!(
        "{:<18} {:>11} {:>9} {:>12} {:>16}",
        "strategy", "final loss", "test acc", "time (units)", "time to loss 0.4"
    );
    for r in &rows {
        println!(
            "{:<18} {:>11.4} {:>9.4} {:>12.0} {:>16}",
            r.name,
            r.final_loss,
            r.acc,
            r.time,
            r.time_to_04
                .map(|t| format!("{t:.0}"))
                .unwrap_or_else(|| "—".into())
        );
    }
    println!(
        "\nreading: at matched iteration counts MATCHA's loss tracks vanilla \
         (same per-epoch convergence) while its virtual time shrinks with CB; \
         P-DecenSGD at the same budget converges worse per epoch (Fig 6)."
    );
}
