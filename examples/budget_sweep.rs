//! Error-vs-wallclock sweep on the fast simulator (paper Figures 4/5/6
//! in miniature): MATCHA at several budgets vs vanilla and P-DecenSGD on
//! a non-IID logistic-regression task over the Figure-1 topology.
//!
//! Run: `cargo run --release --example budget_sweep`

use matcha::budget::optimize_activation_probabilities;
use matcha::delay::DelayModel;
use matcha::graph::paper_figure1_graph;
use matcha::matching::decompose;
use matcha::mixing::{optimize_alpha, optimize_alpha_periodic, vanilla_design};
use matcha::sim::{run_decentralized, LogisticProblem, LogisticSpec, RunConfig};
use matcha::topology::{MatchaSampler, PeriodicSampler, TopologySampler, VanillaSampler};

fn main() {
    let g = paper_figure1_graph();
    let d = decompose(&g);
    let problem = LogisticProblem::generate(LogisticSpec {
        num_workers: g.num_nodes(),
        non_iid: 0.8,
        seed: 13,
        ..LogisticSpec::default()
    });

    let iters = 2000;
    let mk_cfg = |alpha: f64| RunConfig {
        lr: 0.1,
        iterations: iters,
        record_every: 25,
        alpha,
        compute_units: 1.0, // communication-heavy regime, like CIFAR-100/WRN
        delay: DelayModel::UnitPerMatching,
        seed: 3,
        ..RunConfig::default()
    };

    struct Row {
        name: String,
        final_loss: f64,
        acc: f64,
        time: f64,
        time_to_04: Option<f64>,
    }
    let mut rows: Vec<Row> = Vec::new();

    let mut run = |name: String, alpha: f64, mut sampler: Box<dyn TopologySampler>| {
        let res = run_decentralized(&problem, &d.matchings, &mut sampler, &mk_cfg(alpha));
        rows.push(Row {
            name,
            final_loss: res.metrics.last("loss_vs_iter").unwrap(),
            acc: res.metrics.last("test_acc_vs_iter").unwrap_or(f64::NAN),
            time: res.total_time,
            time_to_04: res.metrics.first_x_below("loss_vs_time", 0.4),
        });
    };

    let van = vanilla_design(&g.laplacian());
    run("vanilla".into(), van.alpha, Box::new(VanillaSampler::new(d.len())));

    for cb in [0.5, 0.25, 0.1] {
        let probs = optimize_activation_probabilities(&d, cb);
        let mix = optimize_alpha(&d, &probs.probabilities);
        run(
            format!("matcha CB={cb}"),
            mix.alpha,
            Box::new(MatchaSampler::new(probs.probabilities.clone(), 11)),
        );
        let per = optimize_alpha_periodic(&g.laplacian(), cb);
        run(
            format!("periodic CB={cb}"),
            per.alpha,
            Box::new(PeriodicSampler::from_budget(d.len(), cb)),
        );
    }

    println!(
        "{:<18} {:>11} {:>9} {:>12} {:>16}",
        "strategy", "final loss", "test acc", "time (units)", "time to loss 0.4"
    );
    for r in &rows {
        println!(
            "{:<18} {:>11.4} {:>9.4} {:>12.0} {:>16}",
            r.name,
            r.final_loss,
            r.acc,
            r.time,
            r.time_to_04
                .map(|t| format!("{t:.0}"))
                .unwrap_or_else(|| "—".into())
        );
    }
    println!(
        "\nreading: at matched iteration counts MATCHA's loss tracks vanilla \
         (same per-epoch convergence) while its virtual time shrinks with CB; \
         P-DecenSGD at the same budget converges worse per epoch (Fig 6)."
    );
}
