//! Scenario tour of the event-driven engine: the analytic baseline, a
//! straggler, a heterogeneous cluster, and flaky links — the deployment
//! realities ("From Promise to Practice") the closed-form simulator
//! cannot express — plus a parallel budget sweep across cores.
//!
//! Run: `cargo run --release --example engine_scenarios`

use matcha::budget::optimize_activation_probabilities;
use matcha::engine::{
    available_threads, run_engine, sweep_parallel, AnalyticPolicy, DelayPolicy, EngineConfig,
    FlakyLinkPolicy, HeterogeneousPolicy, StragglerPolicy,
};
use matcha::graph::paper_figure1_graph;
use matcha::matching::decompose;
use matcha::mixing::optimize_alpha;
use matcha::rng::Rng;
use matcha::sim::{QuadraticProblem, RunConfig};
use matcha::topology::MatchaSampler;

fn main() {
    let g = paper_figure1_graph();
    let d = decompose(&g);
    let cb = 0.5;
    let probs = optimize_activation_probabilities(&d, cb);
    let mix = optimize_alpha(&d, &probs.probabilities);
    let problem = {
        let mut r = Rng::new(5);
        QuadraticProblem::generate(g.num_nodes(), 16, 1.0, 0.2, &mut r)
    };
    let cfg = RunConfig {
        lr: 0.02,
        iterations: 800,
        record_every: 100,
        alpha: mix.alpha,
        seed: 1,
        ..RunConfig::default()
    };
    let engine_cfg = EngineConfig { run: cfg.clone(), threads: 1 };

    println!("=== engine scenarios on the Figure-1 graph (CB = {cb}) ===\n");
    let mut table = matcha::benchkit::Table::new(&[
        "scenario",
        "virtual time",
        "final subopt",
        "dropped links",
    ]);

    let scenarios: Vec<(&str, Box<dyn DelayPolicy>)> = vec![
        ("analytic baseline", Box::new(AnalyticPolicy::matching_run_config(&cfg))),
        (
            "straggler (worker 0, 5x)",
            Box::new(StragglerPolicy::new(
                AnalyticPolicy::matching_run_config(&cfg),
                vec![0],
                5.0,
            )),
        ),
        (
            "heterogeneous cluster",
            Box::new(HeterogeneousPolicy::generate(&g, 1.0, 17)),
        ),
        (
            "flaky links (p = 0.2)",
            Box::new(FlakyLinkPolicy::new(
                AnalyticPolicy::matching_run_config(&cfg),
                0.2,
                23,
            )),
        ),
    ];

    for (name, mut policy) in scenarios {
        let mut sampler = MatchaSampler::new(probs.probabilities.clone(), 3);
        let res = run_engine(&problem, &d.matchings, &mut sampler, policy.as_mut(), &engine_cfg);
        table.row(&[
            name.to_string(),
            format!("{:.0}", res.run.total_time),
            format!("{:.5}", res.run.metrics.last("subopt_vs_iter").unwrap_or(f64::NAN)),
            format!("{}", res.dropped_links),
        ]);
    }
    table.print();

    // Parallel budget sweep: the fig4-style grid, fanned across cores.
    let budgets = [0.1, 0.25, 0.5, 0.75, 1.0];
    let threads = available_threads();
    println!("\n=== parallel budget sweep ({threads} threads) ===");
    let wall = std::time::Instant::now();
    let results = sweep_parallel(&budgets, threads, |_i, &b| {
        let probs = optimize_activation_probabilities(&d, b);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let mut sampler = MatchaSampler::new(probs.probabilities.clone(), 3);
        let cfg = EngineConfig {
            run: RunConfig {
                lr: 0.02,
                iterations: 800,
                record_every: 400,
                alpha: mix.alpha,
                seed: 1,
                ..RunConfig::default()
            },
            threads: 1,
        };
        let r = matcha::engine::run_engine_analytic(&problem, &d.matchings, &mut sampler, &cfg);
        (b, r.run.total_time, r.run.metrics.last("subopt_vs_iter").unwrap_or(f64::NAN))
    });
    for (b, time, subopt) in results {
        println!("  CB {b:<5} -> virtual time {time:>6.0}, final subopt {subopt:.5}");
    }
    println!("sweep wallclock: {:.2}s", wall.elapsed().as_secs_f64());
}
