//! Scenario tour of the event-driven engine: the analytic baseline, a
//! straggler, a heterogeneous cluster, and flaky links — the deployment
//! realities ("From Promise to Practice") the closed-form simulator
//! cannot express — plus a parallel budget sweep that **streams each
//! finished grid point** through the experiment [`Observer`].
//!
//! Every scenario is the same `ExperimentSpec` with a different `policy`
//! string; the spec is what `matcha run --spec` would load from JSON.
//!
//! Run: `cargo run --release --example engine_scenarios`

use matcha::experiment::{
    self, Backend, ExperimentResult, ExperimentSpec, Observer, ProblemSpec, Strategy,
};

fn spec(policy: &str, cb: f64) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(Strategy::Matcha { budget: cb })
        .problem(ProblemSpec::Quadratic { dim: 16, hetero: 1.0, noise_std: 0.2, seed: Some(5) })
        .policy(policy)
        .backend(Backend::EngineSequential)
        .lr(0.02)
        .iterations(800)
        .record_every(100)
        .seed(1)
        .sampler_seed(3)
}

fn main() {
    let cb = 0.5;
    println!("=== engine scenarios on the Figure-1 graph (CB = {cb}) ===\n");
    let mut table = matcha::benchkit::Table::new(&[
        "scenario",
        "policy spec",
        "virtual time",
        "final subopt",
        "dropped links",
    ]);

    let scenarios = [
        ("analytic baseline", "analytic"),
        ("straggler (worker 0, 5x)", "straggler:0:5.0"),
        ("heterogeneous cluster", "hetero:17"),
        ("flaky links (p = 0.2)", "flaky:0.2"),
    ];

    for (name, policy) in scenarios {
        let res = experiment::run(&spec(policy, cb)).expect("scenario run");
        table.row(&[
            name.to_string(),
            policy.to_string(),
            format!("{:.0}", res.total_time),
            format!("{:.5}", res.metrics.last("subopt_vs_iter").unwrap_or(f64::NAN)),
            format!("{}", res.dropped_links),
        ]);
    }
    table.print();

    // Parallel budget sweep: the fig4-style grid fanned across cores,
    // with per-point streaming — each line prints the moment that grid
    // point finishes, not when the whole sweep joins.
    struct StreamLine<'a> {
        budgets: &'a [f64],
    }
    impl Observer for StreamLine<'_> {
        fn on_point(&mut self, index: usize, result: &ExperimentResult) {
            println!(
                "  [streamed] CB {:<5} -> virtual time {:>6.0}, final subopt {:.5}",
                self.budgets[index],
                result.total_time,
                result.metrics.last("subopt_vs_iter").unwrap_or(f64::NAN)
            );
        }
    }

    let budgets = [0.1, 0.25, 0.5, 0.75, 1.0];
    let threads = matcha::engine::available_threads();
    println!("\n=== parallel budget sweep ({threads} threads, streamed) ===");
    let wall = std::time::Instant::now();
    let mut streamer = StreamLine { budgets: &budgets };
    let results = experiment::run_sweep(&spec("analytic", cb), &budgets, threads, &mut streamer)
        .expect("sweep");
    println!("sweep wallclock: {:.2}s; final table (input order):", wall.elapsed().as_secs_f64());
    for (b, r) in &results {
        println!(
            "  CB {b:<5} -> virtual time {:>6.0}, final subopt {:.5}",
            r.total_time,
            r.metrics.last("subopt_vs_iter").unwrap_or(f64::NAN)
        );
    }
}
