//! Quickstart: the full MATCHA pipeline on the paper's Figure-1 graph.
//!
//! Demonstrates the three steps of §3 — matching decomposition,
//! activation-probability optimization, mixing-weight optimization — plus
//! the apriori schedule and the per-node communication-time savings the
//! paper's Figure 1 illustrates.
//!
//! Run: `cargo run --release --example quickstart`

use matcha::budget::optimize_activation_probabilities;
use matcha::graph::{expected_node_comm_time, paper_figure1_graph};
use matcha::matching::decompose;
use matcha::mixing::{optimize_alpha, vanilla_design};
use matcha::topology::{MatchaSampler, Schedule};

fn main() {
    let g = paper_figure1_graph();
    println!("base graph: {} nodes, {} edges, Δ = {}\n", g.num_nodes(), g.num_edges(), g.max_degree());

    // Step 1: matching decomposition (Misra–Gries, M ≤ Δ+1).
    let d = decompose(&g);
    println!("Step 1 — decomposition into M = {} matchings:", d.len());
    for (j, m) in d.matchings.iter().enumerate() {
        println!("  G_{j}: {:?}", m.edges());
    }

    // Step 2: activation probabilities at a 50% communication budget.
    let cb = 0.5;
    let probs = optimize_activation_probabilities(&d, cb);
    println!("\nStep 2 — activation probabilities (CB = {cb}):");
    for (j, p) in probs.probabilities.iter().enumerate() {
        println!("  p_{j} = {p:.3}");
    }
    println!("  λ₂ of expected topology: {:.4}", probs.lambda2);

    // Step 3: mixing weight α minimizing the spectral norm ρ.
    let mix = optimize_alpha(&d, &probs.probabilities);
    let van = vanilla_design(&g.laplacian());
    println!("\nStep 3 — mixing design:");
    println!("  MATCHA  α = {:.4}, ρ = {:.4}", mix.alpha, mix.rho);
    println!("  vanilla α = {:.4}, ρ = {:.4}", van.alpha, van.rho);
    println!("  (ρ < 1 ⇒ convergence guaranteed; Theorem 2)");

    // The apriori schedule (paper §1: zero runtime scheduling overhead).
    let mut sampler = MatchaSampler::new(probs.probabilities.clone(), 0);
    let schedule = Schedule::generate(&mut sampler, mix.alpha, d.len(), 1000);
    println!(
        "\nschedule: 1000 rounds pregenerated, mean comm = {:.2} units/iter \
         (vanilla: {} units/iter)",
        schedule.mean_comm_units(),
        d.len()
    );

    // Figure-1 style per-node communication times.
    println!("\nper-node expected communication time (units/iter):");
    println!("  node  degree  vanilla  matcha(CB=0.5)");
    let vanilla_t = expected_node_comm_time(g.num_nodes(), &d.matchings, &vec![1.0; d.len()]);
    let matcha_t = expected_node_comm_time(g.num_nodes(), &d.matchings, &probs.probabilities);
    let deg = g.degrees();
    for i in 0..g.num_nodes() {
        println!(
            "  {:>4}  {:>6}  {:>7.2}  {:>14.2}",
            i, deg[i], vanilla_t[i], matcha_t[i]
        );
    }
    println!(
        "\nnote how the degree-1 node (4) keeps its communication while the \
         degree-5 node (1) is throttled — critical links first."
    );
}
