//! Quickstart: the unified experiment pipeline — **spec → plan → run →
//! observe** — on the paper's Figure-1 graph.
//!
//! One typed [`ExperimentSpec`] declares the whole run; planning exposes
//! the paper's three steps (matching decomposition, activation
//! probabilities, mixing weight) before anything executes; `run_observed`
//! streams progress through an [`Observer`]; and the spec round-trips
//! through JSON so it can be saved and replayed with
//! `matcha run --spec FILE`.
//!
//! Run: `cargo run --release --example quickstart`

use matcha::experiment::{
    self, Backend, ExperimentResult, ExperimentSpec, Observer, ProblemSpec, Strategy,
};
use matcha::graph::expected_node_comm_time;
use matcha::metrics::Recorder;

/// Prints a progress line at every metrics record.
struct ProgressPrinter;

impl Observer for ProgressPrinter {
    fn on_record(&mut self, k: usize, time: f64, metrics: &Recorder) {
        if let Some(loss) = metrics.last("loss_vs_iter") {
            println!("  iter {k:>5}  virtual time {time:>8.1}  loss {loss:.5}");
        }
    }
}

fn main() {
    // --- Spec: declare the experiment -----------------------------------
    let spec = ExperimentSpec::new("fig1")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::logistic())
        .backend(Backend::EngineSequential)
        .lr(0.1)
        .iterations(1000)
        .record_every(200)
        .seed(0)
        .validated()
        .expect("spec validates");
    println!("spec (JSON, loadable via `matcha run --spec`):\n{}\n", spec.to_json_string());

    // --- Plan: the paper's §3 pipeline, before any run -------------------
    let plan = experiment::plan(&spec).expect("plan");
    println!(
        "base graph: {} nodes, {} edges, Δ = {}",
        plan.graph.num_nodes(),
        plan.graph.num_edges(),
        plan.graph.max_degree()
    );
    println!("Step 1 — decomposition into M = {} matchings:", plan.decomposition.len());
    for (j, m) in plan.decomposition.matchings.iter().enumerate() {
        println!("  G_{j}: {:?}", m.edges());
    }
    println!("\nStep 2 — activation probabilities (CB = 0.5):");
    for (j, p) in plan.probabilities.iter().enumerate() {
        println!("  p_{j} = {p:.3}");
    }
    println!("  λ₂ of expected topology: {:.4}", plan.lambda2);
    println!("\nStep 3 — mixing design: α = {:.4}, ρ = {:.4}", plan.alpha, plan.rho);
    println!("  (ρ < 1 ⇒ convergence guaranteed; Theorem 2)");

    // The apriori schedule (paper §1: zero runtime scheduling overhead).
    let schedule = plan.schedule(1000, spec.seed);
    println!(
        "\nschedule: 1000 rounds pregenerated, mean comm = {:.2} units/iter \
         (vanilla: {} units/iter)",
        schedule.mean_comm_units(),
        plan.decomposition.len()
    );

    // Figure-1 style per-node communication times.
    println!("\nper-node expected communication time (units/iter):");
    println!("  node  degree  vanilla  matcha(CB=0.5)");
    let all_on = vec![1.0; plan.decomposition.len()];
    let vanilla_t =
        expected_node_comm_time(plan.graph.num_nodes(), &plan.decomposition.matchings, &all_on);
    let matcha_t = expected_node_comm_time(
        plan.graph.num_nodes(),
        &plan.decomposition.matchings,
        &plan.probabilities,
    );
    let deg = plan.graph.degrees();
    for i in 0..plan.graph.num_nodes() {
        println!(
            "  {:>4}  {:>6}  {:>7.2}  {:>14.2}",
            i, deg[i], vanilla_t[i], matcha_t[i]
        );
    }

    // --- Run + observe ---------------------------------------------------
    println!("\nrunning (streaming records through an Observer):");
    let result: ExperimentResult =
        experiment::run_planned(&spec, &plan, &mut ProgressPrinter).expect("run");
    println!(
        "\ndone: final loss {:.5}, total virtual time {:.1} units, comm {:.1} units",
        result.final_loss(),
        result.total_time,
        result.total_comm_units
    );

    println!(
        "\nnote how the degree-1 node (4) keeps its communication while the \
         degree-5 node (1) is throttled — critical links first."
    );
}
