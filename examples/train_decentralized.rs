//! End-to-end driver: decentralized training of the transformer LM over
//! the Figure-1 topology with MATCHA at several communication budgets —
//! the full three-layer stack (Rust coordinator → AOT XLA train/mix
//! steps → Pallas-kernel model) on a real workload.
//!
//! Requires `make artifacts` (default: small preset, 8 workers).
//!
//! Run: `cargo run --release --example train_decentralized -- [steps] [--pallas]`
//!
//! The loss curves land in `results/e2e_<strategy>_<cb>.json`; the summary
//! table printed at the end is the EXPERIMENTS.md headline run.

use matcha::config::ArtifactPaths;
use matcha::coordinator::{plan_matcha, plan_vanilla, Trainer, TrainerConfig};
use matcha::graph::paper_figure1_graph;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(300);
    let use_pallas = args.iter().any(|a| a == "--pallas");

    let g = paper_figure1_graph();
    let artifacts = ArtifactPaths::new("artifacts");
    std::fs::create_dir_all("results")?;

    // The paper's Figure 4 sweep: vanilla vs MATCHA at CB ∈ {0.5, 0.1}.
    let runs: Vec<(String, matcha::coordinator::MatchaPlan)> = vec![
        ("vanilla_1.0".to_string(), plan_vanilla(&g, steps)),
        ("matcha_0.5".to_string(), plan_matcha(&g, 0.5, steps, 7)),
        ("matcha_0.1".to_string(), plan_matcha(&g, 0.1, steps, 7)),
    ];

    println!("end-to-end decentralized training: fig1 graph, {steps} steps, pallas={use_pallas}");
    let mut summary = Vec::new();
    for (name, plan) in runs {
        let cfg = TrainerConfig {
            steps,
            lr: 0.5,
            lr_decay: 0.5,
            lr_decay_every: steps / 2,
            eval_every: (steps / 10).max(1),
            use_pallas,
            compute_units: 1.0,
            seed: 7,
            ..TrainerConfig::default()
        };
        let trainer = Trainer::new(&artifacts, plan.decomposition.clone(), cfg)?;
        println!(
            "\n== {name}: α={:.4} ρ={:.4} mean-comm={:.2} units/iter ==",
            plan.alpha,
            plan.rho,
            plan.schedule.mean_comm_units()
        );
        let report = trainer.run(&plan.schedule)?;
        // Print the loss curve (x = iteration, y = train loss).
        for s in report.metrics.get("train_loss_vs_iter").iter().step_by((steps / 15).max(1)) {
            println!("  iter {:>5}  train loss {:.4}", s.x, s.y);
        }
        println!(
            "  final: train {:.4}, eval {:.4}, virtual time {:.1}, comm {:.1}, wall {:.1}s",
            report.final_train_loss,
            report.final_eval_loss,
            report.total_time_units,
            report.total_comm_units,
            report.wallclock_secs
        );
        report
            .metrics
            .save_json(std::path::Path::new(&format!("results/e2e_{name}.json")))?;
        summary.push((name, report));
    }

    println!("\n===== summary (virtual time from the paper's delay model) =====");
    println!("{:<14} {:>10} {:>10} {:>12} {:>10}", "run", "train", "eval", "time(units)", "comm");
    for (name, r) in &summary {
        println!(
            "{:<14} {:>10.4} {:>10.4} {:>12.1} {:>10.1}",
            name, r.final_train_loss, r.final_eval_loss, r.total_time_units, r.total_comm_units
        );
    }
    let vanilla_t = summary[0].1.total_time_units;
    for (name, r) in &summary[1..] {
        println!(
            "{name}: {:.2}x less total time than vanilla at matched iterations",
            vanilla_t / r.total_time_units
        );
    }
    Ok(())
}
