#!/usr/bin/env bash
# CI for the offline MATCHA crate: build, tests, lints, docs, spec smoke,
# bench smoke.
#
# The default feature set is dependency-free; the `xla` feature (NN
# training path) needs vendored xla/anyhow crates and is NOT built here.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo fmt --check"
# Enforced: formatting drift fails CI. Run `cargo fmt` before pushing.
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
# All default-feature targets: lib, bin, tests, examples, benches.
cargo clippy --all-targets -- -D warnings

echo "==> cargo doc --no-deps (deny rustdoc warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> experiment spec smoke (matcha run --spec ... --dry-run)"
# Every committed example spec must parse, validate and plan.
for spec in examples/specs/*.json; do
  echo "--- $spec"
  ./target/release/matcha run --spec "$spec" --dry-run
done

echo "==> trace smoke (matcha run --trace + trace-check)"
# A traced run must produce well-formed Chrome trace-event JSON
# (Perfetto-loadable); trace-check validates structure and prints the
# event/track counts.
./target/release/matcha run --spec examples/specs/cluster_ring.json \
  --trace /tmp/matcha_ci_trace.json
./target/release/matcha trace-check --file /tmp/matcha_ci_trace.json
rm -f /tmp/matcha_ci_trace.json

echo "==> report smoke (matcha report --spec + saved-report re-render)"
# The convergence observatory end-to-end: run a spec, render the
# design-vs-realized report, persist the JSON, and re-render the saved
# artifact standalone.
./target/release/matcha report --spec examples/specs/cluster_ring.json \
  --out /tmp/matcha_ci_report.json
./target/release/matcha report /tmp/matcha_ci_report.json
rm -f /tmp/matcha_ci_report.json

echo "==> shard-node process smoke (two daemons + remote coordinator)"
# The deployment shape end-to-end across real processes: two shard-node
# daemons on the ports committed in cluster_remote.json, driven by a
# remote-coordinator run of that same spec. `--once` makes each daemon
# exit cleanly on the coordinator's Shutdown, so `wait` doubles as the
# success check.
./target/release/matcha shard-node --listen 127.0.0.1:7841 --once &
NODE_A=$!
./target/release/matcha shard-node --listen 127.0.0.1:7842 --once &
NODE_B=$!
sleep 1
# Live health probe: an idle daemon answers `matcha status` without
# consuming its --once session.
./target/release/matcha status 127.0.0.1:7841
# The traced remote run harvests every daemon's telemetry into one
# merged multi-process Chrome trace; trace-check validates it (and
# warns on ring truncation).
./target/release/matcha run --spec examples/specs/cluster_remote.json \
  --trace /tmp/matcha_ci_remote_trace.json
./target/release/matcha trace-check --file /tmp/matcha_ci_remote_trace.json
rm -f /tmp/matcha_ci_remote_trace.json
wait "$NODE_A" "$NODE_B"

echo "==> bench smoke (--dry-run) + perf-trajectory gate"
# Hotpath smoke includes the state-arena mixing sweep (asserts zero
# allocations per iteration in the gossip mix hot path) and the
# disabled-tracer emission check (asserts zero allocations per emit);
# both land in BENCH_state.json (perf trajectory). Each BENCH artifact
# is then gated against the last committed BENCH_history/ entry —
# >25% regression on a gated key fails CI — and appended to the
# history, so committing the updated JSONL records the trajectory
# (this --append flow is also how the machine-dependent keys are
# seeded from the CI machine's own first run). --diff prints the
# old-vs-new table so a regression is diagnosable from this log.
cargo bench --bench hotpath -- --dry-run
test -f BENCH_state.json || { echo "BENCH_state.json not emitted"; exit 1; }
tools/bench_regress --artifact BENCH_state.json \
  --history BENCH_history/state.jsonl --append --diff
# Same sweep with the SIMD row kernels forced off: the scalar fallback
# must satisfy the identical zero-allocation assertions (the escape
# hatch stays honest). Gated against the same history — the alloc keys
# are exact-match and identical on both paths.
MATCHA_NO_SIMD=1 cargo bench --bench hotpath -- --dry-run
tools/bench_regress --artifact BENCH_state.json \
  --history BENCH_history/state.jsonl --append --diff
cargo bench --bench engine_sweep -- --dry-run
# Async-vs-barrier smoke: also emits BENCH_async.json (perf trajectory).
cargo bench --bench async_vs_barrier -- --dry-run
test -f BENCH_async.json || { echo "BENCH_async.json not emitted"; exit 1; }
tools/bench_regress --artifact BENCH_async.json \
  --history BENCH_history/async.jsonl --append --diff
# Cluster transport smoke: bytes/iteration + loopback-vs-TCP throughput
# (emits BENCH_cluster.json; exercises the wire over real localhost TCP).
cargo bench --bench cluster_transport -- --dry-run
test -f BENCH_cluster.json || { echo "BENCH_cluster.json not emitted"; exit 1; }
tools/bench_regress --artifact BENCH_cluster.json \
  --history BENCH_history/cluster.jsonl --append --diff
# Shard-node pipeline smoke: real daemons on localhost, window sweep
# (emits BENCH_node.json; exercises the pipelined remote coordinator).
cargo bench --bench node_pipeline -- --dry-run
test -f BENCH_node.json || { echo "BENCH_node.json not emitted"; exit 1; }
tools/bench_regress --artifact BENCH_node.json \
  --history BENCH_history/node.jsonl --append --diff

echo "CI OK"
