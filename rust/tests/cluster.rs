//! End-to-end tests of the multi-node cluster runtime: the spec-driven
//! cluster backend against the in-process backends, over both
//! transports.
//!
//! The bit-for-bit loopback pin against the committed golden fixtures
//! lives in `rust/tests/golden.rs`; here the cluster backend is compared
//! directly against the actors backend across every strategy, and the
//! TCP transport is exercised over real localhost sockets.

use matcha::cluster::TransportKind;
use matcha::experiment::{self, Backend, ExperimentSpec, ProblemSpec, Strategy};
use matcha::metrics::Recorder;

fn spec(strategy: Strategy, backend: Backend) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(strategy)
        .problem(ProblemSpec::quadratic())
        .backend(backend)
        .lr(0.03)
        .iterations(50)
        .record_every(10)
        .seed(13)
        .sampler_seed(7)
}

const STRATEGIES: [Strategy; 4] = [
    Strategy::Matcha { budget: 0.5 },
    Strategy::Vanilla,
    Strategy::Periodic { budget: 0.5 },
    Strategy::SingleMatching { budget: 0.5 },
];

#[test]
fn loopback_cluster_matches_actors_across_all_strategies() {
    for strategy in STRATEGIES {
        let actors =
            experiment::run(&spec(strategy, Backend::EngineActors { threads: 3 })).unwrap();
        let cluster = experiment::run(&spec(
            strategy,
            Backend::Cluster { shards: 3, transport: TransportKind::Loopback },
        ))
        .unwrap();
        let name = strategy.name();
        assert_eq!(cluster.final_mean, actors.final_mean, "{name}: final mean diverged");
        assert_eq!(cluster.final_states, actors.final_states, "{name}: arenas diverged");
        assert_eq!(cluster.total_time, actors.total_time, "{name}: virtual time diverged");
        assert_eq!(
            cluster.total_comm_units, actors.total_comm_units,
            "{name}: comm accounting diverged"
        );
        for series in ["loss_vs_iter", "consensus_vs_iter", "comm_units_vs_iter"] {
            let a = actors.metrics.get(series);
            let c = cluster.metrics.get(series);
            assert_eq!(a.len(), c.len(), "{name}: {series} length");
            for (pa, pc) in a.iter().zip(c) {
                assert_eq!(pa.x.to_bits(), pc.x.to_bits(), "{name}: {series} x");
                assert_eq!(pa.y.to_bits(), pc.y.to_bits(), "{name}: {series} y");
            }
        }
        assert!(cluster.cluster_stats.unwrap().total_bytes() > 0, "{name}: no wire traffic");
    }
}

#[test]
fn tcp_cluster_over_localhost_completes_the_same_schedule() {
    let strategy = Strategy::Matcha { budget: 0.5 };
    let loopback = experiment::run(&spec(
        strategy,
        Backend::Cluster { shards: 3, transport: TransportKind::Loopback },
    ))
    .unwrap();
    let tcp = experiment::run(&spec(
        strategy,
        Backend::Cluster { shards: 3, transport: TransportKind::Tcp },
    ))
    .unwrap();
    // Acceptance bound: final loss within 1e-9. The wire is actually
    // lossless (LE f64 bit patterns), so the trajectories are identical.
    let diff = (tcp.final_loss() - loopback.final_loss()).abs();
    assert!(diff <= 1e-9, "tcp vs loopback final loss diff {diff}");
    assert_eq!(tcp.final_mean, loopback.final_mean, "tcp trajectory diverged");
    assert_eq!(tcp.total_time, loopback.total_time);
    // Identical schedule + protocol → identical traffic, byte for byte.
    let (lb, tc) = (
        loopback.cluster_stats.expect("loopback stats"),
        tcp.cluster_stats.expect("tcp stats"),
    );
    assert_eq!(lb.total_bytes(), tc.total_bytes(), "transports must carry the same frames");
    assert_eq!(lb.total_frames(), tc.total_frames());
    assert_eq!(lb.transport, TransportKind::Loopback);
    assert_eq!(tc.transport, TransportKind::Tcp);
}

#[test]
fn cluster_backend_streams_observer_callbacks() {
    struct Counting {
        iterations: usize,
        records: usize,
    }
    impl experiment::Observer for Counting {
        fn on_iteration(&mut self, _k: usize, _time: f64, _comm: f64) {
            self.iterations += 1;
        }
        fn on_record(&mut self, _k: usize, _time: f64, metrics: &Recorder) {
            self.records += 1;
            assert!(!metrics.get("loss_vs_iter").is_empty());
        }
    }
    let mut obs = Counting { iterations: 0, records: 0 };
    let s = spec(
        Strategy::Matcha { budget: 0.5 },
        Backend::Cluster { shards: 2, transport: TransportKind::Loopback },
    );
    experiment::run_observed(&s, &mut obs).unwrap();
    assert_eq!(obs.iterations, 50);
    assert_eq!(obs.records, 1 + 50 / 10);
}

#[test]
fn committed_cluster_spec_executes() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("examples/specs/cluster_ring.json");
    let spec = ExperimentSpec::load(&path).expect("committed cluster spec loads");
    assert!(matches!(spec.backend, Backend::Cluster { .. }), "spec must use the cluster backend");
    let result = experiment::run(&spec).expect("committed cluster spec runs");
    assert!(result.final_loss().is_finite());
    assert!(result.cluster_stats.expect("cluster stats").total_bytes() > 0);
}
