//! Asynchronous gossip runtime contract tests.
//!
//! The contract under test (ISSUE 3): at `max_staleness = 0` the
//! barrier-free runtime degrades to the synchronous kernel — identical
//! trajectories to `sim::run_decentralized` **bit-for-bit** per seed, for
//! arbitrary graphs, strategies, seeds and compression settings — while
//! under a positive staleness bound it stays deterministic, respects the
//! bound, converges on the quadratic workload, and beats the barrier
//! engine's virtual time under stragglers.

use matcha::budget::optimize_activation_probabilities;
use matcha::engine::{run_engine, AnalyticPolicy, EngineConfig, StragglerPolicy};
use matcha::experiment::{self, Backend, ExperimentSpec, ProblemSpec, Strategy};
use matcha::gossip::{run_async, AsyncConfig};
use matcha::graph;
use matcha::matching::decompose;
use matcha::mixing::optimize_alpha;
use matcha::proptest::{check, PropConfig};
use matcha::rng::Rng;
use matcha::sim::{run_decentralized, Compression, QuadraticProblem, RunConfig};
use matcha::topology::{MatchaSampler, PeriodicSampler, VanillaSampler};

#[test]
fn property_staleness_zero_matches_sim_bit_for_bit() {
    // Random connected ER graphs × strategies × seeds × thread counts ×
    // compression: staleness-0 async and the reference simulator must
    // produce identical trajectories (final iterate and every recorded
    // state-derived metric).
    check(
        PropConfig { cases: 18, seed: 0x90551b },
        |rng| {
            let m = 4 + rng.below(8);
            let g = graph::erdos_renyi_connected(m, 0.5, rng);
            let cb = rng.uniform_in(0.2, 1.0);
            let seed = rng.next_u64();
            let strategy = rng.below(3);
            let threads = 1 + rng.below(4);
            let compress = rng.below(2) == 1;
            (g, cb, seed, strategy, threads, compress)
        },
        |(g, cb, seed, strategy, threads, compress)| {
            let d = decompose(g);
            let probs = optimize_activation_probabilities(&d, *cb);
            let mix = optimize_alpha(&d, &probs.probabilities);
            let problem = {
                let mut r = Rng::new(seed ^ 0x5eed);
                QuadraticProblem::generate(g.num_nodes(), 6, 1.0, 0.2, &mut r)
            };
            let cfg = RunConfig {
                lr: 0.02,
                iterations: 60,
                record_every: 20,
                alpha: mix.alpha,
                compression: if *compress {
                    Some(Compression::TopK { frac: 0.5 })
                } else {
                    None
                },
                seed: *seed,
                ..RunConfig::default()
            };
            fn make_sampler(
                strategy: usize,
                probs: &[f64],
                num_matchings: usize,
                cb: f64,
                seed: u64,
            ) -> Box<dyn matcha::topology::TopologySampler> {
                match strategy {
                    0 => Box::new(MatchaSampler::new(probs.to_vec(), seed ^ 1)),
                    1 => Box::new(VanillaSampler::new(num_matchings)),
                    _ => Box::new(PeriodicSampler::from_budget(num_matchings, cb)),
                }
            }
            let mut s1 =
                make_sampler(*strategy, &probs.probabilities, d.len(), *cb, *seed);
            let mut s2 =
                make_sampler(*strategy, &probs.probabilities, d.len(), *cb, *seed);
            let reference = run_decentralized(&problem, &d.matchings, &mut s1, &cfg);

            let mut policy = AnalyticPolicy::matching_run_config(&cfg);
            let async_cfg =
                AsyncConfig { run: cfg.clone(), threads: *threads, max_staleness: 0 };
            let res = run_async(&problem, &d.matchings, &mut s2, &mut policy, &async_cfg);

            if res.run.final_mean != reference.final_mean {
                return Err(format!(
                    "final iterates diverged: {:?} vs {:?}",
                    res.run.final_mean, reference.final_mean
                ));
            }
            for series in ["loss_vs_iter", "consensus_vs_iter", "gradnorm2_vs_iter"] {
                let a = res.run.metrics.get(series);
                let b = reference.metrics.get(series);
                if a.len() != b.len() {
                    return Err(format!("{series}: {} vs {} records", a.len(), b.len()));
                }
                for (pa, pb) in a.iter().zip(b) {
                    if pa.x != pb.x || pa.y != pb.y {
                        return Err(format!(
                            "{series} diverged at x={}: {} vs {}",
                            pa.x, pa.y, pb.y
                        ));
                    }
                }
            }
            if res.stats.max_staleness() != 0 {
                return Err(format!(
                    "staleness 0 run observed staleness {}",
                    res.stats.max_staleness()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn property_bounded_staleness_is_deterministic_and_bounded() {
    // Under a positive bound the trajectory differs from the sync kernel
    // but must be a pure function of the seed (any thread count) and
    // never exceed the bound.
    check(
        PropConfig { cases: 10, seed: 0xb0417d },
        |rng| {
            let m = 4 + rng.below(6);
            let g = graph::erdos_renyi_connected(m, 0.55, rng);
            let seed = rng.next_u64();
            let bound = 1 + rng.below(4);
            (g, seed, bound)
        },
        |(g, seed, bound)| {
            let d = decompose(g);
            let run_one = |threads: usize| {
                let mut sampler = VanillaSampler::new(d.len());
                let cfg = RunConfig {
                    lr: 0.02,
                    iterations: 80,
                    record_every: 40,
                    alpha: 0.1,
                    seed: *seed,
                    ..RunConfig::default()
                };
                let problem = {
                    let mut r = Rng::new(seed ^ 0x5eed);
                    QuadraticProblem::generate(g.num_nodes(), 6, 1.0, 0.2, &mut r)
                };
                let mut policy = StragglerPolicy::new(
                    AnalyticPolicy::matching_run_config(&cfg),
                    vec![0],
                    4.0,
                );
                let async_cfg = AsyncConfig { run: cfg, threads, max_staleness: *bound };
                run_async(&problem, &d.matchings, &mut sampler, &mut policy, &async_cfg)
            };
            let a = run_one(1);
            let b = run_one(3);
            if a.run.final_mean != b.run.final_mean {
                return Err("thread count changed the trajectory".into());
            }
            if a.run.total_time != b.run.total_time {
                return Err("thread count changed the virtual clock".into());
            }
            if a.stats != b.stats {
                return Err("thread count changed the staleness stats".into());
            }
            if a.stats.max_staleness() > *bound {
                return Err(format!(
                    "bound {bound} violated: observed {}",
                    a.stats.max_staleness()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn unbounded_staleness_is_deterministic_at_any_thread_count() {
    // The unbounded AD-PSGD mode (ROADMAP item): no staleness gate at
    // all, yet still a pure function of the seed — same trajectory,
    // virtual clock and stats for repeated runs and any pool size.
    use matcha::gossip::UNBOUNDED_STALENESS;
    let g = graph::erdos_renyi_connected(9, 0.5, &mut Rng::new(42));
    let d = decompose(&g);
    let run_one = |threads: usize| {
        let mut sampler = VanillaSampler::new(d.len());
        let cfg = RunConfig {
            lr: 0.02,
            iterations: 100,
            record_every: 50,
            alpha: 0.1,
            seed: 17,
            ..RunConfig::default()
        };
        let problem = {
            let mut r = Rng::new(0x5eed);
            QuadraticProblem::generate(g.num_nodes(), 6, 1.0, 0.2, &mut r)
        };
        let mut policy =
            StragglerPolicy::new(AnalyticPolicy::matching_run_config(&cfg), vec![0], 6.0);
        let async_cfg = AsyncConfig { run: cfg, threads, max_staleness: UNBOUNDED_STALENESS };
        run_async(&problem, &d.matchings, &mut sampler, &mut policy, &async_cfg)
    };
    let a = run_one(1);
    let b = run_one(1);
    let c = run_one(4);
    assert_eq!(a.run.final_mean, b.run.final_mean, "rerun changed the trajectory");
    assert_eq!(a.run.final_mean, c.run.final_mean, "thread count changed the trajectory");
    assert_eq!(a.run.total_time, c.run.total_time, "thread count changed the clock");
    assert_eq!(a.stats, c.stats, "thread count changed the stats");
    // With a 6× straggler and no gate, the fast workers must actually
    // run ahead beyond the old default bound — the mode is observably
    // different from the bounded runs.
    assert!(
        a.stats.max_staleness() > matcha::gossip::DEFAULT_MAX_STALENESS,
        "straggler should induce staleness beyond the default bound, got {}",
        a.stats.max_staleness()
    );
    assert!(a.run.final_mean.iter().all(|v| v.is_finite()));
}

#[test]
fn unbounded_staleness_spec_runs_end_to_end() {
    // `"max_staleness": null` through the whole spec pipeline.
    let text = r#"{
        "graph": "ring:8",
        "strategy": {"kind": "matcha", "budget": 0.5},
        "problem": {"kind": "quad", "dim": 8, "hetero": 1.0, "noise_std": 0.2},
        "policy": "straggler:0:5.0",
        "backend": {"kind": "async", "threads": 2, "max_staleness": null},
        "run": {"lr": 0.03, "iterations": 60, "record_every": 20, "seed": 3}
    }"#;
    let spec = ExperimentSpec::parse(text).unwrap();
    assert_eq!(
        spec.backend,
        Backend::Async { threads: 2, max_staleness: matcha::gossip::UNBOUNDED_STALENESS }
    );
    let a = experiment::run(&spec).unwrap();
    let b = experiment::run(&spec).unwrap();
    assert_eq!(a.final_mean, b.final_mean, "unbounded spec runs must be deterministic");
    assert!(a.final_loss().is_finite());
}

#[test]
fn bounded_staleness_converges_on_the_quadratic() {
    // The convergence half of the ROADMAP item: under a straggler and a
    // positive staleness bound, loss still decreases to tolerance.
    let spec = ExperimentSpec::new("er:16:4:3")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::Quadratic { dim: 12, hetero: 1.0, noise_std: 0.1, seed: Some(2) })
        .policy("straggler:0:5.0")
        .backend(Backend::Async { threads: 2, max_staleness: 4 })
        .lr(0.03)
        .iterations(800)
        .record_every(100)
        .seed(11)
        .validated()
        .unwrap();
    let res = experiment::run(&spec).unwrap();
    let sub = res.metrics.get("subopt_vs_iter");
    let sub0 = sub[0].y;
    let subf = res.metrics.last("subopt_vs_iter").unwrap();
    assert!(
        subf < 0.05 * sub0,
        "bounded-staleness async did not converge: {sub0} -> {subf}"
    );
    let stats = res.async_stats.expect("async stats");
    assert!(stats.max_staleness() <= 4);
    assert!(stats.mean_staleness() > 0.0, "straggler should induce staleness");
}

#[test]
fn async_beats_barrier_virtual_time_under_straggler() {
    // The wall-clock claim's deterministic core: the straggler gates
    // every barrier iteration (compute + full comm serialized); async
    // overlaps the straggler's compute with communication.
    let g = graph::ring(16);
    let d = decompose(&g);
    let problem = {
        let mut r = Rng::new(5);
        QuadraticProblem::generate(16, 8, 1.0, 0.1, &mut r)
    };
    let cfg = RunConfig { lr: 0.02, iterations: 200, alpha: 0.2, seed: 3, ..RunConfig::default() };

    let mut s1 = VanillaSampler::new(d.len());
    let mut p1 = StragglerPolicy::new(AnalyticPolicy::matching_run_config(&cfg), vec![0], 8.0);
    let barrier = run_engine(
        &problem,
        &d.matchings,
        &mut s1,
        &mut p1,
        &EngineConfig { run: cfg.clone(), threads: 1 },
    );

    let mut s2 = VanillaSampler::new(d.len());
    let mut p2 = StragglerPolicy::new(AnalyticPolicy::matching_run_config(&cfg), vec![0], 8.0);
    let async_cfg = AsyncConfig { run: cfg, threads: 2, max_staleness: 8 };
    let res = run_async(&problem, &d.matchings, &mut s2, &mut p2, &async_cfg);

    assert!(
        res.run.total_time < barrier.run.total_time,
        "async should finish sooner: {} vs {}",
        res.run.total_time,
        barrier.run.total_time
    );
    // The non-straggling workers log idle time waiting at the bound.
    let stats = &res.stats;
    assert!(stats.total_idle() > 0.0);
    assert!(stats.per_worker.iter().any(|w| w.exchanges > 0));
}

#[test]
fn async_spec_runs_end_to_end_from_committed_example() {
    // The committed example spec must execute (not just dry-run plan).
    let path = std::path::Path::new("examples/specs/async_straggler.json");
    let mut spec = ExperimentSpec::load(path).expect("committed async spec loads");
    assert_eq!(spec.backend.name(), "async");
    spec.iterations = 60; // keep the test quick; the full run is the bench's job
    spec.record_every = Some(20);
    let res = experiment::run(&spec).unwrap();
    assert!(res.final_loss().is_finite());
    assert!(res.async_stats.is_some());
}
