//! Engine ⇄ simulator equivalence and scenario regression tests.
//!
//! The contract under test (ISSUE 1): the engine's deterministic mode
//! reproduces `sim::run_decentralized` **bit-for-bit** — identical final
//! iterates and identical total virtual time — for arbitrary graphs,
//! seeds, budgets and delay models; and the parallel actor mode is
//! indistinguishable from the sequential engine.

use matcha::budget::optimize_activation_probabilities;
use matcha::delay::DelayModel;
use matcha::engine::{
    run_engine, run_engine_analytic, AnalyticPolicy, EngineConfig, FlakyLinkPolicy,
    StragglerPolicy,
};
use matcha::graph;
use matcha::matching::decompose;
use matcha::mixing::optimize_alpha;
use matcha::proptest::{check, PropConfig};
use matcha::rng::Rng;
use matcha::sim::{run_decentralized, Compression, QuadraticProblem, RunConfig};
use matcha::topology::{MatchaSampler, VanillaSampler};

#[test]
fn property_engine_matches_sim_on_random_graphs() {
    // Random connected ER graphs × random budgets × random seeds × all
    // three delay models: engine (sequential deterministic mode) and the
    // reference simulator must agree exactly.
    check(
        PropConfig { cases: 25, seed: 0xe61e },
        |rng| {
            let m = 4 + rng.below(8);
            let g = graph::erdos_renyi_connected(m, 0.5, rng);
            let cb = rng.uniform_in(0.2, 1.0);
            let seed = rng.next_u64();
            let delay = match rng.below(3) {
                0 => DelayModel::UnitPerMatching,
                1 => DelayModel::MaxDegree,
                _ => DelayModel::StochasticLink { min_units: 0.5, max_units: 2.0 },
            };
            (g, cb, seed, delay)
        },
        |(g, cb, seed, delay)| {
            let d = decompose(g);
            let probs = optimize_activation_probabilities(&d, *cb);
            let mix = optimize_alpha(&d, &probs.probabilities);
            let problem = {
                let mut r = Rng::new(seed ^ 0x5eed);
                QuadraticProblem::generate(g.num_nodes(), 6, 1.0, 0.2, &mut r)
            };
            let cfg = RunConfig {
                lr: 0.02,
                iterations: 60,
                record_every: 20,
                alpha: mix.alpha,
                delay: delay.clone(),
                seed: *seed,
                ..RunConfig::default()
            };

            let mut s1 = MatchaSampler::new(probs.probabilities.clone(), seed ^ 1);
            let reference = run_decentralized(&problem, &d.matchings, &mut s1, &cfg);

            let mut s2 = MatchaSampler::new(probs.probabilities.clone(), seed ^ 1);
            let engine = run_engine_analytic(
                &problem,
                &d.matchings,
                &mut s2,
                &EngineConfig { run: cfg, threads: 1 },
            );

            if engine.run.final_mean != reference.final_mean {
                return Err(format!(
                    "final iterates diverged: {:?} vs {:?}",
                    engine.run.final_mean, reference.final_mean
                ));
            }
            if engine.run.total_time != reference.total_time {
                return Err(format!(
                    "total virtual time diverged: {} vs {} ({delay:?})",
                    engine.run.total_time, reference.total_time
                ));
            }
            if engine.run.total_comm_units != reference.total_comm_units {
                return Err(format!(
                    "comm units diverged: {} vs {}",
                    engine.run.total_comm_units, reference.total_comm_units
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn property_parallel_actors_match_sequential_engine() {
    // The actor pool must be indistinguishable from the in-process
    // executor — including with message compression enabled (per-edge
    // derived RNG streams).
    check(
        PropConfig { cases: 8, seed: 0xac70 },
        |rng| {
            let m = 4 + rng.below(6);
            let g = graph::erdos_renyi_connected(m, 0.55, rng);
            let seed = rng.next_u64();
            let compress = rng.below(2) == 1;
            (g, seed, compress)
        },
        |(g, seed, compress)| {
            let d = decompose(g);
            let probs = optimize_activation_probabilities(&d, 0.5);
            let mix = optimize_alpha(&d, &probs.probabilities);
            let problem = {
                let mut r = Rng::new(seed ^ 0xbead);
                QuadraticProblem::generate(g.num_nodes(), 5, 1.0, 0.1, &mut r)
            };
            let cfg = RunConfig {
                lr: 0.03,
                iterations: 40,
                record_every: 10,
                alpha: mix.alpha,
                compression: if *compress {
                    Some(Compression::Quantize { bits: 6 })
                } else {
                    None
                },
                seed: *seed,
                ..RunConfig::default()
            };
            let mut s1 = MatchaSampler::new(probs.probabilities.clone(), 2);
            let seq = run_engine_analytic(
                &problem,
                &d.matchings,
                &mut s1,
                &EngineConfig { run: cfg.clone(), threads: 1 },
            );
            let mut s2 = MatchaSampler::new(probs.probabilities.clone(), 2);
            let par = run_engine_analytic(
                &problem,
                &d.matchings,
                &mut s2,
                &EngineConfig { run: cfg, threads: 8 },
            );
            if par.run.final_mean != seq.run.final_mean {
                return Err(format!(
                    "actor iterates diverged (compress={compress}): {:?} vs {:?}",
                    par.run.final_mean, seq.run.final_mean
                ));
            }
            if par.run.total_time != seq.run.total_time {
                return Err("actor virtual time diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn parallel_mode_matches_plain_simulator_end_to_end() {
    // The full chain: run_decentralized == engine actors, compression on.
    let g = graph::paper_figure1_graph();
    let d = decompose(&g);
    let probs = optimize_activation_probabilities(&d, 0.5);
    let mix = optimize_alpha(&d, &probs.probabilities);
    let problem = {
        let mut r = Rng::new(8);
        QuadraticProblem::generate(8, 12, 1.0, 0.2, &mut r)
    };
    let cfg = RunConfig {
        lr: 0.02,
        iterations: 150,
        alpha: mix.alpha,
        compression: Some(Compression::TopK { frac: 0.5 }),
        seed: 77,
        ..RunConfig::default()
    };
    let mut s1 = MatchaSampler::new(probs.probabilities.clone(), 5);
    let reference = run_decentralized(&problem, &d.matchings, &mut s1, &cfg);
    let mut s2 = MatchaSampler::new(probs.probabilities.clone(), 5);
    let engine = run_engine_analytic(
        &problem,
        &d.matchings,
        &mut s2,
        &EngineConfig { run: cfg, threads: 8 },
    );
    assert_eq!(engine.run.final_mean, reference.final_mean);
    assert_eq!(engine.run.total_time, reference.total_time);
    assert_eq!(engine.run.total_comm_units, reference.total_comm_units);
}

#[test]
fn straggler_scenario_regression() {
    // Regression for the ISSUE's straggler scenario: a 6×-slow worker 0
    // stretches virtual time by exactly the compute gap, leaves the
    // trajectory untouched, and MATCHA's budgeted schedule still beats
    // vanilla on total time under the same straggler.
    let g = graph::paper_figure1_graph();
    let d = decompose(&g);
    let probs = optimize_activation_probabilities(&d, 0.4);
    let mix = optimize_alpha(&d, &probs.probabilities);
    let problem = {
        let mut r = Rng::new(21);
        QuadraticProblem::generate(8, 10, 1.0, 0.1, &mut r)
    };
    let iters = 200usize;
    let factor = 6.0;
    let mk_cfg = |alpha: f64| RunConfig {
        lr: 0.02,
        iterations: iters,
        alpha,
        seed: 9,
        ..RunConfig::default()
    };

    // Vanilla under the straggler.
    let van_cfg = mk_cfg(matcha::mixing::vanilla_design(&g.laplacian()).alpha);
    let mut vs = VanillaSampler::new(d.len());
    let mut van_policy = StragglerPolicy::new(
        AnalyticPolicy::matching_run_config(&van_cfg),
        vec![0],
        factor,
    );
    let van = run_engine(
        &problem,
        &d.matchings,
        &mut vs,
        &mut van_policy,
        &EngineConfig { run: van_cfg.clone(), threads: 1 },
    );
    // Closed form: every iteration pays factor·compute + M comm units.
    assert_eq!(
        van.run.total_time,
        iters as f64 * (factor + d.len() as f64),
        "straggler must gate every vanilla iteration"
    );

    // MATCHA under the same straggler.
    let m_cfg = mk_cfg(mix.alpha);
    let mut ms = MatchaSampler::new(probs.probabilities.clone(), 3);
    let mut m_policy = StragglerPolicy::new(
        AnalyticPolicy::matching_run_config(&m_cfg),
        vec![0],
        factor,
    );
    let matcha_run = run_engine(
        &problem,
        &d.matchings,
        &mut ms,
        &mut m_policy,
        &EngineConfig { run: m_cfg.clone(), threads: 1 },
    );
    assert!(
        matcha_run.run.total_time < van.run.total_time,
        "MATCHA must still win on wallclock under stragglers: {} vs {}",
        matcha_run.run.total_time,
        van.run.total_time
    );

    // The straggler changes time only, not the trajectory: rerun MATCHA
    // without the straggler and compare iterates.
    let mut ms2 = MatchaSampler::new(probs.probabilities.clone(), 3);
    let clean = run_engine_analytic(
        &problem,
        &d.matchings,
        &mut ms2,
        &EngineConfig { run: m_cfg, threads: 1 },
    );
    assert_eq!(clean.run.final_mean, matcha_run.run.final_mean);
    assert!(clean.run.total_time < matcha_run.run.total_time);
}

#[test]
fn flaky_links_still_converge_and_report_drops() {
    let g = graph::ring(8);
    let d = decompose(&g);
    let probs = optimize_activation_probabilities(&d, 0.8);
    let mix = optimize_alpha(&d, &probs.probabilities);
    let problem = {
        let mut r = Rng::new(31);
        QuadraticProblem::generate(8, 8, 1.0, 0.1, &mut r)
    };
    let cfg = RunConfig {
        lr: 0.03,
        iterations: 500,
        alpha: mix.alpha,
        seed: 13,
        ..RunConfig::default()
    };
    let mut sampler = MatchaSampler::new(probs.probabilities.clone(), 7);
    let mut policy = FlakyLinkPolicy::new(AnalyticPolicy::matching_run_config(&cfg), 0.25, 19);
    let res = run_engine(
        &problem,
        &d.matchings,
        &mut sampler,
        &mut policy,
        &EngineConfig { run: cfg, threads: 1 },
    );
    assert!(res.dropped_links > 0);
    let sub0 = res.run.metrics.get("subopt_vs_iter")[0].y;
    let subf = res.run.metrics.last("subopt_vs_iter").unwrap();
    assert!(
        subf < 0.25 * sub0,
        "flaky-link run failed to converge: {sub0} -> {subf} \
         ({} links dropped)",
        res.dropped_links
    );
}
