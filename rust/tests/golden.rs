//! Golden-trajectory fixtures: every backend × strategy pair is pinned
//! against a committed JSON fixture, bit-for-bit.
//!
//! The in-process parity tests (`rust/tests/engine.rs`,
//! `rust/tests/gossip.rs`, `rust/tests/experiment.rs`) prove the
//! backends agree with *each other*; these fixtures additionally pin the
//! trajectories across **time**, so a future refactor that changed the
//! arithmetic identically in every backend would still be caught.
//!
//! Every `f64` is stored as the hex of its IEEE-754 bit pattern, so the
//! comparison survives the JSON round-trip exactly.
//!
//! Fixtures live in `rust/tests/fixtures/golden_<strategy>.json`. A
//! missing fixture is (re)generated from the reference simulator on the
//! first run — commit the generated files. Set `MATCHA_UPDATE_FIXTURES=1`
//! to regenerate after an *intentional* trajectory change.

use matcha::cluster::TransportKind;
use matcha::experiment::{self, Backend, ExperimentSpec, ExperimentResult, ProblemSpec, Strategy};
use matcha::json::Json;
use std::path::PathBuf;

/// Iteration-indexed series every backend must reproduce exactly
/// (excludes the time-indexed and comm series: the async runtime's
/// per-link clock and aggregate-bandwidth accounting are intentionally
/// different quantities).
const CORE_SERIES: &[&str] =
    &["loss_vs_iter", "consensus_vs_iter", "gradnorm2_vs_iter", "subopt_vs_iter"];

/// The backend-independent part of a trajectory, as raw f64 bit patterns.
#[derive(Clone, Debug, PartialEq)]
struct Core {
    series: Vec<Vec<(u64, u64)>>,
    final_mean: Vec<u64>,
}

/// The full barrier-backend trajectory: core + the shared time/comm
/// accounting.
#[derive(Clone, Debug, PartialEq)]
struct Full {
    core: Core,
    comm_series: Vec<(u64, u64)>,
    total_time: u64,
    total_comm: u64,
}

fn capture_core(res: &ExperimentResult) -> Core {
    Core {
        series: CORE_SERIES
            .iter()
            .map(|name| {
                res.metrics.get(name).iter().map(|s| (s.x.to_bits(), s.y.to_bits())).collect()
            })
            .collect(),
        final_mean: res.final_mean.iter().map(|v| v.to_bits()).collect(),
    }
}

fn capture(res: &ExperimentResult) -> Full {
    Full {
        core: capture_core(res),
        comm_series: res
            .metrics
            .get("comm_units_vs_iter")
            .iter()
            .map(|s| (s.x.to_bits(), s.y.to_bits()))
            .collect(),
        total_time: res.total_time.to_bits(),
        total_comm: res.total_comm_units.to_bits(),
    }
}

// ---------------------------------------------------------------------
// Fixture encode / decode (hex bit patterns through the Json module)
// ---------------------------------------------------------------------

fn hex(bits: u64) -> Json {
    Json::Str(format!("{bits:016x}"))
}

fn unhex(j: &Json) -> u64 {
    u64::from_str_radix(j.as_str().expect("fixture: hex string"), 16).expect("fixture: hex u64")
}

fn series_json(series: &[(u64, u64)]) -> Json {
    Json::Arr(series.iter().map(|&(x, y)| Json::Arr(vec![hex(x), hex(y)])).collect())
}

fn series_from(j: &Json) -> Vec<(u64, u64)> {
    j.as_array()
        .expect("fixture: series array")
        .iter()
        .map(|p| {
            let pair = p.as_array().expect("fixture: [x, y] pair");
            (unhex(&pair[0]), unhex(&pair[1]))
        })
        .collect()
}

fn fixture_json(spec: &ExperimentSpec, full: &Full) -> Json {
    let series = CORE_SERIES
        .iter()
        .zip(&full.core.series)
        .map(|(name, s)| (*name, series_json(s)))
        .collect();
    Json::obj(vec![
        // Provenance only — the comparison uses the bit patterns below.
        ("spec", Json::Str(spec.to_json_string())),
        ("series", Json::obj(series)),
        ("comm_units_vs_iter", series_json(&full.comm_series)),
        (
            "final_mean",
            Json::Arr(full.core.final_mean.iter().map(|&b| hex(b)).collect()),
        ),
        ("total_time", hex(full.total_time)),
        ("total_comm_units", hex(full.total_comm)),
    ])
}

fn fixture_from(j: &Json) -> Full {
    let series_obj = j.get("series").expect("fixture: series");
    Full {
        core: Core {
            series: CORE_SERIES
                .iter()
                .map(|name| series_from(series_obj.get(name).expect("fixture: named series")))
                .collect(),
            final_mean: j
                .get("final_mean")
                .and_then(Json::as_array)
                .expect("fixture: final_mean")
                .iter()
                .map(unhex)
                .collect(),
        },
        comm_series: series_from(j.get("comm_units_vs_iter").expect("fixture: comm series")),
        total_time: unhex(j.get("total_time").expect("fixture: total_time")),
        total_comm: unhex(j.get("total_comm_units").expect("fixture: total_comm_units")),
    }
}

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(format!("golden_{name}.json"))
}

// ---------------------------------------------------------------------
// The pinned scenario
// ---------------------------------------------------------------------

/// One fixed scenario per strategy: the paper's Figure-1 graph, the
/// default quadratic workload, fixed run/sampler seeds. Small enough to
/// run 4 backends × 4 strategies in a blink, long enough to catch
/// order-of-accumulation drift.
fn base_spec(strategy: Strategy) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(strategy)
        .problem(ProblemSpec::quadratic())
        .lr(0.03)
        .iterations(80)
        .record_every(20)
        .seed(11)
        .sampler_seed(5)
}

fn check_strategy(name: &str, strategy: Strategy) {
    let spec = base_spec(strategy);
    let reference = experiment::run(&spec).expect("sim reference run");
    let observed = capture(&reference);

    let path = fixture_path(name);
    if std::env::var_os("MATCHA_UPDATE_FIXTURES").is_some() || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("fixtures dir");
        std::fs::write(&path, fixture_json(&spec, &observed).to_string())
            .expect("write golden fixture");
        eprintln!("golden: wrote {}", path.display());
    }
    let text = std::fs::read_to_string(&path).expect("read golden fixture");
    let fixture = fixture_from(&Json::parse(&text).expect("parse golden fixture"));

    assert_eq!(
        observed, fixture,
        "{name}: sim reference drifted from the committed golden fixture"
    );

    // Barrier backends: full parity, including time/comm accounting.
    // The loopback cluster backend serializes every phase command
    // through the wire format and must land on the same bits.
    for backend in [
        Backend::EngineSequential,
        Backend::EngineActors { threads: 3 },
        Backend::Cluster { shards: 3, transport: TransportKind::Loopback },
    ] {
        let res = experiment::run(&spec.clone().backend(backend)).expect("backend run");
        assert_eq!(
            capture(&res),
            fixture,
            "{name}: backend {:?} drifted from the golden fixture",
            backend
        );
    }

    // Async runtime at staleness 0 degrades to the synchronous kernel:
    // identical iterates, per-link time accounting (compared via core).
    let async_backend = Backend::Async { threads: 2, max_staleness: 0 };
    let res = experiment::run(&spec.clone().backend(async_backend)).expect("async run");
    assert_eq!(
        capture_core(&res),
        fixture.core,
        "{name}: async (staleness 0) drifted from the golden fixture"
    );
}

#[test]
fn golden_matcha() {
    check_strategy("matcha", Strategy::Matcha { budget: 0.5 });
}

#[test]
fn golden_vanilla() {
    check_strategy("vanilla", Strategy::Vanilla);
}

#[test]
fn golden_periodic() {
    check_strategy("periodic", Strategy::Periodic { budget: 0.5 });
}

#[test]
fn golden_single() {
    check_strategy("single", Strategy::SingleMatching { budget: 0.5 });
}
