//! Acceptance tests for the unified `experiment` API (ISSUE 2):
//!
//! - golden-file JSON round-trips (parse → serialize → parse) plus a
//!   rejection message for each invalid field;
//! - **bit-for-bit parity**: spec-driven runs reproduce the legacy
//!   `sim::run_decentralized` and `engine::run_engine_analytic`
//!   entry points exactly, per seed;
//! - the full scenario matrix: all four strategies × both problems × all
//!   three backends through one `ExperimentSpec`;
//! - streaming: the `Observer` sees every iteration/record, and the sweep
//!   driver streams every grid point.

use matcha::engine::{run_engine_analytic, EngineConfig};
use matcha::experiment::{
    self, Backend, ExperimentResult, ExperimentSpec, Observer, Plan, ProblemSpec, Strategy,
};
use matcha::graph::parse_graph_spec;
use matcha::rng::Rng;
use matcha::sim::{run_decentralized, LogisticProblem, LogisticSpec, QuadraticProblem};

// ---------------------------------------------------------------------------
// JSON round-trips
// ---------------------------------------------------------------------------

/// A "golden" spec file exercising every field, written the way a user
/// would write it by hand (pretty-printed, shorthand forms mixed in).
const GOLDEN_FULL: &str = r#"
{
  "graph": "er:16:8:303",
  "strategy": {"kind": "matcha", "budget": 0.4},
  "problem": {"kind": "logreg", "non_iid": 0.8, "separation": 2.0, "seed": 5},
  "delay": "stochastic:0.5:2.0",
  "policy": "straggler:3:2.5",
  "backend": {"kind": "actors", "threads": 4},
  "run": {
    "lr": 0.1,
    "lr_decay": 0.5,
    "lr_decay_every": 200,
    "iterations": 500,
    "record_every": 25,
    "compute_units": 0.2,
    "latency_floor": 0.05,
    "seed": 7,
    "sampler_seed": 21,
    "compression": {"kind": "quantize", "bits": 8}
  }
}
"#;

const GOLDEN_MINIMAL: &str = r#"{"graph": "fig1"}"#;

const GOLDEN_ASYNC: &str = r#"
{
  "graph": "ring:12",
  "strategy": {"kind": "matcha", "budget": 0.5},
  "problem": "quad",
  "policy": "flaky:0.1",
  "backend": {"kind": "async", "threads": 3, "max_staleness": 6},
  "run": {"iterations": 80, "record_every": 20}
}
"#;

const GOLDEN_EXPLICIT_GRAPH: &str = r#"
{
  "graph": {"nodes": 5, "edges": [[0,1],[1,2],[2,3],[3,4],[4,0]]},
  "strategy": "vanilla",
  "problem": "quad",
  "backend": "engine",
  "run": {"iterations": 40}
}
"#;

#[test]
fn golden_specs_roundtrip_exactly() {
    for (name, text) in [
        ("full", GOLDEN_FULL),
        ("minimal", GOLDEN_MINIMAL),
        ("explicit-graph", GOLDEN_EXPLICIT_GRAPH),
        ("async", GOLDEN_ASYNC),
    ] {
        let first = ExperimentSpec::parse(text).unwrap_or_else(|e| panic!("{name}: {e}"));
        let emitted = first.to_json_string();
        let second = ExperimentSpec::parse(&emitted)
            .unwrap_or_else(|e| panic!("{name} re-parse: {e}\n{emitted}"));
        assert_eq!(second, first, "{name}: parse → serialize → parse must be identity");
        // And serialization is a fixpoint.
        assert_eq!(second.to_json_string(), emitted, "{name}");
    }
}

#[test]
fn golden_full_spec_fields_land_where_expected() {
    let spec = ExperimentSpec::parse(GOLDEN_FULL).unwrap();
    assert_eq!(spec.strategy, Strategy::Matcha { budget: 0.4 });
    assert_eq!(
        spec.problem,
        ProblemSpec::Logistic { non_iid: 0.8, separation: 2.0, seed: Some(5) }
    );
    assert_eq!(spec.delay, "stochastic:0.5:2.0");
    assert_eq!(spec.policy, "straggler:3:2.5");
    assert_eq!(spec.backend, Backend::EngineActors { threads: 4 });
    assert_eq!(spec.lr, 0.1);
    assert_eq!(spec.lr_decay, 0.5);
    assert_eq!(spec.lr_decay_every, 200);
    assert_eq!(spec.iterations, 500);
    assert_eq!(spec.record_every, Some(25));
    assert_eq!(spec.compute_units, 0.2);
    assert_eq!(spec.seed, 7);
    assert_eq!(spec.sampler_seed, Some(21));
    assert!(spec.compression.is_some());
}

#[test]
fn rejection_messages_name_the_offending_field() {
    let cases: &[(&str, &str)] = &[
        // Structural.
        (r#"[1, 2]"#, "top level"),
        (r#"{"strategy": "matcha"}"#, "graph"),
        (r#"{"graph": "fig1", "wormhole": 1}"#, "unknown key 'wormhole'"),
        (r#"{"graph": "fig1", "strategy": {"kind": "warp"}}"#, "strategy"),
        (r#"{"graph": "fig1", "strategy": {"kind": "matcha", "x": 1}}"#, "unknown key 'x'"),
        (r#"{"graph": "fig1", "problem": {"kind": "tsp"}}"#, "problem"),
        (r#"{"graph": "fig1", "backend": {"kind": "gpu"}}"#, "backend"),
        (r#"{"graph": "fig1", "backend": "actors"}"#, "threads"),
        (r#"{"graph": "fig1", "run": {"lr": "fast"}}"#, "'lr' must be a number"),
        (r#"{"graph": "fig1", "run": {"iterations": 2.5}}"#, "'iterations'"),
        (
            r#"{"graph": "fig1", "run": {"compression": {"kind": "zip"}}}"#,
            "compression",
        ),
        // Graph semantics.
        (r#"{"graph": "warp:9"}"#, "graph"),
        (r#"{"graph": {"nodes": 4, "edges": [[0,1],[2,3]]}}"#, "connected"),
        (r#"{"graph": {"nodes": 3, "edges": [[0,3]]}}"#, "out of range"),
        (r#"{"graph": {"nodes": 3, "edges": [[1,1]]}}"#, "self-loop"),
        // Field semantics (validate()).
        (r#"{"graph": "fig1", "strategy": {"kind": "matcha", "budget": 0}}"#, "strategy"),
        (r#"{"graph": "fig1", "strategy": {"kind": "periodic", "budget": 1.5}}"#, "strategy"),
        (r#"{"graph": "fig1", "run": {"lr": 0}}"#, "run: lr"),
        (r#"{"graph": "fig1", "run": {"iterations": 0}}"#, "run: iterations"),
        (r#"{"graph": "fig1", "run": {"record_every": 0}}"#, "run: record_every"),
        (r#"{"graph": "fig1", "delay": "stochastic:2:1"}"#, "delay"),
        (r#"{"graph": "fig1", "policy": "flaky:7"}"#, "policy"),
        (r#"{"graph": "fig1", "policy": "straggler:99:2.0"}"#, "policy"),
        (
            // Link-failure injection needs a link-granular delay model.
            r#"{"graph": "fig1", "backend": "engine", "delay": "maxdeg", "policy": "flaky:0.1"}"#,
            "policy",
        ),
        (
            // Engine-only policies cannot run on the reference simulator.
            r#"{"graph": "fig1", "backend": "sim", "policy": "hetero:3"}"#,
            "policy",
        ),
        (
            r#"{"graph": "fig1", "problem": {"kind": "logreg", "non_iid": 2.0}}"#,
            "problem",
        ),
        (
            r#"{"graph": "fig1", "backend": {"kind": "actors", "threads": 0}}"#,
            "backend",
        ),
    ];
    for (text, needle) in cases {
        let err = ExperimentSpec::parse(text)
            .err()
            .unwrap_or_else(|| panic!("spec should be rejected: {text}"));
        assert!(
            err.contains(needle),
            "error for {text} should mention '{needle}', got: {err}"
        );
    }
}

#[test]
fn spec_files_roundtrip_through_disk() {
    let dir = std::env::temp_dir().join("matcha_experiment_specs");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.json");
    let spec = ExperimentSpec::parse(GOLDEN_FULL).unwrap();
    spec.save(&path).unwrap();
    let loaded = ExperimentSpec::load(&path).unwrap();
    assert_eq!(loaded, spec);
}

// ---------------------------------------------------------------------------
// Bit-for-bit parity with the legacy entry points
// ---------------------------------------------------------------------------

fn parity_spec(seed: u64, backend: Backend) -> ExperimentSpec {
    ExperimentSpec::new("grid:3x4")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::quadratic())
        .backend(backend)
        .lr(0.02)
        .iterations(150)
        .record_every(50)
        .seed(seed)
}

/// Rebuild exactly what the spec-driven path should produce, using only
/// legacy APIs: hand-wired plan + problem + sampler + `RunConfig`.
fn legacy_pieces(
    spec: &ExperimentSpec,
) -> (Plan, QuadraticProblem, matcha::sim::RunConfig) {
    let g = parse_graph_spec("grid:3x4").unwrap();
    let plan = Plan::for_graph(g, spec.strategy).unwrap();
    let mut rng = Rng::new(spec.seed ^ 0x9a9a);
    let problem = QuadraticProblem::generate(plan.graph.num_nodes(), 20, 1.0, 0.2, &mut rng);
    let cfg = plan.run_config(spec).unwrap();
    (plan, problem, cfg)
}

#[test]
fn spec_driven_sim_matches_run_decentralized_bit_for_bit() {
    for seed in [0u64, 7, 0xfeed] {
        let spec = parity_spec(seed, Backend::SimReference);
        let res = experiment::run(&spec).unwrap();

        let (plan, problem, cfg) = legacy_pieces(&spec);
        let mut sampler = plan.sampler(seed);
        let legacy = run_decentralized(&problem, &plan.decomposition.matchings, &mut sampler, &cfg);

        assert_eq!(res.final_mean, legacy.final_mean, "seed {seed}");
        assert_eq!(res.total_time, legacy.total_time, "seed {seed}");
        assert_eq!(res.total_comm_units, legacy.total_comm_units, "seed {seed}");
        let spec_loss = res.metrics.get("loss_vs_iter");
        let legacy_loss = legacy.metrics.get("loss_vs_iter");
        assert_eq!(spec_loss, legacy_loss, "seed {seed}: full loss series must match");
    }
}

#[test]
fn spec_driven_engine_matches_run_engine_analytic_bit_for_bit() {
    for seed in [3u64, 11] {
        let spec = parity_spec(seed, Backend::EngineSequential);
        let res = experiment::run(&spec).unwrap();

        let (plan, problem, cfg) = legacy_pieces(&spec);
        let mut sampler = plan.sampler(seed);
        let legacy = run_engine_analytic(
            &problem,
            &plan.decomposition.matchings,
            &mut sampler,
            &EngineConfig { run: cfg, threads: 1 },
        );

        assert_eq!(res.final_mean, legacy.run.final_mean, "seed {seed}");
        assert_eq!(res.total_time, legacy.run.total_time, "seed {seed}");
        assert_eq!(res.total_comm_units, legacy.run.total_comm_units, "seed {seed}");
        assert_eq!(res.events, legacy.events, "seed {seed}");
    }
}

#[test]
fn logreg_spec_matches_legacy_problem_generation() {
    // The logistic seed derivation (run.seed ^ 0x10f) must match the
    // historical CLI wiring.
    let spec = ExperimentSpec::new("ring:6")
        .problem(ProblemSpec::Logistic { non_iid: 0.3, separation: 1.5, seed: None })
        .lr(0.1)
        .iterations(80)
        .record_every(40)
        .seed(42);
    let res = experiment::run(&spec).unwrap();

    let g = parse_graph_spec("ring:6").unwrap();
    let plan = Plan::for_graph(g, spec.strategy).unwrap();
    let problem = LogisticProblem::generate(LogisticSpec {
        num_workers: 6,
        non_iid: 0.3,
        seed: 42 ^ 0x10f,
        ..LogisticSpec::default()
    });
    let cfg = plan.run_config(&spec).unwrap();
    let mut sampler = plan.sampler(42);
    let legacy = run_decentralized(&problem, &plan.decomposition.matchings, &mut sampler, &cfg);
    assert_eq!(res.final_mean, legacy.final_mean);
    assert_eq!(res.total_time, legacy.total_time);
}

// ---------------------------------------------------------------------------
// The full scenario matrix
// ---------------------------------------------------------------------------

#[test]
fn every_strategy_problem_backend_combination_runs() {
    let strategies = [
        Strategy::Matcha { budget: 0.5 },
        Strategy::Vanilla,
        Strategy::Periodic { budget: 0.5 },
        Strategy::SingleMatching { budget: 0.5 },
    ];
    let problems = [ProblemSpec::quadratic(), ProblemSpec::logistic()];
    let backends = [
        Backend::SimReference,
        Backend::EngineSequential,
        Backend::EngineActors { threads: 8 },
        Backend::Async { threads: 2, max_staleness: 2 },
    ];
    for strategy in strategies {
        for problem in &problems {
            for backend in backends {
                let spec = ExperimentSpec::new("fig1")
                    .strategy(strategy)
                    .problem(problem.clone())
                    .backend(backend)
                    .lr(0.03)
                    .iterations(30)
                    .record_every(10)
                    .seed(1);
                let res = experiment::run(&spec).unwrap_or_else(|e| {
                    panic!("{} × {} × {}: {e}", strategy.name(), problem.name(), backend.name())
                });
                assert!(
                    res.final_loss().is_finite(),
                    "{} × {} × {}",
                    strategy.name(),
                    problem.name(),
                    backend.name()
                );
                assert!(res.total_time > 0.0);
                assert!(res.rho < 1.0);
            }
        }
    }
}

#[test]
fn backends_agree_bit_for_bit_per_strategy() {
    // Sim reference, sequential engine and the actor pool must produce
    // identical trajectories for every strategy under the analytic policy.
    for strategy in [
        Strategy::Matcha { budget: 0.4 },
        Strategy::Vanilla,
        Strategy::Periodic { budget: 0.4 },
        Strategy::SingleMatching { budget: 0.4 },
    ] {
        let spec = |backend: Backend| {
            ExperimentSpec::new("fig1")
                .strategy(strategy)
                .problem(ProblemSpec::quadratic())
                .backend(backend)
                .lr(0.02)
                .iterations(80)
                .record_every(20)
                .seed(5)
        };
        let sim = experiment::run(&spec(Backend::SimReference)).unwrap();
        let eng = experiment::run(&spec(Backend::EngineSequential)).unwrap();
        let act = experiment::run(&spec(Backend::EngineActors { threads: 8 })).unwrap();
        let asy =
            experiment::run(&spec(Backend::Async { threads: 2, max_staleness: 0 })).unwrap();
        assert_eq!(sim.final_mean, eng.final_mean, "{}", strategy.name());
        assert_eq!(sim.total_time, eng.total_time, "{}", strategy.name());
        assert_eq!(eng.final_mean, act.final_mean, "{}", strategy.name());
        assert_eq!(eng.total_time, act.total_time, "{}", strategy.name());
        // Staleness-0 async joins the trajectory agreement (its clock is
        // barrier-free, so only the iterates are compared).
        assert_eq!(sim.final_mean, asy.final_mean, "{}", strategy.name());
    }
}

#[test]
fn engine_policies_run_through_specs() {
    for policy in ["analytic", "hetero:17", "straggler:0:4.0", "flaky:0.2"] {
        let spec = ExperimentSpec::new("ring:8")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::EngineSequential)
            .policy(policy)
            .lr(0.02)
            .iterations(60)
            .record_every(20)
            .seed(2);
        let res = experiment::run(&spec).unwrap_or_else(|e| panic!("{policy}: {e}"));
        assert!(res.final_loss().is_finite(), "{policy}");
        if policy.starts_with("flaky") {
            assert!(res.dropped_links > 0, "failure injection must trigger");
        }
    }
}

// ---------------------------------------------------------------------------
// Streaming
// ---------------------------------------------------------------------------

#[test]
fn observer_streams_iterations_records_and_sweep_points() {
    #[derive(Default)]
    struct Tally {
        iterations: usize,
        records: usize,
        points: Vec<usize>,
    }
    impl Observer for Tally {
        fn on_iteration(&mut self, _k: usize, _t: f64, _c: f64) {
            self.iterations += 1;
        }
        fn on_record(&mut self, _k: usize, _t: f64, _m: &matcha::metrics::Recorder) {
            self.records += 1;
        }
        fn on_point(&mut self, index: usize, _r: &ExperimentResult) {
            self.points.push(index);
        }
    }

    // Per-run streaming, on both execution paths.
    for backend in [Backend::SimReference, Backend::EngineSequential] {
        let spec = ExperimentSpec::new("ring:6")
            .problem(ProblemSpec::quadratic())
            .backend(backend)
            .iterations(40)
            .record_every(10)
            .seed(3);
        let mut tally = Tally::default();
        experiment::run_observed(&spec, &mut tally).unwrap();
        assert_eq!(tally.iterations, 40, "{}", backend.name());
        assert_eq!(tally.records, 1 + 4, "{}", backend.name());
    }

    // Sweep streaming: every grid point observed exactly once, results in
    // input order.
    let base = ExperimentSpec::new("ring:6")
        .problem(ProblemSpec::quadratic())
        .backend(Backend::EngineSequential)
        .iterations(30)
        .record_every(30)
        .seed(3);
    let budgets = [0.2, 0.5, 0.8, 1.0];
    let mut tally = Tally::default();
    let results = experiment::run_sweep(&base, &budgets, 4, &mut tally).unwrap();
    let mut seen = tally.points.clone();
    seen.sort_unstable();
    assert_eq!(seen, vec![0, 1, 2, 3]);
    assert_eq!(results.len(), 4);
    for ((cb, r), expect) in results.iter().zip(&budgets) {
        assert_eq!(cb, expect);
        assert!(r.total_time > 0.0);
    }
}

#[test]
fn sweep_matches_individual_runs_bit_for_bit() {
    let base = ExperimentSpec::new("ring:6")
        .problem(ProblemSpec::quadratic())
        .backend(Backend::EngineSequential)
        .iterations(50)
        .record_every(25)
        .seed(8);
    let budgets = [0.3, 0.7];
    let swept =
        experiment::run_sweep(&base, &budgets, 2, &mut experiment::NoopObserver).unwrap();
    for (cb, r) in &swept {
        let solo = experiment::run(&base.clone().with_budget(*cb)).unwrap();
        assert_eq!(r.final_mean, solo.final_mean, "cb {cb}");
        assert_eq!(r.total_time, solo.total_time, "cb {cb}");
    }
}
