//! Cross-backend trace determinism and export integration tests.
//!
//! Per seed, the barrier backends emit identical virtual-time event
//! sequences: sim ≡ engine once per-link schedule events are filtered
//! out (the sequential simulator accounts communication time in closed
//! form and emits none), and cluster loopback ≡ actors event-for-event
//! once wire-frame events are filtered out. Every backend's trace
//! exports as well-formed Chrome trace-event JSON.

use matcha::cluster::TransportKind;
use matcha::experiment::{self, Backend, ExperimentSpec, NoopObserver, ProblemSpec, Strategy};
use matcha::trace::{chrome_trace, validate_chrome_trace, RingSink, TraceEvent, Tracer};

fn base_spec(seed: u64) -> ExperimentSpec {
    ExperimentSpec::new("ring:6")
        .problem(ProblemSpec::quadratic())
        .strategy(Strategy::Matcha { budget: 0.5 })
        .lr(0.03)
        .iterations(40)
        .record_every(10)
        .seed(seed)
}

/// Run the spec with a tracer attached and return the `(event, vt)`
/// sequence. `wall_ns` is deliberately excluded: it is informational
/// and never part of the determinism contract.
fn traced_events(spec: &ExperimentSpec) -> Vec<(TraceEvent, f64)> {
    let plan = experiment::plan(spec).unwrap();
    let mut sink = RingSink::new(1 << 17);
    let mut tracer = Tracer::attached(&mut sink);
    experiment::run_planned_traced(spec, &plan, &mut NoopObserver, &mut tracer).unwrap();
    drop(tracer);
    assert_eq!(sink.dropped(), 0, "ring must hold the whole run");
    sink.records().iter().map(|r| (r.ev, r.vt)).collect()
}

#[test]
fn sim_and_engine_emit_identical_event_sequences_per_seed() {
    for seed in [1, 9, 42] {
        let sim = traced_events(&base_spec(seed));
        let engine = traced_events(&base_spec(seed).backend(Backend::EngineSequential));
        assert!(engine.iter().any(|(ev, _)| ev.is_link()), "engine emits link events");
        assert!(!sim.iter().any(|(ev, _)| ev.is_link()), "sim emits no link events");
        let engine_filtered: Vec<_> =
            engine.into_iter().filter(|(ev, _)| !ev.is_link()).collect();
        assert_eq!(sim, engine_filtered, "seed {seed}");
    }
}

#[test]
fn cluster_loopback_trace_matches_actors_event_for_event() {
    let actors = traced_events(&base_spec(7).backend(Backend::EngineActors { threads: 2 }));
    let cluster = traced_events(
        &base_spec(7)
            .backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    );
    assert!(cluster.iter().any(|(ev, _)| ev.is_frame()), "cluster emits frame events");
    assert!(!actors.iter().any(|(ev, _)| ev.is_frame()));
    let cluster_filtered: Vec<_> =
        cluster.into_iter().filter(|(ev, _)| !ev.is_frame()).collect();
    assert_eq!(actors, cluster_filtered);
}

#[test]
fn remote_coordinator_trace_matches_loopback_event_for_event() {
    // The remote pipelined path must emit the same coordinator-side
    // trace as the in-process loopback cluster once transport-shaped
    // events are filtered: frame markers differ (TCP framing vs
    // loopback pipes) and reconnects only exist remotely, but the
    // engine-loop events — compute/link spans, mixes, barriers — are
    // identical in kind, order, and virtual time.
    use matcha::node::DaemonOptions;
    let spawn = || {
        let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).expect("bind daemon");
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let _ = matcha::node::run_daemon(listener, &DaemonOptions::default());
        });
        addr
    };
    let addrs = vec![spawn(), spawn()];
    let loopback = traced_events(
        &base_spec(7).backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    );
    let remote = traced_events(&base_spec(7).backend(Backend::Cluster {
        shards: 2,
        transport: TransportKind::Remote { addrs },
    }));
    assert!(remote.iter().any(|(ev, _)| ev.is_frame()), "remote emits frame events");
    let strip = |events: Vec<(TraceEvent, f64)>| -> Vec<(TraceEvent, f64)> {
        events
            .into_iter()
            .filter(|(ev, _)| !ev.is_frame() && !matches!(ev, TraceEvent::Reconnect { .. }))
            .collect()
    };
    assert_eq!(strip(remote), strip(loopback));
}

#[test]
fn async_trace_is_deterministic_per_seed() {
    let spec = base_spec(5)
        .policy("straggler:0:4.0")
        .backend(Backend::Async { threads: 2, max_staleness: 3 });
    let a = traced_events(&spec);
    let b = traced_events(&spec);
    assert_eq!(a, b, "async traces are reproducible per seed");
    assert!(a.iter().any(|(ev, _)| matches!(ev, TraceEvent::StaleExchange { .. })));
}

#[test]
fn every_backend_exports_a_valid_chrome_trace() {
    let backends = [
        Backend::EngineSequential,
        Backend::EngineActors { threads: 2 },
        Backend::Async { threads: 2, max_staleness: 3 },
        Backend::Cluster { shards: 2, transport: TransportKind::Loopback },
    ];
    for backend in backends {
        let spec = base_spec(3).backend(backend);
        let plan = experiment::plan(&spec).unwrap();
        let mut sink = RingSink::new(1 << 17);
        let mut tracer = Tracer::attached(&mut sink);
        let result =
            experiment::run_planned_traced(&spec, &plan, &mut NoopObserver, &mut tracer)
                .unwrap();
        drop(tracer);
        let json = chrome_trace(&sink.records(), &result.snapshot.to_json());
        let check = validate_chrome_trace(&json.to_string()).unwrap();
        assert!(check.events > 0, "{:?}", spec.backend);
        assert!(check.tracks >= 2, "{:?}", spec.backend);
        assert_eq!(json.get("otherData"), Some(&result.snapshot.to_json()));
    }
}
