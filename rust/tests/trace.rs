//! Cross-backend trace determinism and export integration tests.
//!
//! Per seed, the barrier backends emit identical virtual-time event
//! sequences: sim ≡ engine once per-link schedule events are filtered
//! out (the sequential simulator accounts communication time in closed
//! form and emits none), and cluster loopback ≡ actors event-for-event
//! once wire-frame events are filtered out. Every backend's trace
//! exports as well-formed Chrome trace-event JSON.

use matcha::cluster::TransportKind;
use matcha::experiment::{
    self, Backend, ExperimentSpec, NoopObserver, ProblemSpec, ReportSpec, Strategy,
};
use matcha::trace::{
    chrome_trace, validate_chrome_trace, Observatory, ObservatoryConfig, RingSink, TraceEvent,
    Tracer,
};

fn base_spec(seed: u64) -> ExperimentSpec {
    ExperimentSpec::new("ring:6")
        .problem(ProblemSpec::quadratic())
        .strategy(Strategy::Matcha { budget: 0.5 })
        .lr(0.03)
        .iterations(40)
        .record_every(10)
        .seed(seed)
}

/// Run the spec with a tracer attached and return the `(event, vt)`
/// sequence. `wall_ns` is deliberately excluded: it is informational
/// and never part of the determinism contract.
fn traced_events(spec: &ExperimentSpec) -> Vec<(TraceEvent, f64)> {
    let plan = experiment::plan(spec).unwrap();
    let mut sink = RingSink::new(1 << 17);
    let mut tracer = Tracer::attached(&mut sink);
    experiment::run_planned_traced(spec, &plan, &mut NoopObserver, &mut tracer).unwrap();
    drop(tracer);
    assert_eq!(sink.dropped(), 0, "ring must hold the whole run");
    sink.records().iter().map(|r| (r.ev, r.vt)).collect()
}

#[test]
fn sim_and_engine_emit_identical_event_sequences_per_seed() {
    for seed in [1, 9, 42] {
        let sim = traced_events(&base_spec(seed));
        let engine = traced_events(&base_spec(seed).backend(Backend::EngineSequential));
        assert!(engine.iter().any(|(ev, _)| ev.is_link()), "engine emits link events");
        assert!(!sim.iter().any(|(ev, _)| ev.is_link()), "sim emits no link events");
        let engine_filtered: Vec<_> =
            engine.into_iter().filter(|(ev, _)| !ev.is_link()).collect();
        assert_eq!(sim, engine_filtered, "seed {seed}");
    }
}

#[test]
fn cluster_loopback_trace_matches_actors_event_for_event() {
    let actors = traced_events(&base_spec(7).backend(Backend::EngineActors { threads: 2 }));
    let cluster = traced_events(
        &base_spec(7)
            .backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    );
    assert!(cluster.iter().any(|(ev, _)| ev.is_frame()), "cluster emits frame events");
    assert!(!actors.iter().any(|(ev, _)| ev.is_frame()));
    let cluster_filtered: Vec<_> =
        cluster.into_iter().filter(|(ev, _)| !ev.is_frame()).collect();
    assert_eq!(actors, cluster_filtered);
}

#[test]
fn remote_coordinator_trace_matches_loopback_event_for_event() {
    // The remote pipelined path must emit the same coordinator-side
    // trace as the in-process loopback cluster once transport-shaped
    // events are filtered: frame markers differ (TCP framing vs
    // loopback pipes) and reconnects only exist remotely, but the
    // engine-loop events — compute/link spans, mixes, barriers — are
    // identical in kind, order, and virtual time.
    use matcha::node::DaemonOptions;
    let spawn = || {
        let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).expect("bind daemon");
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let _ = matcha::node::run_daemon(listener, &DaemonOptions::default());
        });
        addr
    };
    let addrs = vec![spawn(), spawn()];
    let loopback = traced_events(
        &base_spec(7).backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    );
    let remote = traced_events(&base_spec(7).backend(Backend::Cluster {
        shards: 2,
        transport: TransportKind::Remote { addrs },
    }));
    assert!(remote.iter().any(|(ev, _)| ev.is_frame()), "remote emits frame events");
    let strip = |events: Vec<(TraceEvent, f64)>| -> Vec<(TraceEvent, f64)> {
        events
            .into_iter()
            .filter(|(ev, _)| !ev.is_frame() && !matches!(ev, TraceEvent::Reconnect { .. }))
            .collect()
    };
    assert_eq!(strip(remote), strip(loopback));
}

#[test]
fn async_trace_is_deterministic_per_seed() {
    let spec = base_spec(5)
        .policy("straggler:0:4.0")
        .backend(Backend::Async { threads: 2, max_staleness: 3 });
    let a = traced_events(&spec);
    let b = traced_events(&spec);
    assert_eq!(a, b, "async traces are reproducible per seed");
    assert!(a.iter().any(|(ev, _)| matches!(ev, TraceEvent::StaleExchange { .. })));
}

#[test]
fn observatory_snapshot_is_identical_across_barrier_backends() {
    // One ObservatorySnapshot schema, one value: the sequential
    // simulator, the event engine, the bounded actor pool, and the
    // loopback cluster must all report the same ledger, windows,
    // frontier, and audit for the same seed. (The async backend is
    // deliberately excluded: its round structure is barrier-free.)
    let spec = |backend| base_spec(11).report(ReportSpec { window: 2 }).backend(backend);
    let sim = experiment::run(&spec(Backend::SimReference)).unwrap().observatory.unwrap();
    let engine = experiment::run(&spec(Backend::EngineSequential)).unwrap().observatory.unwrap();
    let actors =
        experiment::run(&spec(Backend::EngineActors { threads: 2 })).unwrap().observatory.unwrap();
    let cluster = experiment::run(
        &spec(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    )
    .unwrap()
    .observatory
    .unwrap();
    assert_eq!(sim.rounds, 40);
    // 40 iterations recorded every 10 → 4 frontier samples → 2 closed
    // windows of 2 samples each.
    assert_eq!(sim.frontier.len(), 4);
    assert_eq!(sim.windows.len(), 2);
    assert_eq!(sim, engine);
    assert_eq!(sim, actors);
    assert_eq!(sim, cluster);
}

#[test]
fn activation_audit_tracks_design_on_fig5_topologies() {
    // The paper's fig-5 topologies (ring and ladder = grid(2, m)): the
    // sampler realizes the designed p_j, so a faithful run's ledger must
    // sit under the drift threshold — and a mis-stated design over the
    // same realized schedule must be flagged.
    for graph in ["ring:8", "grid:2x4"] {
        let spec = ExperimentSpec::new(graph)
            .problem(ProblemSpec::quadratic())
            .strategy(Strategy::Matcha { budget: 0.5 })
            .iterations(400)
            .record_every(100)
            .seed(3)
            .report(ReportSpec { window: 2 });
        let plan = experiment::plan(&spec).unwrap();
        let obs = experiment::run_planned(&spec, &plan, &mut NoopObserver)
            .unwrap()
            .observatory
            .unwrap();
        assert_eq!(obs.rounds, 400, "{graph}");
        assert_eq!(obs.ledger.designed, plan.probabilities, "{graph}");
        assert_eq!(obs.ledger.realized.len(), plan.decomposition.matchings.len(), "{graph}");
        assert!(
            !obs.ledger.drifted,
            "{graph}: realized schedule drifted from its own design (score {})",
            obs.ledger.drift_score
        );

        // Same realized rounds, audited against a warped design.
        let mut wrong = Observatory::enabled(ObservatoryConfig {
            designed: plan.probabilities.iter().map(|p| (0.3 * p).clamp(0.02, 0.98)).collect(),
            matchings: plan.decomposition.matchings.iter().map(|g| g.edges().to_vec()).collect(),
            rho: plan.rho,
            workers: plan.graph.num_nodes(),
            window: 2,
        });
        let mut sampler = plan.sampler(spec.sampler_seed.unwrap_or(spec.seed));
        for k in 0..400 {
            wrong.on_round(&sampler.round(k).activated, &[]);
        }
        let warped = wrong.snapshot().unwrap();
        assert!(warped.ledger.drifted, "{graph}: warped design must be flagged");
        assert!(warped.ledger.drift_score > obs.ledger.drift_score, "{graph}");
    }
}

#[test]
fn ring_sink_wraparound_drops_oldest_and_keeps_newest() {
    let mut sink = RingSink::new(8);
    let mut tracer = Tracer::attached(&mut sink);
    for k in 0..20 {
        tracer.set_now(k as f64);
        tracer.emit(TraceEvent::RoundBarrier { k });
    }
    drop(tracer);
    // 20 emits through a capacity-8 ring: exactly 12 overwritten, the
    // survivors are the 8 newest, still in emission order.
    assert_eq!(sink.dropped(), 12);
    let records = sink.records();
    assert_eq!(records.len(), 8);
    let ks: Vec<usize> = records
        .iter()
        .map(|r| match r.ev {
            TraceEvent::RoundBarrier { k } => k,
            ev => panic!("unexpected event {ev:?}"),
        })
        .collect();
    assert_eq!(ks, (12..20).collect::<Vec<_>>());
}

#[test]
fn every_backend_exports_a_valid_chrome_trace() {
    let backends = [
        Backend::EngineSequential,
        Backend::EngineActors { threads: 2 },
        Backend::Async { threads: 2, max_staleness: 3 },
        Backend::Cluster { shards: 2, transport: TransportKind::Loopback },
    ];
    for backend in backends {
        let spec = base_spec(3).backend(backend);
        let plan = experiment::plan(&spec).unwrap();
        let mut sink = RingSink::new(1 << 17);
        let mut tracer = Tracer::attached(&mut sink);
        let result =
            experiment::run_planned_traced(&spec, &plan, &mut NoopObserver, &mut tracer)
                .unwrap();
        drop(tracer);
        let json = chrome_trace(&sink.records(), &result.snapshot.to_json());
        let check = validate_chrome_trace(&json.to_string()).unwrap();
        assert!(check.events > 0, "{:?}", spec.backend);
        assert!(check.tracks >= 2, "{:?}", spec.backend);
        assert_eq!(json.get("otherData"), Some(&result.snapshot.to_json()));
    }
}
