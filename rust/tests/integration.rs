//! Cross-module integration tests: the full MATCHA pipeline over a zoo of
//! topologies, schedule persistence, the CLI surface, and (when `make
//! artifacts` has run) the XLA runtime path.

use matcha::budget::optimize_activation_probabilities;
use matcha::coordinator::{plan_matcha, plan_periodic, plan_vanilla};
use matcha::graph::{self, algebraic_connectivity, Graph};
use matcha::matching::decompose;
use matcha::mixing::{optimize_alpha, rho_monte_carlo, vanilla_design};
use matcha::proptest::{check, PropConfig};
use matcha::rng::Rng;
use matcha::sim::{run_decentralized, QuadraticProblem, RunConfig};
use matcha::topology::{MatchaSampler, Schedule, TopologySampler, VanillaSampler};

/// The generator zoo used by several tests.
fn zoo() -> Vec<(String, Graph)> {
    let mut rng = Rng::new(1);
    vec![
        ("fig1".into(), graph::paper_figure1_graph()),
        ("ring8".into(), graph::ring(8)),
        ("ring9".into(), graph::ring(9)),
        ("star7".into(), graph::star(7)),
        ("complete6".into(), graph::complete(6)),
        ("grid3x4".into(), graph::grid(3, 4)),
        ("geom16".into(), graph::geometric_connected(16, 0.5, &mut rng)),
        ("er12".into(), graph::erdos_renyi_connected(12, 0.4, &mut rng)),
    ]
}

#[test]
fn full_pipeline_invariants_across_topology_zoo() {
    for (name, g) in zoo() {
        let d = decompose(&g);
        d.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            d.len() <= g.max_degree() + 1,
            "{name}: Vizing bound violated (M={} Δ={})",
            d.len(),
            g.max_degree()
        );
        for cb in [0.15, 0.5, 1.0] {
            let probs = optimize_activation_probabilities(&d, cb);
            // Budget respected.
            let total: f64 = probs.probabilities.iter().sum();
            assert!(total <= cb * d.len() as f64 + 1e-6, "{name} cb={cb}");
            // Theorem 2 end to end: connected expectation, ρ < 1.
            assert!(probs.lambda2 > 1e-8, "{name} cb={cb}: disconnected expectation");
            let mix = optimize_alpha(&d, &probs.probabilities);
            assert!(mix.rho < 1.0, "{name} cb={cb}: ρ = {}", mix.rho);
            assert!(mix.alpha > 0.0 && mix.alpha.is_finite());
        }
    }
}

#[test]
fn property_random_graphs_pipeline() {
    // Property test: random connected ER graphs × random budgets keep all
    // pipeline invariants.
    check(
        PropConfig { cases: 40, seed: 0xbeef },
        |rng| {
            let m = 4 + rng.below(10);
            let g = graph::erdos_renyi_connected(m, 0.5, rng);
            let cb = rng.uniform_in(0.1, 1.0);
            (g, cb)
        },
        |(g, cb)| {
            let d = decompose(g);
            d.validate()?;
            let probs = optimize_activation_probabilities(&d, *cb);
            let mix = optimize_alpha(&d, &probs.probabilities);
            if mix.rho >= 1.0 {
                return Err(format!("rho {} >= 1", mix.rho));
            }
            if probs.lambda2 <= 0.0 {
                return Err("lambda2 <= 0".into());
            }
            Ok(())
        },
    );
}

#[test]
fn monte_carlo_validates_rho_formula_on_random_graph() {
    let mut rng = Rng::new(42);
    let g = graph::erdos_renyi_connected(10, 0.45, &mut rng);
    let d = decompose(&g);
    let probs = optimize_activation_probabilities(&d, 0.35);
    let mix = optimize_alpha(&d, &probs.probabilities);
    let mc = rho_monte_carlo(&d, &probs.probabilities, mix.alpha, 15_000, &mut rng);
    assert!(
        (mc - mix.rho).abs() < 0.03,
        "closed-form ρ {} vs Monte-Carlo {mc}",
        mix.rho
    );
}

#[test]
fn plans_share_decomposition_and_disagree_on_schedules() {
    let g = graph::paper_figure1_graph();
    let steps = 200;
    let pm = plan_matcha(&g, 0.3, steps, 3);
    let pv = plan_vanilla(&g, steps);
    let pp = plan_periodic(&g, 0.3, steps);
    assert_eq!(pm.decomposition.len(), pv.decomposition.len());
    // Budgets: matcha ≈ periodic ≈ 0.3 × vanilla.
    let (cm, cv, cp) = (
        pm.schedule.mean_comm_units(),
        pv.schedule.mean_comm_units(),
        pp.schedule.mean_comm_units(),
    );
    assert!((cm / cv - 0.3).abs() < 0.1, "matcha {cm} vs vanilla {cv}");
    assert!((cp / cv - 0.3).abs() < 0.1, "periodic {cp} vs vanilla {cv}");
    // Vanilla's rho is the worst of the three here? Not necessarily — but
    // all must be < 1 and matcha ≤ periodic (Fig 3).
    assert!(pm.rho < 1.0 && pv.rho < 1.0 && pp.rho < 1.0);
    assert!(pm.rho <= pp.rho + 1e-9);
}

#[test]
fn schedule_persistence_roundtrip_through_file() {
    let g = graph::paper_figure1_graph();
    let plan = plan_matcha(&g, 0.5, 500, 9);
    let dir = std::env::temp_dir().join("matcha_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("schedule.json");
    plan.schedule.save(&path).unwrap();
    let loaded = Schedule::load(&path).unwrap();
    assert_eq!(loaded, plan.schedule);
    // Frequencies of the loaded schedule match the optimized probabilities.
    let freqs = loaded.activation_frequencies();
    for (f, p) in freqs.iter().zip(&plan.probabilities) {
        assert!((f - p).abs() < 0.08, "freq {f} vs p {p}");
    }
}

#[test]
fn corollary1_error_decreases_with_more_iterations() {
    // Run the same problem for K and 4K iterations with η ∝ 1/√K; the
    // averaged gradient norm must improve (Corollary 1's rate).
    let g = graph::paper_figure1_graph();
    let d = decompose(&g);
    let probs = optimize_activation_probabilities(&d, 0.5);
    let mix = optimize_alpha(&d, &probs.probabilities);
    let problem = {
        let mut r = Rng::new(5);
        QuadraticProblem::generate(8, 16, 1.0, 0.5, &mut r)
    };
    let run = |iters: usize| {
        let mut s = MatchaSampler::new(probs.probabilities.clone(), 2);
        let cfg = RunConfig {
            lr: 0.3 / (iters as f64).sqrt(),
            iterations: iters,
            record_every: iters / 4,
            alpha: mix.alpha,
            seed: 8,
            ..RunConfig::default()
        };
        let res = run_decentralized(&problem, &d.matchings, &mut s, &cfg);
        res.metrics.last("gradnorm2_vs_iter").unwrap()
    };
    let short = run(400);
    let long = run(1600);
    assert!(
        long < short,
        "gradient norm should shrink with K: K=400 → {short}, K=1600 → {long}"
    );
}

#[test]
fn matcha_matches_vanilla_per_iteration_on_zoo_subset() {
    // Fig 4 d–f in miniature, asserted across two very different graphs.
    for (name, g) in [("fig1", graph::paper_figure1_graph()), ("ring8", graph::ring(8))] {
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.5);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let van = vanilla_design(&g.laplacian());
        let problem = {
            let mut r = Rng::new(11);
            QuadraticProblem::generate(g.num_nodes(), 12, 1.0, 0.3, &mut r)
        };
        let cfg = |alpha: f64| RunConfig {
            lr: 0.03,
            iterations: 600,
            record_every: 100,
            alpha,
            seed: 21,
            ..RunConfig::default()
        };
        let mut ms = MatchaSampler::new(probs.probabilities.clone(), 5);
        let mres = run_decentralized(&problem, &d.matchings, &mut ms, &cfg(mix.alpha));
        let mut vs = VanillaSampler::new(d.len());
        let vres = run_decentralized(&problem, &d.matchings, &mut vs, &cfg(van.alpha));
        let msub = mres.metrics.last("subopt_vs_iter").unwrap();
        let vsub = vres.metrics.last("subopt_vs_iter").unwrap();
        assert!(
            msub < vsub.max(0.02) * 3.0,
            "{name}: MATCHA subopt {msub} vs vanilla {vsub}"
        );
        assert!(mres.total_comm_units < 0.65 * vres.total_comm_units, "{name}");
    }
}

#[test]
fn compression_combo_converges_and_cuts_comm_time() {
    // Paper §1: MATCHA is complementary to compression. Combined run must
    // still converge while the bandwidth-bound comm time shrinks further.
    use matcha::sim::Compression;
    let g = graph::paper_figure1_graph();
    let d = decompose(&g);
    let probs = optimize_activation_probabilities(&d, 0.5);
    let mix = optimize_alpha(&d, &probs.probabilities);
    let problem = {
        let mut r = Rng::new(61);
        QuadraticProblem::generate(8, 16, 1.0, 0.2, &mut r)
    };
    let cfg = |compression: Option<Compression>| RunConfig {
        lr: 0.02,
        iterations: 900,
        record_every: 100,
        alpha: mix.alpha,
        compression,
        latency_floor: 0.05,
        seed: 14,
        ..RunConfig::default()
    };
    let mut s1 = MatchaSampler::new(probs.probabilities.clone(), 8);
    let plain = run_decentralized(&problem, &d.matchings, &mut s1, &cfg(None));
    let mut s2 = MatchaSampler::new(probs.probabilities.clone(), 8);
    let compressed = run_decentralized(
        &problem,
        &d.matchings,
        &mut s2,
        &cfg(Some(Compression::TopK { frac: 0.25 })),
    );
    let ps = plain.metrics.last("subopt_vs_iter").unwrap();
    let cs = compressed.metrics.last("subopt_vs_iter").unwrap();
    assert!(ps < 0.05, "plain failed to converge: {ps}");
    assert!(cs < 0.15, "compressed failed to converge: {cs}");
    // Bandwidth-bound regime: comm time scaled by the payload ratio.
    let ratio = compressed.total_comm_units / plain.total_comm_units;
    assert!((ratio - 0.25).abs() < 0.02, "comm ratio {ratio}, expected 0.25");
}

#[test]
fn adaptive_budget_schedule_converges() {
    use matcha::topology::AdaptiveMatchaSampler;
    let g = graph::paper_figure1_graph();
    let d = decompose(&g);
    let (mut sampler, alpha) =
        AdaptiveMatchaSampler::from_budget_schedule(&d, &[(0, 0.8), (400, 0.15)], 4);
    let problem = {
        let mut r = Rng::new(71);
        QuadraticProblem::generate(8, 16, 1.0, 0.2, &mut r)
    };
    let cfg = RunConfig {
        lr: 0.02,
        iterations: 800,
        record_every: 100,
        alpha,
        seed: 9,
        ..RunConfig::default()
    };
    let res = run_decentralized(&problem, &d.matchings, &mut sampler, &cfg);
    assert!(res.metrics.last("subopt_vs_iter").unwrap() < 0.1);
    // Back half must be cheaper than the front half (budget decayed).
    let comm = res.metrics.get("comm_units_vs_iter");
    let mid = comm[comm.len() / 2].y;
    let end = comm.last().unwrap().y;
    assert!(end - mid < mid, "late-phase comm {} vs early {}", end - mid, mid);
}

#[test]
fn cli_surface_smoke() {
    let sv = |items: &[&str]| -> Vec<String> { items.iter().map(|s| s.to_string()).collect() };
    matcha::cli::run(&sv(&["decompose", "--graph", "grid:2x3"])).unwrap();
    matcha::cli::run(&sv(&["probs", "--graph", "ring:6", "--budget", "0.4"])).unwrap();
    matcha::cli::run(&sv(&["alpha", "--graph", "ring:6", "--budget", "0.4"])).unwrap();
    matcha::cli::run(&sv(&["commtime", "--graph", "fig1", "--budget", "0.5"])).unwrap();
    let out = std::env::temp_dir().join("matcha_cli_sched.json");
    matcha::cli::run(&sv(&[
        "schedule",
        "--graph",
        "fig1",
        "--budget",
        "0.5",
        "--steps",
        "50",
        "--out",
        out.to_str().unwrap(),
    ]))
    .unwrap();
    assert!(out.exists());
}

// ---------------- artifact-gated runtime tests --------------------------
// (Compiled only with the `xla` feature; the offline image cannot build
// the XLA crates, so the default build skips them entirely.)

#[cfg(feature = "xla")]
fn artifacts_dir() -> Option<matcha::config::ArtifactPaths> {
    let p = matcha::config::ArtifactPaths::new(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    );
    if p.meta().exists() {
        Some(p)
    } else {
        eprintln!("skipping runtime test: run `make artifacts` first");
        None
    }
}

#[cfg(feature = "xla")]
#[test]
fn runtime_mix_step_matches_rust_matmul() {
    let Some(arts) = artifacts_dir() else { return };
    let meta = matcha::config::ModelMeta::load(&arts.meta()).unwrap();
    let rt = matcha::runtime::Runtime::cpu().unwrap();
    let mix = rt.load_hlo(&arts.mix(false)).unwrap();

    let m = meta.workers;
    let d = meta.param_count;
    let mut rng = Rng::new(4);
    // Random doubly-stochastic-ish W (exact structure irrelevant for the
    // numerical check) and random stacked params.
    let g = graph::ring(m);
    let design = vanilla_design(&g.laplacian());
    let mut w = vec![0.0f32; m * m];
    for i in 0..m {
        w[i * m + i] = 1.0;
    }
    for &(u, v) in g.edges() {
        w[u * m + u] -= design.alpha as f32;
        w[v * m + v] -= design.alpha as f32;
        w[u * m + v] += design.alpha as f32;
        w[v * m + u] += design.alpha as f32;
    }
    let stacked: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32 * 0.1).collect();

    let outs = mix
        .run(&[
            matcha::runtime::literal_f32(&w, &[m as i64, m as i64]).unwrap(),
            matcha::runtime::literal_f32(&stacked, &[m as i64, d as i64]).unwrap(),
        ])
        .unwrap();
    let got = matcha::runtime::to_vec_f32(&outs[0]).unwrap();

    // Rust-side reference on a subsample of columns.
    for col in (0..d).step_by(d / 97 + 1) {
        for row in 0..m {
            let mut expect = 0.0f64;
            for k in 0..m {
                expect += w[row * m + k] as f64 * stacked[k * d + col] as f64;
            }
            let gotv = got[row * d + col] as f64;
            assert!(
                (gotv - expect).abs() < 1e-4,
                "mix mismatch at ({row},{col}): {gotv} vs {expect}"
            );
        }
    }
}

#[cfg(feature = "xla")]
#[test]
fn runtime_train_step_learns_and_preserves_shapes() {
    let Some(arts) = artifacts_dir() else { return };
    let meta = matcha::config::ModelMeta::load(&arts.meta()).unwrap();
    let rt = matcha::runtime::Runtime::cpu().unwrap();
    let train = rt.load_hlo(&arts.train_step(false)).unwrap();

    let d = meta.param_count;
    let mut rng = Rng::new(9);
    let mut flat = meta.init_params(&mut rng);
    let corpus = matcha::data::Corpus::synthesize(1, 20_000, 100, false, 2);
    let mut it =
        matcha::data::BatchIter::new(&corpus.shards[0].tokens, meta.batch, meta.seq_len, 3);
    let dims = [meta.batch as i64, meta.seq_len as i64];

    let (xs, ys) = it.next_batch();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for step in 0..6 {
        let outs = train
            .run(&[
                matcha::runtime::literal_f32(&flat, &[d as i64]).unwrap(),
                matcha::runtime::literal_i32(&xs, &dims).unwrap(),
                matcha::runtime::literal_i32(&ys, &dims).unwrap(),
                matcha::runtime::literal_scalar_f32(0.5),
            ])
            .unwrap();
        flat = matcha::runtime::to_vec_f32(&outs[0]).unwrap();
        let loss = matcha::runtime::to_scalar_f32(&outs[1]).unwrap();
        assert!(loss.is_finite());
        assert_eq!(flat.len(), d);
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
    }
    assert!(
        last_loss < first_loss,
        "repeated steps on one batch must overfit: {first_loss} -> {last_loss}"
    );
}

#[test]
fn lambda2_monotone_under_budget_on_zoo() {
    // Paper-implied sanity bound: λ₂ of the optimized expectation never
    // exceeds the base graph's λ₂ and is at least CB·λ₂ (achieved by the
    // uniform allocation p_j = CB, Theorem 2's eq. (80)).
    for (name, g) in zoo() {
        let base_l2 = algebraic_connectivity(&g);
        let d = decompose(&g);
        for cb in [0.25, 0.6] {
            let probs = optimize_activation_probabilities(&d, cb);
            assert!(
                probs.lambda2 <= base_l2 + 1e-7,
                "{name}: λ₂ {} exceeds base {base_l2}",
                probs.lambda2
            );
            assert!(
                probs.lambda2 >= cb * base_l2 - 1e-6,
                "{name}: λ₂ {} below uniform bound {}",
                probs.lambda2,
                cb * base_l2
            );
        }
    }
}
