//! Integration tests of the standalone shard-node daemon and the
//! pipelined remote coordinator (`crate::node`).
//!
//! Every test runs real daemons on ephemeral localhost ports: the
//! determinism contract under test is that a remote run — pipelined,
//! across processes-worth of isolation, even through injected
//! connection drops — is **bit-for-bit** the in-process cluster run.

use matcha::cluster::TransportKind;
use matcha::experiment::{
    self, Backend, ExperimentSpec, NoopObserver, ProblemSpec, ReportSpec, Strategy,
};
use matcha::node::{
    query_status, run_daemon, run_remote, run_remote_traced, DaemonOptions, RemoteOptions,
};
use matcha::trace::{Counter, MetricsSnapshot, RingSink, TraceEvent, Tracer, UNASSIGNED_SHARD};
use std::net::TcpListener;

/// Bind an ephemeral port and serve a daemon on a background thread.
/// The thread outlives the test harmlessly (blocked in accept) unless
/// `once` ends it; what matters is the address.
fn spawn_daemon(opts: DaemonOptions) -> String {
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind daemon port");
    let addr = listener.local_addr().expect("daemon addr").to_string();
    std::thread::spawn(move || {
        if let Err(e) = run_daemon(listener, &opts) {
            eprintln!("test daemon exited: {e}");
        }
    });
    addr
}

fn base_spec() -> ExperimentSpec {
    ExperimentSpec::new("ring:6")
        .problem(ProblemSpec::quadratic())
        .strategy(Strategy::Matcha { budget: 0.5 })
        .lr(0.03)
        .iterations(60)
        .record_every(20)
        .seed(9)
}

fn remote_spec(addrs: Vec<String>) -> ExperimentSpec {
    let shards = addrs.len();
    base_spec().backend(Backend::Cluster {
        shards,
        transport: TransportKind::Remote { addrs },
    })
}

#[test]
fn remote_daemons_match_loopback_cluster_bit_for_bit() {
    let addrs = vec![
        spawn_daemon(DaemonOptions::default()),
        spawn_daemon(DaemonOptions::default()),
    ];
    let loopback = experiment::run(
        &base_spec().backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    )
    .unwrap();
    // Through the unified runner: a spec naming remote daemons
    // dispatches to the node coordinator automatically.
    let remote = experiment::run(&remote_spec(addrs.clone())).unwrap();
    assert_eq!(remote.final_mean, loopback.final_mean);
    assert_eq!(remote.final_states, loopback.final_states);
    assert_eq!(remote.total_time, loopback.total_time);
    assert_eq!(remote.total_comm_units, loopback.total_comm_units);
    // Identical schedule, identical frames: identical bytes on the wire.
    let remote_stats = remote.cluster_stats.expect("remote stats");
    let loopback_stats = loopback.cluster_stats.expect("loopback stats");
    assert_eq!(remote_stats.total_bytes(), loopback_stats.total_bytes());
    assert_eq!(remote_stats.per_link.len(), 2);

    // Shutdown resets each daemon's session in place, so the same fleet
    // serves a second, independent run with identical results.
    let again = experiment::run(&remote_spec(addrs)).unwrap();
    assert_eq!(again.final_mean, loopback.final_mean);
    assert_eq!(again.final_states, loopback.final_states);
}

#[test]
fn pipeline_window_never_changes_results() {
    let addrs = vec![
        spawn_daemon(DaemonOptions::default()),
        spawn_daemon(DaemonOptions::default()),
    ];
    let spec = remote_spec(addrs);
    let run_with_window = |window: usize| {
        run_remote(&spec, &RemoteOptions { window, ..RemoteOptions::default() }).unwrap()
    };
    // window = 1 degenerates to the in-process driver's strict
    // request/reply protocol; deeper windows only hide latency.
    let strict = run_with_window(1);
    let deep = run_with_window(8);
    assert_eq!(deep.run.final_mean, strict.run.final_mean);
    assert_eq!(deep.run.final_states, strict.run.final_states);
    assert_eq!(deep.run.total_time, strict.run.total_time);
    assert_eq!(deep.stats.total_bytes(), strict.stats.total_bytes());
    assert_eq!(deep.stats.total_frames(), strict.stats.total_frames());
}

#[test]
fn reconnect_resumes_mid_run_bit_for_bit() {
    // Shard 0's daemon drops its connection once after 7 commands; the
    // coordinator must reconnect, resume, and finish with the exact
    // trajectory of a run that never dropped.
    let addrs = vec![
        spawn_daemon(DaemonOptions { drop_after: Some(7), ..DaemonOptions::default() }),
        spawn_daemon(DaemonOptions::default()),
    ];
    let spec = remote_spec(addrs);
    let opts = RemoteOptions { reconnect_delay_ms: 10, ..RemoteOptions::default() };

    let mut sink = RingSink::new(65_536);
    let (result, snapshot) = {
        let mut tracer = Tracer::attached(&mut sink);
        let result = run_remote_traced(&spec, &opts, &mut NoopObserver, &mut tracer).unwrap();
        let snapshot = MetricsSnapshot::from_registry(&tracer.registry);
        (result, snapshot)
    };
    assert!(snapshot.counter(Counter::Reconnects) >= 1, "the injected drop must reconnect");
    assert!(
        sink.records().iter().any(|r| matches!(r.ev, TraceEvent::Reconnect { link: 0, .. })),
        "the reconnect must be visible in the trace"
    );

    let loopback = experiment::run(
        &base_spec().backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    )
    .unwrap();
    assert_eq!(result.run.final_mean, loopback.final_mean);
    assert_eq!(Some(result.run.final_states), loopback.final_states);
    assert_eq!(result.run.total_time, loopback.total_time);
}

#[test]
fn observatory_snapshot_matches_loopback_even_through_reconnects() {
    // The coordinator's observatory hooks fire on its side of the wire,
    // and its engine loop executes each round exactly once — a replayed
    // command stream after an injected drop must therefore leave the
    // ledger, windows, and frontier bit-for-bit equal to the loopback
    // run that never dropped.
    let addrs = vec![
        spawn_daemon(DaemonOptions { drop_after: Some(7), ..DaemonOptions::default() }),
        spawn_daemon(DaemonOptions::default()),
    ];
    let loopback = experiment::run(
        &base_spec()
            .backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback })
            .report(ReportSpec { window: 2 }),
    )
    .unwrap();
    let remote =
        experiment::run(&remote_spec(addrs).report(ReportSpec { window: 2 })).unwrap();
    let lo = loopback.observatory.expect("loopback observatory");
    let ro = remote.observatory.expect("remote observatory");
    assert_eq!(lo.rounds, 60);
    // 60 iterations recorded every 20 → 3 frontier samples → 1 closed
    // window of 2.
    assert_eq!(lo.frontier.len(), 3);
    assert_eq!(lo.windows.len(), 1);
    assert_eq!(ro, lo, "remote observatory must not double-count across the reconnect");
}

#[test]
fn silent_daemon_surfaces_a_timeout_error() {
    // A listener that accepts into its backlog but never speaks: the
    // coordinator's handshake deadline must turn that into a fast typed
    // error instead of hanging the run.
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind silent port");
    let addr = listener.local_addr().unwrap().to_string();
    let spec = remote_spec(vec![addr]);
    let opts = RemoteOptions {
        io_timeout_ms: 150,
        reconnect_attempts: 2,
        reconnect_delay_ms: 10,
        ..RemoteOptions::default()
    };
    let started = std::time::Instant::now();
    let err = run_remote(&spec, &opts).unwrap_err();
    assert!(err.contains("timed out"), "want the typed deadline error, got: {err}");
    assert!(
        started.elapsed() < std::time::Duration::from_secs(10),
        "the deadline must fire promptly"
    );
    drop(listener);
}

#[test]
fn stray_run_against_restarted_daemon_is_rejected() {
    // A daemon that answers a re-dial with a fresh (done = 0) session
    // mid-run has lost state; here the inverse guard: a *new* run must
    // refuse a daemon that is mid-session from some earlier coordinator.
    // Drive a daemon a few commands in by hand, drop the connection, and
    // start a fresh run against it.
    use matcha::cluster::{Transport, WireMsg, PROTO_VERSION};
    let addr = spawn_daemon(DaemonOptions::default());
    let spec = remote_spec(vec![addr.clone()]);
    let spec_json = spec.to_json_string();
    {
        let stream = std::net::TcpStream::connect(&addr).expect("dial daemon");
        let mut tx = matcha::cluster::TcpTransport::new(stream).unwrap();
        let mut scratch = Vec::new();
        let mut body = Vec::new();
        tx.send_msg(&WireMsg::Assign { shard: 0, shards: 1, spec_json }, &mut scratch).unwrap();
        let hello = tx.recv_msg(&mut body).unwrap();
        assert!(matches!(hello, WireMsg::Hello { shard: 0, proto: PROTO_VERSION }));
        let resume = tx.recv_msg(&mut body).unwrap();
        assert!(matches!(resume, WireMsg::Resume { done: 0, .. }));
        tx.send_msg(&WireMsg::Step { lr: 0.03 }, &mut scratch).unwrap();
        let reply = tx.recv_msg(&mut body).unwrap();
        assert!(matches!(reply, WireMsg::States { .. }));
        // Drop without Shutdown: the session stays live at done = 1.
    }
    let err = run_remote(&spec, &RemoteOptions::default()).unwrap_err();
    assert!(err.contains("mid-session"), "got: {err}");
}

/// A spec whose trace block asks for the merged telemetry export.
fn traced_spec(addrs: Vec<String>, path: &std::path::Path) -> ExperimentSpec {
    let mut spec = remote_spec(addrs);
    spec.trace = Some(experiment::TraceSpec {
        path: path.to_string_lossy().into_owned(),
        format: matcha::trace::TraceFormat::Chrome,
        capacity: 65_536,
        telemetry: true,
        telemetry_capacity: 65_536,
    });
    spec
}

#[test]
fn status_answers_idle_and_dead_daemons() {
    // Idle daemon (no Assign yet): health comes back unassigned, with
    // zeroed session counters and no trace records.
    let addr = spawn_daemon(DaemonOptions::default());
    let t = query_status(&addr, 2_000).unwrap();
    assert_eq!(t.shard, UNASSIGNED_SHARD);
    assert_eq!(t.rounds_done, 0);
    assert_eq!(t.reconnects, 0);
    assert!(t.records.is_empty(), "health pulls never drain the ring");
    assert!(t.observatory.is_none(), "no observatory digest before an Assign");
    // A dead address is a fast error, not a hang.
    let dead = {
        let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        addr
    };
    let started = std::time::Instant::now();
    assert!(query_status(&dead, 500).is_err());
    assert!(started.elapsed() < std::time::Duration::from_secs(5));
}

#[test]
fn status_reports_mid_session_health_without_perturbing_the_run() {
    // Drive a daemon two commands into a session by hand and query its
    // status between commands: the daemon polls for side connections at
    // the top of its command loop, so the pull is answered after the
    // next command without entering the replay machinery.
    use matcha::cluster::{Transport, WireMsg};
    let addr = spawn_daemon(DaemonOptions::default());
    let spec = remote_spec(vec![addr.clone()]);
    let spec_json = spec.to_json_string();
    let stream = std::net::TcpStream::connect(&addr).expect("dial daemon");
    let mut tx = matcha::cluster::TcpTransport::new(stream).unwrap();
    let mut scratch = Vec::new();
    let mut body = Vec::new();
    tx.send_msg(&WireMsg::Assign { shard: 0, shards: 1, spec_json }, &mut scratch).unwrap();
    let _hello = tx.recv_msg(&mut body).unwrap();
    let _resume = tx.recv_msg(&mut body).unwrap();
    tx.send_msg(&WireMsg::Step { lr: 0.03 }, &mut scratch).unwrap();
    assert!(matches!(tx.recv_msg(&mut body).unwrap(), WireMsg::States { .. }));
    // Queue the status connection, then let the next command's loop
    // iteration pick it up.
    let status_addr = addr.clone();
    let pull = std::thread::spawn(move || query_status(&status_addr, 10_000));
    std::thread::sleep(std::time::Duration::from_millis(100));
    tx.send_msg(&WireMsg::Step { lr: 0.03 }, &mut scratch).unwrap();
    assert!(matches!(tx.recv_msg(&mut body).unwrap(), WireMsg::States { .. }));
    let t = pull.join().expect("status thread").expect("status reply");
    assert_eq!(t.shard, 0);
    // ring:6 on one shard: every step computes all 6 workers, and at
    // least one step had landed when the pull was answered.
    let steps = t.registry.counter(Counter::ShardSteps);
    assert!(steps >= 6, "mid-session status must carry live counters, got {steps}");
    assert!(t.records.is_empty(), "status pulls are non-draining");
    // The daemon arms its observatory on Assign, so the digest is
    // present — and all-zero, since no mix round has run yet.
    let obs = t.observatory.expect("assigned daemon must ship an observatory digest");
    assert_eq!(obs.rounds, 0);
    assert_eq!(obs.windows, 0);
    assert_eq!(obs.contraction_rate, 0.0);
    // The session continues untouched afterwards.
    tx.send_msg(&WireMsg::Step { lr: 0.03 }, &mut scratch).unwrap();
    assert!(matches!(tx.recv_msg(&mut body).unwrap(), WireMsg::States { .. }));
}

#[test]
fn merged_remote_trace_has_one_pid_per_daemon_and_stays_bit_for_bit() {
    let addrs = vec![
        spawn_daemon(DaemonOptions::default()),
        spawn_daemon(DaemonOptions::default()),
    ];
    let dir = std::env::temp_dir().join("matcha_node_telemetry");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("merged_trace.json");
    let remote = experiment::run(&traced_spec(addrs, &path)).unwrap();

    // Telemetry on changes nothing about the results.
    let loopback = experiment::run(
        &base_spec().backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    )
    .unwrap();
    assert_eq!(remote.final_mean, loopback.final_mean);
    assert_eq!(remote.final_states, loopback.final_states);
    assert_eq!(remote.total_time, loopback.total_time);

    // The export is one valid Chrome trace with coordinator pid 0 plus
    // one pid per daemon, each carrying real compute/mix work.
    let text = std::fs::read_to_string(&path).unwrap();
    let check = matcha::trace::validate_chrome_trace(&text).unwrap();
    assert_eq!(check.pids, 3, "coordinator + 2 daemon processes");
    assert_eq!(check.dropped, Some(0));
    let json = matcha::json::Json::parse(&text).unwrap();
    let events = json.get("traceEvents").unwrap().as_array().unwrap();
    for pid in [1.0, 2.0] {
        let spans = events
            .iter()
            .filter(|e| e.get("pid").and_then(matcha::json::Json::as_f64) == Some(pid))
            .filter(|e| {
                matches!(
                    e.get("name").and_then(matcha::json::Json::as_str),
                    Some("compute") | Some("mix")
                )
            })
            .count();
        assert!(spans > 0, "daemon pid {pid} must contribute compute/mix spans");
    }
    // The aggregate snapshot is daemon-authoritative and exact: every
    // worker stepped every iteration, counted once.
    assert_eq!(
        remote.snapshot.counter(Counter::ShardSteps),
        loopback.snapshot.counter(Counter::ShardSteps),
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn telemetry_survives_reconnects_without_double_counting() {
    // Shard 0 drops its connection once mid-run. Daemon registries are
    // cumulative and the collector replaces (never adds) per pull, so
    // the aggregate must equal the drop-free loopback run's counters.
    let addrs = vec![
        spawn_daemon(DaemonOptions { drop_after: Some(7), ..DaemonOptions::default() }),
        spawn_daemon(DaemonOptions::default()),
    ];
    let dir = std::env::temp_dir().join("matcha_node_telemetry_reconnect");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("reconnect_trace.json");
    let remote = experiment::run(&traced_spec(addrs, &path)).unwrap();
    assert!(
        remote.snapshot.counter(Counter::Reconnects) >= 1,
        "the injected drop must surface as a reconnect"
    );
    let loopback = experiment::run(
        &base_spec().backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }),
    )
    .unwrap();
    assert_eq!(remote.final_mean, loopback.final_mean);
    assert_eq!(remote.final_states, loopback.final_states);
    assert_eq!(
        remote.snapshot.counter(Counter::ShardSteps),
        loopback.snapshot.counter(Counter::ShardSteps),
        "daemon step counts must not double-count across the reconnect"
    );
    assert_eq!(
        remote.snapshot.counter(Counter::ShardMsgsFolded),
        loopback.snapshot.counter(Counter::ShardMsgsFolded),
        "daemon fold counts must not double-count across the reconnect"
    );
    let check =
        matcha::trace::validate_chrome_trace(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(check.pids, 3);
    std::fs::remove_file(&path).ok();
}
