//! Row-major dense matrix.

use std::fmt;

/// A dense row-major `f64` matrix.
///
/// Sized for MATCHA's needs: graph Laplacians and mixing matrices with
/// `m ≤ 64` nodes, plus the simulator's `m × d` parameter blocks.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Matrix with every entry equal to `v`.
    pub fn full(rows: usize, cols: usize, v: f64) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// The averaging matrix `J = 11ᵀ/n`.
    pub fn averaging(n: usize) -> Self {
        Mat::full(n, n, 1.0 / n as f64)
    }

    /// Build from row slices (used heavily in tests).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        assert!(r > 0, "from_rows: empty");
        let c = rows[0].len();
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "from_rows: ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Build from a flat row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: size mismatch");
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Immutable access to the underlying row-major storage.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable access to the underlying row-major storage.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_assign_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] += v;
    }

    /// One row as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f64) -> Mat {
        let data = self.data.iter().map(|a| a * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `self += other * s` in place (axpy); avoids allocation in hot loops.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Matrix-matrix product (ikj loop order for cache friendliness).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul: inner dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        let n = other.cols;
        for i in 0..self.rows {
            let orow = i * n;
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let brow = k * n;
                for j in 0..n {
                    out.data[orow + j] += a * other.data[brow + j];
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec: dim mismatch");
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let mut acc = 0.0;
            let row = self.row(i);
            for j in 0..self.cols {
                acc += row[j] * x[j];
            }
            out[i] = acc;
        }
        out
    }

    /// Quadratic form `xᵀ A x`.
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        let ax = self.matvec(x);
        dot(x, &ax)
    }

    /// Frobenius norm.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0_f64, f64::max)
    }

    /// Is this matrix symmetric up to `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self.get(i, j) - self.get(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Is every row sum ≈ 1 and every column sum ≈ 1 (doubly stochastic,
    /// in the signed sense used by mixing matrices `I - αL`)?
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            let rs: f64 = self.row(i).iter().sum();
            if (rs - 1.0).abs() > tol {
                return false;
            }
        }
        for j in 0..self.cols {
            let cs: f64 = (0..self.rows).map(|i| self.get(i, j)).sum();
            if (cs - 1.0).abs() > tol {
                return false;
            }
        }
        true
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.get(i, i)).sum()
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            write!(f, "  ")?;
            for j in 0..self.cols {
                write!(f, "{:9.4} ", self.get(i, j))?;
            }
            writeln!(f)?;
        }
        write!(f, "]")
    }
}

/// Dot product of two equal-length slices.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn quad_form_matches_manual() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = [1.0, -1.0];
        // xᵀAx = 2 - 1 - 1 + 3 = 3
        assert!((a.quad_form(&x) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn averaging_matrix_is_doubly_stochastic() {
        let j = Mat::averaging(5);
        assert!(j.is_doubly_stochastic(1e-12));
        assert!(j.is_symmetric(1e-12));
    }

    #[test]
    fn axpy_matches_add_scale() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[0.5, -0.5], &[1.5, -2.0]]);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c, a.add(&b.scale(2.0)));
    }
}
