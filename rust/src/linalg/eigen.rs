//! Cyclic Jacobi eigensolver for real symmetric matrices.
//!
//! Classic two-sided Jacobi rotations applied in row-cyclic sweeps until
//! the off-diagonal Frobenius mass falls below a tolerance. Produces the
//! full spectrum and an orthonormal eigenbasis. For the m ≤ 64 matrices in
//! MATCHA's optimizers this converges in a handful of sweeps and is easily
//! fast enough to sit inside the projected-gradient loop.

use super::Mat;

/// Result of a symmetric eigendecomposition: `A = V diag(values) Vᵀ`.
///
/// `values` are sorted ascending; column `k` of `vectors` is the
/// eigenvector for `values[k]`.
pub struct EigenDecomposition {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors, stored as columns.
    pub vectors: Mat,
}

impl EigenDecomposition {
    /// Extract eigenvector `k` as an owned vector.
    pub fn vector(&self, k: usize) -> Vec<f64> {
        (0..self.vectors.rows()).map(|i| self.vectors.get(i, k)).collect()
    }
}

/// Off-diagonal Frobenius norm squared.
fn offdiag_sq(a: &Mat) -> f64 {
    let n = a.rows();
    let mut s = 0.0;
    for i in 0..n {
        for j in (i + 1)..n {
            let v = a.get(i, j);
            s += 2.0 * v * v;
        }
    }
    s
}

/// Full eigendecomposition of a symmetric matrix via cyclic Jacobi.
///
/// Panics if `a` is not square. Symmetry is assumed (the strictly lower
/// triangle is ignored in the rotations but kept consistent).
pub fn symmetric_eigen(a: &Mat) -> EigenDecomposition {
    assert_eq!(a.rows(), a.cols(), "symmetric_eigen: matrix must be square");
    let n = a.rows();
    if n == 0 {
        return EigenDecomposition { values: vec![], vectors: Mat::zeros(0, 0) };
    }
    if n == 1 {
        return EigenDecomposition { values: vec![a.get(0, 0)], vectors: Mat::eye(1) };
    }

    let mut m = a.clone();
    let mut v = Mat::eye(n);
    // Tolerance relative to the matrix scale; Laplacian entries are O(1)..O(m).
    let scale = m.frobenius_norm().max(1.0);
    let tol = (scale * 1e-14).powi(2);
    let max_sweeps = 64;

    for _sweep in 0..max_sweeps {
        if offdiag_sq(&m) <= tol {
            break;
        }
        for p in 0..n - 1 {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                // Rotation angle: standard stable formulation.
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Apply rotation G(p,q,θ): M ← GᵀMG. Hot loop — work on
                // raw storage (§Perf: ~1.7x over indexed get/set).
                {
                    let data = m.as_mut_slice();
                    // Columns p and q (stride-n walk).
                    let (mut ip, mut iq) = (p, q);
                    for _ in 0..n {
                        let mkp = data[ip];
                        let mkq = data[iq];
                        data[ip] = c * mkp - s * mkq;
                        data[iq] = s * mkp + c * mkq;
                        ip += n;
                        iq += n;
                    }
                    // Rows p and q (contiguous; p < q by loop structure).
                    let (head, tail) = data.split_at_mut(q * n);
                    let rp = &mut head[p * n..p * n + n];
                    let rq = &mut tail[..n];
                    for (xp, xq) in rp.iter_mut().zip(rq.iter_mut()) {
                        let vp = *xp;
                        let vq = *xq;
                        *xp = c * vp - s * vq;
                        *xq = s * vp + c * vq;
                    }
                }
                // Accumulate eigenvectors: V ← V·G (columns p, q).
                {
                    let vd = v.as_mut_slice();
                    let (mut ip, mut iq) = (p, q);
                    for _ in 0..n {
                        let vkp = vd[ip];
                        let vkq = vd[iq];
                        vd[ip] = c * vkp - s * vkq;
                        vd[iq] = s * vkp + c * vkq;
                        ip += n;
                        iq += n;
                    }
                }
            }
        }
    }

    // Collect and sort ascending, permuting eigenvector columns alongside.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m.get(i, i), i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|&(val, _)| val).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for i in 0..n {
            vectors.set(i, new_col, v.get(i, old_col));
        }
    }
    EigenDecomposition { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::dot;

    fn reconstruct(e: &EigenDecomposition) -> Mat {
        let n = e.values.len();
        let mut d = Mat::zeros(n, n);
        for i in 0..n {
            d.set(i, i, e.values[i]);
        }
        e.vectors.matmul(&d).matmul(&e.vectors.transpose())
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, -1.0]]);
        let e = symmetric_eigen(&a);
        assert!((e.values[0] + 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let e = symmetric_eigen(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-10);
        assert!((e.values[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn complete_graph_laplacian_spectrum() {
        // K_n Laplacian: eigenvalues {0, n, n, ..., n}.
        let n = 7;
        let mut a = Mat::full(n, n, -1.0);
        for i in 0..n {
            a.set(i, i, (n - 1) as f64);
        }
        let e = symmetric_eigen(&a);
        assert!(e.values[0].abs() < 1e-9);
        for k in 1..n {
            assert!((e.values[k] - n as f64).abs() < 1e-9, "values = {:?}", e.values);
        }
    }

    #[test]
    fn ring_laplacian_spectrum() {
        // Cycle C_n Laplacian eigenvalues: 2 - 2cos(2πk/n).
        let n = 8;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            a.set(i, i, 2.0);
            a.set(i, (i + 1) % n, -1.0);
            a.set((i + 1) % n, i, -1.0);
        }
        let e = symmetric_eigen(&a);
        let mut expected: Vec<f64> = (0..n)
            .map(|k| 2.0 - 2.0 * (2.0 * std::f64::consts::PI * k as f64 / n as f64).cos())
            .collect();
        expected.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for k in 0..n {
            assert!((e.values[k] - expected[k]).abs() < 1e-9);
        }
    }

    #[test]
    fn reconstruction_and_orthonormality() {
        // Deterministic pseudo-random symmetric matrix.
        let n = 12;
        let mut a = Mat::zeros(n, n);
        let mut state: u64 = 0x9e3779b97f4a7c15;
        for i in 0..n {
            for j in i..n {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let v = ((state >> 11) as f64 / (1u64 << 53) as f64) * 2.0 - 1.0;
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        let e = symmetric_eigen(&a);
        let rec = reconstruct(&e);
        assert!(rec.max_abs_diff(&a) < 1e-9, "reconstruction error");
        // VᵀV = I
        let vtv = e.vectors.transpose().matmul(&e.vectors);
        assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-9, "orthonormality");
        // Trace preserved.
        let eigsum: f64 = e.values.iter().sum();
        assert!((eigsum - a.trace()).abs() < 1e-9);
    }

    #[test]
    fn eigenvector_residuals() {
        let a = Mat::from_rows(&[
            &[4.0, 1.0, 0.5],
            &[1.0, 3.0, -0.25],
            &[0.5, -0.25, 1.0],
        ]);
        let e = symmetric_eigen(&a);
        for k in 0..3 {
            let v = e.vector(k);
            let av = a.matvec(&v);
            let mut r = 0.0;
            for i in 0..3 {
                r += (av[i] - e.values[k] * v[i]).powi(2);
            }
            assert!(r.sqrt() < 1e-9);
            assert!((dot(&v, &v) - 1.0).abs() < 1e-9);
        }
    }
}
