//! Dense linear algebra substrate.
//!
//! MATCHA's optimizers need symmetric eigendecompositions of graph
//! Laplacians (m ≤ ~64 nodes), spectral norms of mixing matrices, and
//! small-matrix arithmetic for the gossip simulator. We implement a
//! row-major dense [`Mat`] and a cyclic Jacobi eigensolver — no external
//! BLAS/LAPACK is available in this offline image, and the sizes involved
//! make O(m³) Jacobi entirely adequate.

mod dense;
mod eigen;

pub use dense::{dot, norm2, Mat};
pub use eigen::{symmetric_eigen, EigenDecomposition};

/// Largest absolute eigenvalue of a symmetric matrix (its spectral norm).
pub fn spectral_norm_symmetric(a: &Mat) -> f64 {
    let eig = symmetric_eigen(a);
    eig.values
        .iter()
        .fold(0.0_f64, |acc, &v| acc.max(v.abs()))
}

/// Second-smallest eigenvalue of a symmetric PSD matrix together with a
/// corresponding unit eigenvector (the Fiedler pair for a Laplacian).
///
/// Returns `(lambda_2, v_2)`. Eigenvalues are sorted ascending by
/// [`symmetric_eigen`], so this is simply index 1.
pub fn fiedler_pair(a: &Mat) -> (f64, Vec<f64>) {
    assert!(a.rows() >= 2, "fiedler_pair needs at least a 2x2 matrix");
    let eig = symmetric_eigen(a);
    (eig.values[1], eig.vector(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectral_norm_of_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.set(0, 0, -5.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, 4.0);
        assert!((spectral_norm_symmetric(&a) - 5.0).abs() < 1e-10);
    }

    #[test]
    fn fiedler_of_path_graph_laplacian() {
        // Path graph P3 Laplacian: eigenvalues 0, 1, 3.
        let a = Mat::from_rows(&[
            &[1.0, -1.0, 0.0],
            &[-1.0, 2.0, -1.0],
            &[0.0, -1.0, 1.0],
        ]);
        let (l2, v2) = fiedler_pair(&a);
        assert!((l2 - 1.0).abs() < 1e-9, "lambda2 = {l2}");
        // v2 must be a unit eigenvector: ||A v2 - l2 v2|| small.
        let av = a.matvec(&v2);
        let mut resid = 0.0;
        for i in 0..3 {
            resid += (av[i] - l2 * v2[i]).powi(2);
        }
        assert!(resid.sqrt() < 1e-8);
    }
}
