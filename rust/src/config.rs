//! Artifact metadata (`artifacts/meta.json`) — the contract between the
//! Python AOT path and the Rust coordinator — plus parameter
//! initialization implemented from that metadata (so the Rust binary is
//! self-contained after `make artifacts`).

use crate::json::Json;
use crate::rng::Rng;
use std::path::{Path, PathBuf};

/// One tensor in the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    /// "normal" | "ones" | "zeros"
    pub init: String,
    pub std: f64,
}

/// Parsed `meta.json`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub workers: usize,
    pub param_count: usize,
    pub params: Vec<ParamEntry>,
}

impl ModelMeta {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<ModelMeta, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let field = |k: &str| -> Result<usize, String> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("meta.json: missing/bad '{k}'"))
        };
        let params_json = j
            .get("params")
            .and_then(Json::as_array)
            .ok_or("meta.json: missing 'params'")?;
        let mut params = Vec::with_capacity(params_json.len());
        for (i, p) in params_json.iter().enumerate() {
            let name = p
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("param {i}: missing name"))?
                .to_string();
            let shape: Vec<usize> = p
                .get("shape")
                .and_then(Json::as_array)
                .ok_or_else(|| format!("param {name}: missing shape"))?
                .iter()
                .map(|s| s.as_usize().ok_or_else(|| format!("param {name}: bad shape")))
                .collect::<Result<_, _>>()?;
            let offset = p
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("param {name}: missing offset"))?;
            let size = p
                .get("size")
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("param {name}: missing size"))?;
            let init = p
                .get("init")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("param {name}: missing init"))?
                .to_string();
            let std = p.get("std").and_then(Json::as_f64).unwrap_or(0.0);
            let computed: usize = shape.iter().product();
            if computed != size {
                return Err(format!("param {name}: size {size} != shape product {computed}"));
            }
            params.push(ParamEntry { name, shape, offset, size, init, std });
        }
        let meta = ModelMeta {
            preset: j
                .get("preset")
                .and_then(Json::as_str)
                .unwrap_or("custom")
                .to_string(),
            vocab: field("vocab")?,
            d_model: field("d_model")?,
            n_heads: field("n_heads")?,
            n_layers: field("n_layers")?,
            seq_len: field("seq_len")?,
            batch: field("batch")?,
            workers: field("workers")?,
            param_count: field("param_count")?,
            params,
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Load from `artifacts/meta.json`.
    pub fn load(path: &Path) -> Result<ModelMeta, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e} (run `make artifacts` first?)", path.display()))?;
        Self::parse(&text)
    }

    /// Layout invariants (mirrors python/tests/test_aot.py).
    pub fn validate(&self) -> Result<(), String> {
        let mut offset = 0;
        for p in &self.params {
            if p.offset != offset {
                return Err(format!("param {}: offset {} != expected {offset}", p.name, p.offset));
            }
            offset += p.size;
        }
        if offset != self.param_count {
            return Err(format!("param_count {} != layout total {offset}", self.param_count));
        }
        if self.workers == 0 || self.batch == 0 || self.seq_len == 0 {
            return Err("degenerate meta fields".into());
        }
        Ok(())
    }

    /// Initialize a flat parameter vector per the metadata (normal
    /// entries scaled by their std; ones/zeros exact). Statistically
    /// equivalent to `model.init_params`, not bit-identical — all
    /// convergence claims tolerate that (and tests check the statistics).
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.param_count];
        for p in &self.params {
            let dst = &mut flat[p.offset..p.offset + p.size];
            match p.init.as_str() {
                "ones" => dst.iter_mut().for_each(|v| *v = 1.0),
                "zeros" => {}
                _ => dst
                    .iter_mut()
                    .for_each(|v| *v = (rng.normal() * p.std) as f32),
            }
        }
        flat
    }
}

/// Standard artifact locations rooted at a directory.
#[derive(Clone, Debug)]
pub struct ArtifactPaths {
    pub dir: PathBuf,
}

impl ArtifactPaths {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        ArtifactPaths { dir: dir.into() }
    }

    pub fn meta(&self) -> PathBuf {
        self.dir.join("meta.json")
    }

    /// Train step; `pallas = true` selects the Pallas-kernel lowering,
    /// otherwise the XLA-fused fast path.
    pub fn train_step(&self, pallas: bool) -> PathBuf {
        self.dir.join(if pallas { "train_step.hlo.txt" } else { "train_step_fused.hlo.txt" })
    }

    pub fn eval_step(&self) -> PathBuf {
        self.dir.join("eval_step.hlo.txt")
    }

    /// Gossip mix; `pallas = true` selects the Pallas-kernel lowering,
    /// otherwise the XLA-fused fast path (§Perf: on CPU the interpret
    /// grid loop makes the Pallas variant ~40x slower).
    pub fn mix(&self, pallas: bool) -> PathBuf {
        self.dir.join(if pallas { "mix.hlo.txt" } else { "mix_fused.hlo.txt" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta() -> String {
        r#"{
          "preset": "tiny", "vocab": 64, "d_model": 8, "n_heads": 2,
          "n_layers": 1, "seq_len": 4, "batch": 2, "workers": 3,
          "param_count": 20,
          "params": [
            {"name": "a", "shape": [2, 4], "offset": 0, "size": 8, "init": "normal", "std": 0.5},
            {"name": "b", "shape": [8], "offset": 8, "size": 8, "init": "ones", "std": 0},
            {"name": "c", "shape": [4], "offset": 16, "size": 4, "init": "zeros", "std": 0}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn parse_valid_meta() {
        let m = ModelMeta::parse(&sample_meta()).unwrap();
        assert_eq!(m.workers, 3);
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.params[1].init, "ones");
    }

    #[test]
    fn reject_gap_in_layout() {
        let bad = sample_meta().replace("\"offset\": 8", "\"offset\": 9");
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn reject_size_shape_mismatch() {
        let bad = sample_meta().replace("\"size\": 4", "\"size\": 5");
        assert!(ModelMeta::parse(&bad).is_err());
    }

    #[test]
    fn init_respects_kinds() {
        let m = ModelMeta::parse(&sample_meta()).unwrap();
        let mut rng = Rng::new(5);
        let flat = m.init_params(&mut rng);
        assert_eq!(flat.len(), 20);
        // "ones" block
        assert!(flat[8..16].iter().all(|&v| v == 1.0));
        // "zeros" block
        assert!(flat[16..20].iter().all(|&v| v == 0.0));
        // normal block: nonzero, roughly std 0.5
        let std: f64 =
            (flat[0..8].iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 8.0).sqrt();
        assert!(std > 0.1 && std < 1.2, "std = {std}");
    }

    #[test]
    fn artifact_paths() {
        let p = ArtifactPaths::new("/tmp/a");
        assert!(p.train_step(true).ends_with("train_step.hlo.txt"));
        assert!(p.train_step(false).ends_with("train_step_fused.hlo.txt"));
        assert!(p.mix(true).ends_with("mix.hlo.txt"));
        assert!(p.mix(false).ends_with("mix_fused.hlo.txt"));
    }

    #[test]
    fn real_artifact_meta_parses_if_present() {
        // Integration hook: when `make artifacts` has run, validate the
        // real contract end to end.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/meta.json");
        if path.exists() {
            let m = ModelMeta::load(&path).unwrap();
            assert_eq!(m.vocab, crate::data::VOCAB);
            assert!(m.param_count > 0);
        }
    }
}
