//! Communication delay models (paper §2, "Convergence in terms of Error
//! Versus Wallclock Time").
//!
//! The paper's model: links at one node are serialized, node-disjoint
//! links run in parallel, and sending+receiving over one link costs one
//! unit of time. With the matching decomposition, one iteration's
//! communication therefore costs **one unit per activated matching**
//! ([`DelayModel::UnitPerMatching`]). Without decomposition, the busiest
//! node serializes its Δ links ([`DelayModel::MaxDegree`]). §3 sketches
//! an extension where each link's time is a random variable — modelled by
//! [`DelayModel::StochasticLink`].

use crate::graph::Graph;
use crate::rng::Rng;

/// How communication time per iteration is computed from the activated
/// matchings.
#[derive(Clone, Debug)]
pub enum DelayModel {
    /// One unit per activated matching (the paper's model once the graph
    /// is matching-decomposed; matchings communicate sequentially, links
    /// inside a matching in parallel).
    UnitPerMatching,
    /// Maximal node degree of the activated topology — the cost of a
    /// naive (non-decomposed) implementation where each node serializes
    /// its own links. Used to quantify what the decomposition itself buys.
    MaxDegree,
    /// Each activated matching's time is the max over its links of an
    /// i.i.d. uniform link time in `[min_units, max_units]` (still
    /// sequential across matchings). Extension from §3.
    StochasticLink { min_units: f64, max_units: f64 },
}

impl DelayModel {
    /// Parse from a CLI string: `unit`, `maxdeg`, `stochastic:lo:hi`.
    ///
    /// Never panics: every malformed form (`stochastic`, `stochastic:0.5`,
    /// trailing fields, non-numeric bounds, inverted/negative ranges, the
    /// empty string) returns `Err` with a usage hint.
    pub fn parse(s: &str) -> Result<DelayModel, String> {
        const USAGE: &str = "expected unit | maxdeg | stochastic:lo:hi";
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "unit" if parts.len() == 1 => Ok(DelayModel::UnitPerMatching),
            "maxdeg" if parts.len() == 1 => Ok(DelayModel::MaxDegree),
            "unit" | "maxdeg" => {
                Err(format!("delay model '{s}': '{}' takes no arguments ({USAGE})", parts[0]))
            }
            "stochastic" => {
                if parts.len() != 3 {
                    return Err(format!(
                        "delay model '{s}': stochastic needs exactly two bounds ({USAGE})"
                    ));
                }
                let lo = parts[1]
                    .parse::<f64>()
                    .map_err(|e| format!("delay model '{s}': bad lower bound: {e} ({USAGE})"))?;
                let hi = parts[2]
                    .parse::<f64>()
                    .map_err(|e| format!("delay model '{s}': bad upper bound: {e} ({USAGE})"))?;
                if !lo.is_finite() || !hi.is_finite() || lo < 0.0 || hi < lo {
                    return Err(format!("delay model '{s}': bad stochastic bounds [{lo},{hi}]"));
                }
                Ok(DelayModel::StochasticLink { min_units: lo, max_units: hi })
            }
            other => Err(format!("unknown delay model '{other}' ({USAGE})")),
        }
    }

    /// Communication time of one iteration, given the activated matchings.
    ///
    /// `rng` is consulted only by the stochastic model.
    pub fn comm_time(
        &self,
        matchings: &[Graph],
        activated: &[usize],
        rng: &mut Rng,
    ) -> f64 {
        match self {
            DelayModel::UnitPerMatching => activated.len() as f64,
            DelayModel::MaxDegree => {
                if activated.is_empty() {
                    return 0.0;
                }
                let m = matchings[0].num_nodes();
                let mut deg = vec![0usize; m];
                for &j in activated {
                    for &(u, v) in matchings[j].edges() {
                        deg[u] += 1;
                        deg[v] += 1;
                    }
                }
                deg.into_iter().max().unwrap_or(0) as f64
            }
            DelayModel::StochasticLink { min_units, max_units } => activated
                .iter()
                .map(|&j| {
                    matchings[j]
                        .edges()
                        .iter()
                        .map(|_| rng.uniform_in(*min_units, *max_units))
                        .fold(0.0_f64, f64::max)
                })
                .sum(),
        }
    }
}

/// Aggregate runtime accounting for a training run under a delay model:
/// iteration time = computation time + communication time (paper §2:
/// "total training time is a product of total iterations and run time
/// per iteration").
#[derive(Clone, Debug)]
pub struct VirtualClock {
    /// Computation time per local SGD step, in the same units as link
    /// time (the paper's plots set this implicitly via the measured
    /// per-iteration computation).
    pub compute_units_per_step: f64,
    elapsed: f64,
}

impl VirtualClock {
    pub fn new(compute_units_per_step: f64) -> Self {
        VirtualClock { compute_units_per_step, elapsed: 0.0 }
    }

    /// Advance the clock by one iteration with the given communication
    /// time; returns the new elapsed total.
    pub fn tick(&mut self, comm_time: f64) -> f64 {
        self.elapsed += self.compute_units_per_step + comm_time;
        self.elapsed
    }

    /// Advance by an arbitrary duration; returns the new elapsed total.
    /// Used by the event-driven engine, where an iteration's compute
    /// phase is the *maximum* over per-worker durations (stragglers!)
    /// rather than the fixed `compute_units_per_step`. Calling
    /// `advance(compute + comm)` is bit-identical to `tick(comm)` when
    /// `compute == compute_units_per_step`.
    pub fn advance(&mut self, duration: f64) -> f64 {
        self.elapsed += duration;
        self.elapsed
    }

    pub fn elapsed(&self) -> f64 {
        self.elapsed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;

    #[test]
    fn unit_model_counts_matchings() {
        let d = decompose(&paper_figure1_graph());
        let mut rng = Rng::new(0);
        let m = DelayModel::UnitPerMatching;
        assert_eq!(m.comm_time(&d.matchings, &[0, 2], &mut rng), 2.0);
        assert_eq!(m.comm_time(&d.matchings, &[], &mut rng), 0.0);
        let all: Vec<usize> = (0..d.len()).collect();
        assert_eq!(m.comm_time(&d.matchings, &all, &mut rng), d.len() as f64);
    }

    #[test]
    fn maxdeg_model_on_full_activation_equals_base_delta() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let mut rng = Rng::new(0);
        let all: Vec<usize> = (0..d.len()).collect();
        let t = DelayModel::MaxDegree.comm_time(&d.matchings, &all, &mut rng);
        assert_eq!(t, g.max_degree() as f64);
    }

    #[test]
    fn unit_vs_maxdeg_bound() {
        // Unit-per-matching never beats Δ by more than the Vizing slack:
        // M ≤ Δ+1, and for single activations it is ≤ the naive cost.
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let mut rng = Rng::new(0);
        let all: Vec<usize> = (0..d.len()).collect();
        let unit = DelayModel::UnitPerMatching.comm_time(&d.matchings, &all, &mut rng);
        assert!(unit <= (g.max_degree() + 1) as f64);
    }

    #[test]
    fn stochastic_model_within_bounds() {
        let d = decompose(&paper_figure1_graph());
        let mut rng = Rng::new(8);
        let m = DelayModel::StochasticLink { min_units: 0.5, max_units: 2.0 };
        for _ in 0..100 {
            let t = m.comm_time(&d.matchings, &[0, 1], &mut rng);
            assert!(t >= 1.0 - 1e-9 && t <= 4.0 + 1e-9, "t = {t}");
        }
    }

    #[test]
    fn parse_delay_models() {
        assert!(matches!(DelayModel::parse("unit"), Ok(DelayModel::UnitPerMatching)));
        assert!(matches!(DelayModel::parse("maxdeg"), Ok(DelayModel::MaxDegree)));
        assert!(matches!(
            DelayModel::parse("stochastic:0.5:1.5"),
            Ok(DelayModel::StochasticLink { .. })
        ));
        assert!(matches!(
            DelayModel::parse("stochastic:0:0"),
            Ok(DelayModel::StochasticLink { .. })
        ));
    }

    #[test]
    fn parse_rejects_every_malformed_form_without_panicking() {
        for bad in [
            "",
            "bogus",
            "stochastic",          // missing both bounds (would index parts[1])
            "stochastic:0.5",      // missing upper bound (would index parts[2])
            "stochastic:0.5:1:2",  // trailing field
            "stochastic:a:1",      // non-numeric lower
            "stochastic:0:b",      // non-numeric upper
            "stochastic::",        // empty bounds
            "stochastic:2:1",      // inverted range
            "stochastic:-1:1",     // negative lower
            "stochastic:nan:1",    // non-finite lower
            "stochastic:0:inf",    // non-finite upper
            "unit:1",              // arguments on an argument-free model
            "maxdeg:x",
        ] {
            let r = DelayModel::parse(bad);
            assert!(r.is_err(), "'{bad}' should be rejected");
            let msg = r.unwrap_err();
            assert!(
                msg.contains("unit | maxdeg | stochastic:lo:hi") || msg.contains("bounds"),
                "error for '{bad}' should carry a usage hint: {msg}"
            );
        }
    }

    #[test]
    fn virtual_clock_accumulates() {
        let mut c = VirtualClock::new(1.0);
        assert_eq!(c.tick(2.0), 3.0);
        assert_eq!(c.tick(0.0), 4.0);
        assert_eq!(c.elapsed(), 4.0);
    }

    #[test]
    fn advance_matches_tick_for_constant_compute() {
        let mut a = VirtualClock::new(0.7);
        let mut b = VirtualClock::new(0.7);
        for comm in [0.0, 1.3, 2.9, 0.1] {
            assert_eq!(a.tick(comm), b.advance(0.7 + comm));
        }
    }
}
