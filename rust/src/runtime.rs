//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the crate touches XLA. `python/compile/aot.py`
//! lowers the L2 model once to HLO *text* (xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos; the text parser reassigns ids);
//! here we parse, compile for the CPU PJRT client, and expose typed
//! execute helpers plus flat-`Vec<f32>` marshalling for the coordinator's
//! hot path.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus a place to compile executables from.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled computation (train step / eval step / mix).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this image).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl Executable {
    /// Execute with literal inputs; returns the elements of the result
    /// tuple (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple().context("untupling result")
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

// --- literal marshalling -------------------------------------------------

/// Flat `&[f32]` -> literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {:?} != len {}", dims, data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Flat `&[i32]` -> literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {:?} != len {}", dims, data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal -> owned `Vec<f32>`.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Literal -> single f32 (for scalar losses).
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/ (they require
    // `make artifacts` to have run). Here: marshalling only.

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = literal_scalar_f32(3.5);
        assert_eq!(to_scalar_f32(&lit).unwrap(), 3.5);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, 2, 3, 4];
        let lit = literal_i32(&data, &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }
}
