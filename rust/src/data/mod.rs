//! Synthetic text corpus and batching for the NN training path.
//!
//! The paper trains on CIFAR/PTB; neither is available offline, so we
//! synthesize a character-level corpus from a seeded order-2 Markov chain
//! over a small alphabet (structured enough that a language model's loss
//! drops well below the uniform entropy, so loss curves are informative).
//! The corpus is partitioned evenly across workers — IID by default, or
//! per-worker chain temperature for the non-IID regime — matching the
//! paper's "training datasets are evenly partitioned over a network of
//! workers".

use crate::rng::Rng;

/// Vocabulary size for the synthetic corpus (fits in a byte; matches the
/// model's `vocab` in `python/compile/model.py` metadata).
pub const VOCAB: usize = 64;

/// A tokenized corpus shard for one worker.
#[derive(Clone, Debug)]
pub struct Shard {
    pub tokens: Vec<u8>,
}

/// Synthetic corpus: per-worker shards plus a held-out eval stream.
pub struct Corpus {
    pub shards: Vec<Shard>,
    pub eval: Vec<u8>,
}

/// Order-2 Markov chain over `VOCAB` symbols with a sparse, seeded
/// transition structure. `temperature` in (0,1]: lower = more
/// deterministic (lower entropy) text.
pub struct MarkovSource {
    /// For each (prev2, prev1) pair: candidate next symbols.
    table: Vec<[u8; 4]>,
    temperature: f64,
}

impl MarkovSource {
    pub fn new(seed: u64, temperature: f64) -> Self {
        assert!(temperature > 0.0 && temperature <= 1.0);
        let mut rng = Rng::new(seed);
        let table = (0..VOCAB * VOCAB)
            .map(|_| {
                [
                    rng.below(VOCAB) as u8,
                    rng.below(VOCAB) as u8,
                    rng.below(VOCAB) as u8,
                    rng.below(VOCAB) as u8,
                ]
            })
            .collect();
        MarkovSource { table, temperature }
    }

    /// Generate `n` tokens.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<u8> {
        let mut out = Vec::with_capacity(n);
        let mut p2 = rng.below(VOCAB);
        let mut p1 = rng.below(VOCAB);
        for _ in 0..n {
            let cands = &self.table[p2 * VOCAB + p1];
            // With prob (1 - temperature) take the first (modal) choice,
            // else sample among the four candidates; small uniform
            // smoothing keeps every symbol reachable.
            let next = if rng.uniform() < 0.02 {
                rng.below(VOCAB) as u8
            } else if rng.uniform() >= self.temperature {
                cands[0]
            } else {
                cands[rng.below(4)]
            };
            out.push(next);
            p2 = p1;
            p1 = next as usize;
        }
        out
    }
}

impl Corpus {
    /// Build a corpus of `tokens_per_worker` tokens per shard for `m`
    /// workers plus `eval_tokens` held-out tokens.
    ///
    /// `non_iid = false`: all shards from one chain. `true`: each worker
    /// gets its own chain temperature (local distributions differ, the
    /// paper's federated-flavored regime).
    pub fn synthesize(
        m: usize,
        tokens_per_worker: usize,
        eval_tokens: usize,
        non_iid: bool,
        seed: u64,
    ) -> Corpus {
        let mut rng = Rng::new(seed);
        let base = MarkovSource::new(seed ^ 0x5eed, 0.6);
        let mut shards = Vec::with_capacity(m);
        for w in 0..m {
            let mut wrng = rng.split();
            let tokens = if non_iid {
                let temp = 0.3 + 0.6 * (w as f64 / m.max(1) as f64);
                let src = MarkovSource::new(seed ^ (w as u64), temp);
                src.generate(tokens_per_worker, &mut wrng)
            } else {
                base.generate(tokens_per_worker, &mut wrng)
            };
            shards.push(Shard { tokens });
        }
        let mut erng = rng.split();
        let eval = base.generate(eval_tokens, &mut erng);
        Corpus { shards, eval }
    }
}

/// Iterator yielding `(inputs, targets)` next-token batches from a shard:
/// each of `batch` rows is `seq_len` consecutive tokens; targets are the
/// same window shifted by one.
pub struct BatchIter<'a> {
    tokens: &'a [u8],
    batch: usize,
    seq_len: usize,
    rng: Rng,
}

impl<'a> BatchIter<'a> {
    pub fn new(tokens: &'a [u8], batch: usize, seq_len: usize, seed: u64) -> Self {
        assert!(
            tokens.len() > seq_len + 1,
            "shard too small: {} tokens for seq_len {}",
            tokens.len(),
            seq_len
        );
        BatchIter { tokens, batch, seq_len, rng: Rng::new(seed) }
    }

    /// Next batch as flat row-major `batch × seq_len` token ids.
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(self.batch * self.seq_len);
        let mut ys = Vec::with_capacity(self.batch * self.seq_len);
        let max_start = self.tokens.len() - self.seq_len - 1;
        for _ in 0..self.batch {
            let s = self.rng.below(max_start + 1);
            for t in 0..self.seq_len {
                xs.push(self.tokens[s + t] as i32);
                ys.push(self.tokens[s + t + 1] as i32);
            }
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes() {
        let c = Corpus::synthesize(4, 1000, 500, false, 1);
        assert_eq!(c.shards.len(), 4);
        for s in &c.shards {
            assert_eq!(s.tokens.len(), 1000);
            assert!(s.tokens.iter().all(|&t| (t as usize) < VOCAB));
        }
        assert_eq!(c.eval.len(), 500);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Corpus::synthesize(2, 200, 100, false, 7);
        let b = Corpus::synthesize(2, 200, 100, false, 7);
        assert_eq!(a.shards[0].tokens, b.shards[0].tokens);
        assert_eq!(a.eval, b.eval);
    }

    #[test]
    fn markov_text_has_structure() {
        // Bigram entropy of chain text must be clearly below uniform:
        // a learnable signal for the LM.
        let src = MarkovSource::new(3, 0.5);
        let mut rng = Rng::new(4);
        let text = src.generate(400_000, &mut rng);
        // The chain is order-2: measure H(next | prev2, prev1) with a
        // trigram table (a bigram table would mix contexts and look
        // near-uniform by design).
        let mut counts = std::collections::HashMap::<(u8, u8, u8), f64>::new();
        let mut ctx = std::collections::HashMap::<(u8, u8), f64>::new();
        for w in text.windows(3) {
            *counts.entry((w[0], w[1], w[2])).or_default() += 1.0;
            *ctx.entry((w[0], w[1])).or_default() += 1.0;
        }
        let total: f64 = counts.values().sum();
        let mut h = 0.0;
        for (&(a, b, _), &c) in &counts {
            let j = c / total;
            let cond = c / ctx[&(a, b)];
            h -= j * cond.ln();
        }
        let uniform = (VOCAB as f64).ln();
        assert!(
            h < 0.8 * uniform,
            "conditional entropy {h:.3} vs uniform {uniform:.3}: no structure"
        );
    }

    #[test]
    fn non_iid_shards_differ_in_statistics() {
        let c = Corpus::synthesize(4, 20_000, 10, true, 9);
        // Unigram distributions of first and last shards should differ
        // noticeably (different chains).
        let hist = |tokens: &[u8]| {
            let mut h = vec![0f64; VOCAB];
            for &t in tokens {
                h[t as usize] += 1.0 / tokens.len() as f64;
            }
            h
        };
        let h0 = hist(&c.shards[0].tokens);
        let h3 = hist(&c.shards[3].tokens);
        let tv: f64 = h0.iter().zip(&h3).map(|(a, b)| (a - b).abs()).sum::<f64>() / 2.0;
        assert!(tv > 0.05, "total variation {tv} too small for non-IID");
    }

    #[test]
    fn batches_are_shifted_windows() {
        let c = Corpus::synthesize(1, 500, 10, false, 11);
        let mut it = BatchIter::new(&c.shards[0].tokens, 3, 8, 0);
        let (xs, ys) = it.next_batch();
        assert_eq!(xs.len(), 24);
        assert_eq!(ys.len(), 24);
        // Within each row, y[t] must equal x[t+1].
        for row in 0..3 {
            for t in 0..7 {
                assert_eq!(ys[row * 8 + t], xs[row * 8 + t + 1]);
            }
        }
    }
}
