//! Self-contained run reports: the `matcha report` renderer.
//!
//! A [`RunReport`] bundles a run's identity (spec name, backend,
//! strategy, planned α/ρ) with its headline outcome and the full
//! [`ObservatorySnapshot`], serializes to one JSON document, and
//! renders a human-readable summary — the activation ledger, the
//! contraction windows, the error-runtime frontier (paper fig-4 axes)
//! and the straggler/staleness audit — so a single file answers "did
//! this run do what the plan designed?". `matcha report --spec F` runs
//! an experiment and writes/renders the report; `matcha report R.json`
//! re-renders a saved one. The renderer is total: runs too short to
//! close a contraction window (or with no stochastic matchings) still
//! produce a complete report.

use super::observatory::ObservatorySnapshot;
use crate::json::Json;

/// Schema version stamped into every report JSON.
pub const REPORT_VERSION: u64 = 1;

/// Everything `matcha report` persists and renders for one run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunReport {
    /// Spec name (the graph/topology identifier).
    pub spec_name: String,
    /// Backend label (spec JSON form, e.g. `"engine-parallel"`).
    pub backend: String,
    /// Strategy label (e.g. `"matcha(0.5)"`).
    pub strategy: String,
    /// Planned mixing parameter α.
    pub alpha: f64,
    /// Planned spectral norm ρ (predicted contraction per round).
    pub rho: f64,
    /// Final recorded loss.
    pub final_loss: f64,
    /// Total virtual time of the run.
    pub total_time: f64,
    /// Total expected communication units of the run.
    pub total_comm: f64,
    /// The algorithm-level readout.
    pub observatory: ObservatorySnapshot,
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("report: missing '{key}'"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64, String> {
    req(j, key)?.as_f64().ok_or_else(|| format!("report: '{key}' must be a number"))
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| format!("report: '{key}' must be a string"))?
        .to_string())
}

impl RunReport {
    /// The self-contained JSON document `matcha report` writes.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("report_version", Json::Num(REPORT_VERSION as f64)),
            ("spec", Json::Str(self.spec_name.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("strategy", Json::Str(self.strategy.clone())),
            ("alpha", Json::Num(self.alpha)),
            ("rho", Json::Num(self.rho)),
            ("final_loss", Json::Num(self.final_loss)),
            ("total_time", Json::Num(self.total_time)),
            ("total_comm", Json::Num(self.total_comm)),
            ("observatory", self.observatory.to_json()),
        ])
    }

    /// Parse a saved report document (what `matcha report R.json`
    /// re-renders from).
    pub fn from_json(j: &Json) -> Result<RunReport, String> {
        let version = req_f64(j, "report_version")? as u64;
        if version != REPORT_VERSION {
            return Err(format!(
                "report: unsupported report_version {version} (expected {REPORT_VERSION})"
            ));
        }
        Ok(RunReport {
            spec_name: req_str(j, "spec")?,
            backend: req_str(j, "backend")?,
            strategy: req_str(j, "strategy")?,
            alpha: req_f64(j, "alpha")?,
            rho: req_f64(j, "rho")?,
            final_loss: req_f64(j, "final_loss")?,
            total_time: req_f64(j, "total_time")?,
            total_comm: req_f64(j, "total_comm")?,
            observatory: ObservatorySnapshot::from_json(req(j, "observatory")?)?,
        })
    }

    /// The human-readable summary (header, ledger table, contraction
    /// windows, frontier table, straggler/staleness audit).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let obs = &self.observatory;
        out.push_str(&format!("== matcha run report: {} ==\n", self.spec_name));
        out.push_str(&format!(
            "backend {} | strategy {} | alpha {:.4} | predicted rho {:.4}\n",
            self.backend, self.strategy, self.alpha, self.rho
        ));
        out.push_str(&format!(
            "final loss {:.6e} | virtual time {:.2} | comm units {:.2} | rounds {}\n",
            self.final_loss, self.total_time, self.total_comm, obs.rounds
        ));

        let l = &obs.ledger;
        out.push_str(&format!(
            "\n-- activation ledger (drift score {:.3}, L1 {:.4}, {}) --\n",
            l.drift_score,
            l.drift_l1,
            if l.drifted { "DRIFTED" } else { "ok" }
        ));
        out.push_str("matching  designed  realized  realized-freq\n");
        let n = obs.rounds.max(1) as f64;
        for (j, (&p, &c)) in l.designed.iter().zip(&l.realized).enumerate() {
            out.push_str(&format!("{j:>8}  {p:>8.4}  {c:>8}  {:>13.4}\n", c as f64 / n));
        }
        if l.links.is_empty() {
            out.push_str("links: none tracked\n");
        } else if l.links.len() <= 24 {
            out.push_str("matching  link         count\n");
            for lc in &l.links {
                let edge = format!("({},{})", lc.u, lc.v);
                out.push_str(&format!("{:>8}  {edge:<11}  {:>5}\n", lc.matching, lc.count));
            }
        } else {
            let min = l.links.iter().map(|lc| lc.count).min().unwrap_or(0);
            let max = l.links.iter().map(|lc| lc.count).max().unwrap_or(0);
            out.push_str(&format!(
                "links: {} tracked, activation counts {min}..{max}\n",
                l.links.len()
            ));
        }

        out.push_str(&format!("\n-- contraction windows (predicted rho {:.4}) --\n", self.rho));
        if obs.windows.is_empty() {
            out.push_str("(no window closed: not enough record samples)\n");
        } else {
            out.push_str("window  k-range      consensus start -> end     rate    verdict\n");
            for w in &obs.windows {
                let range = format!("{}..{}", w.k_start, w.k_end);
                out.push_str(&format!(
                    "{:>6}  {range:<11}  {:>11.4e} -> {:>10.4e}  {:>6.4}  {}\n",
                    w.index,
                    w.consensus_start,
                    w.consensus_end,
                    w.rate,
                    if w.slower { "SLOWER" } else { "ok" }
                ));
            }
        }

        out.push_str("\n-- error-runtime frontier --\n");
        if obs.frontier.is_empty() {
            out.push_str("(no record samples)\n");
        } else {
            out.push_str("     k        time        comm          loss     consensus\n");
            let len = obs.frontier.len();
            let step = len.div_ceil(16).max(1);
            let mut shown = 0usize;
            for (i, p) in obs.frontier.iter().enumerate() {
                if i % step != 0 && i != len - 1 {
                    continue;
                }
                shown += 1;
                out.push_str(&format!(
                    "{:>6}  {:>10.2}  {:>10.2}  {:>12.4e}  {:>12.4e}\n",
                    p.k, p.time, p.comm, p.loss, p.consensus
                ));
            }
            if shown < len {
                out.push_str(&format!("({} of {len} samples shown)\n", shown));
            }
        }

        out.push_str("\n-- straggler audit --\n");
        out.push_str("worker  spans      mean       p95\n");
        for c in &obs.audit.compute {
            out.push_str(&format!(
                "{:>6}  {:>5}  {:>8.3}  {:>8.3}\n",
                c.worker, c.count, c.mean, c.p95
            ));
        }
        out.push_str(&format!("compute p95 skew: {:.3}\n", obs.audit.compute_p95_skew));
        if obs.audit.staleness.is_empty() {
            out.push_str("staleness: none recorded (synchronous run)\n");
        } else {
            out.push_str("edge         exchanges      mean       max\n");
            for s in &obs.audit.staleness {
                let edge = format!("({},{})", s.u, s.v);
                out.push_str(&format!(
                    "{edge:<11}  {:>9}  {:>8.3}  {:>8.3}\n",
                    s.count, s.mean, s.max
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::observatory::{
        ActivationLedger, ComputeAudit, FrontierPoint, LinkCount, RunAudit, StalenessAudit,
        WindowStats,
    };
    use super::*;

    fn sample_report() -> RunReport {
        RunReport {
            spec_name: "ring:8".to_string(),
            backend: "engine-sequential".to_string(),
            strategy: "matcha(0.5)".to_string(),
            alpha: 0.41,
            rho: 0.87,
            final_loss: 1.25e-3,
            total_time: 60.0,
            total_comm: 140.0,
            observatory: ObservatorySnapshot {
                rounds: 60,
                ledger: ActivationLedger {
                    designed: vec![0.6, 0.4],
                    realized: vec![35, 26],
                    links: vec![
                        LinkCount { matching: 0, u: 0, v: 1, count: 35 },
                        LinkCount { matching: 1, u: 1, v: 2, count: 26 },
                    ],
                    drift_score: 0.2,
                    drift_l1: 0.01,
                    drifted: false,
                },
                windows: vec![WindowStats {
                    index: 0,
                    k_start: 0,
                    k_end: 30,
                    consensus_start: 0.5,
                    consensus_end: 0.05,
                    rate: 0.926,
                    predicted_rho: 0.87,
                    slower: true,
                    drift_score: 0.2,
                    rounds: 31,
                }],
                frontier: vec![
                    FrontierPoint { k: 0, time: 0.0, comm: 0.0, loss: 2.0, consensus: 0.0 },
                    FrontierPoint { k: 30, time: 30.0, comm: 70.0, loss: 0.5, consensus: 0.5 },
                    FrontierPoint {
                        k: 60,
                        time: 60.0,
                        comm: 140.0,
                        loss: 1.25e-3,
                        consensus: 0.05,
                    },
                ],
                audit: RunAudit {
                    compute: vec![
                        ComputeAudit { worker: 0, count: 60, mean: 1.0, p95: 1.0 },
                        ComputeAudit { worker: 1, count: 60, mean: 1.5, p95: 2.0 },
                    ],
                    compute_p95_skew: 2.0,
                    staleness: vec![StalenessAudit { u: 0, v: 1, count: 12, mean: 0.5, max: 2.0 }],
                },
            },
        }
    }

    #[test]
    fn report_json_roundtrips() {
        let report = sample_report();
        let text = report.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, report);
    }

    #[test]
    fn render_includes_every_section() {
        let text = sample_report().render();
        for needle in [
            "matcha run report: ring:8",
            "activation ledger",
            "contraction windows",
            "SLOWER",
            "error-runtime frontier",
            "straggler audit",
            "compute p95 skew: 2.000",
            "exchanges",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn render_tolerates_empty_observatory() {
        let mut report = sample_report();
        report.observatory = ObservatorySnapshot::default();
        let text = report.render();
        assert!(text.contains("no window closed"), "{text}");
        assert!(text.contains("no record samples"), "{text}");
        assert!(text.contains("staleness: none recorded"), "{text}");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut j = sample_report().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("report_version".to_string(), Json::Num(99.0));
        }
        let err = RunReport::from_json(&j).unwrap_err();
        assert!(err.contains("unsupported report_version"), "{err}");
    }
}
