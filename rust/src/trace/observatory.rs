//! The convergence observatory: algorithm-level telemetry for a run.
//!
//! The trace layer observes the *system* (spans, counters, bytes); this
//! module observes the *algorithm*. MATCHA's claim is an error-runtime
//! trade-off — activate critical matchings with designed probabilities
//! `p_j`, get a spectral contraction ρ < 1, reach a target loss sooner —
//! and the observatory measures whether a run actually delivers it:
//!
//! - **Activation ledger** — per-matching and per-link realized
//!   activation counts against the plan's designed `p_j`, with a
//!   chi-square-style drift score (paper §3: the sampler must realize
//!   the optimized Bernoulli frequencies for Theorem 2 to apply).
//! - **Contraction tracker** — the consensus-distance decay rate
//!   estimated online over tumbling windows of record samples and
//!   compared against the plan's predicted ρ, flagging windows where
//!   realized contraction is slower than designed.
//! - **Error-runtime frontier** — `(iteration, virtual time, comm
//!   units, loss, consensus)` samples at every record point: the
//!   paper's figure-4 axes, directly comparable across specs.
//! - **Straggler/staleness audit** — per-worker compute-duration
//!   histograms (p95 skew exposes stragglers) and, on the async
//!   backend, per-edge staleness histograms (AD-PSGD's τ).
//!
//! An [`Observatory`] rides on the [`super::Tracer`] exactly like the
//! sink: every hook is one `Option` branch and **zero allocations when
//! disabled** (asserted under the counting allocator in
//! `benches/hotpath.rs`). Enabled, it is pure read-side bookkeeping —
//! it never touches iterates, RNG streams or arithmetic order, so
//! traced trajectories are bit-for-bit the untraced ones and the
//! snapshot is identical across the deterministic backends
//! (sim ≡ engine ≡ actors ≡ cluster ≡ remote per seed; enforced by
//! `rust/tests/trace.rs` / `rust/tests/node.rs`).

use crate::json::Json;

/// Chi-square-style drift score above which the ledger flags the run:
/// the realized activation frequencies are implausible under the
/// designed `p_j` (≈ the 95th percentile of χ²(1) per matching).
pub const DRIFT_THRESHOLD: f64 = 4.0;

/// One closed contraction window, streamed through
/// [`crate::experiment::Observer::on_window`] as the run crosses record
/// points and kept in [`ObservatorySnapshot::windows`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowStats {
    /// Window ordinal (0-based, tumbling).
    pub index: usize,
    /// Iteration of the window's first record sample.
    pub k_start: usize,
    /// Iteration of the window's last record sample.
    pub k_end: usize,
    /// Consensus distance at the first sample.
    pub consensus_start: f64,
    /// Consensus distance at the last sample.
    pub consensus_end: f64,
    /// Realized per-round contraction factor
    /// `(consensus_end / consensus_start)^(1/(k_end - k_start))`;
    /// `0.0` when either endpoint is not positive (the shared initial
    /// iterate makes consensus exactly 0 at k = 0).
    pub rate: f64,
    /// The plan's predicted spectral norm ρ.
    pub predicted_rho: f64,
    /// True when the window contracted slower than the design predicts
    /// (`rate > predicted_rho`, with a positive measured rate).
    pub slower: bool,
    /// Ledger drift score at window close.
    pub drift_score: f64,
    /// Gossip rounds the ledger had absorbed at window close.
    pub rounds: u64,
}

/// A per-worker compute-duration summary in the audit.
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeAudit {
    pub worker: usize,
    /// Compute spans observed.
    pub count: u64,
    /// Mean span duration (virtual units).
    pub mean: f64,
    /// 95th-percentile span duration (bucket-interpolated).
    pub p95: f64,
}

/// A per-edge staleness summary in the audit (async backend only;
/// empty elsewhere).
#[derive(Clone, Debug, PartialEq)]
pub struct StalenessAudit {
    pub u: usize,
    pub v: usize,
    /// Exchanges observed on this edge.
    pub count: u64,
    /// Mean model-version drift τ.
    pub mean: f64,
    /// Largest τ observed.
    pub max: f64,
}

/// The straggler/staleness audit of a run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunAudit {
    /// One entry per worker, worker order.
    pub compute: Vec<ComputeAudit>,
    /// Ratio of the largest to the smallest per-worker compute p95
    /// (workers with observations only); `1.0` when undefined. A value
    /// well above 1 is a straggler.
    pub compute_p95_skew: f64,
    /// Per-edge staleness summaries, canonical `(u, v)` order.
    pub staleness: Vec<StalenessAudit>,
}

/// One realized per-link activation count in the ledger.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkCount {
    pub matching: usize,
    pub u: usize,
    pub v: usize,
    pub count: u64,
}

/// The design-vs-realized activation ledger.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ActivationLedger {
    /// The plan's designed activation probabilities `p_j`.
    pub designed: Vec<f64>,
    /// Realized activation counts per matching.
    pub realized: Vec<u64>,
    /// Realized exchange counts per link (failed links excluded).
    pub links: Vec<LinkCount>,
    /// Mean chi-square term `n (f_j − p_j)² / (p_j (1 − p_j))` over the
    /// stochastic matchings (`0 < p_j < 1`); 0 when every matching is
    /// deterministic (vanilla) or no rounds ran.
    pub drift_score: f64,
    /// Mean absolute frequency error `|f_j − p_j|` over all matchings.
    pub drift_l1: f64,
    /// `drift_score > DRIFT_THRESHOLD`.
    pub drifted: bool,
}

/// One error-runtime frontier sample (the paper's fig-4 axes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrontierPoint {
    pub k: usize,
    pub time: f64,
    pub comm: f64,
    pub loss: f64,
    pub consensus: f64,
}

/// The observatory's end-of-run readout, carried on
/// [`crate::experiment::ExperimentResult::observatory`] with one JSON
/// schema across every backend.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObservatorySnapshot {
    /// Gossip rounds absorbed into the ledger.
    pub rounds: u64,
    pub ledger: ActivationLedger,
    /// Every closed contraction window, in order.
    pub windows: Vec<WindowStats>,
    /// Every record sample, in order.
    pub frontier: Vec<FrontierPoint>,
    pub audit: RunAudit,
}

/// The compact health view a shard-node daemon ships inside
/// [`super::NodeTelemetry`] (the `matcha status` one-liner): current
/// drift score and the latest closed window's contraction rate.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObservatoryHealth {
    /// Gossip rounds absorbed so far.
    pub rounds: u64,
    /// Current ledger drift score.
    pub drift_score: f64,
    /// Per-round contraction rate of the latest closed window
    /// (`0.0` until the first window closes — never NaN).
    pub contraction_rate: f64,
    /// Contraction windows closed so far.
    pub windows: u64,
}

/// What [`Observatory::enabled`] needs from a plan: the designed
/// probabilities, the matchings' edge lists, the predicted ρ, the
/// worker count and the contraction window size (record samples).
#[derive(Clone, Debug)]
pub struct ObservatoryConfig {
    pub designed: Vec<f64>,
    /// Edge list per matching, canonical `u < v` orientation.
    pub matchings: Vec<Vec<(usize, usize)>>,
    pub rho: f64,
    pub workers: usize,
    /// Record samples per tumbling contraction window (≥ 2).
    pub window: usize,
}

/// Live state of an enabled observatory. Boxed behind the `Option` so a
/// disabled [`Observatory`] is one pointer-width and every hook costs
/// one branch.
struct ObsCore {
    designed: Vec<f64>,
    realized: Vec<u64>,
    /// `(matching, u, v)` per link, grouped by matching.
    links: Vec<(usize, usize, usize)>,
    link_counts: Vec<u64>,
    /// Link indices per matching (ranges into `links`).
    matching_links: Vec<Vec<usize>>,
    /// `(matching, u, v)` → link index, for the async per-exchange feed.
    link_ids: std::collections::BTreeMap<(usize, usize, usize), usize>,
    rho: f64,
    window: usize,
    rounds: u64,
    frontier: Vec<FrontierPoint>,
    windows: Vec<WindowStats>,
    /// Record samples accumulated in the open window.
    win_samples: usize,
    win_k_start: usize,
    win_consensus_start: f64,
    compute: Vec<super::metrics::Histogram>,
    staleness: std::collections::BTreeMap<(usize, usize), super::metrics::Histogram>,
}

/// The algorithm-level observability hook threaded through every
/// backend on the [`super::Tracer`]. Disabled by default
/// ([`Observatory::disabled`]); [`crate::experiment::run`] enables it
/// when the spec carries a `report` block.
pub struct Observatory(Option<Box<ObsCore>>);

impl Default for Observatory {
    fn default() -> Self {
        Observatory::disabled()
    }
}

impl Observatory {
    /// The no-op observatory every hook call branches away from.
    pub fn disabled() -> Observatory {
        Observatory(None)
    }

    /// An observatory tracking the given design.
    pub fn enabled(config: ObservatoryConfig) -> Observatory {
        let m = config.designed.len();
        let mut links = Vec::new();
        let mut matching_links = Vec::with_capacity(m);
        let mut link_ids = std::collections::BTreeMap::new();
        for (j, edges) in config.matchings.iter().enumerate() {
            let mut ids = Vec::with_capacity(edges.len());
            for &(u, v) in edges {
                let id = links.len();
                links.push((j, u, v));
                link_ids.insert((j, u, v), id);
                ids.push(id);
            }
            matching_links.push(ids);
        }
        let link_counts = vec![0u64; links.len()];
        Observatory(Some(Box::new(ObsCore {
            designed: config.designed,
            realized: vec![0; m],
            links,
            link_counts,
            matching_links,
            link_ids,
            rho: config.rho,
            window: config.window.max(2),
            rounds: 0,
            frontier: Vec::new(),
            windows: Vec::new(),
            win_samples: 0,
            win_k_start: 0,
            win_consensus_start: 0.0,
            compute: vec![super::metrics::Histogram::default(); config.workers],
            staleness: std::collections::BTreeMap::new(),
        })))
    }

    /// Is the observatory collecting?
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// One worker compute span of `dur` virtual units.
    #[inline]
    pub fn on_compute(&mut self, worker: usize, dur: f64) {
        if let Some(core) = self.0.as_deref_mut() {
            core.compute[worker].observe(dur);
        }
    }

    /// One synchronous gossip round: `activated` matchings fired,
    /// links in `dead` failed (canonical `u < v`). Counts the round,
    /// the matchings, and every surviving link.
    #[inline]
    pub fn on_round(&mut self, activated: &[usize], dead: &[(usize, usize)]) {
        if let Some(core) = self.0.as_deref_mut() {
            core.on_round(activated, dead);
        }
    }

    /// Matching-level accounting for one asynchronously applied round
    /// (the async runtime counts links separately, per completed
    /// exchange, via [`Observatory::on_link`]).
    #[inline]
    pub fn on_matchings(&mut self, activated: &[usize]) {
        if let Some(core) = self.0.as_deref_mut() {
            core.rounds += 1;
            for &j in activated {
                core.realized[j] += 1;
            }
        }
    }

    /// One completed (non-failed) pairwise exchange on link
    /// `(matching, u, v)` — the async runtime's link-level feed.
    #[inline]
    pub fn on_link(&mut self, matching: usize, u: usize, v: usize) {
        if let Some(core) = self.0.as_deref_mut() {
            if let Some(&id) = core.link_ids.get(&(matching, u, v)) {
                core.link_counts[id] += 1;
            }
        }
    }

    /// One staleness observation `tau` on edge `(u, v)` (async only).
    #[inline]
    pub fn on_stale_exchange(&mut self, u: usize, v: usize, tau: usize) {
        if let Some(core) = self.0.as_deref_mut() {
            let key = if u < v { (u, v) } else { (v, u) };
            core.staleness.entry(key).or_default().observe(tau as f64);
        }
    }

    /// One record sample: appends a frontier point and advances the
    /// contraction window, returning the window's stats when this
    /// sample closes it.
    #[inline]
    pub fn on_record(
        &mut self,
        k: usize,
        time: f64,
        comm: f64,
        loss: f64,
        consensus: f64,
    ) -> Option<WindowStats> {
        match self.0.as_deref_mut() {
            Some(core) => core.on_record(k, time, comm, loss, consensus),
            None => None,
        }
    }

    /// The end-of-run readout (`None` when disabled).
    pub fn snapshot(&self) -> Option<ObservatorySnapshot> {
        self.0.as_deref().map(ObsCore::snapshot)
    }

    /// The compact daemon-health view (`None` when disabled).
    pub fn health(&self) -> Option<ObservatoryHealth> {
        self.0.as_deref().map(|core| ObservatoryHealth {
            rounds: core.rounds,
            drift_score: core.drift_score(),
            contraction_rate: core.windows.last().map_or(0.0, |w| w.rate),
            windows: core.windows.len() as u64,
        })
    }
}

impl ObsCore {
    fn on_round(&mut self, activated: &[usize], dead: &[(usize, usize)]) {
        self.rounds += 1;
        for &j in activated {
            self.realized[j] += 1;
            for &id in &self.matching_links[j] {
                let (_, u, v) = self.links[id];
                if !dead.contains(&(u, v)) {
                    self.link_counts[id] += 1;
                }
            }
        }
    }

    fn on_record(
        &mut self,
        k: usize,
        time: f64,
        comm: f64,
        loss: f64,
        consensus: f64,
    ) -> Option<WindowStats> {
        self.frontier.push(FrontierPoint { k, time, comm, loss, consensus });
        if self.win_samples == 0 {
            self.win_k_start = k;
            self.win_consensus_start = consensus;
        }
        self.win_samples += 1;
        if self.win_samples < self.window {
            return None;
        }
        // The window closes on its last sample; the next sample opens a
        // fresh one (tumbling, no shared endpoints).
        let (c0, c1) = (self.win_consensus_start, consensus);
        let span = k.saturating_sub(self.win_k_start);
        let rate = if c0 > 0.0 && c1 > 0.0 && span > 0 {
            (c1 / c0).powf(1.0 / span as f64)
        } else {
            0.0
        };
        let stats = WindowStats {
            index: self.windows.len(),
            k_start: self.win_k_start,
            k_end: k,
            consensus_start: c0,
            consensus_end: c1,
            rate,
            predicted_rho: self.rho,
            slower: rate > 0.0 && rate > self.rho,
            drift_score: self.drift_score(),
            rounds: self.rounds,
        };
        self.windows.push(stats);
        self.win_samples = 0;
        Some(stats)
    }

    /// Mean chi-square term over the stochastic matchings.
    fn drift_score(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        let n = self.rounds as f64;
        let (mut score, mut terms) = (0.0, 0usize);
        for (j, &p) in self.designed.iter().enumerate() {
            if p <= 0.0 || p >= 1.0 {
                continue; // deterministic matchings cannot drift
            }
            let f = self.realized[j] as f64 / n;
            score += n * (f - p) * (f - p) / (p * (1.0 - p));
            terms += 1;
        }
        if terms == 0 {
            0.0
        } else {
            score / terms as f64
        }
    }

    /// Mean absolute frequency error over all matchings.
    fn drift_l1(&self) -> f64 {
        if self.rounds == 0 || self.designed.is_empty() {
            return 0.0;
        }
        let n = self.rounds as f64;
        let total: f64 = self
            .designed
            .iter()
            .zip(&self.realized)
            .map(|(&p, &c)| (c as f64 / n - p).abs())
            .sum();
        total / self.designed.len() as f64
    }

    fn snapshot(&self) -> ObservatorySnapshot {
        let drift_score = self.drift_score();
        let compute: Vec<ComputeAudit> = self
            .compute
            .iter()
            .enumerate()
            .map(|(w, h)| ComputeAudit {
                worker: w,
                count: h.count,
                mean: h.mean(),
                p95: h.quantile(0.95),
            })
            .collect();
        let observed: Vec<f64> =
            compute.iter().filter(|c| c.count > 0).map(|c| c.p95).collect();
        let skew = match (
            observed.iter().cloned().fold(f64::INFINITY, f64::min),
            observed.iter().cloned().fold(0.0f64, f64::max),
        ) {
            (min, max) if min > 0.0 && min.is_finite() => max / min,
            _ => 1.0,
        };
        ObservatorySnapshot {
            rounds: self.rounds,
            ledger: ActivationLedger {
                designed: self.designed.clone(),
                realized: self.realized.clone(),
                links: self
                    .links
                    .iter()
                    .zip(&self.link_counts)
                    .map(|(&(matching, u, v), &count)| LinkCount { matching, u, v, count })
                    .collect(),
                drift_score,
                drift_l1: self.drift_l1(),
                drifted: drift_score > DRIFT_THRESHOLD,
            },
            windows: self.windows.clone(),
            frontier: self.frontier.clone(),
            audit: RunAudit {
                compute,
                compute_p95_skew: skew,
                staleness: self
                    .staleness
                    .iter()
                    .map(|(&(u, v), h)| StalenessAudit {
                        u,
                        v,
                        count: h.count,
                        mean: h.mean(),
                        max: if h.count == 0 { 0.0 } else { h.max },
                    })
                    .collect(),
            },
        }
    }
}

fn req<'a>(obj: &'a Json, key: &str, ctx: &str) -> Result<&'a Json, String> {
    obj.get(key).ok_or_else(|| format!("observatory: {ctx}: missing '{key}'"))
}

fn req_f64(obj: &Json, key: &str, ctx: &str) -> Result<f64, String> {
    req(obj, key, ctx)?
        .as_f64()
        .ok_or_else(|| format!("observatory: {ctx}: '{key}' must be a number"))
}

fn req_usize(obj: &Json, key: &str, ctx: &str) -> Result<usize, String> {
    req(obj, key, ctx)?
        .as_usize()
        .ok_or_else(|| format!("observatory: {ctx}: '{key}' must be a non-negative integer"))
}

fn req_bool(obj: &Json, key: &str, ctx: &str) -> Result<bool, String> {
    req(obj, key, ctx)?
        .as_bool()
        .ok_or_else(|| format!("observatory: {ctx}: '{key}' must be a boolean"))
}

fn req_arr<'a>(obj: &'a Json, key: &str, ctx: &str) -> Result<&'a [Json], String> {
    req(obj, key, ctx)?
        .as_array()
        .ok_or_else(|| format!("observatory: {ctx}: '{key}' must be an array"))
}

impl WindowStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", Json::Num(self.index as f64)),
            ("k_start", Json::Num(self.k_start as f64)),
            ("k_end", Json::Num(self.k_end as f64)),
            ("consensus_start", Json::Num(self.consensus_start)),
            ("consensus_end", Json::Num(self.consensus_end)),
            ("rate", Json::Num(self.rate)),
            ("predicted_rho", Json::Num(self.predicted_rho)),
            ("slower", Json::Bool(self.slower)),
            ("drift_score", Json::Num(self.drift_score)),
            ("rounds", Json::Num(self.rounds as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<WindowStats, String> {
        let ctx = "window";
        Ok(WindowStats {
            index: req_usize(j, "index", ctx)?,
            k_start: req_usize(j, "k_start", ctx)?,
            k_end: req_usize(j, "k_end", ctx)?,
            consensus_start: req_f64(j, "consensus_start", ctx)?,
            consensus_end: req_f64(j, "consensus_end", ctx)?,
            rate: req_f64(j, "rate", ctx)?,
            predicted_rho: req_f64(j, "predicted_rho", ctx)?,
            slower: req_bool(j, "slower", ctx)?,
            drift_score: req_f64(j, "drift_score", ctx)?,
            rounds: req_usize(j, "rounds", ctx)? as u64,
        })
    }
}

impl ObservatorySnapshot {
    /// The one-schema JSON form (same keys on every backend).
    pub fn to_json(&self) -> Json {
        let l = &self.ledger;
        Json::obj(vec![
            ("rounds", Json::Num(self.rounds as f64)),
            (
                "ledger",
                Json::obj(vec![
                    ("designed", Json::Arr(l.designed.iter().map(|&p| Json::Num(p)).collect())),
                    (
                        "realized",
                        Json::Arr(l.realized.iter().map(|&c| Json::Num(c as f64)).collect()),
                    ),
                    (
                        "links",
                        Json::Arr(
                            l.links
                                .iter()
                                .map(|lc| {
                                    Json::obj(vec![
                                        ("matching", Json::Num(lc.matching as f64)),
                                        ("u", Json::Num(lc.u as f64)),
                                        ("v", Json::Num(lc.v as f64)),
                                        ("count", Json::Num(lc.count as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("drift_score", Json::Num(l.drift_score)),
                    ("drift_l1", Json::Num(l.drift_l1)),
                    ("drifted", Json::Bool(l.drifted)),
                ]),
            ),
            ("windows", Json::Arr(self.windows.iter().map(WindowStats::to_json).collect())),
            (
                "frontier",
                Json::Arr(
                    self.frontier
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("k", Json::Num(p.k as f64)),
                                ("time", Json::Num(p.time)),
                                ("comm", Json::Num(p.comm)),
                                ("loss", Json::Num(p.loss)),
                                ("consensus", Json::Num(p.consensus)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "audit",
                Json::obj(vec![
                    (
                        "compute",
                        Json::Arr(
                            self.audit
                                .compute
                                .iter()
                                .map(|c| {
                                    Json::obj(vec![
                                        ("worker", Json::Num(c.worker as f64)),
                                        ("count", Json::Num(c.count as f64)),
                                        ("mean", Json::Num(c.mean)),
                                        ("p95", Json::Num(c.p95)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("compute_p95_skew", Json::Num(self.audit.compute_p95_skew)),
                    (
                        "staleness",
                        Json::Arr(
                            self.audit
                                .staleness
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("u", Json::Num(s.u as f64)),
                                        ("v", Json::Num(s.v as f64)),
                                        ("count", Json::Num(s.count as f64)),
                                        ("mean", Json::Num(s.mean)),
                                        ("max", Json::Num(s.max)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ])
    }

    /// Parse the [`ObservatorySnapshot::to_json`] form back (what
    /// `matcha report RESULT.json` re-renders from).
    pub fn from_json(j: &Json) -> Result<ObservatorySnapshot, String> {
        let ledger = req(j, "ledger", "snapshot")?;
        let audit = req(j, "audit", "snapshot")?;
        Ok(ObservatorySnapshot {
            rounds: req_usize(j, "rounds", "snapshot")? as u64,
            ledger: ActivationLedger {
                designed: req_arr(ledger, "designed", "ledger")?
                    .iter()
                    .map(|p| p.as_f64().ok_or("observatory: ledger: bad probability".to_string()))
                    .collect::<Result<_, _>>()?,
                realized: req_arr(ledger, "realized", "ledger")?
                    .iter()
                    .map(|c| {
                        c.as_usize()
                            .map(|c| c as u64)
                            .ok_or("observatory: ledger: bad count".to_string())
                    })
                    .collect::<Result<_, _>>()?,
                links: req_arr(ledger, "links", "ledger")?
                    .iter()
                    .map(|lc| {
                        Ok(LinkCount {
                            matching: req_usize(lc, "matching", "link")?,
                            u: req_usize(lc, "u", "link")?,
                            v: req_usize(lc, "v", "link")?,
                            count: req_usize(lc, "count", "link")? as u64,
                        })
                    })
                    .collect::<Result<_, String>>()?,
                drift_score: req_f64(ledger, "drift_score", "ledger")?,
                drift_l1: req_f64(ledger, "drift_l1", "ledger")?,
                drifted: req_bool(ledger, "drifted", "ledger")?,
            },
            windows: req_arr(j, "windows", "snapshot")?
                .iter()
                .map(WindowStats::from_json)
                .collect::<Result<_, _>>()?,
            frontier: req_arr(j, "frontier", "snapshot")?
                .iter()
                .map(|p| {
                    Ok(FrontierPoint {
                        k: req_usize(p, "k", "frontier")?,
                        time: req_f64(p, "time", "frontier")?,
                        comm: req_f64(p, "comm", "frontier")?,
                        loss: req_f64(p, "loss", "frontier")?,
                        consensus: req_f64(p, "consensus", "frontier")?,
                    })
                })
                .collect::<Result<_, String>>()?,
            audit: RunAudit {
                compute: req_arr(audit, "compute", "audit")?
                    .iter()
                    .map(|c| {
                        Ok(ComputeAudit {
                            worker: req_usize(c, "worker", "compute")?,
                            count: req_usize(c, "count", "compute")? as u64,
                            mean: req_f64(c, "mean", "compute")?,
                            p95: req_f64(c, "p95", "compute")?,
                        })
                    })
                    .collect::<Result<_, String>>()?,
                compute_p95_skew: req_f64(audit, "compute_p95_skew", "audit")?,
                staleness: req_arr(audit, "staleness", "audit")?
                    .iter()
                    .map(|s| {
                        Ok(StalenessAudit {
                            u: req_usize(s, "u", "staleness")?,
                            v: req_usize(s, "v", "staleness")?,
                            count: req_usize(s, "count", "staleness")? as u64,
                            mean: req_f64(s, "mean", "staleness")?,
                            max: req_f64(s, "max", "staleness")?,
                        })
                    })
                    .collect::<Result<_, String>>()?,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_matching_config(designed: Vec<f64>) -> ObservatoryConfig {
        ObservatoryConfig {
            designed,
            matchings: vec![vec![(0, 1), (2, 3)], vec![(1, 2)]],
            rho: 0.9,
            workers: 4,
            window: 2,
        }
    }

    #[test]
    fn disabled_hooks_are_inert() {
        let mut obs = Observatory::disabled();
        obs.on_compute(0, 1.0);
        obs.on_round(&[0], &[]);
        obs.on_matchings(&[0]);
        obs.on_link(0, 0, 1);
        obs.on_stale_exchange(0, 1, 2);
        assert!(obs.on_record(0, 0.0, 0.0, 1.0, 1.0).is_none());
        assert!(obs.snapshot().is_none());
        assert!(obs.health().is_none());
        assert!(!obs.is_enabled());
    }

    #[test]
    fn ledger_counts_matchings_and_links_minus_dead() {
        let mut obs = Observatory::enabled(two_matching_config(vec![0.5, 0.5]));
        obs.on_round(&[0, 1], &[]);
        obs.on_round(&[0], &[(2, 3)]);
        let snap = obs.snapshot().unwrap();
        assert_eq!(snap.rounds, 2);
        assert_eq!(snap.ledger.realized, vec![2, 1]);
        let counts: Vec<u64> = snap.ledger.links.iter().map(|l| l.count).collect();
        // (0,1) twice; (2,3) once (dead in round 2); (1,2) once.
        assert_eq!(counts, vec![2, 1, 1]);
    }

    #[test]
    fn window_closes_with_contraction_rate() {
        let mut obs = Observatory::enabled(two_matching_config(vec![0.5, 0.5]));
        // First window: consensus 0 at k=0 -> rate 0, never "slower".
        assert!(obs.on_record(0, 0.0, 0.0, 1.0, 0.0).is_none());
        let w0 = obs.on_record(10, 1.0, 1.0, 0.9, 4.0).expect("window 0");
        assert_eq!(w0.rate, 0.0);
        assert!(!w0.slower);
        // Second window: 4.0 -> 1.0 over 10 rounds.
        assert!(obs.on_record(20, 2.0, 2.0, 0.8, 4.0).is_none());
        let w1 = obs.on_record(30, 3.0, 3.0, 0.7, 1.0).expect("window 1");
        assert!((w1.rate - 0.25f64.powf(0.1)).abs() < 1e-12);
        assert_eq!(w1.index, 1);
        assert_eq!(w1.predicted_rho, 0.9);
        assert!(w1.rate < 0.9 && !w1.slower);
        let health = obs.health().unwrap();
        assert_eq!(health.windows, 2);
        assert_eq!(health.contraction_rate, w1.rate);
        assert_eq!(obs.snapshot().unwrap().frontier.len(), 4);
    }

    #[test]
    fn slower_window_is_flagged() {
        let mut obs = Observatory::enabled(two_matching_config(vec![0.5, 0.5]));
        obs.on_record(0, 0.0, 0.0, 1.0, 1.0);
        let w = obs.on_record(10, 1.0, 1.0, 0.9, 0.99).expect("window");
        assert!(w.rate > 0.9, "barely-contracting rate {}", w.rate);
        assert!(w.slower);
    }

    #[test]
    fn realized_frequencies_near_design_score_low() {
        let mut obs = Observatory::enabled(two_matching_config(vec![0.5, 0.25]));
        // 1000 rounds at exactly the designed frequencies.
        for k in 0..1000usize {
            let mut act = Vec::new();
            if k % 2 == 0 {
                act.push(0);
            }
            if k % 4 == 0 {
                act.push(1);
            }
            obs.on_round(&act, &[]);
        }
        let snap = obs.snapshot().unwrap();
        assert!(snap.ledger.drift_score < 0.1, "score {}", snap.ledger.drift_score);
        assert!(snap.ledger.drift_l1 < 0.01);
        assert!(!snap.ledger.drifted);
    }

    #[test]
    fn mis_weighted_schedule_is_flagged() {
        // Designed 0.9 but realized ~0.5: the ledger must flag it.
        let mut obs = Observatory::enabled(two_matching_config(vec![0.9, 0.9]));
        for k in 0..200usize {
            let act: Vec<usize> = if k % 2 == 0 { vec![0, 1] } else { Vec::new() };
            obs.on_round(&act, &[]);
        }
        let snap = obs.snapshot().unwrap();
        assert!(snap.ledger.drift_score > DRIFT_THRESHOLD, "score {}", snap.ledger.drift_score);
        assert!(snap.ledger.drifted);
    }

    #[test]
    fn vanilla_all_ones_never_drifts() {
        let mut obs = Observatory::enabled(two_matching_config(vec![1.0, 1.0]));
        for _ in 0..50 {
            obs.on_round(&[0, 1], &[]);
        }
        let snap = obs.snapshot().unwrap();
        assert_eq!(snap.ledger.drift_score, 0.0);
        assert_eq!(snap.ledger.drift_l1, 0.0);
        assert!(!snap.ledger.drifted);
    }

    #[test]
    fn async_feeds_count_links_and_staleness() {
        let mut obs = Observatory::enabled(two_matching_config(vec![0.5, 0.5]));
        obs.on_matchings(&[0]);
        obs.on_link(0, 0, 1);
        obs.on_link(0, 2, 3);
        obs.on_matchings(&[0, 1]);
        obs.on_link(0, 0, 1);
        obs.on_link(1, 1, 2);
        obs.on_stale_exchange(0, 1, 0);
        obs.on_stale_exchange(0, 1, 2);
        obs.on_stale_exchange(2, 1, 1);
        let snap = obs.snapshot().unwrap();
        assert_eq!(snap.rounds, 2);
        assert_eq!(snap.ledger.realized, vec![2, 1]);
        let counts: Vec<u64> = snap.ledger.links.iter().map(|l| l.count).collect();
        assert_eq!(counts, vec![2, 1, 1]);
        assert_eq!(snap.audit.staleness.len(), 2);
        let e01 = &snap.audit.staleness[0];
        assert_eq!((e01.u, e01.v, e01.count), (0, 1, 2));
        assert_eq!(e01.max, 2.0);
        let e12 = &snap.audit.staleness[1];
        assert_eq!((e12.u, e12.v, e12.count), (1, 2, 1));
    }

    #[test]
    fn compute_audit_exposes_straggler_skew() {
        let mut obs = Observatory::enabled(two_matching_config(vec![0.5, 0.5]));
        for _ in 0..100 {
            for w in 0..4 {
                obs.on_compute(w, if w == 2 { 5.0 } else { 1.0 });
            }
        }
        let snap = obs.snapshot().unwrap();
        assert_eq!(snap.audit.compute.len(), 4);
        assert_eq!(snap.audit.compute[2].count, 100);
        assert!(snap.audit.compute[2].mean > snap.audit.compute[0].mean);
        assert!(snap.audit.compute_p95_skew > 1.5, "skew {}", snap.audit.compute_p95_skew);
    }

    #[test]
    fn snapshot_json_roundtrips() {
        let mut obs = Observatory::enabled(two_matching_config(vec![0.5, 0.25]));
        for k in 0..40usize {
            obs.on_compute(k % 4, 1.0 + (k % 3) as f64);
            obs.on_round(if k % 2 == 0 { &[0, 1] } else { &[1] }, &[]);
            obs.on_stale_exchange(0, 1, k % 3);
        }
        obs.on_record(0, 0.0, 0.0, 2.0, 0.5);
        obs.on_record(20, 10.0, 8.0, 1.0, 0.25);
        obs.on_record(40, 20.0, 16.0, 0.5, 0.125);
        let snap = obs.snapshot().unwrap();
        let text = snap.to_json().to_string();
        let back = ObservatorySnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let j = Json::parse(r#"{"rounds": 3}"#).unwrap();
        let err = ObservatorySnapshot::from_json(&j).unwrap_err();
        assert!(err.contains("missing 'ledger'"), "got: {err}");
    }
}
