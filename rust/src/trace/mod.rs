//! Unified tracing & metrics: the cross-backend observability layer.
//!
//! MATCHA's argument is an error-*runtime* trade-off, so seeing **where**
//! time goes inside a run — which links stall, which workers idle, how
//! staleness evolves — matters as much as the final loss curve. This
//! module is that lens, threaded through every execution backend:
//!
//! - [`span`] — the typed event vocabulary ([`TraceEvent`]:
//!   compute/link spans, mix/barrier markers, wire frames, stale
//!   exchanges) and the stamped [`TraceRecord`] (virtual time +
//!   wall-clock nanoseconds).
//! - [`sink`] — the [`TraceSink`] trait, the preallocated [`RingSink`]
//!   collector, and the [`Tracer`] handle the backends emit through.
//!   With no sink attached, emission is one branch and the hot paths
//!   stay allocation-free (asserted in `benches/hotpath.rs`).
//! - [`metrics`] — fixed-slot counters and histograms
//!   ([`MetricsRegistry`]) that are always on, summarized into the
//!   [`MetricsSnapshot`] every
//!   [`crate::experiment::ExperimentResult`] carries — one uniform
//!   home for what used to live in `LinkStats` / `AsyncStats` /
//!   `ClusterStats`.
//! - [`observatory`] — the **algorithm-level** lens ([`Observatory`]
//!   on the [`Tracer`]): the design-vs-realized activation ledger
//!   (designed `p_j` vs realized frequencies, chi-square drift score),
//!   windowed consensus-contraction tracking against the plan's
//!   predicted ρ, the error-runtime frontier, and the
//!   straggler/staleness audit — summarized into the
//!   [`ObservatorySnapshot`] that rides on
//!   [`crate::experiment::ExperimentResult::observatory`] with one
//!   schema across every backend.
//! - [`report`] — the self-contained run report ([`RunReport`]):
//!   run identity + observatory snapshot as one JSON document plus a
//!   human-readable rendering, behind `matcha report`.
//! - [`export`] — Chrome trace-event JSON (Perfetto /
//!   `chrome://tracing` loadable; one track per worker, per link and
//!   per wire link) and a JSONL event stream, plus the well-formedness
//!   validator behind `matcha trace-check`.
//!
//! Reachable end-to-end as `matcha run --spec exp.json --trace out.json`
//! or a `"trace": {"path": ...}` block in the spec; in-process via
//! [`crate::experiment::run_planned_traced`]:
//!
//! ```
//! use matcha::experiment::{self, ExperimentSpec, NoopObserver, ProblemSpec};
//! use matcha::trace::{chrome_trace, validate_chrome_trace, RingSink, Tracer};
//!
//! let spec = ExperimentSpec::new("ring:6")
//!     .problem(ProblemSpec::quadratic())
//!     .iterations(10)
//!     .validated()
//!     .unwrap();
//! let plan = experiment::plan(&spec).unwrap();
//! let mut sink = RingSink::new(4096);
//! let mut tracer = Tracer::attached(&mut sink);
//! let result =
//!     experiment::run_planned_traced(&spec, &plan, &mut NoopObserver, &mut tracer).unwrap();
//! assert!(!sink.is_empty());
//! let trace = chrome_trace(&sink.records(), &result.snapshot.to_json());
//! validate_chrome_trace(&trace.to_string()).unwrap();
//! ```
//!
//! Per seed, the barrier backends emit **identical virtual-time event
//! sequences** (sim ≡ engine modulo per-link events; cluster loopback ≡
//! actors event-for-event modulo wire frames) — pinned by
//! `rust/tests/trace.rs`.

//! Remote runs extend the lens across process boundaries:
//! [`telemetry`] defines the [`NodeTelemetry`] snapshot every
//! shard-node daemon can answer over the wire and the
//! [`TelemetryCollector`] the coordinator uses to merge per-daemon
//! streams into one multi-process Chrome trace (one `pid` per shard,
//! coordinator = pid 0) and an aggregate metrics snapshot.

pub mod export;
pub mod metrics;
pub mod observatory;
pub mod report;
pub mod sink;
pub mod span;
pub mod telemetry;

pub use export::{
    chrome_trace, chrome_trace_merged, jsonl_lines, validate_chrome_trace, validate_jsonl_trace,
    write_trace, JsonlCheck, PidTrack, TraceCheck, TraceFormat,
};
pub use metrics::{Counter, Hist, Histogram, MetricsRegistry, MetricsSnapshot};
pub use observatory::{
    ActivationLedger, Observatory, ObservatoryConfig, ObservatoryHealth, ObservatorySnapshot,
    WindowStats,
};
pub use report::RunReport;
pub use sink::{RingSink, TraceSink, Tracer};
pub use span::{TraceEvent, TraceRecord};
pub use telemetry::{NodeTelemetry, TelemetryCollector, UNASSIGNED_SHARD};
