//! The counter/histogram registry: fixed-slot, allocation-free run
//! metrics unifying what used to live scattered across `LinkStats`,
//! `AsyncStats` and `ClusterStats`.
//!
//! Every named metric has a compile-time slot ([`Counter`] /
//! [`Hist`] enums indexing fixed arrays), so recording is an array
//! increment — no hashing, no allocation, safe to leave always-on in
//! every backend's hot loop. The per-run [`MetricsRegistry`] rides on
//! the [`crate::trace::Tracer`] and is summarized into a
//! [`MetricsSnapshot`] carried on
//! [`crate::experiment::ExperimentResult`].

use crate::json::Json;

/// Monotonic counters with fixed registry slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Worker compute completions processed (engine/async event loops,
    /// sim step loops).
    ComputeEvents,
    /// Per-link transmissions completed (engine/async schedules).
    LinkEvents,
    /// Gossip mix rounds applied (one per iteration, all backends).
    MixRounds,
    /// Links dropped by failure injection.
    DroppedLinks,
    /// Pairwise exchanges applied by the async runtime.
    Exchanges,
    /// Wire frames the cluster coordinator sent.
    WireFramesSent,
    /// Wire frames the cluster coordinator received.
    WireFramesReceived,
    /// Wire bytes the cluster coordinator sent.
    WireBytesSent,
    /// Wire bytes the cluster coordinator received.
    WireBytesReceived,
    /// Local SGD steps executed inside actor/cluster shards.
    ShardSteps,
    /// Gossip messages folded inside actor/cluster shards.
    ShardMsgsFolded,
    /// Reconnect-with-resume cycles the remote coordinator completed
    /// against shard-node daemons (0 on every in-process backend).
    Reconnects,
}

/// Number of counter slots.
pub const NUM_COUNTERS: usize = 12;

impl Counter {
    /// Every counter, in slot order.
    pub const ALL: [Counter; NUM_COUNTERS] = [
        Counter::ComputeEvents,
        Counter::LinkEvents,
        Counter::MixRounds,
        Counter::DroppedLinks,
        Counter::Exchanges,
        Counter::WireFramesSent,
        Counter::WireFramesReceived,
        Counter::WireBytesSent,
        Counter::WireBytesReceived,
        Counter::ShardSteps,
        Counter::ShardMsgsFolded,
        Counter::Reconnects,
    ];

    /// Stable metric name (the key in [`MetricsSnapshot::to_json`]).
    pub fn name(self) -> &'static str {
        match self {
            Counter::ComputeEvents => "compute_events",
            Counter::LinkEvents => "link_events",
            Counter::MixRounds => "mix_rounds",
            Counter::DroppedLinks => "dropped_links",
            Counter::Exchanges => "exchanges",
            Counter::WireFramesSent => "wire_frames_sent",
            Counter::WireFramesReceived => "wire_frames_received",
            Counter::WireBytesSent => "wire_bytes_sent",
            Counter::WireBytesReceived => "wire_bytes_received",
            Counter::ShardSteps => "shard_steps",
            Counter::ShardMsgsFolded => "shard_msgs_folded",
            Counter::Reconnects => "reconnects",
        }
    }

    fn slot(self) -> usize {
        self as usize
    }
}

/// Histograms with fixed registry slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// Version drift τ of applied async exchanges.
    Staleness,
    /// Event-queue depth sampled at each async event pop.
    QueueDepth,
    /// Virtual units a gated async worker spent idle before restarting.
    IdleUnits,
}

/// Number of histogram slots.
pub const NUM_HISTS: usize = 3;

impl Hist {
    /// Every histogram, in slot order.
    pub const ALL: [Hist; NUM_HISTS] = [Hist::Staleness, Hist::QueueDepth, Hist::IdleUnits];

    /// Stable metric name (the key in [`MetricsSnapshot::to_json`]).
    pub fn name(self) -> &'static str {
        match self {
            Hist::Staleness => "staleness",
            Hist::QueueDepth => "queue_depth",
            Hist::IdleUnits => "idle_units",
        }
    }

    fn slot(self) -> usize {
        self as usize
    }
}

/// Number of buckets per histogram.
pub const HIST_BUCKETS: usize = 8;

/// Upper bounds of the first `HIST_BUCKETS - 1` buckets (`value <=
/// bound`); the last bucket is the overflow. Coarse doubling bounds
/// cover the small-integer distributions (staleness, queue depth) and
/// the idle-unit scale alike.
pub const HIST_BOUNDS: [f64; HIST_BUCKETS - 1] = [0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 64.0];

/// A fixed-bucket histogram: count/sum/min/max plus doubling buckets.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    buckets: [u64; HIST_BUCKETS],
}

impl Histogram {
    /// Rebuild a histogram from its raw parts — the wire codec's decode
    /// side ([`crate::cluster::wire`] telemetry snapshots). The fields
    /// are trusted as-is; only the encoder's own output round-trips.
    pub(crate) fn from_parts(
        count: u64,
        sum: f64,
        min: f64,
        max: f64,
        buckets: [u64; HIST_BUCKETS],
    ) -> Histogram {
        Histogram { count, sum, min, max, buckets }
    }

    /// Record one observation.
    pub fn observe(&mut self, value: f64) {
        if self.count == 0 {
            self.min = value;
            self.max = value;
        } else {
            self.min = self.min.min(value);
            self.max = self.max.max(value);
        }
        self.count += 1;
        self.sum += value;
        let mut slot = HIST_BUCKETS - 1;
        for (i, bound) in HIST_BOUNDS.iter().enumerate() {
            if value <= *bound {
                slot = i;
                break;
            }
        }
        self.buckets[slot] += 1;
    }

    /// Mean of the observations (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Bucket occupancy, in [`HIST_BOUNDS`] order (last = overflow).
    pub fn buckets(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }

    /// Estimate the `q`-quantile (`0.0..=1.0`) by linear interpolation
    /// inside the bucket holding the target rank, using the tracked
    /// min/max as the outer bucket edges. Exact for distributions
    /// uniform within each bucket; always within one bucket width
    /// otherwise. Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 && (cum + c) as f64 >= target {
                let lo = if i == 0 { self.min.min(0.0) } else { HIST_BOUNDS[i - 1] };
                let hi = if i == HIST_BUCKETS - 1 {
                    self.max.max(HIST_BOUNDS[HIST_BUCKETS - 2])
                } else {
                    HIST_BOUNDS[i]
                };
                let frac = ((target - cum as f64) / c as f64).clamp(0.0, 1.0);
                return (lo + frac * (hi - lo)).clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }

    /// Fold another histogram's observations into this one.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = *other;
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("min", Json::Num(if self.count == 0 { 0.0 } else { self.min })),
            ("max", Json::Num(if self.count == 0 { 0.0 } else { self.max })),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.quantile(0.50))),
            ("p95", Json::Num(self.quantile(0.95))),
            ("p99", Json::Num(self.quantile(0.99))),
        ])
    }
}

/// The per-run metric store: one `u64` per [`Counter`], one
/// [`Histogram`] per [`Hist`]. Plain fixed arrays — recording never
/// allocates, so it stays on in every backend whether or not a trace
/// sink is attached.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: [u64; NUM_COUNTERS],
    hists: [Histogram; NUM_HISTS],
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Rebuild a registry from raw slot arrays (wire-codec decode side).
    pub(crate) fn from_parts(
        counters: [u64; NUM_COUNTERS],
        hists: [Histogram; NUM_HISTS],
    ) -> MetricsRegistry {
        MetricsRegistry { counters, hists }
    }

    /// Add `by` to counter `c`.
    pub fn count(&mut self, c: Counter, by: u64) {
        self.counters[c.slot()] += by;
    }

    /// Overwrite counter `c` (telemetry aggregation replaces
    /// coordinator-side estimates with daemon-authoritative values).
    pub(crate) fn set_counter(&mut self, c: Counter, value: u64) {
        self.counters[c.slot()] = value;
    }

    /// Current value of counter `c`.
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c.slot()]
    }

    /// Record one observation into histogram `h`.
    pub fn observe(&mut self, h: Hist, value: f64) {
        self.hists[h.slot()].observe(value);
    }

    /// Histogram `h` so far.
    pub fn hist(&self, h: Hist) -> &Histogram {
        &self.hists[h.slot()]
    }

    /// Fold another registry into this one (used when a run phase keeps
    /// its own registry, e.g. merged shard replies).
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (a, b) in self.counters.iter_mut().zip(other.counters.iter()) {
            *a += b;
        }
        for (a, b) in self.hists.iter_mut().zip(other.hists.iter()) {
            a.merge(b);
        }
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.iter().all(|&c| c == 0) && self.hists.iter().all(|h| h.count == 0)
    }
}

/// The immutable end-of-run summary carried on
/// [`crate::experiment::ExperimentResult`]: the final registry, ready
/// for JSON export and exporter metadata.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub registry: MetricsRegistry,
}

impl MetricsSnapshot {
    /// Snapshot a registry (cheap fixed-size copy).
    pub fn from_registry(registry: &MetricsRegistry) -> MetricsSnapshot {
        MetricsSnapshot { registry: registry.clone() }
    }

    /// Counter value by id.
    pub fn counter(&self, c: Counter) -> u64 {
        self.registry.counter(c)
    }

    /// Histogram by id.
    pub fn hist(&self, h: Hist) -> &Histogram {
        self.registry.hist(h)
    }

    /// Total wire bytes in both directions (the `ClusterStats` headline
    /// number, now uniform across backends: 0 where nothing crossed a
    /// wire).
    pub fn wire_bytes(&self) -> u64 {
        self.counter(Counter::WireBytesSent) + self.counter(Counter::WireBytesReceived)
    }

    /// JSON form: `{"counters": {...}, "hists": {name: {count, sum,
    /// min, max, mean}}}`. Zero counters and empty histograms are
    /// included, so the schema is identical across backends.
    pub fn to_json(&self) -> Json {
        let mut counters = Vec::with_capacity(NUM_COUNTERS);
        for c in Counter::ALL {
            counters.push((c.name(), Json::Num(self.registry.counter(c) as f64)));
        }
        let mut hists = Vec::with_capacity(NUM_HISTS);
        for h in Hist::ALL {
            hists.push((h.name(), self.registry.hist(h).to_json()));
        }
        Json::obj(vec![("counters", Json::obj(counters)), ("hists", Json::obj(hists))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_slot() {
        let mut r = MetricsRegistry::new();
        assert!(r.is_empty());
        r.count(Counter::MixRounds, 3);
        r.count(Counter::MixRounds, 2);
        r.count(Counter::WireBytesSent, 100);
        assert_eq!(r.counter(Counter::MixRounds), 5);
        assert_eq!(r.counter(Counter::WireBytesSent), 100);
        assert_eq!(r.counter(Counter::WireBytesReceived), 0);
        assert!(!r.is_empty());
    }

    #[test]
    fn histogram_stats_and_buckets() {
        let mut h = Histogram::default();
        assert_eq!(h.mean(), 0.0);
        for v in [0.0, 1.0, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count, 4);
        assert_eq!(h.min, 0.0);
        assert_eq!(h.max, 100.0);
        assert!((h.mean() - 26.0).abs() < 1e-12);
        // 0.0 -> bucket 0, 1.0 -> bucket 1, 3.0 -> bucket 3 (<= 4),
        // 100.0 -> overflow.
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[1], 1);
        assert_eq!(h.buckets()[3], 1);
        assert_eq!(h.buckets()[HIST_BUCKETS - 1], 1);
        assert_eq!(h.buckets().iter().sum::<u64>(), h.count);
    }

    #[test]
    fn histogram_merge_matches_direct_observation() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut both = Histogram::default();
        for v in [1.0, 5.0] {
            a.observe(v);
            both.observe(v);
        }
        for v in [0.5, 9.0, 2.0] {
            b.observe(v);
            both.observe(v);
        }
        a.merge(&b);
        assert_eq!(a, both);
        // Merging into/with empties is the identity.
        let mut empty = Histogram::default();
        empty.merge(&both);
        assert_eq!(empty, both);
        both.merge(&Histogram::default());
        assert_eq!(empty, both);
    }

    #[test]
    fn quantiles_match_known_uniform_distribution() {
        // 1..=100 uniformly: linear interpolation across the doubling
        // buckets reproduces the exact percentiles of the uniform
        // distribution, because it is uniform within every bucket.
        let mut h = Histogram::default();
        for v in 1..=100 {
            h.observe(v as f64);
        }
        assert!((h.quantile(0.50) - 50.0).abs() < 1e-9, "p50 {}", h.quantile(0.50));
        assert!((h.quantile(0.95) - 95.0).abs() < 1e-9, "p95 {}", h.quantile(0.95));
        assert!((h.quantile(0.99) - 99.0).abs() < 1e-9, "p99 {}", h.quantile(0.99));
        // Extremes clamp to the tracked min/max.
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(1.0), 100.0);
        // Empty histogram reads 0 everywhere.
        assert_eq!(Histogram::default().quantile(0.5), 0.0);
        // A constant distribution collapses every quantile to the value.
        let mut c = Histogram::default();
        for _ in 0..10 {
            c.observe(3.0);
        }
        assert_eq!(c.quantile(0.5), 3.0);
        assert_eq!(c.quantile(0.99), 3.0);
    }

    #[test]
    fn hist_json_includes_percentiles() {
        let mut h = Histogram::default();
        for v in 1..=100 {
            h.observe(v as f64);
        }
        let json = h.to_json();
        let p95 = json.get("p95").and_then(Json::as_f64).unwrap();
        assert!((p95 - 95.0).abs() < 1e-9);
        assert!(json.get("p50").is_some() && json.get("p99").is_some());
    }

    #[test]
    fn registry_merge_adds_everything() {
        let mut a = MetricsRegistry::new();
        let mut b = MetricsRegistry::new();
        a.count(Counter::Exchanges, 2);
        b.count(Counter::Exchanges, 3);
        b.observe(Hist::Staleness, 1.0);
        a.merge(&b);
        assert_eq!(a.counter(Counter::Exchanges), 5);
        assert_eq!(a.hist(Hist::Staleness).count, 1);
    }

    #[test]
    fn snapshot_json_has_uniform_schema() {
        let snap = MetricsSnapshot::default();
        let json = snap.to_json();
        let counters = json.get("counters").and_then(Json::as_object).unwrap();
        assert_eq!(counters.len(), NUM_COUNTERS);
        for c in Counter::ALL {
            assert_eq!(counters.get(c.name()).and_then(Json::as_f64), Some(0.0));
        }
        let hists = json.get("hists").and_then(Json::as_object).unwrap();
        assert_eq!(hists.len(), NUM_HISTS);
        assert_eq!(snap.wire_bytes(), 0);
    }
}
