//! Distributed telemetry: what shard-node daemons ship back over the
//! wire and how the coordinator folds it together.
//!
//! Each daemon runs its workload under a real [`crate::trace::Tracer`]
//! (a [`crate::trace::RingSink`] plus the fixed-slot
//! [`MetricsRegistry`]). A `TelemetryPull` wire frame makes the daemon
//! answer with a [`NodeTelemetry`] snapshot: session health, its
//! cumulative metric registry, and (when the pull asks for a drain)
//! the ring's trace records. The coordinator-side
//! [`TelemetryCollector`] absorbs one snapshot stream per shard:
//! registries are *replaced* on every pull (daemon registries are
//! cumulative, so replacement can never double-count across
//! reconnects), drained records are appended, and the first pull fixes
//! the per-process wall-clock offset used to place daemon records on
//! the coordinator's timeline in the merged per-pid Chrome export.
//!
//! Telemetry is observational only: pulls happen at quiescent points,
//! never enter the command/replay machinery, and are excluded from the
//! experiment's wire accounting — results stay bit-for-bit identical
//! with telemetry on or off.

use super::metrics::{Counter, MetricsRegistry};
use super::observatory::ObservatoryHealth;
use super::span::TraceRecord;

/// `shard` value a daemon reports before any `Assign` arrived.
pub const UNASSIGNED_SHARD: u32 = u32::MAX;

/// One daemon's answer to a `TelemetryPull`: session health, the
/// cumulative metric registry, and (on draining pulls) the trace ring.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeTelemetry {
    /// Assigned shard id, or [`UNASSIGNED_SHARD`] when idle pre-assign.
    pub shard: u32,
    /// Mix rounds completed in the current session.
    pub rounds_done: u64,
    /// Connection losses survived within the current session.
    pub reconnects: u64,
    /// Milliseconds since the daemon started serving.
    pub uptime_ms: u64,
    /// Trace records the ring overwrote (cumulative, survives drains).
    pub ring_dropped: u64,
    /// The daemon's wall clock (ns since its tracer epoch) when the
    /// snapshot was taken — the epoch-alignment anchor.
    pub wall_now_ns: u64,
    /// Drained trace records (empty on non-draining health pulls).
    pub records: Vec<TraceRecord>,
    /// The daemon's cumulative metric registry.
    pub registry: MetricsRegistry,
    /// The daemon's observatory health digest (drift score and windowed
    /// contraction rate); `None` before any `Assign` arrived.
    pub observatory: Option<ObservatoryHealth>,
}

/// Per-shard state the coordinator accumulates across pulls.
#[derive(Clone, Debug, Default)]
struct ShardTelemetry {
    /// All drained records so far, in daemon emission order.
    records: Vec<TraceRecord>,
    /// Latest registry (replaced wholesale per pull).
    registry: MetricsRegistry,
    /// Latest health fields (a [`NodeTelemetry`] with `records` empty).
    health: NodeTelemetry,
    /// `coordinator wall - daemon wall` at the first pull, in ns.
    wall_offset_ns: i64,
    pulls: u64,
    /// Coordinator wall time of the latest pull.
    last_pull_wall_ns: u64,
    /// `rounds_done` as of the previous pull (for rate estimates).
    prev_rounds: u64,
    /// Coordinator wall time of the previous pull.
    prev_pull_wall_ns: u64,
}

/// Coordinator-side aggregator of per-daemon telemetry streams.
pub struct TelemetryCollector {
    shards: Vec<ShardTelemetry>,
    progress: bool,
}

impl TelemetryCollector {
    /// A collector for `shards` daemon streams.
    pub fn new(shards: usize) -> TelemetryCollector {
        TelemetryCollector { shards: vec![ShardTelemetry::default(); shards], progress: false }
    }

    /// Print a per-shard progress line on every absorbed snapshot.
    pub fn enable_progress(&mut self) {
        self.progress = true;
    }

    /// Number of shard streams.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Fold one pulled snapshot into shard `shard`'s stream.
    /// `coord_wall_now_ns` is the coordinator tracer's wall clock at
    /// receipt; `link_bytes` is that link's cumulative wire traffic
    /// (progress reporting only).
    pub fn absorb(
        &mut self,
        shard: usize,
        snap: NodeTelemetry,
        coord_wall_now_ns: u64,
        link_bytes: u64,
    ) {
        let st = &mut self.shards[shard];
        if st.pulls == 0 {
            st.wall_offset_ns = coord_wall_now_ns as i64 - snap.wall_now_ns as i64;
        }
        st.records.extend_from_slice(&snap.records);
        st.registry = snap.registry.clone();
        st.health = NodeTelemetry { records: Vec::new(), registry: MetricsRegistry::new(), ..snap };
        st.prev_pull_wall_ns = st.last_pull_wall_ns;
        st.last_pull_wall_ns = coord_wall_now_ns;
        st.pulls += 1;
        if self.progress {
            self.print_progress(shard, link_bytes);
        }
        let st = &mut self.shards[shard];
        st.prev_rounds = st.health.rounds_done;
    }

    fn print_progress(&self, shard: usize, link_bytes: u64) {
        let st = &self.shards[shard];
        let mut line = format!("progress: shard {shard} round {}", st.health.rounds_done);
        if st.pulls > 1 {
            let dt_s = (st.last_pull_wall_ns.saturating_sub(st.prev_pull_wall_ns)) as f64 / 1e9;
            if dt_s > 0.0 {
                let rate = (st.health.rounds_done.saturating_sub(st.prev_rounds)) as f64 / dt_s;
                line.push_str(&format!(" ({rate:.1} rounds/s"));
                line.push_str(&format!(", {link_bytes} B on wire"));
                line.push_str(&format!(", telemetry was {dt_s:.2}s stale)"));
            }
        } else {
            line.push_str(&format!(" ({link_bytes} B on wire, first snapshot)"));
        }
        eprintln!("{line}");
    }

    /// How many snapshots shard `shard` has delivered.
    pub fn pulls(&self, shard: usize) -> u64 {
        self.shards[shard].pulls
    }

    /// All records drained from shard `shard` so far.
    pub fn records(&self, shard: usize) -> &[TraceRecord] {
        &self.shards[shard].records
    }

    /// `coordinator wall - daemon wall` in ns, fixed at the first pull.
    pub fn wall_offset_ns(&self, shard: usize) -> i64 {
        self.shards[shard].wall_offset_ns
    }

    /// Latest health snapshot for shard `shard` (records stripped);
    /// `None` before the first pull.
    pub fn health(&self, shard: usize) -> Option<&NodeTelemetry> {
        let st = &self.shards[shard];
        if st.pulls == 0 { None } else { Some(&st.health) }
    }

    /// Total trace records lost in daemon rings across all shards.
    pub fn dropped_total(&self) -> u64 {
        self.shards.iter().map(|s| s.health.ring_dropped).sum()
    }

    /// The remote run's aggregate registry: the coordinator's registry
    /// with the shard-local counters (`ShardSteps`, `ShardMsgsFolded`)
    /// replaced by the daemon-authoritative sums and every daemon
    /// histogram folded in. Coordinator wire counters are kept as-is —
    /// its `LinkStats` already cover both directions of every link.
    /// When no pull ever landed (all daemons died before the first
    /// harvest), the coordinator registry is returned unchanged.
    pub fn aggregate(&self, coordinator: &MetricsRegistry) -> MetricsRegistry {
        let mut agg = coordinator.clone();
        if self.shards.iter().all(|s| s.pulls == 0) {
            return agg;
        }
        let mut steps = 0u64;
        let mut folded = 0u64;
        for st in &self.shards {
            steps += st.registry.counter(Counter::ShardSteps);
            folded += st.registry.counter(Counter::ShardMsgsFolded);
        }
        agg.set_counter(Counter::ShardSteps, steps);
        agg.set_counter(Counter::ShardMsgsFolded, folded);
        for st in &self.shards {
            let mut hists_only = st.registry.clone();
            for c in Counter::ALL {
                hists_only.set_counter(c, 0);
            }
            agg.merge(&hists_only);
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Hist, TraceEvent};

    fn snap(shard: u32, rounds: u64, steps: u64, records: usize) -> NodeTelemetry {
        let mut registry = MetricsRegistry::new();
        registry.count(Counter::ShardSteps, steps);
        NodeTelemetry {
            shard,
            rounds_done: rounds,
            reconnects: 0,
            uptime_ms: 5,
            ring_dropped: 1,
            wall_now_ns: 1_000,
            records: (0..records)
                .map(|k| TraceRecord {
                    ev: TraceEvent::RoundBarrier { k },
                    vt: k as f64,
                    wall_ns: k as u64,
                })
                .collect(),
            registry,
            observatory: None,
        }
    }

    #[test]
    fn absorb_replaces_registry_and_appends_records() {
        let mut c = TelemetryCollector::new(2);
        assert!(c.health(0).is_none());
        c.absorb(0, snap(0, 3, 10, 2), 5_000, 0);
        // Cumulative daemon registry arrives again, larger: replaced,
        // not added — pulling twice can never double-count.
        c.absorb(0, snap(0, 7, 25, 3), 9_000, 0);
        assert_eq!(c.pulls(0), 2);
        assert_eq!(c.records(0).len(), 5);
        assert_eq!(c.health(0).unwrap().rounds_done, 7);
        let agg = c.aggregate(&MetricsRegistry::new());
        assert_eq!(agg.counter(Counter::ShardSteps), 25);
        // Offset is fixed at the first pull: 5_000 - 1_000.
        assert_eq!(c.wall_offset_ns(0), 4_000);
    }

    #[test]
    fn aggregate_replaces_shard_counters_and_merges_hists() {
        let mut c = TelemetryCollector::new(2);
        let mut s0 = snap(0, 1, 10, 0);
        s0.registry.observe(Hist::QueueDepth, 2.0);
        c.absorb(0, s0, 100, 0);
        c.absorb(1, snap(1, 1, 30, 0), 100, 0);
        let mut coord = MetricsRegistry::new();
        coord.count(Counter::ShardSteps, 999); // coordinator estimate
        coord.count(Counter::WireBytesSent, 4_096);
        let agg = c.aggregate(&coord);
        assert_eq!(agg.counter(Counter::ShardSteps), 40);
        assert_eq!(agg.counter(Counter::WireBytesSent), 4_096);
        assert_eq!(agg.hist(Hist::QueueDepth).count, 1);
        assert_eq!(c.dropped_total(), 2);
    }

    #[test]
    fn aggregate_without_pulls_is_the_coordinator_registry() {
        let c = TelemetryCollector::new(3);
        let mut coord = MetricsRegistry::new();
        coord.count(Counter::ShardSteps, 42);
        assert_eq!(c.aggregate(&coord), coord);
    }
}
