//! Trace sinks and the [`Tracer`] handle the backends emit through.
//!
//! A [`TraceSink`] consumes stamped [`TraceRecord`]s; the standard
//! implementation is [`RingSink`], a preallocated ring buffer that
//! keeps the most recent `capacity` records and counts what it dropped.
//!
//! The [`Tracer`] is what actually threads through the execution
//! layers: an optional borrowed sink plus the always-on
//! [`MetricsRegistry`]. With no sink attached (the
//! [`Tracer::disabled`] default every non-traced entry point uses),
//! event emission is a single branch and **allocates nothing** — the
//! property `benches/hotpath.rs` asserts under its counting global
//! allocator.

use super::metrics::{Counter, Hist, MetricsRegistry};
use super::observatory::Observatory;
use super::span::{TraceEvent, TraceRecord};
use std::time::Instant;

/// Consumes trace records as a run emits them.
pub trait TraceSink {
    fn record(&mut self, rec: &TraceRecord);

    /// Remove and return everything held, oldest first. Drop accounting
    /// is cumulative and survives a drain. Sinks that keep nothing
    /// (the default) return an empty vec.
    fn drain(&mut self) -> Vec<TraceRecord> {
        Vec::new()
    }

    /// Records lost so far (0 for sinks that never drop).
    fn dropped(&self) -> u64 {
        0
    }
}

/// A bounded, preallocated ring of the most recent trace records.
/// Recording never allocates once constructed; when full, the oldest
/// record is overwritten and [`RingSink::dropped`] counts the loss.
pub struct RingSink {
    buf: Vec<TraceRecord>,
    cap: usize,
    head: usize,
    dropped: u64,
}

impl RingSink {
    /// A ring holding up to `capacity` records (must be >= 1). The
    /// buffer is allocated up front.
    pub fn new(capacity: usize) -> RingSink {
        assert!(capacity >= 1, "trace ring needs capacity >= 1");
        RingSink { buf: Vec::with_capacity(capacity), cap: capacity, head: 0, dropped: 0 }
    }

    /// Records currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// How many records were overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The held records in chronological (emission) order.
    pub fn records(&self) -> Vec<TraceRecord> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    /// Remove and return the held records in chronological order,
    /// leaving the ring empty. [`RingSink::dropped`] is cumulative and
    /// is *not* reset — a telemetry consumer that drains periodically
    /// still sees the total loss across the whole run.
    pub fn drain(&mut self) -> Vec<TraceRecord> {
        let out = self.records();
        self.buf.clear();
        self.head = 0;
        out
    }
}

impl TraceSink for RingSink {
    fn record(&mut self, rec: &TraceRecord) {
        if self.buf.len() < self.cap {
            self.buf.push(*rec);
        } else {
            self.buf[self.head] = *rec;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    fn drain(&mut self) -> Vec<TraceRecord> {
        RingSink::drain(self)
    }

    fn dropped(&self) -> u64 {
        RingSink::dropped(self)
    }
}

/// The emission handle threaded through every backend: an optional
/// borrowed [`TraceSink`] plus the always-on [`MetricsRegistry`].
///
/// Emission stamps each event with the tracer's current virtual time
/// ([`Tracer::set_now`], maintained by the run loops) and the
/// wall-clock nanoseconds since the tracer was created. Counter and
/// histogram recording is unconditional (fixed-array increments);
/// event recording happens only when a sink is attached.
pub struct Tracer<'a> {
    sink: Option<&'a mut dyn TraceSink>,
    /// The run's metric registry; read out into a
    /// [`super::metrics::MetricsSnapshot`] when the run finishes.
    pub registry: MetricsRegistry,
    /// The algorithm-level observability hook
    /// ([`super::observatory::Observatory`]): disabled by default (one
    /// pointer, every hook one branch, zero allocations);
    /// [`crate::experiment::run`] enables it when the spec carries a
    /// `report` block.
    pub observatory: Observatory,
    now: f64,
    epoch: Instant,
}

impl<'a> Tracer<'a> {
    /// A tracer with no sink: events vanish in one branch, metrics
    /// still accumulate. What every non-traced entry point passes.
    pub fn disabled() -> Tracer<'static> {
        Tracer {
            sink: None,
            registry: MetricsRegistry::new(),
            observatory: Observatory::disabled(),
            now: 0.0,
            epoch: Instant::now(),
        }
    }

    /// A tracer recording events into `sink`.
    pub fn attached(sink: &'a mut dyn TraceSink) -> Tracer<'a> {
        Tracer {
            sink: Some(sink),
            registry: MetricsRegistry::new(),
            observatory: Observatory::disabled(),
            now: 0.0,
            epoch: Instant::now(),
        }
    }

    /// Is a sink attached?
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Set the current virtual time; subsequent [`Tracer::emit`] calls
    /// stamp it.
    pub fn set_now(&mut self, vt: f64) {
        self.now = vt;
    }

    /// The current virtual time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Emit `ev` at the current virtual time.
    pub fn emit(&mut self, ev: TraceEvent) {
        let vt = self.now;
        self.emit_at(vt, ev);
    }

    /// Emit `ev` at virtual time `vt`. A no-op (no allocation, no
    /// clock read) when no sink is attached.
    pub fn emit_at(&mut self, vt: f64, ev: TraceEvent) {
        if let Some(sink) = self.sink.as_deref_mut() {
            let wall_ns = self.epoch.elapsed().as_nanos() as u64;
            sink.record(&TraceRecord { ev, vt, wall_ns });
        }
    }

    /// Wall-clock nanoseconds since this tracer was created — the same
    /// clock [`Tracer::emit_at`] stamps into `wall_ns`, so drained
    /// records and this value share one epoch.
    pub fn wall_now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Drain the attached sink (empty when no sink is attached).
    pub fn drain_sink(&mut self) -> Vec<TraceRecord> {
        match self.sink.as_deref_mut() {
            Some(sink) => sink.drain(),
            None => Vec::new(),
        }
    }

    /// Records the attached sink has dropped so far (0 when detached).
    pub fn sink_dropped(&self) -> u64 {
        self.sink.as_deref().map_or(0, |s| s.dropped())
    }

    /// Add `by` to counter `c` (always on).
    pub fn count(&mut self, c: Counter, by: u64) {
        self.registry.count(c, by);
    }

    /// Record one histogram observation (always on).
    pub fn observe(&mut self, h: Hist, value: f64) {
        self.registry.observe(h, value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(k: usize) -> TraceRecord {
        TraceRecord { ev: TraceEvent::RoundBarrier { k }, vt: k as f64, wall_ns: 0 }
    }

    #[test]
    fn ring_keeps_most_recent_in_order() {
        let mut ring = RingSink::new(3);
        assert!(ring.is_empty());
        for k in 0..5 {
            ring.record(&rec(k));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.dropped(), 2);
        let ks: Vec<f64> = ring.records().iter().map(|r| r.vt).collect();
        assert_eq!(ks, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn ring_under_capacity_is_chronological() {
        let mut ring = RingSink::new(8);
        for k in 0..3 {
            ring.record(&rec(k));
        }
        assert_eq!(ring.dropped(), 0);
        let ks: Vec<f64> = ring.records().iter().map(|r| r.vt).collect();
        assert_eq!(ks, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn ring_rejects_zero_capacity() {
        RingSink::new(0);
    }

    #[test]
    fn drain_empties_ring_but_keeps_drop_count() {
        let mut ring = RingSink::new(3);
        for k in 0..5 {
            ring.record(&rec(k));
        }
        let first: Vec<f64> = ring.drain().iter().map(|r| r.vt).collect();
        assert_eq!(first, vec![2.0, 3.0, 4.0]);
        assert!(ring.is_empty());
        assert_eq!(ring.dropped(), 2);
        // Refill past capacity again: drained rings start fresh at
        // head 0 and keep accumulating the cumulative drop count.
        for k in 5..9 {
            ring.record(&rec(k));
        }
        let second: Vec<f64> = ring.drain().iter().map(|r| r.vt).collect();
        assert_eq!(second, vec![6.0, 7.0, 8.0]);
        assert_eq!(ring.dropped(), 3);
    }

    #[test]
    fn tracer_drains_through_the_sink() {
        let mut ring = RingSink::new(4);
        let mut tracer = Tracer::attached(&mut ring);
        tracer.emit(TraceEvent::RoundBarrier { k: 1 });
        assert_eq!(tracer.drain_sink().len(), 1);
        assert_eq!(tracer.drain_sink().len(), 0);
        assert_eq!(tracer.sink_dropped(), 0);
        let mut off = Tracer::disabled();
        off.emit(TraceEvent::RoundBarrier { k: 1 });
        assert!(off.drain_sink().is_empty());
        assert_eq!(off.sink_dropped(), 0);
    }

    #[test]
    fn disabled_tracer_discards_events_but_counts() {
        let mut tracer = Tracer::disabled();
        assert!(!tracer.enabled());
        tracer.set_now(4.5);
        assert_eq!(tracer.now(), 4.5);
        tracer.emit(TraceEvent::RoundBarrier { k: 0 });
        tracer.count(Counter::MixRounds, 1);
        tracer.observe(Hist::QueueDepth, 2.0);
        assert_eq!(tracer.registry.counter(Counter::MixRounds), 1);
        assert_eq!(tracer.registry.hist(Hist::QueueDepth).count, 1);
    }

    #[test]
    fn attached_tracer_stamps_time() {
        let mut ring = RingSink::new(16);
        let mut tracer = Tracer::attached(&mut ring);
        assert!(tracer.enabled());
        tracer.set_now(2.0);
        tracer.emit(TraceEvent::MixApplied { k: 7, activated: 2 });
        tracer.emit_at(3.5, TraceEvent::RoundBarrier { k: 7 });
        drop(tracer);
        let recs = ring.records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].vt, 2.0);
        assert_eq!(recs[0].ev, TraceEvent::MixApplied { k: 7, activated: 2 });
        assert_eq!(recs[1].vt, 3.5);
    }
}
