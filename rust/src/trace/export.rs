//! Trace exporters: Chrome trace-event JSON (Perfetto /
//! `chrome://tracing` loadable) and a line-per-event JSONL stream.
//!
//! The Chrome export lays a trace out as one process (`pid` 0) with one
//! track per worker, one per distinct gossip link, one per cluster wire
//! link, and a control track for round markers. Compute and link spans
//! become complete (`"ph": "X"`) events paired from their
//! `Begin`/`End` records; mixes, barriers, frames and stale exchanges
//! become instants (`"ph": "i"`). All non-metadata events are sorted by
//! timestamp, so `ts` is monotone per track by construction — the
//! property [`validate_chrome_trace`] (and `matcha trace-check`)
//! verifies.
//!
//! Timestamps are microseconds as the format requires; one virtual
//! delay unit maps to 1000 µs so sub-unit link times stay visible.

use super::span::{TraceEvent, TraceRecord};
use crate::json::Json;
use std::collections::BTreeMap;

/// Trace file format selector (`ExperimentSpec` `trace.format`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    /// Chrome trace-event JSON (`{"traceEvents": [...]}`).
    Chrome,
    /// One JSON object per line, one line per record.
    Jsonl,
}

impl TraceFormat {
    /// Short name for specs and logs (`chrome`, `jsonl`).
    pub fn name(&self) -> &'static str {
        match self {
            TraceFormat::Chrome => "chrome",
            TraceFormat::Jsonl => "jsonl",
        }
    }

    /// Parse a spec format name.
    pub fn parse(s: &str) -> Result<TraceFormat, String> {
        match s {
            "chrome" => Ok(TraceFormat::Chrome),
            "jsonl" => Ok(TraceFormat::Jsonl),
            other => Err(format!("unknown trace format '{other}' (expected chrome | jsonl)")),
        }
    }
}

/// Microseconds per virtual delay unit in the Chrome export.
const US_PER_UNIT: f64 = 1000.0;
/// Track id of the control track (mix/barrier instants).
const CONTROL_TID: usize = 9_000;
/// First track id of the per-gossip-link tracks.
const LINK_TID_BASE: usize = 10_000;
/// First track id of the per-wire-link (cluster frame) tracks.
const FRAME_TID_BASE: usize = 20_000;

fn meta_event(tid: usize, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str("thread_name".into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::Str(name.into()))])),
    ])
}

fn span_event(name: String, tid: usize, ts: f64, dur: f64, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name)),
        ("ph", Json::Str("X".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
        ("dur", Json::Num(dur)),
        ("args", args),
    ])
}

fn instant_event(name: &str, tid: usize, ts: f64, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.into())),
        ("ph", Json::Str("i".into())),
        ("s", Json::Str("t".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
        ("args", args),
    ])
}

/// Build the Chrome trace-event JSON for `records`. `other_data` (any
/// non-`Null` value, conventionally the run's metric summaries) lands
/// under the format's `otherData` key.
pub fn chrome_trace(records: &[TraceRecord], other_data: &Json) -> Json {
    // Track assignment: workers keep their id, links get stable tids in
    // first-seen order.
    let mut link_tids: BTreeMap<(usize, usize, usize), usize> = BTreeMap::new();
    let mut frame_tids: BTreeMap<usize, usize> = BTreeMap::new();
    let mut worker_tids: BTreeMap<usize, usize> = BTreeMap::new();
    let mut control_used = false;
    let mut worker = |w: usize, map: &mut BTreeMap<usize, usize>| -> usize {
        map.entry(w).or_insert(w);
        w
    };
    let mut link_tid = |j: usize, u: usize, v: usize| -> usize {
        let next = LINK_TID_BASE + link_tids.len();
        *link_tids.entry((j, u, v)).or_insert(next)
    };

    // Pair Begin/End records into complete spans; everything else is an
    // instant. Unpaired records (ring overflow) are skipped.
    let mut open_compute: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut open_link: BTreeMap<(usize, usize, usize, usize), f64> = BTreeMap::new();
    let mut timed: Vec<(f64, Json)> = Vec::new();
    for rec in records {
        let ts = rec.vt * US_PER_UNIT;
        match rec.ev {
            TraceEvent::ComputeBegin { worker: w, k } => {
                open_compute.insert((w, k), ts);
            }
            TraceEvent::ComputeEnd { worker: w, k } => {
                if let Some(beg) = open_compute.remove(&(w, k)) {
                    let tid = worker(w, &mut worker_tids);
                    let args = Json::obj(vec![("k", Json::Num(k as f64))]);
                    timed.push((beg, span_event("compute".into(), tid, beg, ts - beg, args)));
                }
            }
            TraceEvent::LinkBegin { matching, u, v, k } => {
                open_link.insert((matching, u, v, k), ts);
            }
            TraceEvent::LinkEnd { matching, u, v, k, failed } => {
                if let Some(beg) = open_link.remove(&(matching, u, v, k)) {
                    let tid = link_tid(matching, u, v);
                    let args = Json::obj(vec![
                        ("k", Json::Num(k as f64)),
                        ("failed", Json::Bool(failed)),
                    ]);
                    let name = format!("m{matching} {u}-{v}");
                    timed.push((beg, span_event(name, tid, beg, ts - beg, args)));
                }
            }
            TraceEvent::MixApplied { k, activated } => {
                control_used = true;
                let args = Json::obj(vec![
                    ("k", Json::Num(k as f64)),
                    ("activated", Json::Num(activated as f64)),
                ]);
                timed.push((ts, instant_event("mix", CONTROL_TID, ts, args)));
            }
            TraceEvent::RoundBarrier { k } => {
                control_used = true;
                let args = Json::obj(vec![("k", Json::Num(k as f64))]);
                timed.push((ts, instant_event("barrier", CONTROL_TID, ts, args)));
            }
            TraceEvent::FrameSent { link, bytes } => {
                let next = FRAME_TID_BASE + frame_tids.len();
                let tid = *frame_tids.entry(link).or_insert(next);
                let args = Json::obj(vec![("bytes", Json::Num(bytes as f64))]);
                timed.push((ts, instant_event("frame_sent", tid, ts, args)));
            }
            TraceEvent::FrameReceived { link, bytes } => {
                let next = FRAME_TID_BASE + frame_tids.len();
                let tid = *frame_tids.entry(link).or_insert(next);
                let args = Json::obj(vec![("bytes", Json::Num(bytes as f64))]);
                timed.push((ts, instant_event("frame_recv", tid, ts, args)));
            }
            TraceEvent::Reconnect { link, resumed } => {
                let next = FRAME_TID_BASE + frame_tids.len();
                let tid = *frame_tids.entry(link).or_insert(next);
                let args = Json::obj(vec![("resumed", Json::Num(resumed as f64))]);
                timed.push((ts, instant_event("reconnect", tid, ts, args)));
            }
            TraceEvent::StaleExchange { worker: w, peer, staleness, k } => {
                let tid = worker(w, &mut worker_tids);
                let args = Json::obj(vec![
                    ("peer", Json::Num(peer as f64)),
                    ("staleness", Json::Num(staleness as f64)),
                    ("k", Json::Num(k as f64)),
                ]);
                timed.push((ts, instant_event("stale_exchange", tid, ts, args)));
            }
        }
    }

    // Global sort by timestamp makes `ts` monotone on every track
    // (stable, so same-instant events keep emission order).
    timed.sort_by(|a, b| a.0.total_cmp(&b.0));

    let mut events = Vec::with_capacity(timed.len() + 8);
    events.push(Json::obj(vec![
        ("name", Json::Str("process_name".into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(0.0)),
        ("args", Json::obj(vec![("name", Json::Str("matcha".into()))])),
    ]));
    for (&w, &tid) in &worker_tids {
        events.push(meta_event(tid, &format!("worker {w}")));
    }
    for (&(j, u, v), &tid) in &link_tids {
        events.push(meta_event(tid, &format!("link m{j} {u}-{v}")));
    }
    for (&link, &tid) in &frame_tids {
        events.push(meta_event(tid, &format!("wire link {link}")));
    }
    if control_used {
        events.push(meta_event(CONTROL_TID, "rounds"));
    }
    events.extend(timed.into_iter().map(|(_, e)| e));

    let mut top = vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ];
    if *other_data != Json::Null {
        top.push(("otherData", other_data.clone()));
    }
    Json::obj(top)
}

/// One JSON object per record, one record per line (chronological).
pub fn jsonl_lines(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for rec in records {
        let mut fields = vec![("ev", Json::Str(rec.ev.name().into()))];
        match rec.ev {
            TraceEvent::ComputeBegin { worker, k } | TraceEvent::ComputeEnd { worker, k } => {
                fields.push(("worker", Json::Num(worker as f64)));
                fields.push(("k", Json::Num(k as f64)));
            }
            TraceEvent::LinkBegin { matching, u, v, k } => {
                fields.push(("matching", Json::Num(matching as f64)));
                fields.push(("u", Json::Num(u as f64)));
                fields.push(("v", Json::Num(v as f64)));
                fields.push(("k", Json::Num(k as f64)));
            }
            TraceEvent::LinkEnd { matching, u, v, k, failed } => {
                fields.push(("matching", Json::Num(matching as f64)));
                fields.push(("u", Json::Num(u as f64)));
                fields.push(("v", Json::Num(v as f64)));
                fields.push(("k", Json::Num(k as f64)));
                fields.push(("failed", Json::Bool(failed)));
            }
            TraceEvent::MixApplied { k, activated } => {
                fields.push(("k", Json::Num(k as f64)));
                fields.push(("activated", Json::Num(activated as f64)));
            }
            TraceEvent::RoundBarrier { k } => {
                fields.push(("k", Json::Num(k as f64)));
            }
            TraceEvent::FrameSent { link, bytes } | TraceEvent::FrameReceived { link, bytes } => {
                fields.push(("link", Json::Num(link as f64)));
                fields.push(("bytes", Json::Num(bytes as f64)));
            }
            TraceEvent::Reconnect { link, resumed } => {
                fields.push(("link", Json::Num(link as f64)));
                fields.push(("resumed", Json::Num(resumed as f64)));
            }
            TraceEvent::StaleExchange { worker, peer, staleness, k } => {
                fields.push(("worker", Json::Num(worker as f64)));
                fields.push(("peer", Json::Num(peer as f64)));
                fields.push(("staleness", Json::Num(staleness as f64)));
                fields.push(("k", Json::Num(k as f64)));
            }
        }
        fields.push(("vt", Json::Num(rec.vt)));
        fields.push(("wall_ns", Json::Num(rec.wall_ns as f64)));
        out.push_str(&Json::obj(fields).to_string());
        out.push('\n');
    }
    out
}

/// Write `records` to `path` in `format`, with `other_data` attached to
/// Chrome exports (ignored for JSONL).
pub fn write_trace(
    path: &std::path::Path,
    format: TraceFormat,
    records: &[TraceRecord],
    other_data: &Json,
) -> Result<(), String> {
    let text = match format {
        TraceFormat::Chrome => chrome_trace(records, other_data).to_string(),
        TraceFormat::Jsonl => jsonl_lines(records),
    };
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("trace: cannot create {}: {e}", parent.display()))?;
        }
    }
    std::fs::write(path, text).map_err(|e| format!("trace: cannot write {}: {e}", path.display()))
}

/// What [`validate_chrome_trace`] found in a well-formed trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCheck {
    /// Non-metadata events.
    pub events: usize,
    /// Distinct `(pid, tid)` tracks carrying events.
    pub tracks: usize,
}

/// Validate Chrome trace-event JSON text: a top-level object with a
/// `traceEvents` array whose entries carry `ph`/`pid`/`tid`/`ts`, with
/// `ts` non-decreasing per `(pid, tid)` track (metadata `"M"` events
/// are exempt). This is what `matcha trace-check` and `ci.sh` run over
/// emitted traces.
pub fn validate_chrome_trace(text: &str) -> Result<TraceCheck, String> {
    let json = Json::parse(text).map_err(|e| format!("trace: {e}"))?;
    let obj = json.as_object().ok_or("trace: top level must be an object")?;
    let events = obj
        .get("traceEvents")
        .ok_or("trace: missing 'traceEvents' key")?
        .as_array()
        .ok_or("trace: 'traceEvents' must be an array")?;
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut counted = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let e = ev.as_object().ok_or(format!("trace: event {i} is not an object"))?;
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or(format!("trace: event {i} missing string 'ph'"))?;
        if ph == "M" {
            continue;
        }
        let pid = e
            .get("pid")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: event {i} missing numeric 'pid'"))?;
        let tid = e
            .get("tid")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: event {i} missing numeric 'tid'"))?;
        let ts = e
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: event {i} missing numeric 'ts'"))?;
        if !ts.is_finite() {
            return Err(format!("trace: event {i} has non-finite ts"));
        }
        let key = (pid.to_bits(), tid.to_bits());
        if let Some(prev) = last_ts.get(&key) {
            if ts < *prev {
                return Err(format!(
                    "trace: ts went backwards on track pid {pid} tid {tid} at event {i}: \
                     {ts} < {prev}"
                ));
            }
        }
        last_ts.insert(key, ts);
        counted += 1;
    }
    Ok(TraceCheck { events: counted, tracks: last_ts.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<TraceRecord> {
        let mut recs = Vec::new();
        let mut push = |vt: f64, ev: TraceEvent| recs.push(TraceRecord { ev, vt, wall_ns: 0 });
        for w in 0..2 {
            push(0.0, TraceEvent::ComputeBegin { worker: w, k: 0 });
        }
        for w in 0..2 {
            push(1.0, TraceEvent::ComputeEnd { worker: w, k: 0 });
        }
        push(1.0, TraceEvent::LinkBegin { matching: 0, u: 0, v: 1, k: 0 });
        push(2.0, TraceEvent::LinkEnd { matching: 0, u: 0, v: 1, k: 0, failed: false });
        push(2.0, TraceEvent::FrameSent { link: 0, bytes: 64 });
        push(2.0, TraceEvent::FrameReceived { link: 0, bytes: 32 });
        push(2.0, TraceEvent::StaleExchange { worker: 1, peer: 0, staleness: 1, k: 0 });
        push(2.0, TraceEvent::MixApplied { k: 0, activated: 1 });
        push(2.0, TraceEvent::RoundBarrier { k: 0 });
        recs
    }

    #[test]
    fn chrome_export_validates_with_expected_tracks() {
        let json = chrome_trace(&sample_records(), &Json::Null);
        let text = json.to_string();
        let check = validate_chrome_trace(&text).unwrap();
        // 2 compute spans + 1 link span + 5 instants.
        assert_eq!(check.events, 8);
        // 2 worker tracks, 1 link track, 1 wire track, 1 control track.
        assert_eq!(check.tracks, 5);
        // Thread-name metadata names every track kind.
        assert!(text.contains("worker 0"), "{text}");
        assert!(text.contains("link m0 0-1"), "{text}");
        assert!(text.contains("wire link 0"), "{text}");
        assert!(text.contains("\"displayTimeUnit\""), "{text}");
    }

    #[test]
    fn chrome_export_attaches_other_data() {
        let meta = Json::obj(vec![("final_loss", Json::Num(0.5))]);
        let json = chrome_trace(&sample_records(), &meta);
        assert_eq!(json.get("otherData"), Some(&meta));
        assert_eq!(chrome_trace(&[], &Json::Null).get("otherData"), None);
    }

    #[test]
    fn unpaired_begins_are_skipped_not_exported() {
        let recs = vec![TraceRecord {
            ev: TraceEvent::ComputeBegin { worker: 0, k: 0 },
            vt: 0.0,
            wall_ns: 0,
        }];
        let check = validate_chrome_trace(&chrome_trace(&recs, &Json::Null).to_string()).unwrap();
        assert_eq!(check.events, 0);
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line() {
        let text = jsonl_lines(&sample_records());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), sample_records().len());
        for line in &lines {
            let json = Json::parse(line).unwrap();
            assert!(json.get("ev").and_then(Json::as_str).is_some(), "{line}");
            assert!(json.get("vt").and_then(Json::as_f64).is_some(), "{line}");
        }
        assert!(lines[0].contains("compute_begin"));
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_trace("[]").unwrap_err().contains("object"));
        assert!(validate_chrome_trace("{}").unwrap_err().contains("traceEvents"));
        let backwards = r#"{"traceEvents": [
            {"ph": "i", "pid": 0, "tid": 1, "ts": 5.0},
            {"ph": "i", "pid": 0, "tid": 1, "ts": 4.0}
        ]}"#;
        assert!(validate_chrome_trace(backwards).unwrap_err().contains("backwards"));
        // Different tracks may interleave timestamps freely.
        let two_tracks = r#"{"traceEvents": [
            {"ph": "i", "pid": 0, "tid": 1, "ts": 5.0},
            {"ph": "i", "pid": 0, "tid": 2, "ts": 4.0},
            {"ph": "M", "pid": 0, "tid": 1, "name": "thread_name"}
        ]}"#;
        let check = validate_chrome_trace(two_tracks).unwrap();
        assert_eq!(check.events, 2);
        assert_eq!(check.tracks, 2);
    }

    #[test]
    fn trace_format_names_roundtrip() {
        for f in [TraceFormat::Chrome, TraceFormat::Jsonl] {
            assert_eq!(TraceFormat::parse(f.name()), Ok(f));
        }
        assert!(TraceFormat::parse("pprof").is_err());
    }
}
