//! Trace exporters: Chrome trace-event JSON (Perfetto /
//! `chrome://tracing` loadable) and a line-per-event JSONL stream.
//!
//! The Chrome export lays a trace out as one process (`pid` 0) with one
//! track per worker, one per distinct gossip link, one per cluster wire
//! link, and a control track for round markers. Compute and link spans
//! become complete (`"ph": "X"`) events paired from their
//! `Begin`/`End` records; mixes, barriers, frames and stale exchanges
//! become instants (`"ph": "i"`). All non-metadata events are sorted by
//! timestamp, so `ts` is monotone per track by construction — the
//! property [`validate_chrome_trace`] (and `matcha trace-check`)
//! verifies.
//!
//! Timestamps are microseconds as the format requires; one virtual
//! delay unit maps to 1000 µs so sub-unit link times stay visible.

use super::span::{TraceEvent, TraceRecord};
use crate::json::Json;
use std::collections::BTreeMap;

/// Trace file format selector (`ExperimentSpec` `trace.format`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    /// Chrome trace-event JSON (`{"traceEvents": [...]}`).
    Chrome,
    /// One JSON object per line, one line per record.
    Jsonl,
}

impl TraceFormat {
    /// Short name for specs and logs (`chrome`, `jsonl`).
    pub fn name(&self) -> &'static str {
        match self {
            TraceFormat::Chrome => "chrome",
            TraceFormat::Jsonl => "jsonl",
        }
    }

    /// Parse a spec format name.
    pub fn parse(s: &str) -> Result<TraceFormat, String> {
        match s {
            "chrome" => Ok(TraceFormat::Chrome),
            "jsonl" => Ok(TraceFormat::Jsonl),
            other => Err(format!("unknown trace format '{other}' (expected chrome | jsonl)")),
        }
    }
}

/// Microseconds per virtual delay unit in the Chrome export.
const US_PER_UNIT: f64 = 1000.0;
/// Track id of the control track (mix/barrier instants).
const CONTROL_TID: usize = 9_000;
/// First track id of the per-gossip-link tracks.
const LINK_TID_BASE: usize = 10_000;
/// First track id of the per-wire-link (cluster frame) tracks.
const FRAME_TID_BASE: usize = 20_000;

fn meta_event(pid: usize, tid: usize, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str("thread_name".into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::Str(name.into()))])),
    ])
}

fn span_event(name: String, pid: usize, tid: usize, ts: f64, dur: f64, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name)),
        ("ph", Json::Str("X".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
        ("dur", Json::Num(dur)),
        ("args", args),
    ])
}

fn instant_event(name: &str, pid: usize, tid: usize, ts: f64, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.into())),
        ("ph", Json::Str("i".into())),
        ("s", Json::Str("t".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
        ("args", args),
    ])
}

/// One process in a merged multi-process Chrome export: a `pid`, a
/// display name, its records, and how to place them on the shared
/// timeline.
pub struct PidTrack<'a> {
    /// Chrome `pid` of this process (convention: coordinator = 0,
    /// shard `s` = `s + 1`).
    pub pid: usize,
    /// Process name shown in the viewer.
    pub name: String,
    /// The process's trace records, chronological.
    pub records: &'a [TraceRecord],
    /// `None`: timestamps come from virtual time (the coordinator's
    /// deterministic timeline). `Some(offset_ns)`: timestamps come
    /// from `wall_ns + offset_ns` — daemon records mapped onto the
    /// coordinator's wall clock via the handshake-aligned epoch offset.
    pub wall_offset_ns: Option<i64>,
}

/// Build one process's metadata and timed events. Returns the metadata
/// events; timed events are appended to `timed` for global sorting.
fn build_pid_events(track: &PidTrack<'_>, timed: &mut Vec<(f64, Json)>) -> Vec<Json> {
    let pid = track.pid;
    // Track assignment: workers keep their id, links get stable tids in
    // first-seen order.
    let mut link_tids: BTreeMap<(usize, usize, usize), usize> = BTreeMap::new();
    let mut frame_tids: BTreeMap<usize, usize> = BTreeMap::new();
    let mut worker_tids: BTreeMap<usize, usize> = BTreeMap::new();
    let mut control_used = false;
    let mut worker = |w: usize, map: &mut BTreeMap<usize, usize>| -> usize {
        map.entry(w).or_insert(w);
        w
    };
    let mut link_tid = |j: usize, u: usize, v: usize| -> usize {
        let next = LINK_TID_BASE + link_tids.len();
        *link_tids.entry((j, u, v)).or_insert(next)
    };
    let ts_of = |rec: &TraceRecord| -> f64 {
        match track.wall_offset_ns {
            None => rec.vt * US_PER_UNIT,
            Some(off) => (rec.wall_ns as i64 + off).max(0) as f64 / 1000.0,
        }
    };

    // Pair Begin/End records into complete spans; everything else is an
    // instant. Unpaired records (ring overflow) are skipped.
    let mut open_compute: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut open_link: BTreeMap<(usize, usize, usize, usize), f64> = BTreeMap::new();
    for rec in track.records {
        let ts = ts_of(rec);
        match rec.ev {
            TraceEvent::ComputeBegin { worker: w, k } => {
                open_compute.insert((w, k), ts);
            }
            TraceEvent::ComputeEnd { worker: w, k } => {
                if let Some(beg) = open_compute.remove(&(w, k)) {
                    let tid = worker(w, &mut worker_tids);
                    let args = Json::obj(vec![("k", Json::Num(k as f64))]);
                    timed.push((beg, span_event("compute".into(), pid, tid, beg, ts - beg, args)));
                }
            }
            TraceEvent::LinkBegin { matching, u, v, k } => {
                open_link.insert((matching, u, v, k), ts);
            }
            TraceEvent::LinkEnd { matching, u, v, k, failed } => {
                if let Some(beg) = open_link.remove(&(matching, u, v, k)) {
                    let tid = link_tid(matching, u, v);
                    let args = Json::obj(vec![
                        ("k", Json::Num(k as f64)),
                        ("failed", Json::Bool(failed)),
                    ]);
                    let name = format!("m{matching} {u}-{v}");
                    timed.push((beg, span_event(name, pid, tid, beg, ts - beg, args)));
                }
            }
            TraceEvent::MixApplied { k, activated } => {
                control_used = true;
                let args = Json::obj(vec![
                    ("k", Json::Num(k as f64)),
                    ("activated", Json::Num(activated as f64)),
                ]);
                timed.push((ts, instant_event("mix", pid, CONTROL_TID, ts, args)));
            }
            TraceEvent::RoundBarrier { k } => {
                control_used = true;
                let args = Json::obj(vec![("k", Json::Num(k as f64))]);
                timed.push((ts, instant_event("barrier", pid, CONTROL_TID, ts, args)));
            }
            TraceEvent::FrameSent { link, bytes } => {
                let next = FRAME_TID_BASE + frame_tids.len();
                let tid = *frame_tids.entry(link).or_insert(next);
                let args = Json::obj(vec![("bytes", Json::Num(bytes as f64))]);
                timed.push((ts, instant_event("frame_sent", pid, tid, ts, args)));
            }
            TraceEvent::FrameReceived { link, bytes } => {
                let next = FRAME_TID_BASE + frame_tids.len();
                let tid = *frame_tids.entry(link).or_insert(next);
                let args = Json::obj(vec![("bytes", Json::Num(bytes as f64))]);
                timed.push((ts, instant_event("frame_recv", pid, tid, ts, args)));
            }
            TraceEvent::Reconnect { link, resumed } => {
                let next = FRAME_TID_BASE + frame_tids.len();
                let tid = *frame_tids.entry(link).or_insert(next);
                let args = Json::obj(vec![("resumed", Json::Num(resumed as f64))]);
                timed.push((ts, instant_event("reconnect", pid, tid, ts, args)));
            }
            TraceEvent::StaleExchange { worker: w, peer, staleness, k } => {
                let tid = worker(w, &mut worker_tids);
                let args = Json::obj(vec![
                    ("peer", Json::Num(peer as f64)),
                    ("staleness", Json::Num(staleness as f64)),
                    ("k", Json::Num(k as f64)),
                ]);
                timed.push((ts, instant_event("stale_exchange", pid, tid, ts, args)));
            }
        }
    }

    let mut metas = Vec::with_capacity(8);
    metas.push(Json::obj(vec![
        ("name", Json::Str("process_name".into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        ("args", Json::obj(vec![("name", Json::Str(track.name.clone()))])),
    ]));
    for (&w, &tid) in &worker_tids {
        metas.push(meta_event(pid, tid, &format!("worker {w}")));
    }
    for (&(j, u, v), &tid) in &link_tids {
        metas.push(meta_event(pid, tid, &format!("link m{j} {u}-{v}")));
    }
    for (&link, &tid) in &frame_tids {
        metas.push(meta_event(pid, tid, &format!("wire link {link}")));
    }
    if control_used {
        metas.push(meta_event(pid, CONTROL_TID, "rounds"));
    }
    metas
}

/// Build the Chrome trace-event JSON for a single process (`pid` 0).
/// `other_data` (any non-`Null` value, conventionally the run's metric
/// summaries) lands under the format's `otherData` key.
pub fn chrome_trace(records: &[TraceRecord], other_data: &Json) -> Json {
    let track = PidTrack { pid: 0, name: "matcha".into(), records, wall_offset_ns: None };
    chrome_trace_merged(std::slice::from_ref(&track), other_data)
}

/// Build one Chrome trace-event JSON merging several processes — the
/// distributed-telemetry export, with the coordinator's virtual-time
/// track at `pid` 0 and one wall-clock track per shard daemon. All
/// timed events share one globally sorted timeline, so `ts` stays
/// monotone per `(pid, tid)` track.
pub fn chrome_trace_merged(tracks: &[PidTrack<'_>], other_data: &Json) -> Json {
    let mut timed: Vec<(f64, Json)> = Vec::new();
    let mut events = Vec::new();
    for track in tracks {
        events.extend(build_pid_events(track, &mut timed));
    }
    // Global sort by timestamp makes `ts` monotone on every track
    // (stable, so same-instant events keep emission order).
    timed.sort_by(|a, b| a.0.total_cmp(&b.0));
    events.extend(timed.into_iter().map(|(_, e)| e));

    let mut top = vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ];
    if *other_data != Json::Null {
        top.push(("otherData", other_data.clone()));
    }
    Json::obj(top)
}

/// One JSON object per record, one record per line (chronological).
pub fn jsonl_lines(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for rec in records {
        let mut fields = vec![("ev", Json::Str(rec.ev.name().into()))];
        match rec.ev {
            TraceEvent::ComputeBegin { worker, k } | TraceEvent::ComputeEnd { worker, k } => {
                fields.push(("worker", Json::Num(worker as f64)));
                fields.push(("k", Json::Num(k as f64)));
            }
            TraceEvent::LinkBegin { matching, u, v, k } => {
                fields.push(("matching", Json::Num(matching as f64)));
                fields.push(("u", Json::Num(u as f64)));
                fields.push(("v", Json::Num(v as f64)));
                fields.push(("k", Json::Num(k as f64)));
            }
            TraceEvent::LinkEnd { matching, u, v, k, failed } => {
                fields.push(("matching", Json::Num(matching as f64)));
                fields.push(("u", Json::Num(u as f64)));
                fields.push(("v", Json::Num(v as f64)));
                fields.push(("k", Json::Num(k as f64)));
                fields.push(("failed", Json::Bool(failed)));
            }
            TraceEvent::MixApplied { k, activated } => {
                fields.push(("k", Json::Num(k as f64)));
                fields.push(("activated", Json::Num(activated as f64)));
            }
            TraceEvent::RoundBarrier { k } => {
                fields.push(("k", Json::Num(k as f64)));
            }
            TraceEvent::FrameSent { link, bytes } | TraceEvent::FrameReceived { link, bytes } => {
                fields.push(("link", Json::Num(link as f64)));
                fields.push(("bytes", Json::Num(bytes as f64)));
            }
            TraceEvent::Reconnect { link, resumed } => {
                fields.push(("link", Json::Num(link as f64)));
                fields.push(("resumed", Json::Num(resumed as f64)));
            }
            TraceEvent::StaleExchange { worker, peer, staleness, k } => {
                fields.push(("worker", Json::Num(worker as f64)));
                fields.push(("peer", Json::Num(peer as f64)));
                fields.push(("staleness", Json::Num(staleness as f64)));
                fields.push(("k", Json::Num(k as f64)));
            }
        }
        fields.push(("vt", Json::Num(rec.vt)));
        fields.push(("wall_ns", Json::Num(rec.wall_ns as f64)));
        out.push_str(&Json::obj(fields).to_string());
        out.push('\n');
    }
    out
}

/// Write `records` to `path` in `format`, with `other_data` attached to
/// Chrome exports (ignored for JSONL).
pub fn write_trace(
    path: &std::path::Path,
    format: TraceFormat,
    records: &[TraceRecord],
    other_data: &Json,
) -> Result<(), String> {
    let text = match format {
        TraceFormat::Chrome => chrome_trace(records, other_data).to_string(),
        TraceFormat::Jsonl => jsonl_lines(records),
    };
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("trace: cannot create {}: {e}", parent.display()))?;
        }
    }
    std::fs::write(path, text).map_err(|e| format!("trace: cannot write {}: {e}", path.display()))
}

/// What [`validate_chrome_trace`] found in a well-formed trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCheck {
    /// Non-metadata events.
    pub events: usize,
    /// Distinct `(pid, tid)` tracks carrying events.
    pub tracks: usize,
    /// Distinct `pid`s carrying events (1 for single-process traces).
    pub pids: usize,
    /// Records the producing ring(s) dropped, when the exporter
    /// surfaced it (`otherData.dropped_records`); `None` when absent.
    /// Non-zero means the trace was truncated at the source.
    pub dropped: Option<u64>,
}

/// Validate Chrome trace-event JSON text: a top-level object with a
/// `traceEvents` array whose entries carry `ph`/`pid`/`tid`/`ts`, with
/// `ts` non-decreasing per `(pid, tid)` track (metadata `"M"` events
/// are exempt). This is what `matcha trace-check` and `ci.sh` run over
/// emitted traces.
pub fn validate_chrome_trace(text: &str) -> Result<TraceCheck, String> {
    let json = Json::parse(text).map_err(|e| format!("trace: {e}"))?;
    let obj = json.as_object().ok_or("trace: top level must be an object")?;
    let events = obj
        .get("traceEvents")
        .ok_or("trace: missing 'traceEvents' key")?
        .as_array()
        .ok_or("trace: 'traceEvents' must be an array")?;
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut counted = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let e = ev.as_object().ok_or(format!("trace: event {i} is not an object"))?;
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or(format!("trace: event {i} missing string 'ph'"))?;
        if ph == "M" {
            continue;
        }
        let pid = e
            .get("pid")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: event {i} missing numeric 'pid'"))?;
        let tid = e
            .get("tid")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: event {i} missing numeric 'tid'"))?;
        let ts = e
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: event {i} missing numeric 'ts'"))?;
        if !ts.is_finite() {
            return Err(format!("trace: event {i} has non-finite ts"));
        }
        let key = (pid.to_bits(), tid.to_bits());
        if let Some(prev) = last_ts.get(&key) {
            if ts < *prev {
                return Err(format!(
                    "trace: ts went backwards on track pid {pid} tid {tid} at event {i}: \
                     {ts} < {prev}"
                ));
            }
        }
        last_ts.insert(key, ts);
        counted += 1;
    }
    let pids: std::collections::BTreeSet<u64> = last_ts.keys().map(|&(pid, _)| pid).collect();
    let dropped = obj
        .get("otherData")
        .and_then(|o| o.get("dropped_records"))
        .and_then(Json::as_f64)
        .map(|v| v as u64);
    Ok(TraceCheck { events: counted, tracks: last_ts.len(), pids: pids.len(), dropped })
}

/// What [`validate_jsonl_trace`] found in a well-formed JSONL stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JsonlCheck {
    /// Records (lines) in the stream.
    pub records: usize,
    /// Distinct event kinds seen.
    pub kinds: usize,
}

/// Validate a JSONL trace stream as [`jsonl_lines`] writes it: one
/// JSON object per line, each with a known `ev` name, a finite numeric
/// `vt` and a non-negative numeric `wall_ns`. This is what
/// `matcha trace-check --format jsonl` runs.
pub fn validate_jsonl_trace(text: &str) -> Result<JsonlCheck, String> {
    let mut kinds: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    let mut records = 0usize;
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        let json = Json::parse(line).map_err(|e| format!("trace: line {n}: {e}"))?;
        let obj = json.as_object().ok_or(format!("trace: line {n} is not an object"))?;
        let ev = obj
            .get("ev")
            .and_then(Json::as_str)
            .ok_or(format!("trace: line {n} missing string 'ev'"))?;
        if !TraceEvent::NAMES.contains(&ev) {
            return Err(format!("trace: line {n} has unknown event '{ev}'"));
        }
        let vt = obj
            .get("vt")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: line {n} missing numeric 'vt'"))?;
        if !vt.is_finite() {
            return Err(format!("trace: line {n} has non-finite vt"));
        }
        let wall = obj
            .get("wall_ns")
            .and_then(Json::as_f64)
            .ok_or(format!("trace: line {n} missing numeric 'wall_ns'"))?;
        if !(wall.is_finite() && wall >= 0.0) {
            return Err(format!("trace: line {n} has invalid wall_ns"));
        }
        kinds.insert(ev.to_string());
        records += 1;
    }
    Ok(JsonlCheck { records, kinds: kinds.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<TraceRecord> {
        let mut recs = Vec::new();
        let mut push = |vt: f64, ev: TraceEvent| recs.push(TraceRecord { ev, vt, wall_ns: 0 });
        for w in 0..2 {
            push(0.0, TraceEvent::ComputeBegin { worker: w, k: 0 });
        }
        for w in 0..2 {
            push(1.0, TraceEvent::ComputeEnd { worker: w, k: 0 });
        }
        push(1.0, TraceEvent::LinkBegin { matching: 0, u: 0, v: 1, k: 0 });
        push(2.0, TraceEvent::LinkEnd { matching: 0, u: 0, v: 1, k: 0, failed: false });
        push(2.0, TraceEvent::FrameSent { link: 0, bytes: 64 });
        push(2.0, TraceEvent::FrameReceived { link: 0, bytes: 32 });
        push(2.0, TraceEvent::StaleExchange { worker: 1, peer: 0, staleness: 1, k: 0 });
        push(2.0, TraceEvent::MixApplied { k: 0, activated: 1 });
        push(2.0, TraceEvent::RoundBarrier { k: 0 });
        recs
    }

    #[test]
    fn chrome_export_validates_with_expected_tracks() {
        let json = chrome_trace(&sample_records(), &Json::Null);
        let text = json.to_string();
        let check = validate_chrome_trace(&text).unwrap();
        // 2 compute spans + 1 link span + 5 instants.
        assert_eq!(check.events, 8);
        // 2 worker tracks, 1 link track, 1 wire track, 1 control track.
        assert_eq!(check.tracks, 5);
        assert_eq!(check.pids, 1);
        assert_eq!(check.dropped, None);
        // Thread-name metadata names every track kind.
        assert!(text.contains("worker 0"), "{text}");
        assert!(text.contains("link m0 0-1"), "{text}");
        assert!(text.contains("wire link 0"), "{text}");
        assert!(text.contains("\"displayTimeUnit\""), "{text}");
    }

    #[test]
    fn chrome_export_attaches_other_data() {
        let meta = Json::obj(vec![("final_loss", Json::Num(0.5))]);
        let json = chrome_trace(&sample_records(), &meta);
        assert_eq!(json.get("otherData"), Some(&meta));
        assert_eq!(chrome_trace(&[], &Json::Null).get("otherData"), None);
    }

    #[test]
    fn unpaired_begins_are_skipped_not_exported() {
        let recs = vec![TraceRecord {
            ev: TraceEvent::ComputeBegin { worker: 0, k: 0 },
            vt: 0.0,
            wall_ns: 0,
        }];
        let check = validate_chrome_trace(&chrome_trace(&recs, &Json::Null).to_string()).unwrap();
        assert_eq!(check.events, 0);
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line() {
        let text = jsonl_lines(&sample_records());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), sample_records().len());
        for line in &lines {
            let json = Json::parse(line).unwrap();
            assert!(json.get("ev").and_then(Json::as_str).is_some(), "{line}");
            assert!(json.get("vt").and_then(Json::as_f64).is_some(), "{line}");
        }
        assert!(lines[0].contains("compute_begin"));
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_trace("[]").unwrap_err().contains("object"));
        assert!(validate_chrome_trace("{}").unwrap_err().contains("traceEvents"));
        let backwards = r#"{"traceEvents": [
            {"ph": "i", "pid": 0, "tid": 1, "ts": 5.0},
            {"ph": "i", "pid": 0, "tid": 1, "ts": 4.0}
        ]}"#;
        assert!(validate_chrome_trace(backwards).unwrap_err().contains("backwards"));
        // Different tracks may interleave timestamps freely.
        let two_tracks = r#"{"traceEvents": [
            {"ph": "i", "pid": 0, "tid": 1, "ts": 5.0},
            {"ph": "i", "pid": 0, "tid": 2, "ts": 4.0},
            {"ph": "M", "pid": 0, "tid": 1, "name": "thread_name"}
        ]}"#;
        let check = validate_chrome_trace(two_tracks).unwrap();
        assert_eq!(check.events, 2);
        assert_eq!(check.tracks, 2);
    }

    #[test]
    fn merged_export_keeps_tracks_per_pid() {
        let coord = sample_records();
        // Daemon records: wall-clock stamped compute span + mix marker.
        let daemon = vec![
            TraceRecord {
                ev: TraceEvent::ComputeBegin { worker: 0, k: 0 },
                vt: 0.0,
                wall_ns: 1_000_000,
            },
            TraceRecord {
                ev: TraceEvent::ComputeEnd { worker: 0, k: 0 },
                vt: 0.0,
                wall_ns: 3_000_000,
            },
            TraceRecord {
                ev: TraceEvent::MixApplied { k: 0, activated: 1 },
                vt: 0.0,
                wall_ns: 4_000_000,
            },
        ];
        let tracks = [
            PidTrack { pid: 0, name: "coordinator".into(), records: &coord, wall_offset_ns: None },
            PidTrack {
                pid: 1,
                name: "shard 0".into(),
                records: &daemon,
                // A negative offset clamps instead of going negative.
                wall_offset_ns: Some(-2_000_000),
            },
        ];
        let json = chrome_trace_merged(&tracks, &Json::Null);
        let text = json.to_string();
        let check = validate_chrome_trace(&text).unwrap();
        assert_eq!(check.pids, 2);
        // pid 0's 5 tracks plus the daemon's worker + control tracks.
        assert_eq!(check.tracks, 7);
        assert_eq!(check.events, 8 + 2);
        assert!(text.contains("coordinator"), "{text}");
        assert!(text.contains("shard 0"), "{text}");
    }

    #[test]
    fn dropped_records_surface_through_other_data() {
        let meta = Json::obj(vec![("dropped_records", Json::Num(7.0))]);
        let json = chrome_trace(&sample_records(), &meta);
        let check = validate_chrome_trace(&json.to_string()).unwrap();
        assert_eq!(check.dropped, Some(7));
    }

    #[test]
    fn jsonl_validator_accepts_own_output_and_rejects_garbage() {
        let text = jsonl_lines(&sample_records());
        let check = validate_jsonl_trace(&text).unwrap();
        assert_eq!(check.records, sample_records().len());
        assert!(check.kinds >= 5);
        assert_eq!(validate_jsonl_trace("").unwrap(), JsonlCheck { records: 0, kinds: 0 });
        assert!(validate_jsonl_trace("not json\n").unwrap_err().contains("line 1"));
        assert!(validate_jsonl_trace("[1]\n").unwrap_err().contains("not an object"));
        assert!(validate_jsonl_trace(r#"{"ev": "warp", "vt": 0, "wall_ns": 0}"#)
            .unwrap_err()
            .contains("unknown event"));
        assert!(validate_jsonl_trace(r#"{"ev": "round_barrier", "wall_ns": 0}"#)
            .unwrap_err()
            .contains("vt"));
        assert!(validate_jsonl_trace(r#"{"ev": "round_barrier", "vt": 0, "wall_ns": -5}"#)
            .unwrap_err()
            .contains("wall_ns"));
    }

    #[test]
    fn trace_format_names_roundtrip() {
        for f in [TraceFormat::Chrome, TraceFormat::Jsonl] {
            assert_eq!(TraceFormat::parse(f.name()), Ok(f));
        }
        assert!(TraceFormat::parse("pprof").is_err());
    }
}
