//! Typed trace events and the stamped records the sinks collect.
//!
//! A [`TraceEvent`] is a `Copy` description of one thing that happened
//! inside a run — a worker starting or finishing its local step, a link
//! transmitting, a mix round applying, wire frames moving, a stale
//! exchange resolving. Every backend emits the same vocabulary, which is
//! what makes cross-backend trace comparison (and the determinism tests
//! in `rust/tests/trace.rs`) possible.
//!
//! A [`TraceRecord`] stamps an event with the virtual time it happened
//! at and the wall-clock nanoseconds since the tracer was created. The
//! barrier backends are virtual-time deterministic, so their `(event,
//! vt)` sequences are bit-for-bit reproducible per seed; `wall_ns` is
//! informational (actors/async/cluster thread timing) and never part of
//! any determinism contract.

/// One thing that happened inside a run, tagged with the ids needed to
/// place it on a per-worker or per-link track.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// Worker `worker` began its local gradient step for iteration `k`.
    ComputeBegin { worker: usize, k: usize },
    /// Worker `worker` finished its local gradient step for iteration `k`.
    ComputeEnd { worker: usize, k: usize },
    /// Link `(u, v)` of matching `matching` began transmitting at
    /// iteration `k`.
    LinkBegin { matching: usize, u: usize, v: usize, k: usize },
    /// Link `(u, v)` of matching `matching` finished at iteration `k`;
    /// `failed` marks failure-injected links (time elapsed, edge
    /// excluded from the mix).
    LinkEnd { matching: usize, u: usize, v: usize, k: usize, failed: bool },
    /// The gossip mix for iteration `k` was applied over `activated`
    /// matchings (0 = a round with no communication).
    MixApplied { k: usize, activated: usize },
    /// The barrier closing iteration `k`: every backend's "round done"
    /// point, stamped at the round's final virtual time.
    RoundBarrier { k: usize },
    /// The cluster coordinator finished sending `bytes` of wire frames
    /// to shard link `link` during one phase.
    FrameSent { link: usize, bytes: u64 },
    /// The cluster coordinator finished receiving `bytes` of wire frames
    /// from shard link `link` during one phase.
    FrameReceived { link: usize, bytes: u64 },
    /// The remote coordinator re-established shard link `link` and
    /// resumed the command stream, re-sending `resumed` in-flight
    /// frames the daemon had not yet processed. Never emitted by the
    /// in-process backends.
    Reconnect { link: usize, resumed: u64 },
    /// The async runtime applied a pairwise exchange between `worker`
    /// and `peer` for round `k` at version drift `staleness`.
    StaleExchange { worker: usize, peer: usize, staleness: usize, k: usize },
}

impl TraceEvent {
    /// Every stable event name, in declaration order (the JSONL
    /// validator's vocabulary).
    pub const NAMES: [&'static str; 10] = [
        "compute_begin",
        "compute_end",
        "link_begin",
        "link_end",
        "mix_applied",
        "round_barrier",
        "frame_sent",
        "frame_received",
        "reconnect",
        "stale_exchange",
    ];

    /// Stable event name used by both exporters.
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::ComputeBegin { .. } => "compute_begin",
            TraceEvent::ComputeEnd { .. } => "compute_end",
            TraceEvent::LinkBegin { .. } => "link_begin",
            TraceEvent::LinkEnd { .. } => "link_end",
            TraceEvent::MixApplied { .. } => "mix_applied",
            TraceEvent::RoundBarrier { .. } => "round_barrier",
            TraceEvent::FrameSent { .. } => "frame_sent",
            TraceEvent::FrameReceived { .. } => "frame_received",
            TraceEvent::Reconnect { .. } => "reconnect",
            TraceEvent::StaleExchange { .. } => "stale_exchange",
        }
    }

    /// Is this a wire-frame event? The cluster backend emits these on
    /// top of the schedule events the actors backend produces, so the
    /// cluster-vs-actors trace parity test filters them out.
    pub fn is_frame(&self) -> bool {
        matches!(self, TraceEvent::FrameSent { .. } | TraceEvent::FrameReceived { .. })
    }

    /// Is this a per-link schedule event? The sequential simulator
    /// accounts communication time in closed form and emits no link
    /// events, so the sim-vs-engine parity test filters these.
    pub fn is_link(&self) -> bool {
        matches!(self, TraceEvent::LinkBegin { .. } | TraceEvent::LinkEnd { .. })
    }
}

/// One collected event: what happened, when in virtual time, and how
/// many wall-clock nanoseconds into the run it was recorded.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    pub ev: TraceEvent,
    /// Virtual time of the event (delay-model units; deterministic per
    /// seed for the barrier backends).
    pub vt: f64,
    /// Wall-clock nanoseconds since the tracer's creation
    /// (informational only).
    pub wall_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_stable_and_distinct() {
        let events = [
            TraceEvent::ComputeBegin { worker: 0, k: 0 },
            TraceEvent::ComputeEnd { worker: 0, k: 0 },
            TraceEvent::LinkBegin { matching: 0, u: 0, v: 1, k: 0 },
            TraceEvent::LinkEnd { matching: 0, u: 0, v: 1, k: 0, failed: false },
            TraceEvent::MixApplied { k: 0, activated: 1 },
            TraceEvent::RoundBarrier { k: 0 },
            TraceEvent::FrameSent { link: 0, bytes: 1 },
            TraceEvent::FrameReceived { link: 0, bytes: 1 },
            TraceEvent::Reconnect { link: 0, resumed: 1 },
            TraceEvent::StaleExchange { worker: 0, peer: 1, staleness: 0, k: 0 },
        ];
        let names: Vec<&str> = events.iter().map(|e| e.name()).collect();
        assert_eq!(names, TraceEvent::NAMES, "NAMES must mirror name() in declaration order");
        let mut deduped = names.clone();
        deduped.sort_unstable();
        deduped.dedup();
        assert_eq!(deduped.len(), events.len(), "event names must be distinct");
    }

    #[test]
    fn filters_classify_events() {
        assert!(TraceEvent::FrameSent { link: 0, bytes: 8 }.is_frame());
        assert!(!TraceEvent::RoundBarrier { k: 3 }.is_frame());
        assert!(TraceEvent::LinkBegin { matching: 0, u: 0, v: 1, k: 0 }.is_link());
        assert!(!TraceEvent::ComputeBegin { worker: 0, k: 0 }.is_link());
    }
}
