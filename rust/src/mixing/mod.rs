//! Mixing-weight optimization and spectral-norm analysis (Step 3 of
//! MATCHA; paper Lemma 1 + Theorem 2).
//!
//! The per-iteration mixing matrix is `W⁽ᵏ⁾ = I − α Σ_j B_j L_j`. The
//! convergence rate (Theorem 1) is governed by
//!
//! ```text
//!   ρ(α) = ‖ E[WᵀW] − J ‖₂
//!        = ‖ I − 2α L̄ + α² (L̄² + 2 L̃) − J ‖₂,
//! ```
//!
//! with `L̄ = Σ p_j L_j` and `L̃ = Σ p_j (1−p_j) L_j` (derivation in the
//! paper's Appendix C, eqs. 81–86; the factor 2 uses `L_j² = 2 L_j` for a
//! matching, whose Laplacian has blocks `[[1,-1],[-1,1]]`).
//!
//! The paper formulates minimizing ρ over α as an SDP (Lemma 1) and
//! proves its optimum satisfies `β = α²`, i.e. the relaxation is tight and
//! the problem is *exactly* the 1-D convex minimization of ρ(α): for any
//! unit `x`, `xᵀE(α)x` is a convex quadratic in α (its α²-coefficient is
//! `xᵀ(L̄²+2L̃)x ≥ 0`), so `λ_max(E)` and `−λ_min(E)` are convex and
//! `ρ(α) = max(λ_max, −λ_min)` is convex. We therefore golden-section
//! over α — exact, and with no SDP machinery.

use crate::budget::expected_laplacian;
use crate::linalg::{symmetric_eigen, Mat};
use crate::matching::MatchingDecomposition;

/// The mixing design produced by step 3: the weight α and the spectral
/// norm ρ it achieves (ρ < 1 ⟺ convergence; Theorem 2).
#[derive(Clone, Debug)]
pub struct MixingDesign {
    pub alpha: f64,
    pub rho: f64,
}

/// Build `L̃(p) = Σ_j p_j (1 − p_j) L_j` — the activation-variance term.
pub fn variance_laplacian(laplacians: &[Mat], probs: &[f64]) -> Mat {
    assert_eq!(laplacians.len(), probs.len());
    let n = laplacians[0].rows();
    let mut l = Mat::zeros(n, n);
    for (lj, &p) in laplacians.iter().zip(probs) {
        l.axpy(p * (1.0 - p), lj);
    }
    l
}

/// `E(α) = I − 2αL̄ + α²(L̄² + 2L̃) − J`, the matrix whose spectral norm
/// is ρ. Exposed for tests and for the Monte-Carlo cross-validation.
pub fn rho_matrix(lbar: &Mat, ltilde: &Mat, alpha: f64) -> Mat {
    let n = lbar.rows();
    let mut e = Mat::eye(n);
    e.axpy(-2.0 * alpha, lbar);
    let lbar2 = lbar.matmul(lbar);
    e.axpy(alpha * alpha, &lbar2);
    e.axpy(2.0 * alpha * alpha, ltilde);
    e.axpy(-1.0, &Mat::averaging(n));
    e
}

/// ρ(α) = ‖E(α)‖₂ for independent Bernoulli matching activation.
pub fn rho_for_alpha(lbar: &Mat, ltilde: &Mat, alpha: f64) -> f64 {
    let e = rho_matrix(lbar, ltilde, alpha);
    let eig = symmetric_eigen(&e);
    eig.values.iter().fold(0.0_f64, |a, &v| a.max(v.abs()))
}

/// Golden-section minimization of a convex scalar function on `[lo, hi]`.
fn golden_section<F: Fn(f64) -> f64>(f: F, mut lo: f64, mut hi: f64, iters: usize) -> (f64, f64) {
    let phi = (5.0_f64.sqrt() - 1.0) / 2.0;
    let mut x1 = hi - phi * (hi - lo);
    let mut x2 = lo + phi * (hi - lo);
    let mut f1 = f(x1);
    let mut f2 = f(x2);
    for _ in 0..iters {
        if f1 <= f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - phi * (hi - lo);
            f1 = f(x1);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + phi * (hi - lo);
            f2 = f(x2);
        }
    }
    let x = 0.5 * (lo + hi);
    (x, f(x))
}

/// Minimize ρ(α) over α > 0 for a decomposition with activation
/// probabilities `probs` (paper Lemma 1 — solved exactly; see module
/// docs for why the 1-D convex search is equivalent to the SDP).
pub fn optimize_alpha(decomp: &MatchingDecomposition, probs: &[f64]) -> MixingDesign {
    let laps = decomp.laplacians();
    let lbar = expected_laplacian(&laps, probs);
    let ltilde = variance_laplacian(&laps, probs);
    optimize_alpha_from_laplacians(&lbar, &ltilde)
}

/// Same as [`optimize_alpha`] but from precomputed `L̄`, `L̃`.
pub fn optimize_alpha_from_laplacians(lbar: &Mat, ltilde: &Mat) -> MixingDesign {
    // Hot path: every golden-section probe needs ‖E(α)‖₂ with
    // E(α) = (I − J) − 2α L̄ + α² (L̄² + 2L̃). The α-independent pieces —
    // in particular the O(m³) product L̄² — are computed ONCE here, so a
    // probe is just two axpys + one eigendecomposition (§Perf: this cut
    // the 16-node optimize_alpha from ~91 ms to ~8 ms).
    let n = lbar.rows();
    let base = Mat::eye(n).sub(&Mat::averaging(n));
    let mut quad = lbar.matmul(lbar);
    quad.axpy(2.0, ltilde);
    let f = |a: f64| {
        let mut e = base.clone();
        e.axpy(-2.0 * a, lbar);
        e.axpy(a * a, &quad);
        let eig = symmetric_eigen(&e);
        eig.values.iter().fold(0.0_f64, |acc, &v| acc.max(v.abs()))
    };

    // Bracket: ρ(0) = 1; for α ≥ 2/λ_max(L̄) the (I−αL̄)² term exceeds 1
    // again. Use an upper bound from Gershgorin (λ_max ≤ 2·max degree of
    // the expected graph = 2·max diagonal of L̄). Expand right if the
    // minimizer sits at the boundary (very sparse expected graphs).
    let max_diag = (0..n).map(|i| lbar.get(i, i)).fold(0.0_f64, f64::max);
    let mut hi = if max_diag > 1e-12 { 2.0 / max_diag } else { 1.0 };
    while f(hi * 1.5) < f(hi) && hi < 1e3 {
        hi *= 1.5;
    }
    let (alpha, rho) = golden_section(f, 0.0, hi, 64);
    MixingDesign { alpha, rho }
}

/// ρ(α) for **fully-correlated** activation: with probability `q` every
/// matching activates together, else none. This is the i.i.d. model of
/// P-DecenSGD (periodic decentralized SGD, paper §3/§5 benchmark):
/// `E[WᵀW] = I − 2αqL + α²qL²` (no variance cross-term since the single
/// Bernoulli multiplies the whole Laplacian).
pub fn rho_periodic_for_alpha(base_laplacian: &Mat, q: f64, alpha: f64) -> f64 {
    let n = base_laplacian.rows();
    let mut e = Mat::eye(n);
    e.axpy(-2.0 * alpha * q, base_laplacian);
    let l2m = base_laplacian.matmul(base_laplacian);
    e.axpy(alpha * alpha * q, &l2m);
    e.axpy(-1.0, &Mat::averaging(n));
    let eig = symmetric_eigen(&e);
    eig.values.iter().fold(0.0_f64, |a, &v| a.max(v.abs()))
}

/// Best-α ρ for P-DecenSGD at activation frequency `q`.
pub fn optimize_alpha_periodic(base_laplacian: &Mat, q: f64) -> MixingDesign {
    let n = base_laplacian.rows();
    let max_diag = (0..n).map(|i| base_laplacian.get(i, i)).fold(0.0_f64, f64::max);
    let hi0 = if max_diag > 1e-12 { 2.0 / max_diag } else { 1.0 };
    let f = |a: f64| rho_periodic_for_alpha(base_laplacian, q, a);
    let mut hi = hi0;
    while f(hi * 1.5) < f(hi) && hi < 1e3 {
        hi *= 1.5;
    }
    let (alpha, rho) = golden_section(f, 0.0, hi, 90);
    MixingDesign { alpha, rho }
}

/// Closed-form vanilla DecenSGD design (all p_j = 1 ⇒ L̃ = 0 and the
/// optimum is `α* = 2/(λ₂+λ_m)` with `ρ = ((λ_m−λ₂)/(λ_m+λ₂))²`).
pub fn vanilla_design(base_laplacian: &Mat) -> MixingDesign {
    let eig = symmetric_eigen(base_laplacian);
    let l2 = eig.values[1].max(0.0);
    let lm = *eig.values.last().unwrap();
    if l2 <= 1e-12 {
        // Disconnected base graph: no α achieves consensus.
        return MixingDesign { alpha: 0.0, rho: 1.0 };
    }
    let alpha = 2.0 / (l2 + lm);
    let r = (lm - l2) / (lm + l2);
    MixingDesign { alpha, rho: r * r }
}

/// Monte-Carlo estimate of `‖E[WᵀW] − J‖₂` by sampling activations —
/// used in tests to validate the closed form against the definition.
pub fn rho_monte_carlo(
    decomp: &MatchingDecomposition,
    probs: &[f64],
    alpha: f64,
    samples: usize,
    rng: &mut crate::rng::Rng,
) -> f64 {
    let laps = decomp.laplacians();
    let n = decomp.base.num_nodes();
    let mut acc = Mat::zeros(n, n);
    for _ in 0..samples {
        let mut l = Mat::zeros(n, n);
        for (lj, &p) in laps.iter().zip(probs) {
            if rng.bernoulli(p) {
                l.axpy(1.0, lj);
            }
        }
        let mut w = Mat::eye(n);
        w.axpy(-alpha, &l);
        let wtw = w.transpose().matmul(&w);
        acc.axpy(1.0 / samples as f64, &wtw);
    }
    acc.axpy(-1.0, &Mat::averaging(n));
    let eig = symmetric_eigen(&acc);
    eig.values.iter().fold(0.0_f64, |a, &v| a.max(v.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::optimize_activation_probabilities;
    use crate::graph::{complete, paper_figure1_graph, ring};
    use crate::matching::decompose;
    use crate::rng::Rng;

    #[test]
    fn rho_at_zero_alpha_is_one() {
        let d = decompose(&paper_figure1_graph());
        let probs = vec![0.5; d.len()];
        let laps = d.laplacians();
        let lbar = expected_laplacian(&laps, &probs);
        let ltilde = variance_laplacian(&laps, &probs);
        assert!((rho_for_alpha(&lbar, &ltilde, 0.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn theorem2_rho_below_one_connected_graphs() {
        for g in [paper_figure1_graph(), ring(8), complete(6)] {
            let d = decompose(&g);
            for cb in [0.1, 0.3, 0.6, 1.0] {
                let a = optimize_activation_probabilities(&d, cb);
                let mix = optimize_alpha(&d, &a.probabilities);
                assert!(
                    mix.rho < 1.0 - 1e-6,
                    "Theorem 2 violated: cb={cb}, ρ={}",
                    mix.rho
                );
                assert!(mix.alpha > 0.0);
            }
        }
    }

    #[test]
    fn vanilla_closed_form_matches_generic_path() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = vec![1.0; d.len()];
        let generic = optimize_alpha(&d, &probs);
        let closed = vanilla_design(&g.laplacian());
        assert!(
            (generic.rho - closed.rho).abs() < 1e-6,
            "generic ρ {} vs closed-form ρ {}",
            generic.rho,
            closed.rho
        );
        assert!((generic.alpha - closed.alpha).abs() < 1e-4);
    }

    #[test]
    fn vanilla_complete_graph_perfect_mixing() {
        // K_n with α = 1/n gives W = J exactly ⇒ ρ = 0.
        let design = vanilla_design(&complete(8).laplacian());
        assert!(design.rho < 1e-10, "ρ = {}", design.rho);
        assert!((design.alpha - 1.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn closed_form_matches_monte_carlo() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let a = optimize_activation_probabilities(&d, 0.5);
        let mix = optimize_alpha(&d, &a.probabilities);
        let laps = d.laplacians();
        let lbar = expected_laplacian(&laps, &a.probabilities);
        let ltilde = variance_laplacian(&laps, &a.probabilities);
        let exact = rho_for_alpha(&lbar, &ltilde, mix.alpha);
        let mut rng = Rng::new(5150);
        let mc = rho_monte_carlo(&d, &a.probabilities, mix.alpha, 20_000, &mut rng);
        assert!(
            (exact - mc).abs() < 0.02,
            "closed-form ρ {exact} vs Monte-Carlo {mc}"
        );
    }

    #[test]
    fn periodic_worse_or_equal_than_matcha_at_same_budget() {
        // Fig 3's qualitative claim: at equal budget, MATCHA's optimized
        // ρ is no worse than P-DecenSGD's.
        let g = paper_figure1_graph();
        let d = decompose(&g);
        for cb in [0.2, 0.4, 0.6, 0.8] {
            let a = optimize_activation_probabilities(&d, cb);
            let matcha = optimize_alpha(&d, &a.probabilities);
            let periodic = optimize_alpha_periodic(&g.laplacian(), cb);
            assert!(
                matcha.rho <= periodic.rho + 1e-6,
                "cb={cb}: MATCHA ρ {} > periodic ρ {}",
                matcha.rho,
                periodic.rho
            );
        }
    }

    #[test]
    fn rho_is_convex_in_alpha_sampled() {
        // Midpoint convexity check over a grid (validates the
        // golden-section argument).
        let d = decompose(&paper_figure1_graph());
        let probs = vec![0.4; d.len()];
        let laps = d.laplacians();
        let lbar = expected_laplacian(&laps, &probs);
        let ltilde = variance_laplacian(&laps, &probs);
        let f = |a: f64| rho_for_alpha(&lbar, &ltilde, a);
        let grid: Vec<f64> = (0..30).map(|i| i as f64 * 0.02).collect();
        for i in 0..grid.len() {
            for j in (i + 2)..grid.len() {
                let a = grid[i];
                let b = grid[j];
                let mid = 0.5 * (a + b);
                assert!(
                    f(mid) <= 0.5 * (f(a) + f(b)) + 1e-9,
                    "convexity violated at [{a},{b}]"
                );
            }
        }
    }

    #[test]
    fn golden_section_finds_scalar_minimum() {
        let (x, fx) = golden_section(|x| (x - 3.0) * (x - 3.0) + 1.0, 0.0, 10.0, 80);
        assert!((x - 3.0).abs() < 1e-6);
        assert!((fx - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lemma2_contraction_holds_empirically() {
        // Lemma 2: E‖B(∏ᵢ W⁽ⁱ⁾ − J)‖²_F ≤ ρⁿ ‖B‖²_F for i.i.d. W⁽ⁱ⁾.
        // Monte-Carlo over the MATCHA activation law on the fig1 graph.
        use crate::topology::{mixing_matrix, MatchaSampler, TopologySampler};
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.5);
        let design = optimize_alpha(&d, &probs.probabilities);
        let laps = d.laplacians();
        let n = 4; // product length
        let trials = 3000;
        let mut sampler = MatchaSampler::new(probs.probabilities.clone(), 99);

        // B: a fixed deterministic 3×8 matrix.
        let mut b = Mat::zeros(3, 8);
        for i in 0..3 {
            for j in 0..8 {
                b.set(i, j, ((i * 8 + j) as f64 * 0.37).sin());
            }
        }
        let bj = b.matmul(&Mat::averaging(8));
        let bnorm2 = b.sub(&bj).frobenius_norm().powi(2); // ‖B(I−J)‖²   (tighter start)
        let _ = bnorm2;

        let mut acc = 0.0;
        for t in 0..trials {
            let mut prod = Mat::eye(8);
            for k in 0..n {
                let round = sampler.round(t * n + k);
                prod = prod.matmul(&mixing_matrix(&laps, &round.activated, design.alpha));
            }
            let m = b.matmul(&prod.sub(&Mat::averaging(8)));
            acc += m.frobenius_norm().powi(2) / trials as f64;
        }
        let bound = design.rho.powi(n as i32) * b.frobenius_norm().powi(2);
        assert!(
            acc <= bound * 1.05,
            "Lemma 2 violated: E‖B(ΠW−J)‖² = {acc} > ρⁿ‖B‖² = {bound}"
        );
    }

    #[test]
    fn mixing_matrix_is_doubly_stochastic() {
        // W = I − αL is symmetric doubly stochastic by construction.
        let g = paper_figure1_graph();
        let design = vanilla_design(&g.laplacian());
        let mut w = Mat::eye(8);
        w.axpy(-design.alpha, &g.laplacian());
        assert!(w.is_doubly_stochastic(1e-9));
        assert!(w.is_symmetric(1e-9));
    }
}
