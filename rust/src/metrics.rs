//! Training/experiment metric recording.
//!
//! A [`Recorder`] collects named time series (loss vs iteration, loss vs
//! virtual wall-clock, consensus distance, comm units, ...) and dumps
//! them as CSV or JSON for the figure harnesses and EXPERIMENTS.md.

use crate::json::Json;
use std::collections::BTreeMap;

/// One sample of a named series.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// X coordinate (iteration index, epoch, or virtual time).
    pub x: f64,
    /// Y value.
    pub y: f64,
}

/// Summary statistics over one series' y values — what the trace
/// exporters attach as per-series metadata instead of the full series.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SeriesSummary {
    /// Number of samples.
    pub count: usize,
    /// Smallest y.
    pub min: f64,
    /// Largest y.
    pub max: f64,
    /// Mean of y.
    pub mean: f64,
    /// Final y.
    pub last: f64,
}

impl SeriesSummary {
    /// JSON form: `{"count": ..., "min": ..., "max": ..., "mean": ...,
    /// "last": ...}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("mean", Json::Num(self.mean)),
            ("last", Json::Num(self.last)),
        ])
    }
}

/// A collection of named metric series.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    series: BTreeMap<String, Vec<Sample>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a sample to a series (creating it on first use).
    pub fn push(&mut self, series: &str, x: f64, y: f64) {
        self.series
            .entry(series.to_string())
            .or_default()
            .push(Sample { x, y });
    }

    /// Get a series (empty slice if absent).
    pub fn get(&self, series: &str) -> &[Sample] {
        self.series.get(series).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Names of all recorded series.
    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(String::as_str).collect()
    }

    /// Last y-value of a series, if any.
    pub fn last(&self, series: &str) -> Option<f64> {
        self.get(series).last().map(|s| s.y)
    }

    /// First x at which a series' y drops to or below `threshold`
    /// (e.g. "virtual time to reach training loss 0.1", the paper's
    /// time-to-loss metric in Fig 5).
    pub fn first_x_below(&self, series: &str, threshold: f64) -> Option<f64> {
        self.get(series)
            .iter()
            .find(|s| s.y <= threshold)
            .map(|s| s.x)
    }

    /// Running minimum of the series' y values.
    pub fn min_y(&self, series: &str) -> Option<f64> {
        self.get(series)
            .iter()
            .map(|s| s.y)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Summary statistics of a series' y values (`None` if the series
    /// is absent or empty).
    pub fn summary(&self, series: &str) -> Option<SeriesSummary> {
        let samples = self.get(series);
        let last = samples.last()?.y;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0;
        for s in samples {
            min = min.min(s.y);
            max = max.max(s.y);
            sum += s.y;
        }
        Some(SeriesSummary {
            count: samples.len(),
            min,
            max,
            mean: sum / samples.len() as f64,
            last,
        })
    }

    /// Summaries of every recorded series, in name order.
    pub fn summaries(&self) -> Vec<(&str, SeriesSummary)> {
        self.series
            .keys()
            .filter_map(|name| self.summary(name).map(|s| (name.as_str(), s)))
            .collect()
    }

    /// Merge another recorder's series into this one under a prefix:
    /// series `s` lands as `prefix/s`. Used by the engine's sweep driver
    /// to collect per-grid-point recorders into one artifact.
    pub fn merge(&mut self, prefix: &str, other: &Recorder) {
        for (name, samples) in &other.series {
            self.series
                .entry(format!("{prefix}/{name}"))
                .or_default()
                .extend(samples.iter().cloned());
        }
    }

    /// Serialize all series as JSON: `{name: [[x,y], ...], ...}`.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.series
                .iter()
                .map(|(name, samples)| {
                    (
                        name.clone(),
                        Json::Arr(
                            samples
                                .iter()
                                .map(|s| Json::Arr(vec![Json::Num(s.x), Json::Num(s.y)]))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        )
    }

    /// Emit one series as CSV (`x,y` with a header line).
    pub fn series_csv(&self, series: &str) -> String {
        let mut out = String::from("x,y\n");
        for s in self.get(series) {
            out.push_str(&format!("{},{}\n", s.x, s.y));
        }
        out
    }

    /// Write the JSON dump to a file.
    pub fn save_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_get() {
        let mut r = Recorder::new();
        r.push("loss", 0.0, 2.5);
        r.push("loss", 1.0, 1.5);
        assert_eq!(r.get("loss").len(), 2);
        assert_eq!(r.last("loss"), Some(1.5));
        assert_eq!(r.get("missing"), &[]);
    }

    #[test]
    fn first_x_below_threshold() {
        let mut r = Recorder::new();
        for (x, y) in [(0.0, 3.0), (1.0, 1.0), (2.0, 0.09), (3.0, 0.05)] {
            r.push("loss", x, y);
        }
        assert_eq!(r.first_x_below("loss", 0.1), Some(2.0));
        assert_eq!(r.first_x_below("loss", 0.01), None);
    }

    #[test]
    fn merge_prefixes_series() {
        let mut a = Recorder::new();
        a.push("loss", 0.0, 1.0);
        let mut b = Recorder::new();
        b.push("loss", 0.0, 2.0);
        b.push("acc", 0.0, 0.5);
        a.merge("cb=0.5", &b);
        assert_eq!(a.last("loss"), Some(1.0), "own series untouched");
        assert_eq!(a.last("cb=0.5/loss"), Some(2.0));
        assert_eq!(a.last("cb=0.5/acc"), Some(0.5));
        assert_eq!(a.names().len(), 3);
    }

    #[test]
    fn summary_reports_min_max_mean_last() {
        let mut r = Recorder::new();
        for (x, y) in [(0.0, 4.0), (1.0, 1.0), (2.0, 2.5)] {
            r.push("loss", x, y);
        }
        let s = r.summary("loss").unwrap();
        assert_eq!(s.count, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.last, 2.5);
        assert_eq!(r.summary("missing"), None);
        let j = s.to_json();
        assert_eq!(j.get("count").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("last").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn summaries_cover_every_series_in_name_order() {
        let mut r = Recorder::new();
        r.push("b", 0.0, 1.0);
        r.push("a", 0.0, 2.0);
        let all = r.summaries();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].0, "a");
        assert_eq!(all[1].0, "b");
        assert_eq!(all[0].1.last, 2.0);
    }

    #[test]
    fn json_shape() {
        let mut r = Recorder::new();
        r.push("a", 1.0, 2.0);
        let j = r.to_json();
        let arr = j.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_array().unwrap()[1].as_f64(), Some(2.0));
    }

    #[test]
    fn csv_format() {
        let mut r = Recorder::new();
        r.push("s", 0.0, 1.0);
        r.push("s", 1.0, 0.5);
        assert_eq!(r.series_csv("s"), "x,y\n0,1\n1,0.5\n");
    }
}
