//! Minimal property-testing harness.
//!
//! The `proptest` crate is not available in this offline image; this
//! module provides the piece of it we rely on: run a predicate over many
//! generated cases from a seeded [`Rng`], and on failure report the seed
//! and a best-effort shrunk case description so the failure reproduces
//! deterministically.

use crate::rng::Rng;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 200, seed: 0x4d41_5443_4841 } // "MATCHA"
    }
}

/// Run `prop` over `config.cases` generated inputs. `gen` draws a case
/// from the RNG; `prop` returns `Err(description)` to fail.
///
/// Panics with the case index, seed, and description on the first
/// failure, so `cargo test` output pinpoints the reproducer.
pub fn check<T, G, P>(config: PropConfig, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let mut case_rng = rng.split();
        let input = generate(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}):\n  input: {input:?}\n  {msg}",
                config.cases, config.seed
            );
        }
    }
}

/// Shorthand with the default config.
pub fn check_default<T, G, P>(generate: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(PropConfig::default(), generate, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            PropConfig { cases: 50, seed: 1 },
            |rng| rng.below(100),
            |&x| {
                count += 1;
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_context() {
        check(
            PropConfig { cases: 100, seed: 2 },
            |rng| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
    }

    #[test]
    fn same_seed_generates_same_cases() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check(PropConfig { cases: 20, seed: 9 }, |r| r.next_u64(), |&x| {
            a.push(x);
            Ok(())
        });
        check(PropConfig { cases: 20, seed: 9 }, |r| r.next_u64(), |&x| {
            b.push(x);
            Ok(())
        });
        assert_eq!(a, b);
    }
}
