//! Command-line interface for the `matcha` binary.
//!
//! Hand-rolled parsing (no `clap` in this offline image): subcommand +
//! `--flag value` pairs. The run-shaped commands (`run`, `sim`, `engine`,
//! `sweep`, `schedule`) are thin shells over the
//! [`crate::experiment`] spec → plan → run pipeline; `run --spec FILE`
//! executes a JSON experiment file directly. Every figure harness in
//! `rust/benches/` is also reachable interactively from here.

use crate::budget::{optimize_activation_probabilities, periodic_probabilities};
use crate::config::ArtifactPaths;
use crate::experiment::{
    self, Backend, ExperimentResult, ExperimentSpec, Observer, ProblemSpec, Strategy,
};
use crate::graph::{expected_node_comm_time, parse_graph_spec, Graph};
use crate::json::Json;
use crate::matching::{decompose, decompose_greedy};
use crate::mixing::{optimize_alpha, optimize_alpha_periodic};

/// Parsed `--flag value` arguments.
pub struct Args {
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    /// Parse from raw argv-style strings; returns an error message on
    /// dangling flags, positional arguments, or duplicated flags.
    pub fn parse(raw: &[String]) -> Result<Args, String> {
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < raw.len() {
            let k = &raw[i];
            if let Some(name) = k.strip_prefix("--") {
                let value = if i + 1 >= raw.len() || raw[i + 1].starts_with("--") {
                    // Boolean flag.
                    i += 1;
                    "true".to_string()
                } else {
                    i += 2;
                    raw[i - 1].clone()
                };
                if flags.insert(name.to_string(), value).is_some() {
                    return Err(format!("duplicate flag --{name}"));
                }
            } else {
                return Err(format!("unexpected positional argument '{k}'"));
            }
        }
        Ok(Args { flags })
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(String::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }
}

const USAGE: &str = "\
matcha — MATCHA: decentralized SGD with matching decomposition sampling

USAGE: matcha <command> [--flag value ...]

COMMANDS
  run        --spec FILE [--dry-run] [--out FILE] [--trace FILE] [--progress]
             execute a JSON experiment spec (the spec → plan → run pipeline;
             --dry-run stops after planning and prints the derived quantities;
             --trace writes a Chrome trace-event JSON of the run,
             Perfetto-loadable — remote cluster runs merge every daemon's
             telemetry into one multi-process trace; --progress streams
             per-shard progress lines from daemon telemetry on remote runs)
  status     ADDR [--timeout-ms N]              one-shot health report from a
             shard-node daemon (idle or mid-session): shard, rounds done,
             reconnects survived, uptime, step/fold counters, ring drops,
             and the observatory digest (activation drift score + windowed
             contraction rate) once a session is underway
  report     REPORT.json | --spec FILE [--out FILE]   convergence observatory
             report: re-render a saved REPORT.json, or run a spec and render
             the design-vs-realized activation audit, windowed contraction
             rate vs the predicted rho, error-runtime frontier, and
             straggler/staleness profile; --out saves the JSON report
  trace-check --file FILE [--format chrome|jsonl]   validate a trace file;
             warns when the export was truncated by ring overwrites
  bench-regress --artifact FILE --history FILE [--append] [--tolerance T] [--diff]
             gate a bench artifact against its committed history (JSONL):
             exact-match keys (workers, dim, alloc counts) must be equal,
             lower-is-better keys may grow at most T (default 0.25) over the
             last history entry; wall-clock timings are never gated.
             --append records the current values as a new history line;
             --diff prints the old-vs-new table with per-key gate verdicts
  decompose  --graph SPEC [--greedy]            matching decomposition
  probs      --graph SPEC --budget CB           activation probabilities (problem 4)
  alpha      --graph SPEC --budget CB           mixing weight + spectral norm (Lemma 1)
  rho-curve  --graph SPEC [--points N]          ρ vs budget, MATCHA vs P-DecenSGD (Fig 3)
  commtime   --graph SPEC --budget CB           per-node expected comm time (Fig 1)
  schedule   --graph SPEC --budget CB --steps K [--out FILE]   apriori schedule
  sim        --graph SPEC --strategy S --budget CB --iters N [--problem quad|logreg]
  engine     like sim, through the event-driven engine; adds
             [--backend engine|actors|async|cluster] [--threads T]
             [--max-staleness S|unbounded] [--shards N] [--transport loopback|tcp]
             [--policy analytic|hetero:SEED|straggler:W:F|flaky:P]
             (actors: bounded pool, workers multiplexed over min(T, workers)
             threads; async: barrier-free gossip with staleness-aware mixing,
             S bounds the version drift, S=0 reproduces the sync kernel and
             'unbounded' is pure AD-PSGD; cluster: workers partitioned over N
             transport-separated shards speaking the wire format — loopback
             is bit-for-bit equal to actors, tcp runs over localhost sockets)
  shard-node --listen HOST:PORT [--once] [--io-timeout-ms N] [--drop-after N]
             serve one cluster shard as a standalone daemon: a remote
             coordinator (run --spec with backend \"cluster\" and
             \"transport\": {\"tcp\": [\"host:port\", ...]}) assigns it a shard
             and the full spec over the wire, and the daemon rebuilds the
             identical workload and keeps its session across reconnects.
             --once exits after the first completed run (CI-friendly);
             --io-timeout-ms bounds mid-session peer silence (0 = wait
             forever); --drop-after N drops a connection after N commands
             once (fault injection for reconnect testing)
  sweep      --graph SPEC --budgets A,B,... --iters N [--threads T] [--serial]
             [--spec FILE] [--backend sim|engine|async] parallel budget sweep
             across cores; finished points stream as JSON lines before the
             final table. --spec sweeps the budget axis of a JSON experiment
             file, like run --spec (multi-threaded spec backends are demoted
             to their single-threaded equivalents — points already fan out)
  train      --graph SPEC --strategy S --budget CB --steps N [--artifacts DIR] [--pallas]
             (requires a build with --features xla)
  info       [--artifacts DIR]                  artifact metadata

GRAPH SPECS   fig1 | ring:M | star:M | complete:M | grid:RxC | geom:M:DELTA:SEED | er:M:DELTA:SEED
STRATEGIES    matcha | vanilla | periodic | single
DELAY MODELS  unit | maxdeg | stochastic:lo:hi
";

/// CLI entry point (called from main.rs).
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(msg) => {
            eprintln!("error: {msg}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Dispatch a full command line; separated from `main` for testing.
pub fn run(argv: &[String]) -> Result<(), String> {
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    // `status` and `report` take positional arguments, which the flag
    // parser rejects by design — route them before parsing.
    if cmd == "status" {
        return cmd_status(&argv[1..]);
    }
    if cmd == "report" {
        return cmd_report(&argv[1..]);
    }
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "decompose" => cmd_decompose(&args),
        "probs" => cmd_probs(&args),
        "alpha" => cmd_alpha(&args),
        "rho-curve" => cmd_rho_curve(&args),
        "commtime" => cmd_commtime(&args),
        "schedule" => cmd_schedule(&args),
        "sim" => cmd_sim(&args),
        "engine" => cmd_engine(&args),
        "shard-node" => cmd_shard_node(&args),
        "sweep" => cmd_sweep(&args),
        "train" => cmd_train(&args),
        "info" => cmd_info(&args),
        "trace-check" => cmd_trace_check(&args),
        "bench-regress" => cmd_bench_regress(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    }
}

fn graph_arg(args: &Args) -> Result<Graph, String> {
    parse_graph_spec(args.str_or("graph", "fig1"))
}

/// Parse `--max-staleness`: a bound, or `unbounded` for the pure
/// AD-PSGD mode ([`crate::gossip::UNBOUNDED_STALENESS`]).
fn max_staleness_arg(args: &Args) -> Result<usize, String> {
    match args.flags.get("max-staleness").map(String::as_str) {
        None => Ok(crate::gossip::DEFAULT_MAX_STALENESS),
        Some("unbounded") => Ok(crate::gossip::UNBOUNDED_STALENESS),
        Some(v) => v
            .parse()
            .map_err(|e| format!("--max-staleness: {e} (use a bound or 'unbounded')")),
    }
}

/// Assemble an [`ExperimentSpec`] from `sim`/`engine`/`sweep`-style flags.
/// This is the single translation point from CLI flags to the typed API —
/// the per-command glue it replaced lives on only in git history.
fn spec_from_args(args: &Args, backend: Backend) -> Result<ExperimentSpec, String> {
    let cb = args.f64_or("budget", 0.5)?;
    let strategy = match args.str_or("strategy", "matcha") {
        "matcha" => Strategy::Matcha { budget: cb },
        "vanilla" => Strategy::Vanilla,
        "periodic" => Strategy::Periodic { budget: cb },
        "single" => Strategy::SingleMatching { budget: cb },
        other => return Err(format!("unknown strategy '{other}'")),
    };
    let problem = match args.str_or("problem", "logreg") {
        "quad" => ProblemSpec::quadratic(),
        "logreg" => ProblemSpec::Logistic {
            non_iid: args.f64_or("non-iid", 0.0)?,
            separation: 1.5,
            seed: None,
        },
        other => return Err(format!("unknown problem '{other}'")),
    };
    // Validation happens inside plan()/run(), which every caller goes
    // through next — validating here too would resolve generator graph
    // specs twice.
    Ok(ExperimentSpec::new(args.str_or("graph", "fig1"))
        .strategy(strategy)
        .problem(problem)
        .delay(args.str_or("delay", "unit"))
        .policy(args.str_or("policy", "analytic"))
        .backend(backend)
        .lr(args.f64_or("lr", 0.05)?)
        .iterations(args.usize_or("iters", 1000)?)
        .compute_units(args.f64_or("compute-units", 1.0)?)
        .seed(args.usize_or("seed", 0)? as u64))
}

fn save_metrics(args: &Args, metrics: &crate::metrics::Recorder) -> Result<(), String> {
    if let Some(out) = args.flags.get("out") {
        metrics
            .save_json(std::path::Path::new(out))
            .map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn print_run_summary(label: &str, result: &ExperimentResult) {
    println!(
        "{label}: final loss {:.5}, total virtual time {:.1} units, comm {:.1} units",
        result.final_loss(),
        result.total_time,
        result.total_comm_units
    );
    if let Some(acc) = result.metrics.last("test_acc_vs_iter") {
        println!("final test accuracy {acc:.4}");
    }
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let Some(path) = args.flags.get("spec") else {
        return Err("run: --spec FILE is required".into());
    };
    let mut spec = ExperimentSpec::load(std::path::Path::new(path))?;
    if let Some(trace_path) = args.flags.get("trace") {
        // The flag overrides any trace block in the spec file: Chrome
        // format at the default ring capacities, daemon telemetry on.
        spec.trace = Some(experiment::TraceSpec {
            path: trace_path.clone(),
            format: crate::trace::TraceFormat::Chrome,
            capacity: crate::experiment::DEFAULT_TRACE_CAPACITY,
            telemetry: true,
            telemetry_capacity: crate::experiment::DEFAULT_TELEMETRY_CAPACITY,
        });
    }
    let plan = experiment::plan(&spec)?;
    println!(
        "plan: strategy={} problem={} backend={} policy={} | {} nodes, M={} matchings, \
         α={:.5}, ρ={:.6}, λ₂={:.6}, E[comm]={:.3}/iter",
        spec.strategy.name(),
        spec.problem.name(),
        spec.backend.name(),
        spec.policy,
        plan.graph.num_nodes(),
        plan.decomposition.len(),
        plan.alpha,
        plan.rho,
        plan.lambda2,
        plan.expected_comm_units()
    );
    if args.bool("dry-run") {
        println!("dry-run: spec valid, stopping before execution");
        return Ok(());
    }
    let result = experiment::run_planned_progress(
        &spec,
        &plan,
        &mut experiment::NoopObserver,
        args.bool("progress"),
    )?;
    print_run_summary(
        &format!("run iters={}", spec.iterations),
        &result,
    );
    if result.events > 0 {
        println!(
            "events processed: {}, links dropped by failure injection: {}",
            result.events, result.dropped_links
        );
    }
    if let Some(trace) = &spec.trace {
        println!("wrote trace to {} ({})", trace.path, trace.format.name());
    }
    save_metrics(args, &result.metrics)
}

fn cmd_decompose(args: &Args) -> Result<(), String> {
    let g = graph_arg(args)?;
    let d = if args.bool("greedy") { decompose_greedy(&g) } else { decompose(&g) };
    println!(
        "graph: {} nodes, {} edges, Δ = {}",
        g.num_nodes(),
        g.num_edges(),
        g.max_degree()
    );
    println!("M = {} matchings (Vizing bound Δ+1 = {})", d.len(), g.max_degree() + 1);
    for (j, m) in d.matchings.iter().enumerate() {
        println!("  G_{j}: {:?}", m.edges());
    }
    Ok(())
}

fn cmd_probs(args: &Args) -> Result<(), String> {
    let g = graph_arg(args)?;
    let cb = args.f64_or("budget", 0.5)?;
    let d = decompose(&g);
    let opt = optimize_activation_probabilities(&d, cb);
    let uni = periodic_probabilities(&d, cb);
    println!("budget CB = {cb}  (Σp ≤ {:.3})", cb * d.len() as f64);
    for (j, p) in opt.probabilities.iter().enumerate() {
        println!("  p_{j} = {p:.4}   edges {:?}", d.matchings[j].edges());
    }
    println!("λ₂(Σ p L) = {:.6}  (uniform allocation: {:.6})", opt.lambda2, uni.lambda2);
    println!("expected comm time = {:.3} units/iter", opt.expected_comm_time());
    Ok(())
}

fn cmd_alpha(args: &Args) -> Result<(), String> {
    let g = graph_arg(args)?;
    let cb = args.f64_or("budget", 0.5)?;
    let matcha = experiment::Plan::for_graph(g.clone(), Strategy::Matcha { budget: cb })?;
    let per = experiment::Plan::for_graph(g.clone(), Strategy::Periodic { budget: cb })?;
    let van = experiment::Plan::for_graph(g, Strategy::Vanilla)?;
    println!("MATCHA    CB={cb}: α = {:.5}, ρ = {:.6}", matcha.alpha, matcha.rho);
    println!("P-DecenSGD CB={cb}: α = {:.5}, ρ = {:.6}", per.alpha, per.rho);
    println!("vanilla   CB=1.0: α = {:.5}, ρ = {:.6}", van.alpha, van.rho);
    Ok(())
}

fn cmd_rho_curve(args: &Args) -> Result<(), String> {
    let g = graph_arg(args)?;
    let points = args.usize_or("points", 10)?;
    let d = decompose(&g);
    println!("CB, rho_matcha, rho_periodic, lambda2");
    for i in 1..=points {
        let cb = i as f64 / points as f64;
        let probs = optimize_activation_probabilities(&d, cb);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let per = optimize_alpha_periodic(&g.laplacian(), cb);
        println!("{cb:.2}, {:.6}, {:.6}, {:.6}", mix.rho, per.rho, probs.lambda2);
    }
    Ok(())
}

fn cmd_commtime(args: &Args) -> Result<(), String> {
    let g = graph_arg(args)?;
    let cb = args.f64_or("budget", 0.5)?;
    let d = decompose(&g);
    let probs = optimize_activation_probabilities(&d, cb);
    let vanilla = expected_node_comm_time(g.num_nodes(), &d.matchings, &vec![1.0; d.len()]);
    let matcha = expected_node_comm_time(g.num_nodes(), &d.matchings, &probs.probabilities);
    println!("node, degree, vanilla_units, matcha_units(CB={cb})");
    let deg = g.degrees();
    for i in 0..g.num_nodes() {
        println!("{i}, {}, {:.3}, {:.3}", deg[i], vanilla[i], matcha[i]);
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<(), String> {
    let g = graph_arg(args)?;
    let cb = args.f64_or("budget", 0.5)?;
    let steps = args.usize_or("steps", 100)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let plan = experiment::Plan::for_graph(g, Strategy::Matcha { budget: cb })?;
    let schedule = plan.schedule(steps, seed);
    println!(
        "schedule: {} rounds, α = {:.5}, ρ = {:.6}, mean comm = {:.3} units/iter",
        schedule.rounds.len(),
        plan.alpha,
        plan.rho,
        schedule.mean_comm_units()
    );
    if let Some(out) = args.flags.get("out") {
        schedule
            .save(std::path::Path::new(out))
            .map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args, Backend::SimReference)?;
    let result = experiment::run(&spec)?;
    print_run_summary(
        &format!(
            "strategy={} problem={} iters={} CB={}",
            spec.strategy.name(),
            spec.problem.name(),
            spec.iterations,
            spec.strategy.budget().unwrap_or(1.0)
        ),
        &result,
    );
    save_metrics(args, &result.metrics)
}

fn cmd_engine(args: &Args) -> Result<(), String> {
    let threads = args.usize_or("threads", 1)?;
    let backend = match args.str_or("backend", "auto") {
        // Legacy behavior: --threads alone picks sequential vs actors.
        "auto" => {
            if threads <= 1 {
                Backend::EngineSequential
            } else {
                Backend::EngineActors { threads }
            }
        }
        "engine" => Backend::EngineSequential,
        "actors" => {
            if threads == 0 {
                return Err(
                    "--backend actors needs --threads >= 1 (a one-thread pool is valid \
                     and matches the sequential engine bit-for-bit)"
                        .into(),
                );
            }
            Backend::EngineActors { threads }
        }
        "async" => Backend::Async {
            threads: threads.max(1),
            max_staleness: max_staleness_arg(args)?,
        },
        "cluster" => {
            let shards = args.usize_or("shards", 2)?;
            if shards == 0 {
                return Err("--backend cluster needs --shards >= 1".into());
            }
            let transport =
                crate::cluster::TransportKind::parse(args.str_or("transport", "loopback"))
                    .map_err(|e| format!("--transport: {e}"))?;
            Backend::Cluster { shards, transport }
        }
        other => {
            return Err(format!(
                "unknown backend '{other}' (expected engine | actors | async | cluster)"
            ))
        }
    };
    let spec = spec_from_args(args, backend)?;
    let plan = experiment::plan(&spec)?;
    // The pool multiplexes workers over min(threads, workers) OS
    // threads; surface the clamp so nobody is surprised.
    if let Backend::EngineActors { threads } = spec.backend {
        let nodes = plan.graph.num_nodes();
        let pool = threads.min(nodes);
        if pool < threads {
            println!("note: actor pool clamped to {pool} thread(s) for {nodes} workers");
        }
    }
    let result = experiment::run_planned(&spec, &plan, &mut experiment::NoopObserver)?;
    // Report the effective thread count of the chosen backend, not the
    // raw --threads flag (defaults and clamps may differ).
    let effective_threads = match spec.backend {
        Backend::EngineActors { threads } => threads.min(plan.graph.num_nodes()),
        Backend::Async { threads, .. } => threads.min(plan.graph.num_nodes()),
        Backend::Cluster { shards, .. } => shards.min(plan.graph.num_nodes()),
        _ => 1,
    };
    print_run_summary(
        &format!(
            "engine backend={} strategy={} policy={} threads={effective_threads} iters={} CB={}",
            spec.backend.name(),
            spec.strategy.name(),
            spec.policy,
            spec.iterations,
            spec.strategy.budget().unwrap_or(1.0)
        ),
        &result,
    );
    println!(
        "events processed: {}, links dropped by failure injection: {}",
        result.events, result.dropped_links
    );
    if let Some(stats) = &result.async_stats {
        println!(
            "staleness: mean {:.3}, max {}, exchanges {}, total idle {:.1} units",
            stats.mean_staleness(),
            stats.max_staleness(),
            stats.total_exchanges(),
            stats.total_idle()
        );
    }
    if let Some(stats) = &result.cluster_stats {
        println!(
            "wire: transport {}, {} frames / {} bytes across {} links \
             ({} payload bytes never shipped: intra-shard rows suppressed)",
            stats.transport.name(),
            stats.total_frames(),
            stats.total_bytes(),
            stats.per_link.len(),
            stats.suppressed_bytes()
        );
    }
    save_metrics(args, &result.metrics)
}

/// `matcha shard-node`: block serving one cluster shard until a
/// coordinator finishes a run (with `--once`) or the process is killed.
fn cmd_shard_node(args: &Args) -> Result<(), String> {
    let Some(addr) = args.flags.get("listen") else {
        return Err("shard-node: --listen HOST:PORT is required".into());
    };
    let opts = crate::node::DaemonOptions {
        once: args.bool("once"),
        io_timeout_ms: args.usize_or("io-timeout-ms", 0)? as u64,
        drop_after: match args.flags.get("drop-after") {
            None => None,
            Some(v) => Some(v.parse().map_err(|e| format!("--drop-after: {e}"))?),
        },
    };
    crate::node::listen_and_serve(addr, &opts)
}

/// `matcha status ADDR`: one-shot, non-draining telemetry pull against
/// a shard-node daemon — works while it is idle (pre-`Assign`) and
/// mid-session (the daemon polls for side connections between
/// commands), and never perturbs the run or its trace ring.
fn cmd_status(rest: &[String]) -> Result<(), String> {
    let Some(addr) = rest.first().filter(|a| !a.starts_with("--")) else {
        return Err("status: ADDR is required (matcha status HOST:PORT)".into());
    };
    let args = Args::parse(&rest[1..])?;
    let timeout_ms = args.usize_or("timeout-ms", 2_000)? as u64;
    let t = crate::node::query_status(addr, timeout_ms)?;
    use crate::trace::{Counter, UNASSIGNED_SHARD};
    let session = if t.shard == UNASSIGNED_SHARD {
        "idle (no shard assigned)".to_string()
    } else {
        format!("shard {}, round {}", t.shard, t.rounds_done)
    };
    println!(
        "{addr}: {session}, {} reconnect(s) survived, up {:.1}s",
        t.reconnects,
        t.uptime_ms as f64 / 1000.0
    );
    println!(
        "  steps {}, msgs folded {}, trace ring dropped {}",
        t.registry.counter(Counter::ShardSteps),
        t.registry.counter(Counter::ShardMsgsFolded),
        t.ring_dropped
    );
    if let Some(obs) = &t.observatory {
        println!(
            "  observatory: drift score {:.3} over {} round(s), contraction rate {:.4} \
             ({} window(s) closed)",
            obs.drift_score, obs.rounds, obs.contraction_rate, obs.windows
        );
    }
    Ok(())
}

/// `matcha report`: render the convergence-observatory run report.
/// With a positional `REPORT.json` argument, re-render a saved report;
/// with `--spec FILE`, run the experiment (arming the observatory at
/// defaults when the spec carries no `report` block), render, and
/// optionally persist the self-contained JSON with `--out`.
fn cmd_report(rest: &[String]) -> Result<(), String> {
    use crate::trace::RunReport;
    if let Some(path) = rest.first().filter(|a| !a.starts_with("--")) {
        if rest.len() > 1 {
            return Err("report: a saved REPORT.json takes no extra flags".into());
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("report: cannot read {path}: {e}"))?;
        let json = Json::parse(&text).map_err(|e| format!("report: {path}: {e}"))?;
        print!("{}", RunReport::from_json(&json)?.render());
        return Ok(());
    }
    let args = Args::parse(rest)?;
    let Some(path) = args.flags.get("spec") else {
        return Err("report: REPORT.json or --spec FILE is required".into());
    };
    let mut spec = ExperimentSpec::load(std::path::Path::new(path))?;
    if spec.report.is_none() {
        spec.report = Some(experiment::ReportSpec::default());
    }
    let plan = experiment::plan(&spec)?;
    let result = experiment::run_planned(&spec, &plan, &mut experiment::NoopObserver)?;
    let spec_name = match &spec.graph {
        experiment::GraphSource::Spec(s) => s.clone(),
        experiment::GraphSource::Explicit(g) => format!("explicit:{}", g.num_nodes()),
    };
    let strategy = match spec.strategy.budget() {
        Some(cb) => format!("{}({cb})", spec.strategy.name()),
        None => spec.strategy.name().to_string(),
    };
    let report = RunReport {
        spec_name,
        backend: spec.backend.name().to_string(),
        strategy,
        alpha: result.alpha,
        rho: result.rho,
        final_loss: result.final_loss(),
        total_time: result.total_time,
        total_comm: result.total_comm_units,
        observatory: result.observatory.unwrap_or_default(),
    };
    print!("{}", report.render());
    if let Some(out) = args.flags.get("out") {
        std::fs::write(out, report.to_json().to_string())
            .map_err(|e| format!("report: cannot write {out}: {e}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Streams one JSON line per finished sweep point (completion order).
struct SweepJsonLines<'a> {
    budgets: &'a [f64],
}

impl Observer for SweepJsonLines<'_> {
    fn on_point(&mut self, index: usize, result: &ExperimentResult) {
        let mut line = result.summary_json();
        if let Json::Obj(map) = &mut line {
            map.insert("point".to_string(), Json::Num(index as f64));
            map.insert("cb".to_string(), Json::Num(self.budgets[index]));
        }
        println!("{line}");
    }
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let threads = if args.bool("serial") {
        1
    } else {
        args.usize_or("threads", crate::engine::available_threads())?
    };
    let budgets: Vec<f64> = args
        .str_or("budgets", "0.1,0.25,0.5,0.75,1.0")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|e| format!("--budgets: '{s}': {e}")))
        .collect::<Result<_, _>>()?;
    if budgets.is_empty() {
        return Err("--budgets: need at least one value".into());
    }
    // Point-level backend is threaded through, so sync and async points
    // can be swept side by side (virtual times are comparable; note that
    // comm_units have different semantics — see `gossip::runtime`).
    // Parallelism comes from fanning points across threads; per-point
    // execution is normalized to single-threaded (also enforced in
    // `experiment::run_sweep` for library callers).
    let base = if let Some(path) = args.flags.get("spec") {
        // A spec file defines the whole experiment; reject config flags
        // it would silently override.
        for flag in [
            "backend", "max-staleness", "shards", "transport", "graph", "strategy", "budget",
            "problem", "delay", "policy", "lr", "iters", "compute-units", "seed", "non-iid",
        ] {
            if args.flags.contains_key(flag) {
                return Err(format!(
                    "sweep: --{flag} conflicts with --spec (the spec file defines it); \
                     edit the spec or drop the flag"
                ));
            }
        }
        let mut spec = ExperimentSpec::load(std::path::Path::new(path))?;
        // Thread counts never change results on any backend, so a
        // multi-threaded spec backend is demoted to its sequential
        // equivalent rather than oversubscribing cores point × pool.
        match spec.backend {
            Backend::EngineActors { .. } => {
                println!("note: sweep points run single-threaded; using the 'engine' backend");
                spec.backend = Backend::EngineSequential;
            }
            Backend::Cluster { .. } => {
                println!(
                    "note: sweep points run single-threaded; using the 'engine' backend \
                     (identical results, no shard fleet per point)"
                );
                spec.backend = Backend::EngineSequential;
            }
            Backend::Async { threads, max_staleness } if threads > 1 => {
                println!("note: sweep points run single-threaded; async pool clamped to 1");
                spec.backend = Backend::Async { threads: 1, max_staleness };
            }
            _ => {}
        }
        spec
    } else {
        let backend = match args.str_or("backend", "engine") {
            "engine" => Backend::EngineSequential,
            "sim" => Backend::SimReference,
            "async" => Backend::Async { threads: 1, max_staleness: max_staleness_arg(args)? },
            "actors" | "cluster" => {
                return Err(
                    "sweep points fan across threads already; use --backend engine \
                     (or async) for per-point execution"
                        .into(),
                )
            }
            other => {
                return Err(format!(
                    "unknown backend '{other}' (expected sim | engine | async)"
                ))
            }
        };
        spec_from_args(args, backend)?
    };

    let wall = std::time::Instant::now();
    let mut streamer = SweepJsonLines { budgets: &budgets };
    let results = experiment::run_sweep(&base, &budgets, threads, &mut streamer)?;
    let elapsed = wall.elapsed().as_secs_f64();

    let mut table = crate::benchkit::Table::new(&[
        "CB",
        "final loss",
        "virtual time",
        "comm units",
    ]);
    let mut merged = crate::metrics::Recorder::new();
    for (cb, r) in &results {
        table.row(&[
            format!("{cb}"),
            format!("{:.5}", r.final_loss()),
            format!("{:.1}", r.total_time),
            format!("{:.1}", r.total_comm_units),
        ]);
        merged.merge(&format!("cb={cb}"), &r.metrics);
    }
    table.print();
    println!(
        "sweep: {} points × {} iters on {threads} thread(s) in {elapsed:.2}s wallclock",
        budgets.len(),
        base.iterations
    );
    save_metrics(args, &merged)
}

#[cfg(feature = "xla")]
fn cmd_train(args: &Args) -> Result<(), String> {
    use crate::coordinator::{plan_matcha, plan_periodic, plan_vanilla, Trainer, TrainerConfig};
    let g = graph_arg(args)?;
    let cb = args.f64_or("budget", 0.5)?;
    let steps = args.usize_or("steps", 200)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let strategy = args.str_or("strategy", "matcha");
    let artifacts = ArtifactPaths::new(args.str_or("artifacts", "artifacts"));

    let plan = match strategy {
        "matcha" => plan_matcha(&g, cb, steps, seed),
        "vanilla" => plan_vanilla(&g, steps),
        "periodic" => plan_periodic(&g, cb, steps),
        other => return Err(format!("unknown strategy '{other}'")),
    };
    println!(
        "plan: strategy={strategy} CB={cb} M={} α={:.5} ρ={:.6} mean-comm={:.2}",
        plan.decomposition.len(),
        plan.alpha,
        plan.rho,
        plan.schedule.mean_comm_units()
    );

    let cfg = TrainerConfig {
        steps,
        lr: args.f64_or("lr", 0.5)? as f32,
        eval_every: args.usize_or("eval-every", 50)?,
        use_pallas: args.bool("pallas"),
        compute_units: args.f64_or("compute-units", 1.0)?,
        non_iid: args.bool("non-iid"),
        seed,
        ..TrainerConfig::default()
    };
    let trainer =
        Trainer::new(&artifacts, plan.decomposition.clone(), cfg).map_err(|e| format!("{e:#}"))?;
    println!(
        "model: preset={} params={} workers={}",
        trainer.meta().preset,
        trainer.meta().param_count,
        trainer.meta().workers
    );
    let report = trainer.run(&plan.schedule).map_err(|e| format!("{e:#}"))?;
    println!(
        "done: train loss {:.4}, eval loss {:.4}, virtual time {:.1} units, \
         comm {:.1} units, wallclock {:.1}s",
        report.final_train_loss,
        report.final_eval_loss,
        report.total_time_units,
        report.total_comm_units,
        report.wallclock_secs
    );
    if let Some(out) = args.flags.get("out") {
        report
            .metrics
            .save_json(std::path::Path::new(out))
            .map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_train(_args: &Args) -> Result<(), String> {
    Err("the 'train' command needs the XLA runtime, which this offline \
         build omits. To enable it: vendor the `xla` and `anyhow` crates, \
         add them as optional dependencies of the `xla` feature in \
         Cargo.toml, then rebuild with `cargo build --features xla`. \
         The pure-Rust paths are available via 'sim' and 'engine'."
        .into())
}

fn cmd_trace_check(args: &Args) -> Result<(), String> {
    let Some(path) = args.flags.get("file") else {
        return Err("trace-check: --file FILE is required".into());
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("trace-check: cannot read {path}: {e}"))?;
    match crate::trace::TraceFormat::parse(args.str_or("format", "chrome"))? {
        crate::trace::TraceFormat::Chrome => {
            let check = crate::trace::validate_chrome_trace(&text)?;
            println!(
                "{path}: well-formed Chrome trace, {} events on {} tracks across {} process(es)",
                check.events, check.tracks, check.pids
            );
            if let Some(dropped) = check.dropped.filter(|&d| d > 0) {
                eprintln!(
                    "warning: {path}: {dropped} record(s) were overwritten in the trace ring(s) \
                     before export — the trace is truncated; raise trace.capacity (or \
                     trace.telemetry_capacity for daemon rings)"
                );
            }
        }
        crate::trace::TraceFormat::Jsonl => {
            let check = crate::trace::validate_jsonl_trace(&text)?;
            println!(
                "{path}: well-formed JSONL trace, {} record(s) across {} event kind(s)",
                check.records, check.kinds
            );
        }
    }
    Ok(())
}

/// Flatten a JSON tree to its numeric leaves under dotted keys
/// (`grid.0.workers`). Non-numeric leaves are skipped.
fn flatten_numbers(json: &Json, prefix: &str, out: &mut Vec<(String, f64)>) {
    match json {
        Json::Num(n) => out.push((prefix.to_string(), *n)),
        Json::Obj(map) => {
            for (k, v) in map {
                let key =
                    if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten_numbers(v, &key, out);
            }
        }
        Json::Arr(items) => {
            for (i, v) in items.iter().enumerate() {
                let key =
                    if prefix.is_empty() { i.to_string() } else { format!("{prefix}.{i}") };
                flatten_numbers(v, &key, out);
            }
        }
        _ => {}
    }
}

/// Deterministic keys that must match the baseline exactly.
const REGRESS_EXACT: &[&str] = &[
    "workers",
    "shards",
    "dim",
    "allocs_per_iter_arena",
    "allocs_per_iter_compressed",
    "trace_disabled_allocs_per_emit",
    "observatory_disabled_allocs_per_iter",
];

/// Lower-is-better keys gated by the fractional tolerance. Wall-clock
/// timings are deliberately absent — they are machine-dependent and
/// never gated.
const REGRESS_TOLERANCE: &[&str] = &[
    "bytes_per_iter",
    "frames_per_iter",
    "virtual_time_barrier",
    "virtual_time_async",
    "wire_units",
    "simulated_comm_units",
    "dropped_links",
];

fn cmd_bench_regress(args: &Args) -> Result<(), String> {
    let Some(artifact) = args.flags.get("artifact") else {
        return Err("bench-regress: --artifact FILE is required".into());
    };
    let Some(history) = args.flags.get("history") else {
        return Err("bench-regress: --history FILE is required".into());
    };
    let tolerance = args.f64_or("tolerance", 0.25)?;
    if !tolerance.is_finite() || tolerance < 0.0 {
        return Err(format!("bench-regress: --tolerance {tolerance} must be >= 0"));
    }
    let text = std::fs::read_to_string(artifact)
        .map_err(|e| format!("bench-regress: cannot read {artifact}: {e}"))?;
    let json = Json::parse(&text).map_err(|e| format!("bench-regress: {artifact}: {e}"))?;
    let mut current = Vec::new();
    flatten_numbers(&json, "", &mut current);

    // Baseline = last non-empty line of the history JSONL, if the file
    // exists (a fresh history passes with nothing to compare).
    let baseline = match std::fs::read_to_string(history) {
        Err(_) => None,
        Ok(h) => match h.lines().rev().find(|l| !l.trim().is_empty()) {
            None => None,
            Some(line) => {
                let j = Json::parse(line)
                    .map_err(|e| format!("bench-regress: {history}: {e}"))?;
                let mut flat = Vec::new();
                flatten_numbers(&j, "", &mut flat);
                Some(flat)
            }
        },
    };

    let diff = args.bool("diff");
    let mut checked = 0usize;
    let mut failures: Vec<String> = Vec::new();
    let mut diff_rows: Vec<[String; 5]> = Vec::new();
    if let Some(base) = &baseline {
        let base_map: std::collections::BTreeMap<&str, f64> =
            base.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        for (key, cur) in &current {
            let prev = base_map.get(key.as_str()).copied();
            let seg = key.rsplit('.').next().unwrap_or(key);
            // Verdict per key: gated keys report ok/FAIL, everything
            // else (new keys, wall-clock timings) shows as "-".
            let verdict = match prev {
                None => "-",
                Some(prev) if REGRESS_EXACT.contains(&seg) => {
                    checked += 1;
                    if *cur != prev {
                        failures.push(format!("{key}: {prev} -> {cur} (exact-match key)"));
                        "exact-FAIL"
                    } else {
                        "exact-ok"
                    }
                }
                Some(prev) if REGRESS_TOLERANCE.contains(&seg) => {
                    checked += 1;
                    if prev == 0.0 {
                        if *cur > 0.0 {
                            failures.push(format!("{key}: baseline 0 -> {cur}"));
                            "tol-FAIL"
                        } else {
                            "tol-ok"
                        }
                    } else if *cur > prev * (1.0 + tolerance) {
                        failures.push(format!(
                            "{key}: {prev} -> {cur} (over the {:.0}% budget)",
                            tolerance * 100.0
                        ));
                        "tol-FAIL"
                    } else {
                        "tol-ok"
                    }
                }
                Some(_) => "-",
            };
            if diff {
                let (last, delta) = match prev {
                    Some(p) if p != 0.0 => {
                        (format!("{p}"), format!("{:+.1}%", (*cur - p) / p * 100.0))
                    }
                    Some(p) => (format!("{p}"), "-".to_string()),
                    None => ("-".to_string(), "-".to_string()),
                };
                diff_rows.push([key.clone(), last, format!("{cur}"), delta, verdict.to_string()]);
            }
        }
    }
    if diff {
        if diff_rows.is_empty() {
            println!("bench-regress: no baseline in {history}; nothing to diff");
        } else {
            let mut table = crate::benchkit::Table::new(&[
                "key",
                "last committed",
                "current",
                "delta",
                "gate",
            ]);
            for row in &diff_rows {
                table.row(row);
            }
            table.print();
        }
    }

    if args.bool("append") {
        use std::io::Write;
        if let Some(parent) = std::path::Path::new(history).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("bench-regress: {}: {e}", parent.display()))?;
            }
        }
        let obj: std::collections::BTreeMap<String, Json> =
            current.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(history)
            .map_err(|e| format!("bench-regress: cannot open {history}: {e}"))?;
        writeln!(f, "{}", Json::Obj(obj))
            .map_err(|e| format!("bench-regress: cannot append to {history}: {e}"))?;
        println!("appended {} metrics to {history}", current.len());
    }

    if !failures.is_empty() {
        return Err(format!(
            "bench-regress: {} regression(s) vs {history}:\n  {}",
            failures.len(),
            failures.join("\n  ")
        ));
    }
    match baseline {
        Some(_) if checked > 0 => {
            println!("bench-regress: {checked} gated key(s) within budget vs {history}");
        }
        Some(_) => println!("bench-regress: no comparable gated keys vs {history}; pass"),
        None => println!("bench-regress: no baseline in {history}; pass"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let artifacts = ArtifactPaths::new(args.str_or("artifacts", "artifacts"));
    let meta = crate::config::ModelMeta::load(&artifacts.meta())?;
    println!(
        "preset={} vocab={} d_model={} layers={} heads={} seq_len={} batch={}",
        meta.preset, meta.vocab, meta.d_model, meta.n_layers, meta.n_heads, meta.seq_len, meta.batch
    );
    println!("workers={} param_count={}", meta.workers, meta.param_count);
    for p in &meta.params {
        println!("  {:<24} {:?} @ {}", p.name, p.shape, p.offset);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn args_parse_flags_and_booleans() {
        let a = Args::parse(&sv(&["--graph", "ring:5", "--pallas", "--budget", "0.3"])).unwrap();
        assert_eq!(a.str_or("graph", "x"), "ring:5");
        assert!(a.bool("pallas"));
        assert_eq!(a.f64_or("budget", 0.0).unwrap(), 0.3);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn args_reject_positional() {
        assert!(Args::parse(&sv(&["oops"])).is_err());
    }

    #[test]
    fn args_reject_duplicate_flags() {
        let r = Args::parse(&sv(&["--graph", "ring:5", "--graph", "ring:6"]));
        assert!(r.is_err());
        assert!(r.unwrap_err().contains("duplicate flag --graph"));
        // Boolean/value mixtures are duplicates too.
        let r = Args::parse(&sv(&["--pallas", "--pallas"]));
        assert!(r.unwrap_err().contains("duplicate flag --pallas"));
        let r = Args::parse(&sv(&["--seed", "1", "--seed"]));
        assert!(r.unwrap_err().contains("duplicate flag --seed"));
    }

    #[test]
    fn duplicate_flags_surface_through_run_dispatch() {
        let r = run(&sv(&["sim", "--iters", "5", "--iters", "9"]));
        assert!(r.unwrap_err().contains("duplicate flag --iters"));
    }

    #[test]
    fn run_dispatches_fast_commands() {
        run(&sv(&["decompose", "--graph", "ring:6"])).unwrap();
        run(&sv(&["commtime", "--graph", "fig1", "--budget", "0.5"])).unwrap();
        run(&sv(&["help"])).unwrap();
    }

    #[test]
    fn run_rejects_unknown_command() {
        assert!(run(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn sim_quadratic_smoke() {
        run(&sv(&[
            "sim",
            "--graph",
            "ring:6",
            "--strategy",
            "matcha",
            "--budget",
            "0.5",
            "--iters",
            "50",
            "--problem",
            "quad",
        ]))
        .unwrap();
    }

    #[test]
    fn sim_single_matching_strategy_smoke() {
        run(&sv(&[
            "sim",
            "--graph",
            "ring:6",
            "--strategy",
            "single",
            "--budget",
            "0.5",
            "--iters",
            "50",
            "--problem",
            "quad",
        ]))
        .unwrap();
    }

    #[test]
    fn engine_smoke_all_policies() {
        for policy in ["analytic", "hetero:1", "straggler:0:3.0", "flaky:0.2"] {
            run(&sv(&[
                "engine",
                "--graph",
                "ring:6",
                "--strategy",
                "matcha",
                "--budget",
                "0.5",
                "--iters",
                "40",
                "--problem",
                "quad",
                "--policy",
                policy,
            ]))
            .unwrap_or_else(|e| panic!("policy {policy}: {e}"));
        }
    }

    #[test]
    fn engine_parallel_smoke() {
        run(&sv(&[
            "engine",
            "--graph",
            "ring:6",
            "--iters",
            "30",
            "--problem",
            "quad",
            "--threads",
            "4",
        ]))
        .unwrap();
    }

    #[test]
    fn engine_async_backend_smoke() {
        run(&sv(&[
            "engine",
            "--graph",
            "ring:6",
            "--backend",
            "async",
            "--threads",
            "2",
            "--max-staleness",
            "3",
            "--iters",
            "40",
            "--problem",
            "quad",
            "--policy",
            "straggler:0:4.0",
        ]))
        .unwrap();
    }

    #[test]
    fn engine_cluster_backend_smoke() {
        for transport in ["loopback", "tcp"] {
            run(&sv(&[
                "engine",
                "--graph",
                "ring:6",
                "--backend",
                "cluster",
                "--shards",
                "3",
                "--transport",
                transport,
                "--iters",
                "20",
                "--problem",
                "quad",
            ]))
            .unwrap_or_else(|e| panic!("transport {transport}: {e}"));
        }
    }

    #[test]
    fn engine_cluster_rejects_bad_flags() {
        let r = run(&sv(&[
            "engine", "--graph", "ring:4", "--backend", "cluster", "--shards", "0",
        ]));
        assert!(r.unwrap_err().contains("--shards"));
        let r = run(&sv(&[
            "engine", "--graph", "ring:4", "--backend", "cluster", "--transport", "pigeon",
        ]));
        assert!(r.unwrap_err().contains("transport"));
    }

    #[test]
    fn engine_async_unbounded_staleness_smoke() {
        run(&sv(&[
            "engine",
            "--graph",
            "ring:6",
            "--backend",
            "async",
            "--max-staleness",
            "unbounded",
            "--iters",
            "30",
            "--problem",
            "quad",
            "--policy",
            "straggler:0:4.0",
        ]))
        .unwrap();
        let r = run(&sv(&[
            "engine", "--graph", "ring:4", "--backend", "async", "--max-staleness", "lots",
        ]));
        assert!(r.unwrap_err().contains("--max-staleness"));
    }

    #[test]
    fn shard_node_requires_listen_and_rejects_bad_flags() {
        assert!(run(&sv(&["shard-node"])).unwrap_err().contains("--listen"));
        let r = run(&sv(&[
            "shard-node", "--listen", "127.0.0.1:0", "--drop-after", "soon",
        ]));
        assert!(r.unwrap_err().contains("--drop-after"));
        let r = run(&sv(&[
            "shard-node", "--listen", "127.0.0.1:0", "--io-timeout-ms", "many",
        ]));
        assert!(r.unwrap_err().contains("--io-timeout-ms"));
        // An unbindable address fails fast instead of serving.
        assert!(run(&sv(&["shard-node", "--listen", "256.0.0.1:0"])).is_err());
    }

    #[test]
    fn engine_rejects_unknown_backend() {
        let r = run(&sv(&["engine", "--graph", "ring:4", "--backend", "warp"]));
        assert!(r.unwrap_err().contains("backend"));
    }

    #[test]
    fn sweep_async_backend_smoke() {
        run(&sv(&[
            "sweep",
            "--graph",
            "ring:6",
            "--backend",
            "async",
            "--budgets",
            "0.4,0.9",
            "--iters",
            "30",
            "--problem",
            "quad",
            "--threads",
            "2",
        ]))
        .unwrap();
    }

    #[test]
    fn sweep_accepts_spec_files() {
        let spec = ExperimentSpec::new("ring:6")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::Async { threads: 1, max_staleness: 2 })
            .iterations(30)
            .record_every(10);
        let dir = std::env::temp_dir().join("matcha_cli_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        spec.save(&path).unwrap();
        let p = path.to_str().unwrap();
        run(&sv(&["sweep", "--spec", p, "--budgets", "0.3,0.7", "--threads", "2"])).unwrap();
    }

    #[test]
    fn sweep_demotes_multithreaded_spec_backends() {
        // An actors-backend spec must sweep via the (identical-result)
        // sequential engine instead of nesting thread pools per point.
        let spec = ExperimentSpec::new("ring:6")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::EngineActors { threads: 8 })
            .iterations(20)
            .record_every(10);
        let dir = std::env::temp_dir().join("matcha_cli_sweep_demote");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        spec.save(&path).unwrap();
        let p = path.to_str().unwrap();
        run(&sv(&["sweep", "--spec", p, "--budgets", "0.5", "--threads", "2"])).unwrap();
    }

    #[test]
    fn engine_rejects_bad_policy() {
        let r = run(&sv(&["engine", "--graph", "ring:4", "--policy", "warp-drive"]));
        assert!(r.is_err());
    }

    #[test]
    fn sim_rejects_engine_policy() {
        let r = run(&sv(&["sim", "--graph", "ring:4", "--iters", "5", "--policy", "flaky:0.2"]));
        assert!(r.unwrap_err().contains("policy"));
    }

    #[test]
    fn sweep_smoke() {
        run(&sv(&[
            "sweep",
            "--graph",
            "ring:6",
            "--budgets",
            "0.3,0.8",
            "--iters",
            "40",
            "--problem",
            "quad",
            "--threads",
            "2",
        ]))
        .unwrap();
    }

    #[test]
    fn sweep_rejects_bad_budget_list() {
        assert!(run(&sv(&["sweep", "--graph", "ring:4", "--budgets", "0.3,oops"])).is_err());
    }

    #[test]
    fn run_command_executes_and_dry_runs_spec_files() {
        let spec = ExperimentSpec::new("ring:6")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::EngineSequential)
            .iterations(30)
            .record_every(10);
        let dir = std::env::temp_dir().join("matcha_cli_run");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        spec.save(&path).unwrap();
        let p = path.to_str().unwrap();
        run(&sv(&["run", "--spec", p, "--dry-run"])).unwrap();
        run(&sv(&["run", "--spec", p])).unwrap();
    }

    #[test]
    fn run_command_requires_spec_and_rejects_missing_file() {
        assert!(run(&sv(&["run"])).unwrap_err().contains("--spec"));
        let r = run(&sv(&["run", "--spec", "/nonexistent/spec.json"]));
        assert!(r.is_err());
    }

    #[test]
    fn run_command_trace_flag_writes_checkable_trace() {
        let spec = ExperimentSpec::new("ring:6")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::EngineSequential)
            .iterations(20)
            .record_every(10);
        let dir = std::env::temp_dir().join("matcha_cli_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        spec.save(&path).unwrap();
        let trace = dir.join("trace.json");
        let p = path.to_str().unwrap();
        let t = trace.to_str().unwrap();
        run(&sv(&["run", "--spec", p, "--trace", t])).unwrap();
        run(&sv(&["trace-check", "--file", t])).unwrap();
        assert!(run(&sv(&["trace-check"])).unwrap_err().contains("--file"));
        assert!(run(&sv(&["trace-check", "--file", "/nonexistent.json"])).is_err());
    }

    #[test]
    fn report_command_runs_specs_and_rerenders_saved_reports() {
        let spec = ExperimentSpec::new("ring:6")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::EngineSequential)
            .iterations(30)
            .record_every(10);
        let dir = std::env::temp_dir().join("matcha_cli_report");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        spec.save(&path).unwrap();
        let out = dir.join("report.json");
        let p = path.to_str().unwrap();
        let o = out.to_str().unwrap();
        // A spec with no report block still runs: the command arms the
        // observatory at the default window.
        run(&sv(&["report", "--spec", p, "--out", o])).unwrap();
        // The saved JSON re-renders standalone.
        run(&sv(&["report", o])).unwrap();
        assert!(run(&sv(&["report"])).unwrap_err().contains("--spec"));
        assert!(run(&sv(&["report", o, "--out", o])).unwrap_err().contains("no extra flags"));
        assert!(run(&sv(&["report", "/nonexistent/report.json"])).is_err());
    }

    #[test]
    fn bench_regress_gates_exact_and_tolerance_keys() {
        let dir = std::env::temp_dir().join("matcha_cli_regress");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("bench.json");
        let history = dir.join("hist.jsonl");
        std::fs::remove_file(&history).ok();
        let a = artifact.to_str().unwrap().to_string();
        let h = history.to_str().unwrap().to_string();
        let good = r#"{"grid": [{"workers": 8, "ns_per_iter": 100.0, "bytes_per_iter": 64.0}]}"#;
        std::fs::write(&artifact, good).unwrap();

        // No history yet: passes (--diff has nothing to diff), --append
        // seeds the first entry.
        run(&sv(&[
            "bench-regress", "--artifact", &a, "--history", &h, "--append", "--diff",
        ]))
        .unwrap();
        // Identical values gate cleanly against that entry, and --diff
        // renders the old-vs-new table without changing the verdict.
        run(&sv(&["bench-regress", "--artifact", &a, "--history", &h, "--diff"])).unwrap();

        // A wall-clock blowup alone is never gated.
        let wall =
            r#"{"grid": [{"workers": 8, "ns_per_iter": 9000.0, "bytes_per_iter": 64.0}]}"#;
        std::fs::write(&artifact, wall).unwrap();
        run(&sv(&["bench-regress", "--artifact", &a, "--history", &h])).unwrap();

        // >25% growth on a lower-is-better key fails.
        let slow =
            r#"{"grid": [{"workers": 8, "ns_per_iter": 100.0, "bytes_per_iter": 100.0}]}"#;
        std::fs::write(&artifact, slow).unwrap();
        let err =
            run(&sv(&["bench-regress", "--artifact", &a, "--history", &h])).unwrap_err();
        assert!(err.contains("bytes_per_iter"), "{err}");
        // ... but a loose enough --tolerance accepts it.
        run(&sv(&[
            "bench-regress", "--artifact", &a, "--history", &h, "--tolerance", "0.8",
        ]))
        .unwrap();

        // Exact-match keys reject any drift.
        let drift = r#"{"grid": [{"workers": 9, "ns_per_iter": 100.0, "bytes_per_iter": 64.0}]}"#;
        std::fs::write(&artifact, drift).unwrap();
        let err =
            run(&sv(&["bench-regress", "--artifact", &a, "--history", &h])).unwrap_err();
        assert!(err.contains("workers"), "{err}");

        assert!(run(&sv(&["bench-regress", "--history", &h])).unwrap_err().contains("--artifact"));
        assert!(run(&sv(&["bench-regress", "--artifact", &a])).unwrap_err().contains("--history"));
    }

    #[test]
    fn trace_check_validates_jsonl_format() {
        let dir = std::env::temp_dir().join("matcha_cli_trace_jsonl");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        std::fs::write(
            &path,
            "{\"ev\": \"round_barrier\", \"k\": 0, \"vt\": 1.0, \"wall_ns\": 5}\n",
        )
        .unwrap();
        let p = path.to_str().unwrap();
        run(&sv(&["trace-check", "--file", p, "--format", "jsonl"])).unwrap();
        assert!(run(&sv(&["trace-check", "--file", p, "--format", "pprof"])).is_err());
        // A JSONL stream is not a Chrome trace.
        assert!(run(&sv(&["trace-check", "--file", p])).is_err());
    }

    #[test]
    fn status_requires_addr_and_fails_on_dead_daemon() {
        assert!(run(&sv(&["status"])).unwrap_err().contains("ADDR"));
        assert!(run(&sv(&["status", "--timeout-ms", "100"])).unwrap_err().contains("ADDR"));
        // A port nothing listens on: connect fails fast with an error,
        // not a hang (tested against a genuinely dead localhost port).
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = l.local_addr().unwrap().to_string();
            drop(l);
            addr
        };
        assert!(run(&sv(&["status", &dead, "--timeout-ms", "300"])).is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn train_reports_missing_feature() {
        let r = run(&sv(&["train", "--graph", "fig1"]));
        assert!(r.unwrap_err().contains("xla"));
    }
}
