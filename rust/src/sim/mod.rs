//! Pure-Rust decentralized SGD simulator.
//!
//! The paper's sweep experiments (Figures 3–8, 10) compare MATCHA,
//! vanilla DecenSGD and P-DecenSGD across many budgets and topologies.
//! Running every sweep point through the full XLA NN path would be
//! wasteful; this module provides a fast, exact implementation of the
//! DecenSGD recursion (paper eq. (2))
//!
//! ```text
//!   x_i^{(k+1)} = Σ_j W_ij [ x_j^{(k)} − η g(x_j^{(k)}) ]
//! ```
//!
//! over analytically tractable workloads (distributed quadratics with a
//! known optimum, and synthetic logistic regression with train/test
//! splits). The NN path in [`crate::coordinator`] exercises the same
//! schedule code on the real model; the two paths share [`crate::topology`]
//! and [`crate::delay`], so sweep results and NN results are directly
//! comparable.

mod compress;
pub mod kernel;
mod logreg;
mod quadratic;
mod runner;

pub use compress::Compression;
pub use logreg::{LogisticProblem, LogisticSpec};
pub use quadratic::QuadraticProblem;
pub use runner::{
    run_decentralized, run_decentralized_observed, run_decentralized_traced, RunConfig, RunResult,
};

use crate::rng::Rng;

/// A decentralized optimization workload: `m` workers each with a local
/// objective `F_i`; the global objective is their average (paper eq. (1)).
pub trait Problem {
    /// Parameter dimension `d`.
    fn dim(&self) -> usize;
    /// Number of workers `m`.
    fn num_workers(&self) -> usize;
    /// Local full-batch loss `F_i(x)`.
    fn local_loss(&self, worker: usize, x: &[f64]) -> f64;
    /// Stochastic gradient of `F_i` at `x`, written into `out`.
    fn stoch_grad(&self, worker: usize, x: &[f64], rng: &mut Rng, out: &mut [f64]);
    /// Global loss `F(x) = (1/m) Σ F_i(x)`.
    fn global_loss(&self, x: &[f64]) -> f64 {
        let m = self.num_workers();
        (0..m).map(|i| self.local_loss(i, x)).sum::<f64>() / m as f64
    }
    /// Full gradient of the global objective (for reporting ‖∇F(x̄)‖²,
    /// the paper's Theorem-1 convergence metric), written into `out`.
    fn global_grad(&self, x: &[f64], out: &mut [f64]);
    /// Known optimal value `F*` when available (quadratics), to report
    /// suboptimality `F(x̄) − F*`.
    fn optimal_value(&self) -> Option<f64> {
        None
    }
    /// Held-out metric (e.g. test accuracy) when defined.
    fn test_metric(&self, _x: &[f64]) -> Option<f64> {
        None
    }
}

/// Mean iterate x̄ = (1/m) Σ x_i.
pub fn mean_iterate(xs: &[Vec<f64>]) -> Vec<f64> {
    let m = xs.len();
    let d = xs[0].len();
    let mut mean = vec![0.0; d];
    for x in xs {
        for (a, &b) in mean.iter_mut().zip(x) {
            *a += b;
        }
    }
    for a in mean.iter_mut() {
        *a /= m as f64;
    }
    mean
}

/// Consensus distance `(1/m) Σ_i ‖x_i − x̄‖²` — the discrepancy term
/// bounded in the paper's Theorem-1 proof (eq. 62).
pub fn consensus_distance(xs: &[Vec<f64>]) -> f64 {
    let mean = mean_iterate(xs);
    let m = xs.len();
    xs.iter()
        .map(|x| x.iter().zip(&mean).map(|(a, b)| (a - b) * (a - b)).sum::<f64>())
        .sum::<f64>()
        / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_consensus() {
        let xs = vec![vec![1.0, 0.0], vec![3.0, 0.0]];
        assert_eq!(mean_iterate(&xs), vec![2.0, 0.0]);
        // Each worker is distance 1 from the mean -> consensus = 1.
        assert!((consensus_distance(&xs) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn consensus_zero_when_identical() {
        let xs = vec![vec![0.5; 4]; 3];
        assert!(consensus_distance(&xs) < 1e-15);
    }
}
