//! Synthetic logistic-regression workload (the simulator's stand-in for
//! the paper's image-classification tasks).
//!
//! Each worker holds a shard of a two-class Gaussian-mixture dataset;
//! `non_iid > 0` shifts class balance across workers, reproducing the
//! "evenly partitioned but locally different" regime of the paper's
//! experiments. Loss is ℓ2-regularized logistic loss; the test split
//! provides the accuracy series for the Fig 7/10 analogs.

use super::Problem;
use crate::rng::Rng;

/// Specification for generating a [`LogisticProblem`].
#[derive(Clone, Debug)]
pub struct LogisticSpec {
    pub num_workers: usize,
    /// Feature dimension (weights have dim+1 entries: bias last).
    pub feature_dim: usize,
    /// Training samples per worker.
    pub samples_per_worker: usize,
    /// Held-out test samples (global).
    pub test_samples: usize,
    /// Mini-batch size for stochastic gradients.
    pub batch_size: usize,
    /// ℓ2 regularization strength.
    pub l2: f64,
    /// Class-mean separation (higher = easier problem).
    pub separation: f64,
    /// 0 = IID shards; 1 = strongly skewed class balance per worker.
    pub non_iid: f64,
    pub seed: u64,
}

impl Default for LogisticSpec {
    fn default() -> Self {
        LogisticSpec {
            num_workers: 8,
            feature_dim: 32,
            samples_per_worker: 256,
            test_samples: 512,
            batch_size: 16,
            l2: 1e-3,
            separation: 1.5,
            non_iid: 0.0,
            seed: 0,
        }
    }
}

/// See module docs.
pub struct LogisticProblem {
    spec: LogisticSpec,
    /// Per-worker features, row-major `samples × (dim+1)` with bias 1.
    features: Vec<Vec<f64>>,
    /// Per-worker labels in {0, 1}.
    labels: Vec<Vec<f64>>,
    test_features: Vec<f64>,
    test_labels: Vec<f64>,
}

fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl LogisticProblem {
    pub fn generate(spec: LogisticSpec) -> Self {
        let mut rng = Rng::new(spec.seed);
        let d = spec.feature_dim;
        // Class means ±separation/2 along a random unit direction.
        let mut dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let n: f64 = dir.iter().map(|v| v * v).sum::<f64>().sqrt();
        dir.iter_mut().for_each(|v| *v /= n);

        let sample = |class: f64, rng: &mut Rng| -> Vec<f64> {
            let sign = if class > 0.5 { 0.5 } else { -0.5 };
            let mut x: Vec<f64> = (0..d)
                .map(|j| rng.normal() + sign * spec.separation * dir[j])
                .collect();
            x.push(1.0); // bias feature
            x
        };

        let mut features = Vec::with_capacity(spec.num_workers);
        let mut labels = Vec::with_capacity(spec.num_workers);
        for w in 0..spec.num_workers {
            // Worker class-1 fraction: 0.5 shifted by non_iid pattern.
            let skew = spec.non_iid
                * 0.45
                * if w % 2 == 0 { 1.0 } else { -1.0 };
            let p1 = (0.5 + skew).clamp(0.05, 0.95);
            let mut xf = Vec::with_capacity(spec.samples_per_worker * (d + 1));
            let mut yl = Vec::with_capacity(spec.samples_per_worker);
            for _ in 0..spec.samples_per_worker {
                let y = if rng.bernoulli(p1) { 1.0 } else { 0.0 };
                xf.extend(sample(y, &mut rng));
                yl.push(y);
            }
            features.push(xf);
            labels.push(yl);
        }

        let mut test_features = Vec::with_capacity(spec.test_samples * (d + 1));
        let mut test_labels = Vec::with_capacity(spec.test_samples);
        for _ in 0..spec.test_samples {
            let y = if rng.bernoulli(0.5) { 1.0 } else { 0.0 };
            test_features.extend(sample(y, &mut rng));
            test_labels.push(y);
        }

        LogisticProblem { spec, features, labels, test_features, test_labels }
    }

    fn row<'a>(buf: &'a [f64], idx: usize, width: usize) -> &'a [f64] {
        &buf[idx * width..(idx + 1) * width]
    }

    fn logloss(z: f64, y: f64) -> f64 {
        // -y log σ(z) - (1-y) log(1-σ(z)), numerically stable.
        let a = z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
        a
    }
}

impl Problem for LogisticProblem {
    fn dim(&self) -> usize {
        self.spec.feature_dim + 1
    }

    fn num_workers(&self) -> usize {
        self.spec.num_workers
    }

    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        let width = self.dim();
        let n = self.spec.samples_per_worker;
        let mut loss = 0.0;
        for i in 0..n {
            let xi = Self::row(&self.features[worker], i, width);
            let z: f64 = xi.iter().zip(x).map(|(a, b)| a * b).sum();
            loss += Self::logloss(z, self.labels[worker][i]);
        }
        loss / n as f64 + 0.5 * self.spec.l2 * x.iter().map(|v| v * v).sum::<f64>()
    }

    fn stoch_grad(&self, worker: usize, x: &[f64], rng: &mut Rng, out: &mut [f64]) {
        let width = self.dim();
        let n = self.spec.samples_per_worker;
        let b = self.spec.batch_size.min(n);
        out.iter_mut().for_each(|v| *v = 0.0);
        for _ in 0..b {
            let i = rng.below(n);
            let xi = Self::row(&self.features[worker], i, width);
            let z: f64 = xi.iter().zip(&*x).map(|(a, c)| a * c).sum();
            let err = sigmoid(z) - self.labels[worker][i];
            for (o, &f) in out.iter_mut().zip(xi) {
                *o += err * f / b as f64;
            }
        }
        for (o, &w) in out.iter_mut().zip(x) {
            *o += self.spec.l2 * w;
        }
    }

    fn global_grad(&self, x: &[f64], out: &mut [f64]) {
        let width = self.dim();
        let m = self.spec.num_workers;
        let n = self.spec.samples_per_worker;
        out.iter_mut().for_each(|v| *v = 0.0);
        for w in 0..m {
            for i in 0..n {
                let xi = Self::row(&self.features[w], i, width);
                let z: f64 = xi.iter().zip(&*x).map(|(a, c)| a * c).sum();
                let err = sigmoid(z) - self.labels[w][i];
                for (o, &f) in out.iter_mut().zip(xi) {
                    *o += err * f / (m * n) as f64;
                }
            }
        }
        for (o, &w) in out.iter_mut().zip(x) {
            *o += self.spec.l2 * w;
        }
    }

    fn test_metric(&self, x: &[f64]) -> Option<f64> {
        let width = self.dim();
        let n = self.test_labels.len();
        if n == 0 {
            return None;
        }
        let mut correct = 0usize;
        for i in 0..n {
            let xi = Self::row(&self.test_features, i, width);
            let z: f64 = xi.iter().zip(x).map(|(a, b)| a * b).sum();
            let pred = if z > 0.0 { 1.0 } else { 0.0 };
            if (pred - self.test_labels[i]).abs() < 0.5 {
                correct += 1;
            }
        }
        Some(correct as f64 / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> LogisticSpec {
        LogisticSpec {
            num_workers: 4,
            feature_dim: 8,
            samples_per_worker: 64,
            test_samples: 128,
            batch_size: 8,
            seed: 42,
            ..LogisticSpec::default()
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = LogisticProblem::generate(small_spec());
        let b = LogisticProblem::generate(small_spec());
        assert_eq!(a.features[0], b.features[0]);
        assert_eq!(a.test_labels, b.test_labels);
    }

    #[test]
    fn loss_decreases_under_gradient_descent() {
        let p = LogisticProblem::generate(small_spec());
        let mut x = vec![0.0; p.dim()];
        let mut g = vec![0.0; p.dim()];
        let l0 = p.global_loss(&x);
        for _ in 0..100 {
            p.global_grad(&x, &mut g);
            for (xi, &gi) in x.iter_mut().zip(&g) {
                *xi -= 0.5 * gi;
            }
        }
        let l1 = p.global_loss(&x);
        assert!(l1 < l0 * 0.8, "GD failed to reduce loss: {l0} -> {l1}");
        // Separable-ish data: accuracy should comfortably beat chance.
        let acc = p.test_metric(&x).unwrap();
        assert!(acc > 0.7, "test accuracy {acc}");
    }

    #[test]
    fn stoch_grad_unbiasedness() {
        let p = LogisticProblem::generate(small_spec());
        let x = vec![0.1; p.dim()];
        // Average many minibatch gradients for worker 0 vs its full grad.
        let mut rng = Rng::new(5);
        let mut acc = vec![0.0; p.dim()];
        let mut tmp = vec![0.0; p.dim()];
        let n = 5000;
        for _ in 0..n {
            p.stoch_grad(0, &x, &mut rng, &mut tmp);
            for (a, &t) in acc.iter_mut().zip(&tmp) {
                *a += t / n as f64;
            }
        }
        // Full local gradient: batch = all samples, computed directly.
        let width = p.dim();
        let mut full = vec![0.0; p.dim()];
        for i in 0..p.spec.samples_per_worker {
            let xi = LogisticProblem::row(&p.features[0], i, width);
            let z: f64 = xi.iter().zip(&x).map(|(a, c)| a * c).sum();
            let err = sigmoid(z) - p.labels[0][i];
            for (o, &f) in full.iter_mut().zip(xi) {
                *o += err * f / p.spec.samples_per_worker as f64;
            }
        }
        for (o, &w) in full.iter_mut().zip(&x) {
            *o += p.spec.l2 * w;
        }
        for (a, f) in acc.iter().zip(&full) {
            assert!((a - f).abs() < 0.03, "bias {a} vs {f}");
        }
    }

    #[test]
    fn non_iid_skews_worker_labels() {
        let mut spec = small_spec();
        spec.non_iid = 1.0;
        spec.samples_per_worker = 400;
        let p = LogisticProblem::generate(spec);
        let frac1: Vec<f64> = (0..4)
            .map(|w| p.labels[w].iter().sum::<f64>() / p.labels[w].len() as f64)
            .collect();
        assert!(frac1[0] > 0.8, "even workers skew to class 1: {frac1:?}");
        assert!(frac1[1] < 0.2, "odd workers skew to class 0: {frac1:?}");
    }

    #[test]
    fn logloss_stable_at_extremes() {
        assert!(LogisticProblem::logloss(50.0, 1.0) < 1e-10);
        assert!(LogisticProblem::logloss(-50.0, 0.0) < 1e-10);
        assert!(LogisticProblem::logloss(-50.0, 1.0) > 40.0);
        assert!(LogisticProblem::logloss(0.0, 1.0) - (2.0_f64).ln() < 1e-12);
    }
}
