//! The single step/mix kernel shared by the sequential simulator
//! ([`crate::sim::run_decentralized`]), the event-driven engine
//! ([`crate::engine`]) and the asynchronous gossip runtime
//! ([`crate::gossip`]).
//!
//! All execution paths must produce **bit-for-bit identical**
//! trajectories for the same seed, so everything that draws randomness
//! for the iterates lives here exactly once:
//!
//! - [`worker_streams`] — the per-worker gradient-noise RNG derivation.
//!   Giving each worker its own stream (instead of one shared generator
//!   consumed in worker order) is what makes the engine's parallel actor
//!   mode reproducible: a worker's draws depend only on `(seed, worker)`,
//!   never on thread scheduling.
//! - [`init_iterates`] — the common initial point (Theorem 1 starts all
//!   workers at the same iterate), materialized as a
//!   [`StateMatrix`] arena.
//! - [`local_sgd_step`] — one worker's local stochastic-gradient step
//!   over an arena row.
//! - [`apply_gossip`] — the simultaneous gossip mix
//!   `X ← X + α Σ_{j∈activated} (−L_j) X`, applied edge-wise in place
//!   over arena rows by the shared [`MixKernel`]
//!   ([`crate::state::kernel`]), with optional message compression and an
//!   optional set of dead links (the engine's failure injection; the
//!   sequential simulator passes none).
//! - [`edge_rng`] — compression randomness derived per
//!   `(seed, iteration, matching, edge)`, so both endpoints of a link —
//!   and all execution paths — quantize a message identically no matter
//!   in which order edges are processed.
//!
//! The state *representation* (contiguous arena, scratch pools, the mix
//! fold itself) lives in [`crate::state`]; this module binds it to the
//! run semantics (RNG streams, the step rule, metric recording).

use super::{Compression, Problem};
use crate::graph::Graph;
use crate::rng::Rng;
use crate::state::{simd, DeltaPool, MixKernel, RowSource, StateMatrix};

/// Domain-separation constant for the gossip/compression RNG stream.
pub const MIX_STREAM_SALT: u64 = 0xc03f_5eed;

/// Per-worker gradient-noise RNG streams for a run seed.
///
/// The derivation feeds `seed + (w+1)·φ` (with φ the 64-bit golden-ratio
/// constant) through [`Rng::new`]'s SplitMix expansion, which decorrelates
/// even adjacent seeds.
pub fn worker_streams(seed: u64, m: usize) -> Vec<Rng> {
    (0..m)
        .map(|w| {
            Rng::new(seed.wrapping_add((w as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)))
        })
        .collect()
}

/// Initial iterates: every worker starts from the same random point, in
/// one contiguous arena.
pub fn init_iterates(seed: u64, m: usize, d: usize) -> StateMatrix {
    StateMatrix::init(seed, m, d)
}

/// One worker's local SGD step: `x ← x − η g(x)`. `grad` is scratch
/// (lives in the run's [`DeltaPool`]).
pub fn local_sgd_step<P: Problem + ?Sized>(
    problem: &P,
    worker: usize,
    lr: f64,
    x: &mut [f64],
    rng: &mut Rng,
    grad: &mut [f64],
) {
    problem.stoch_grad(worker, x, rng, grad);
    for (xi, &gi) in x.iter_mut().zip(grad.iter()) {
        *xi -= lr * gi;
    }
}

/// Deterministic per-edge RNG for compression: both endpoints of link
/// `(u,v)` in matching `j` at iteration `k` derive the same stream, so
/// they compress the shared difference message identically.
pub fn edge_rng(seed: u64, k: usize, j: usize, u: usize, v: usize) -> Rng {
    let h = (k as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ (j as u64).wrapping_mul(0xc2b2_ae3d_27d4_eb4f)
        ^ (u as u64).wrapping_mul(0x1656_67b1_9e37_79f9)
        ^ (v as u64).wrapping_mul(0x2545_f491_4f6c_dd1d);
    Rng::new(seed ^ MIX_STREAM_SALT ^ h)
}

/// Compute the canonical compressed difference message of edge `(u,v)`
/// (`u < v` in matching storage): `diff = x_v − x_u`, compressed in place
/// when compression is configured. Shared by the full-state mix and the
/// per-worker folds of the actor shards and the async runtime.
///
/// Endpoint rows are [`RowSource`]s, so a peer row borrowed straight
/// from a received wire frame (little-endian bytes) folds without ever
/// being copied into host staging; `scratch` is the caller's recycled
/// TopK magnitude buffer ([`Compression::compress_with`]), keeping the
/// whole message computation allocation-free. The subtraction runs
/// through the SIMD-dispatched [`simd::diff_rows`].
#[allow(clippy::too_many_arguments)]
pub fn edge_diff_message_src(
    xu: RowSource<'_>,
    xv: RowSource<'_>,
    diff: &mut [f64],
    compression: Option<&Compression>,
    scratch: &mut Vec<f64>,
    seed: u64,
    k: usize,
    j: usize,
    u: usize,
    v: usize,
) {
    simd::diff_rows(xu, xv, diff);
    if let Some(comp) = compression {
        let mut rng = edge_rng(seed, k, j, u, v);
        comp.compress_with(diff, &mut rng, scratch);
    }
}

/// Host-rows convenience wrapper over [`edge_diff_message_src`] with a
/// throwaway compression scratch. Hot paths hold a recycled scratch and
/// call the `_src` form; this wrapper is for call sites outside the
/// per-iteration loop (tests, baseline benches).
#[allow(clippy::too_many_arguments)]
pub fn edge_diff_message(
    xu: &[f64],
    xv: &[f64],
    diff: &mut [f64],
    compression: Option<&Compression>,
    seed: u64,
    k: usize,
    j: usize,
    u: usize,
    v: usize,
) {
    let mut scratch = Vec::new();
    edge_diff_message_src(
        RowSource::Host(xu),
        RowSource::Host(xv),
        diff,
        compression,
        &mut scratch,
        seed,
        k,
        j,
        u,
        v,
    );
}

/// Apply one simultaneous gossip step in place over the arena:
/// `X ← X + α Σ_{j∈activated} (−L_j^live) X`, where `L_j^live` omits any
/// links listed in `dead` (failure injection; `dead` uses the canonical
/// `u < v` orientation). This is exactly the matrix product
/// `X ← W⁽ᵏ⁾ X` when no links are dead (verified by
/// `sim::runner::tests::edgewise_mix_equals_matrix_mix`). Thin binding of
/// [`MixKernel::apply`] to the run parameters.
pub fn apply_gossip(
    xs: &mut StateMatrix,
    matchings: &[Graph],
    activated: &[usize],
    alpha: f64,
    compression: Option<&Compression>,
    dead: Option<&[(usize, usize)]>,
    seed: u64,
    k: usize,
    pool: &mut DeltaPool,
) {
    MixKernel::new(seed, compression).apply(xs, matchings, activated, alpha, dead, k, pool);
}

/// Push the standard per-record metrics for the current state. Shared by
/// every runner so their [`crate::metrics::Recorder`] contents are
/// comparable series-for-series. Also the single place every backend
/// feeds the tracer's [`crate::trace::Observatory`] a record sample
/// (frontier point + contraction window); when the sample closes a
/// contraction window, its stats are returned so the runner can stream
/// them through [`crate::experiment::Observer::on_window`].
pub fn record_metrics<P: Problem + ?Sized>(
    problem: &P,
    k: usize,
    time: f64,
    comm: f64,
    xs: &StateMatrix,
    metrics: &mut crate::metrics::Recorder,
    tracer: &mut crate::trace::Tracer<'_>,
) -> Option<crate::trace::WindowStats> {
    let mean = xs.mean();
    let loss = problem.global_loss(&mean);
    let consensus = xs.consensus_distance();
    metrics.push("loss_vs_iter", k as f64, loss);
    metrics.push("loss_vs_time", time, loss);
    metrics.push("consensus_vs_iter", k as f64, consensus);
    metrics.push("comm_units_vs_iter", k as f64, comm);
    let mut g = vec![0.0; xs.dim()];
    problem.global_grad(&mean, &mut g);
    let gn2: f64 = g.iter().map(|v| v * v).sum();
    metrics.push("gradnorm2_vs_iter", k as f64, gn2);
    if let Some(fstar) = problem.optimal_value() {
        metrics.push("subopt_vs_iter", k as f64, loss - fstar);
        metrics.push("subopt_vs_time", time, loss - fstar);
    }
    if let Some(acc) = problem.test_metric(&mean) {
        metrics.push("test_acc_vs_iter", k as f64, acc);
        metrics.push("test_acc_vs_time", time, acc);
    }
    tracer.observatory.on_record(k, time, comm, loss, consensus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::sim::QuadraticProblem;

    #[test]
    fn worker_streams_are_distinct_and_deterministic() {
        let mut a = worker_streams(7, 4);
        let mut b = worker_streams(7, 4);
        for (ra, rb) in a.iter_mut().zip(b.iter_mut()) {
            assert_eq!(ra.next_u64(), rb.next_u64());
        }
        let mut c = worker_streams(7, 2);
        let (x, y) = (c[0].next_u64(), c[1].next_u64());
        assert_ne!(x, y, "adjacent worker streams must differ");
    }

    #[test]
    fn init_iterates_identical_across_workers() {
        let xs = init_iterates(3, 5, 8);
        for w in 1..5 {
            assert_eq!(xs.row(w), xs.row(0));
        }
        assert_eq!(xs, init_iterates(3, 5, 8));
    }

    #[test]
    fn edge_rng_symmetric_in_call_site_only() {
        // Same (seed,k,j,u,v) -> same stream; different edges -> different.
        let mut a = edge_rng(1, 2, 0, 3, 5);
        let mut b = edge_rng(1, 2, 0, 3, 5);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = edge_rng(1, 2, 0, 3, 6);
        let mut d = edge_rng(1, 2, 0, 3, 5);
        assert_ne!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn gossip_preserves_worker_mean_even_with_dead_links() {
        let d = decompose(&paper_figure1_graph());
        let m = 8;
        let dim = 6;
        let mut rng = Rng::new(9);
        let mut xs = StateMatrix::zeros(m, dim);
        for w in 0..m {
            for x in xs.row_mut(w).iter_mut() {
                *x = rng.normal();
            }
        }
        let mean_before = xs.mean();
        let dead = vec![d.matchings[0].edges()[0]];
        let mut pool = DeltaPool::new(m, dim);
        let activated: Vec<usize> = (0..d.len()).collect();
        apply_gossip(
            &mut xs,
            &d.matchings,
            &activated,
            0.31,
            None,
            Some(&dead),
            5,
            0,
            &mut pool,
        );
        let mean_after = xs.mean();
        for (a, b) in mean_before.iter().zip(&mean_after) {
            assert!((a - b).abs() < 1e-12, "mean drifted: {a} vs {b}");
        }
    }

    #[test]
    fn local_step_moves_against_gradient() {
        let mut rng = Rng::new(11);
        let p = QuadraticProblem::generate(3, 5, 1.0, 0.0, &mut rng);
        let mut x = vec![1.0; 5];
        let before = p.local_loss(0, &x);
        let mut grad = vec![0.0; 5];
        let mut wrng = Rng::new(0);
        local_sgd_step(&p, 0, 0.05, &mut x, &mut wrng, &mut grad);
        assert!(p.local_loss(0, &x) < before);
    }
}
