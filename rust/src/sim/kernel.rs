//! The single step/mix kernel shared by the sequential simulator
//! ([`crate::sim::run_decentralized`]) and the event-driven engine
//! ([`crate::engine`]).
//!
//! Both execution paths must produce **bit-for-bit identical**
//! trajectories for the same seed, so everything that touches the
//! iterates or draws randomness for them lives here exactly once:
//!
//! - [`worker_streams`] — the per-worker gradient-noise RNG derivation.
//!   Giving each worker its own stream (instead of one shared generator
//!   consumed in worker order) is what makes the engine's parallel actor
//!   mode reproducible: a worker's draws depend only on `(seed, worker)`,
//!   never on thread scheduling.
//! - [`init_iterates`] — the common initial point (Theorem 1 starts all
//!   workers at the same iterate).
//! - [`local_sgd_step`] — one worker's local stochastic-gradient step.
//! - [`apply_gossip`] / [`fold_edge_into_deltas`] — the simultaneous
//!   gossip mix `X ← X + α Σ_{j∈activated} (−L_j) X`, applied edge-wise,
//!   with optional message compression and an optional set of dead links
//!   (the engine's failure injection; the sequential simulator passes
//!   none).
//! - [`edge_rng`] — compression randomness derived per
//!   `(seed, iteration, matching, edge)`, so both endpoints of a link —
//!   and both execution paths — quantize a message identically no matter
//!   in which order edges are processed.

use super::{Compression, Problem};
use crate::graph::Graph;
use crate::rng::Rng;

/// Domain-separation constant for the gossip/compression RNG stream.
pub const MIX_STREAM_SALT: u64 = 0xc03f_5eed;

/// Per-worker gradient-noise RNG streams for a run seed.
///
/// The derivation feeds `seed + (w+1)·φ` (with φ the 64-bit golden-ratio
/// constant) through [`Rng::new`]'s SplitMix expansion, which decorrelates
/// even adjacent seeds.
pub fn worker_streams(seed: u64, m: usize) -> Vec<Rng> {
    (0..m)
        .map(|w| {
            Rng::new(seed.wrapping_add((w as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)))
        })
        .collect()
}

/// Initial iterates: every worker starts from the same random point.
pub fn init_iterates(seed: u64, m: usize, d: usize) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    let x0: Vec<f64> = (0..d).map(|_| 0.01 * rng.normal()).collect();
    vec![x0; m]
}

/// One worker's local SGD step: `x ← x − η g(x)`. `grad` is scratch.
pub fn local_sgd_step<P: Problem + ?Sized>(
    problem: &P,
    worker: usize,
    lr: f64,
    x: &mut [f64],
    rng: &mut Rng,
    grad: &mut [f64],
) {
    problem.stoch_grad(worker, x, rng, grad);
    for (xi, &gi) in x.iter_mut().zip(grad.iter()) {
        *xi -= lr * gi;
    }
}

/// Deterministic per-edge RNG for compression: both endpoints of link
/// `(u,v)` in matching `j` at iteration `k` derive the same stream, so
/// they compress the shared difference message identically.
pub fn edge_rng(seed: u64, k: usize, j: usize, u: usize, v: usize) -> Rng {
    let h = (k as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ (j as u64).wrapping_mul(0xc2b2_ae3d_27d4_eb4f)
        ^ (u as u64).wrapping_mul(0x1656_67b1_9e37_79f9)
        ^ (v as u64).wrapping_mul(0x2545_f491_4f6c_dd1d);
    Rng::new(seed ^ MIX_STREAM_SALT ^ h)
}

/// Reusable scratch buffers for [`apply_gossip`].
pub struct GossipScratch {
    deltas: Vec<Vec<f64>>,
    diff: Vec<f64>,
}

impl GossipScratch {
    pub fn new(m: usize, d: usize) -> Self {
        GossipScratch { deltas: vec![vec![0.0; d]; m], diff: vec![0.0; d] }
    }
}

/// Compute the canonical compressed difference message of edge `(u,v)`
/// (`u < v` in matching storage): `diff = x_v − x_u`, compressed in place
/// when compression is configured. Shared by the full-state mix below and
/// the engine's per-worker actor mix.
pub fn edge_diff_message(
    xu: &[f64],
    xv: &[f64],
    diff: &mut [f64],
    compression: Option<&Compression>,
    seed: u64,
    k: usize,
    j: usize,
    u: usize,
    v: usize,
) {
    for i in 0..diff.len() {
        diff[i] = xv[i] - xu[i];
    }
    if let Some(comp) = compression {
        let mut rng = edge_rng(seed, k, j, u, v);
        comp.compress(diff, &mut rng);
    }
}

/// Fold one edge's (already computed) message into the delta accumulators:
/// `Δ_u += diff`, `Δ_v −= diff`.
pub fn fold_edge_into_deltas(deltas: &mut [Vec<f64>], u: usize, v: usize, diff: &[f64]) {
    for i in 0..diff.len() {
        deltas[u][i] += diff[i];
        deltas[v][i] -= diff[i];
    }
}

/// Apply one simultaneous gossip step in place:
/// `X ← X + α Σ_{j∈activated} (−L_j^live) X`, where `L_j^live` omits any
/// links listed in `dead` (failure injection; `dead` uses the canonical
/// `u < v` orientation). This is exactly the matrix product
/// `X ← W⁽ᵏ⁾ X` when no links are dead (verified by
/// `sim::runner::tests::edgewise_mix_equals_matrix_mix`).
pub fn apply_gossip(
    xs: &mut [Vec<f64>],
    matchings: &[Graph],
    activated: &[usize],
    alpha: f64,
    compression: Option<&Compression>,
    dead: Option<&[(usize, usize)]>,
    seed: u64,
    k: usize,
    scratch: &mut GossipScratch,
) {
    if activated.is_empty() {
        return;
    }
    for dv in scratch.deltas.iter_mut() {
        dv.iter_mut().for_each(|v| *v = 0.0);
    }
    for &j in activated {
        for &(u, v) in matchings[j].edges() {
            if let Some(dead) = dead {
                if dead.contains(&(u, v)) {
                    continue;
                }
            }
            // Split-borrow xs to read two rows while writing the diff.
            {
                let (xu, xv) = (&xs[u], &xs[v]);
                // Safe: u != v in a simple graph; read-only borrows.
                let diff = &mut scratch.diff;
                edge_diff_message(xu, xv, diff, compression, seed, k, j, u, v);
            }
            fold_edge_into_deltas(&mut scratch.deltas, u, v, &scratch.diff);
        }
    }
    for (x, dv) in xs.iter_mut().zip(&scratch.deltas) {
        for (xi, &di) in x.iter_mut().zip(dv) {
            *xi += alpha * di;
        }
    }
}

/// Push the standard per-record metrics for the current state. Shared by
/// the sequential runner and the engine so their [`crate::metrics::Recorder`]
/// contents are comparable series-for-series.
pub fn record_metrics<P: Problem + ?Sized>(
    problem: &P,
    k: usize,
    time: f64,
    comm: f64,
    xs: &[Vec<f64>],
    metrics: &mut crate::metrics::Recorder,
) {
    let mean = super::mean_iterate(xs);
    let loss = problem.global_loss(&mean);
    metrics.push("loss_vs_iter", k as f64, loss);
    metrics.push("loss_vs_time", time, loss);
    metrics.push("consensus_vs_iter", k as f64, super::consensus_distance(xs));
    metrics.push("comm_units_vs_iter", k as f64, comm);
    let mut g = vec![0.0; xs[0].len()];
    problem.global_grad(&mean, &mut g);
    let gn2: f64 = g.iter().map(|v| v * v).sum();
    metrics.push("gradnorm2_vs_iter", k as f64, gn2);
    if let Some(fstar) = problem.optimal_value() {
        metrics.push("subopt_vs_iter", k as f64, loss - fstar);
        metrics.push("subopt_vs_time", time, loss - fstar);
    }
    if let Some(acc) = problem.test_metric(&mean) {
        metrics.push("test_acc_vs_iter", k as f64, acc);
        metrics.push("test_acc_vs_time", time, acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::sim::QuadraticProblem;

    #[test]
    fn worker_streams_are_distinct_and_deterministic() {
        let mut a = worker_streams(7, 4);
        let mut b = worker_streams(7, 4);
        for (ra, rb) in a.iter_mut().zip(b.iter_mut()) {
            assert_eq!(ra.next_u64(), rb.next_u64());
        }
        let mut c = worker_streams(7, 2);
        let (x, y) = (c[0].next_u64(), c[1].next_u64());
        assert_ne!(x, y, "adjacent worker streams must differ");
    }

    #[test]
    fn init_iterates_identical_across_workers() {
        let xs = init_iterates(3, 5, 8);
        for x in &xs[1..] {
            assert_eq!(x, &xs[0]);
        }
        assert_eq!(xs, init_iterates(3, 5, 8));
    }

    #[test]
    fn edge_rng_symmetric_in_call_site_only() {
        // Same (seed,k,j,u,v) -> same stream; different edges -> different.
        let mut a = edge_rng(1, 2, 0, 3, 5);
        let mut b = edge_rng(1, 2, 0, 3, 5);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = edge_rng(1, 2, 0, 3, 6);
        let mut d = edge_rng(1, 2, 0, 3, 5);
        assert_ne!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn gossip_preserves_worker_mean_even_with_dead_links() {
        let d = decompose(&paper_figure1_graph());
        let m = 8;
        let dim = 6;
        let mut rng = Rng::new(9);
        let mut xs: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        let mean_before = crate::sim::mean_iterate(&xs);
        let dead = vec![d.matchings[0].edges()[0]];
        let mut scratch = GossipScratch::new(m, dim);
        let activated: Vec<usize> = (0..d.len()).collect();
        apply_gossip(
            &mut xs,
            &d.matchings,
            &activated,
            0.31,
            None,
            Some(&dead),
            5,
            0,
            &mut scratch,
        );
        let mean_after = crate::sim::mean_iterate(&xs);
        for (a, b) in mean_before.iter().zip(&mean_after) {
            assert!((a - b).abs() < 1e-12, "mean drifted: {a} vs {b}");
        }
    }

    #[test]
    fn dead_link_freezes_only_that_exchange() {
        let d = decompose(&paper_figure1_graph());
        // Pick a matching with at least two links so one can stay live.
        let j0 = (0..d.len())
            .find(|&j| d.matchings[j].edges().len() >= 2)
            .expect("fig1 decomposition has a multi-link matching");
        let (u, v) = d.matchings[j0].edges()[0];
        let m = 8;
        let dim = 3;
        let mut rng = Rng::new(4);
        let xs0: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        // Activate only matching j0 with its first edge dead.
        let mut with_dead = xs0.clone();
        let mut scratch = GossipScratch::new(m, dim);
        apply_gossip(
            &mut with_dead,
            &d.matchings,
            &[j0],
            0.2,
            None,
            Some(&[(u, v)]),
            1,
            0,
            &mut scratch,
        );
        // u and v did not move; other endpoints of matching j0 did.
        assert_eq!(with_dead[u], xs0[u]);
        assert_eq!(with_dead[v], xs0[v]);
        let moved = d.matchings[j0]
            .edges()
            .iter()
            .filter(|&&e| e != (u, v))
            .any(|&(a, _)| with_dead[a] != xs0[a]);
        assert!(moved, "live links should still exchange");
    }

    #[test]
    fn local_step_moves_against_gradient() {
        let mut rng = Rng::new(11);
        let p = QuadraticProblem::generate(3, 5, 1.0, 0.0, &mut rng);
        let mut x = vec![1.0; 5];
        let before = p.local_loss(0, &x);
        let mut grad = vec![0.0; 5];
        let mut wrng = Rng::new(0);
        local_sgd_step(&p, 0, 0.05, &mut x, &mut wrng, &mut grad);
        assert!(p.local_loss(0, &x) < before);
    }
}
