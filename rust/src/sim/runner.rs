//! The decentralized SGD loop (paper eq. (2)) over any [`Problem`] and
//! any activation strategy, with delay-model time accounting.
//!
//! This is the *sequential reference path*. The actual per-iteration math
//! (local step, gossip mix, RNG-stream derivations) lives in
//! [`crate::sim::kernel`] and is shared with the event-driven engine
//! ([`crate::engine`]), whose deterministic mode reproduces this runner's
//! trajectories bit-for-bit (enforced by `rust/tests/engine.rs`).

use super::kernel::{
    apply_gossip, init_iterates, local_sgd_step, record_metrics, worker_streams,
};
use super::{Compression, Problem};
use crate::delay::{DelayModel, VirtualClock};
use crate::experiment::{NoopObserver, Observer};
use crate::graph::Graph;
use crate::metrics::Recorder;
use crate::rng::Rng;
use crate::state::{DeltaPool, StateMatrix};
use crate::topology::TopologySampler;
use crate::trace::{Counter, TraceEvent, Tracer};

/// Configuration for one simulated training run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Learning rate η.
    pub lr: f64,
    /// Optional step-decay: multiply lr by `decay` every `decay_every`
    /// iterations (paper's experiments decay at fixed epochs).
    pub lr_decay: f64,
    pub lr_decay_every: usize,
    /// Total iterations K.
    pub iterations: usize,
    /// Record metrics every `record_every` iterations.
    pub record_every: usize,
    /// Mixing weight α.
    pub alpha: f64,
    /// Computation time per iteration in delay units.
    pub compute_units: f64,
    /// Delay model for communication time.
    pub delay: DelayModel,
    /// Optional gossip-message compression (paper §1: complementary to
    /// MATCHA). Applied to the per-edge difference messages.
    pub compression: Option<Compression>,
    /// Handshake-latency floor for the compression time factor.
    pub latency_floor: f64,
    /// Seed for gradient noise / batch sampling.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            lr: 0.05,
            lr_decay: 1.0,
            lr_decay_every: usize::MAX,
            iterations: 1000,
            record_every: 10,
            alpha: 0.5,
            compute_units: 1.0,
            delay: DelayModel::UnitPerMatching,
            compression: None,
            latency_floor: 0.05,
            seed: 0,
        }
    }
}

impl RunConfig {
    /// The delay-model RNG stream for this run (shared derivation with
    /// the engine's analytic policy, for exact time parity).
    pub fn delay_rng(&self) -> Rng {
        Rng::new(self.seed ^ 0xdead_beef)
    }
}

/// Result of a run: metric series plus summary statistics.
pub struct RunResult {
    pub metrics: Recorder,
    /// Final averaged iterate x̄.
    pub final_mean: Vec<f64>,
    /// Every worker's final iterate — the run's state arena, one row per
    /// worker.
    pub final_states: StateMatrix,
    /// Total virtual time elapsed.
    pub total_time: f64,
    /// Total communication units spent.
    pub total_comm_units: f64,
}

/// Run decentralized SGD: per iteration each worker takes a local
/// stochastic gradient step, then the activated topology mixes the
/// iterates: `X ← W⁽ᵏ⁾ [X − η G]` with `W⁽ᵏ⁾ = I − α Σ_{j∈activated} L_j`.
///
/// The mix is applied edge-wise from the *pre-mix* state (a simultaneous
/// gossip step, not sequential pairwise averaging), which is exactly the
/// matrix product and costs `O(d · |activated edges|)`.
///
/// Gradient noise uses one independent RNG stream per worker
/// ([`worker_streams`]); compression randomness is derived per edge
/// ([`crate::sim::kernel::edge_rng`]). Both choices make the trajectory a
/// function of `(seed, worker)` alone, which is what lets the engine's
/// parallel actors replay it exactly.
pub fn run_decentralized<P: Problem, S: TopologySampler>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    config: &RunConfig,
) -> RunResult {
    run_decentralized_observed(problem, matchings, sampler, config, &mut NoopObserver)
}

/// [`run_decentralized`] with streaming observation: `observer` receives
/// a callback after every iteration and at every metrics record. The
/// trajectory is identical to the unobserved run.
pub fn run_decentralized_observed<P: Problem, S: TopologySampler>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    config: &RunConfig,
    observer: &mut dyn Observer,
) -> RunResult {
    run_decentralized_traced(problem, matchings, sampler, config, observer, &mut Tracer::disabled())
}

/// [`run_decentralized_observed`] with trace emission: compute spans,
/// mix/barrier markers and run counters flow through `tracer`. With a
/// disabled tracer this **is** the observed run — the trajectory never
/// depends on tracing.
///
/// The reference simulator accounts communication time in closed form,
/// so it emits no per-link events; its per-round
/// compute/mix/barrier sequence matches the engine's exactly under the
/// analytic policy (pinned by `rust/tests/trace.rs`).
pub fn run_decentralized_traced<P: Problem, S: TopologySampler>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    config: &RunConfig,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> RunResult {
    let m = problem.num_workers();
    let d = problem.dim();
    let mut xs = init_iterates(config.seed, m, d);
    let mut worker_rngs = worker_streams(config.seed, m);
    let mut pool = DeltaPool::new(m, d);

    let mut clock = VirtualClock::new(config.compute_units);
    let mut metrics = Recorder::new();
    let mut total_comm = 0.0;
    let mut lr = config.lr;
    let mut delay_rng = config.delay_rng();

    if let Some(w) = record_metrics(problem, 0, 0.0, 0.0, &xs, &mut metrics, tracer) {
        observer.on_window(&w);
    }
    observer.on_record(0, 0.0, &metrics);

    for k in 0..config.iterations {
        // --- local SGD step on every worker -------------------------
        let t0 = clock.elapsed();
        for w in 0..m {
            tracer.emit_at(t0, TraceEvent::ComputeBegin { worker: w, k });
            local_sgd_step(problem, w, lr, xs.row_mut(w), &mut worker_rngs[w], pool.grad_mut());
        }
        for w in 0..m {
            tracer.emit_at(t0 + config.compute_units, TraceEvent::ComputeEnd { worker: w, k });
            tracer.count(Counter::ComputeEvents, 1);
            tracer.observatory.on_compute(w, config.compute_units);
        }

        // --- consensus over the activated topology ------------------
        let round = sampler.round(k);
        apply_gossip(
            &mut xs,
            matchings,
            &round.activated,
            config.alpha,
            config.compression.as_ref(),
            None,
            config.seed,
            k,
            &mut pool,
        );

        // --- time accounting ----------------------------------------
        let mut comm_t = config.delay.comm_time(matchings, &round.activated, &mut delay_rng);
        if let Some(comp) = &config.compression {
            comm_t *= comp.time_factor(config.latency_floor);
        }
        total_comm += comm_t;
        let now = clock.tick(comm_t);
        tracer.set_now(now);
        tracer.emit(TraceEvent::MixApplied { k, activated: round.activated.len() });
        tracer.emit(TraceEvent::RoundBarrier { k });
        tracer.count(Counter::MixRounds, 1);
        tracer.observatory.on_round(&round.activated, &[]);

        // --- lr schedule & recording --------------------------------
        if (k + 1) % config.lr_decay_every == 0 {
            lr *= config.lr_decay;
        }
        if (k + 1) % config.record_every == 0 || k + 1 == config.iterations {
            if let Some(w) =
                record_metrics(problem, k + 1, now, total_comm, &xs, &mut metrics, tracer)
            {
                observer.on_window(&w);
            }
            observer.on_record(k + 1, now, &metrics);
        }
        observer.on_iteration(k + 1, now, total_comm);
    }

    RunResult {
        final_mean: xs.mean(),
        final_states: xs,
        total_time: clock.elapsed(),
        total_comm_units: total_comm,
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::optimize_activation_probabilities;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::mixing::{optimize_alpha, vanilla_design};
    use crate::sim::QuadraticProblem;
    use crate::topology::{MatchaSampler, VanillaSampler};

    fn quad() -> QuadraticProblem {
        let mut rng = Rng::new(99);
        QuadraticProblem::generate(8, 10, 1.0, 0.1, &mut rng)
    }

    #[test]
    fn vanilla_decen_sgd_converges_on_quadratic() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let design = vanilla_design(&g.laplacian());
        let mut sampler = VanillaSampler::new(d.len());
        let p = quad();
        let cfg = RunConfig {
            lr: 0.02,
            iterations: 800,
            alpha: design.alpha,
            ..RunConfig::default()
        };
        let res = run_decentralized(&p, &d.matchings, &mut sampler, &cfg);
        let sub0 = res.metrics.get("subopt_vs_iter")[0].y;
        let subf = res.metrics.last("subopt_vs_iter").unwrap();
        assert!(
            subf < 0.05 * sub0,
            "no convergence: suboptimality {sub0} -> {subf}"
        );
    }

    #[test]
    fn matcha_converges_and_spends_less_comm() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.4);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let p = quad();

        let cfg = |alpha: f64| RunConfig {
            lr: 0.02,
            iterations: 800,
            alpha,
            ..RunConfig::default()
        };

        let design = vanilla_design(&g.laplacian());
        let mut vs = VanillaSampler::new(d.len());
        let vres = run_decentralized(&p, &d.matchings, &mut vs, &cfg(design.alpha));

        let mut ms = MatchaSampler::new(probs.probabilities.clone(), 7);
        let mres = run_decentralized(&p, &d.matchings, &mut ms, &cfg(mix.alpha));

        // Both reach low suboptimality...
        let vsub = vres.metrics.last("subopt_vs_iter").unwrap();
        let msub = mres.metrics.last("subopt_vs_iter").unwrap();
        assert!(vsub < 0.1 && msub < 0.1, "vanilla {vsub}, matcha {msub}");
        // ...but MATCHA uses roughly 40% of the communication.
        let ratio = mres.total_comm_units / vres.total_comm_units;
        assert!(
            (ratio - 0.4).abs() < 0.08,
            "comm ratio {ratio}, expected ≈ 0.4"
        );
        // And therefore finishes sooner in virtual time.
        assert!(mres.total_time < vres.total_time);
    }

    #[test]
    fn runs_are_reproducible_per_seed() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.5);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let p = quad();
        let run = || {
            let mut s = MatchaSampler::new(probs.probabilities.clone(), 3);
            let cfg = RunConfig {
                lr: 0.02,
                iterations: 200,
                alpha: mix.alpha,
                seed: 42,
                ..RunConfig::default()
            };
            run_decentralized(&p, &d.matchings, &mut s, &cfg)
        };
        let a = run();
        let b = run();
        assert_eq!(a.final_mean, b.final_mean);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.total_comm_units, b.total_comm_units);
    }

    #[test]
    fn consensus_distance_shrinks() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.5);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let p = quad();
        let mut ms = MatchaSampler::new(probs.probabilities, 11);
        let cfg = RunConfig {
            lr: 0.02,
            lr_decay: 0.5,
            lr_decay_every: 200,
            iterations: 600,
            alpha: mix.alpha,
            ..RunConfig::default()
        };
        let res = run_decentralized(&p, &d.matchings, &mut ms, &cfg);
        let series = res.metrics.get("consensus_vs_iter");
        let early: f64 = series[1..4].iter().map(|s| s.y).sum::<f64>() / 3.0;
        let late: f64 = series[series.len() - 3..].iter().map(|s| s.y).sum::<f64>() / 3.0;
        assert!(
            late < early,
            "consensus distance grew: early {early} late {late}"
        );
    }

    #[test]
    fn edgewise_mix_equals_matrix_mix() {
        // The edge-wise delta application must equal X ← WX exactly.
        use crate::linalg::Mat;
        use crate::sim::kernel::apply_gossip;
        use crate::topology::mixing_matrix;
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let laps = d.laplacians();
        let alpha = 0.23;
        let activated = vec![0, 2];
        let m = 8;
        let dim = 5;
        let mut rng = Rng::new(321);
        let xs: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();

        // Edge-wise (the shared kernel, as in run_decentralized).
        let mut edgewise = StateMatrix::from_vecs(&xs);
        let mut pool = DeltaPool::new(m, dim);
        apply_gossip(
            &mut edgewise,
            &d.matchings,
            &activated,
            alpha,
            None,
            None,
            0,
            0,
            &mut pool,
        );

        // Matrix: W (m×m) times X (m×dim).
        let w = mixing_matrix(&laps, &activated, alpha);
        let mut xmat = Mat::zeros(m, dim);
        for (r, x) in xs.iter().enumerate() {
            for (c, &v) in x.iter().enumerate() {
                xmat.set(r, c, v);
            }
        }
        let mixed = w.matmul(&xmat);
        for r in 0..m {
            for c in 0..dim {
                assert!(
                    (mixed.get(r, c) - edgewise.row(r)[c]).abs() < 1e-12,
                    "mismatch at ({r},{c})"
                );
            }
        }
    }
}
