//! Distributed quadratic workload with a known optimum.
//!
//! Worker `i` holds `F_i(x) = ½ xᵀ A_i x − b_iᵀ x` with `A_i ≻ 0`; the
//! global objective `F(x) = (1/m) Σ F_i` is minimized at
//! `x* = (Σ A_i)⁻¹ Σ b_i` — computed here with conjugate gradients so the
//! simulator can report exact suboptimality `F(x̄) − F*`. Heterogeneity
//! across workers (distinct `A_i`, `b_i`) makes consensus matter, which is
//! exactly the regime where the spectral norm ρ shows up in Theorem 1.

use super::Problem;
use crate::rng::Rng;

/// See module docs.
pub struct QuadraticProblem {
    m: usize,
    d: usize,
    /// Per-worker PSD matrices, row-major d×d.
    a: Vec<Vec<f64>>,
    /// Per-worker linear terms.
    b: Vec<Vec<f64>>,
    /// Stochastic gradient noise std (Assumption 3's σ).
    noise_std: f64,
    /// Cached optimal value F*.
    f_star: f64,
    x_star: Vec<f64>,
}

impl QuadraticProblem {
    /// Generate a random heterogeneous quadratic problem.
    ///
    /// `hetero` scales how far apart the workers' optima are (0 = IID).
    pub fn generate(m: usize, d: usize, hetero: f64, noise_std: f64, rng: &mut Rng) -> Self {
        let mut a = Vec::with_capacity(m);
        let mut b = Vec::with_capacity(m);
        for _ in 0..m {
            // A_i = Q diag(eigs) Qᵀ built as GᵀG + εI for conditioning.
            let mut g = vec![0.0; d * d];
            for v in g.iter_mut() {
                *v = rng.normal() / (d as f64).sqrt();
            }
            let mut ai = vec![0.0; d * d];
            for r in 0..d {
                for c in 0..d {
                    let mut acc = 0.0;
                    for k in 0..d {
                        acc += g[k * d + r] * g[k * d + c];
                    }
                    ai[r * d + c] = acc;
                }
            }
            for i in 0..d {
                ai[i * d + i] += 0.5; // λ_min ≥ 0.5: strongly convex
            }
            let bi: Vec<f64> = (0..d).map(|_| rng.normal() * hetero).collect();
            a.push(ai);
            b.push(bi);
        }
        let (x_star, f_star) = Self::solve_optimum(m, d, &a, &b);
        QuadraticProblem { m, d, a, b, noise_std, f_star, x_star }
    }

    /// x* = (Σ A_i)⁻¹ Σ b_i via conjugate gradients (Σ A_i is SPD).
    fn solve_optimum(m: usize, d: usize, a: &[Vec<f64>], b: &[Vec<f64>]) -> (Vec<f64>, f64) {
        let mut asum = vec![0.0; d * d];
        let mut bsum = vec![0.0; d];
        for i in 0..m {
            for (s, &v) in asum.iter_mut().zip(&a[i]) {
                *s += v;
            }
            for (s, &v) in bsum.iter_mut().zip(&b[i]) {
                *s += v;
            }
        }
        let matvec = |x: &[f64], out: &mut [f64]| {
            for r in 0..d {
                let mut acc = 0.0;
                for c in 0..d {
                    acc += asum[r * d + c] * x[c];
                }
                out[r] = acc;
            }
        };
        // CG from zero.
        let mut x = vec![0.0; d];
        let mut r = bsum.clone();
        let mut p = r.clone();
        let mut rs: f64 = r.iter().map(|v| v * v).sum();
        let mut ap = vec![0.0; d];
        for _ in 0..(4 * d) {
            if rs.sqrt() < 1e-12 {
                break;
            }
            matvec(&p, &mut ap);
            let alpha = rs / p.iter().zip(&ap).map(|(u, v)| u * v).sum::<f64>();
            for i in 0..d {
                x[i] += alpha * p[i];
                r[i] -= alpha * ap[i];
            }
            let rs_new: f64 = r.iter().map(|v| v * v).sum();
            let beta = rs_new / rs;
            for i in 0..d {
                p[i] = r[i] + beta * p[i];
            }
            rs = rs_new;
        }
        // F* evaluated through the same local-loss formula.
        let prob = |w: usize, x: &[f64]| -> f64 {
            let ai = &a[w];
            let bi = &b[w];
            let mut quad = 0.0;
            for r in 0..d {
                let mut acc = 0.0;
                for c in 0..d {
                    acc += ai[r * d + c] * x[c];
                }
                quad += x[r] * acc;
            }
            0.5 * quad - bi.iter().zip(x).map(|(u, v)| u * v).sum::<f64>()
        };
        let f_star = (0..m).map(|i| prob(i, &x)).sum::<f64>() / m as f64;
        (x, f_star)
    }

    /// The true global minimizer (for tests).
    pub fn optimum(&self) -> &[f64] {
        &self.x_star
    }
}

impl Problem for QuadraticProblem {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_workers(&self) -> usize {
        self.m
    }

    fn local_loss(&self, worker: usize, x: &[f64]) -> f64 {
        let ai = &self.a[worker];
        let bi = &self.b[worker];
        let d = self.d;
        let mut quad = 0.0;
        for r in 0..d {
            let mut acc = 0.0;
            for c in 0..d {
                acc += ai[r * d + c] * x[c];
            }
            quad += x[r] * acc;
        }
        0.5 * quad - bi.iter().zip(x).map(|(u, v)| u * v).sum::<f64>()
    }

    fn stoch_grad(&self, worker: usize, x: &[f64], rng: &mut Rng, out: &mut [f64]) {
        let ai = &self.a[worker];
        let bi = &self.b[worker];
        let d = self.d;
        for r in 0..d {
            let mut acc = 0.0;
            for c in 0..d {
                acc += ai[r * d + c] * x[c];
            }
            out[r] = acc - bi[r] + self.noise_std * rng.normal();
        }
    }

    fn global_grad(&self, x: &[f64], out: &mut [f64]) {
        let d = self.d;
        out.iter_mut().for_each(|v| *v = 0.0);
        let mut tmp = vec![0.0; d];
        for w in 0..self.m {
            let ai = &self.a[w];
            let bi = &self.b[w];
            for r in 0..d {
                let mut acc = 0.0;
                for c in 0..d {
                    acc += ai[r * d + c] * x[c];
                }
                tmp[r] = acc - bi[r];
            }
            for (o, &t) in out.iter_mut().zip(&tmp) {
                *o += t / self.m as f64;
            }
        }
    }

    fn optimal_value(&self) -> Option<f64> {
        Some(self.f_star)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_has_zero_gradient() {
        let mut rng = Rng::new(1234);
        let p = QuadraticProblem::generate(5, 12, 1.0, 0.0, &mut rng);
        let mut g = vec![0.0; 12];
        p.global_grad(p.optimum(), &mut g);
        let gn: f64 = g.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(gn < 1e-8, "‖∇F(x*)‖ = {gn}");
    }

    #[test]
    fn f_star_is_a_lower_bound_nearby() {
        let mut rng = Rng::new(55);
        let p = QuadraticProblem::generate(4, 8, 2.0, 0.0, &mut rng);
        let fstar = p.optimal_value().unwrap();
        for trial in 0..50 {
            let x: Vec<f64> = (0..8)
                .map(|i| p.optimum()[i] + 0.1 * Rng::new(trial).normal())
                .collect();
            assert!(p.global_loss(&x) >= fstar - 1e-9);
        }
    }

    #[test]
    fn stoch_grad_unbiased() {
        // Assumption 2: E[g] = ∇F_i. Average many noisy draws.
        let mut rng = Rng::new(77);
        let p = QuadraticProblem::generate(3, 6, 1.0, 0.5, &mut rng);
        let x = vec![0.3; 6];
        let mut acc = vec![0.0; 6];
        let mut tmp = vec![0.0; 6];
        let n = 20_000;
        for _ in 0..n {
            p.stoch_grad(0, &x, &mut rng, &mut tmp);
            for (a, &t) in acc.iter_mut().zip(&tmp) {
                *a += t / n as f64;
            }
        }
        // Exact gradient of worker 0 via noise-free problem replica.
        let mut rng2 = Rng::new(77);
        let p0 = QuadraticProblem::generate(3, 6, 1.0, 0.0, &mut rng2);
        let mut exact = vec![0.0; 6];
        p0.stoch_grad(0, &x, &mut rng, &mut exact);
        for (a, e) in acc.iter().zip(&exact) {
            assert!((a - e).abs() < 0.02, "bias: {a} vs {e}");
        }
    }

    #[test]
    fn heterogeneity_spreads_local_optima() {
        let mut rng = Rng::new(3);
        let p = QuadraticProblem::generate(4, 5, 3.0, 0.0, &mut rng);
        // Local losses at the global optimum differ across workers.
        let l: Vec<f64> = (0..4).map(|w| p.local_loss(w, p.optimum())).collect();
        let spread = l.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - l.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 1e-3, "degenerate heterogeneity: {l:?}");
    }
}
