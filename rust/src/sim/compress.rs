//! Message compression for the gossip step.
//!
//! The paper (§1, Related Works) positions MATCHA as *complementary* to
//! compression: "reducing the effective node degree … can be easily
//! combined with existing compression schemes". This module provides that
//! combination for the simulator: the per-edge difference messages
//! `x_v − x_u` are compressed before being applied, and the delay model
//! scales each link's payload cost by the compression ratio — floored by
//! a latency term, because (as the paper notes) compression does not help
//! when handshake latency dominates.
//!
//! Applying compression to the antisymmetric *difference* keeps the
//! update antisymmetric (`+αC(d)` at u, `−αC(d)` at v), so the worker
//! average is preserved exactly — the invariant the x̄-analysis of
//! Theorem 1 relies on — at the cost of a weaker per-step contraction.

use crate::rng::Rng;

/// A compression operator applied to gossip difference messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Compression {
    /// Keep the largest-|.| `frac` of coordinates, zero the rest.
    TopK { frac: f64 },
    /// Stochastic uniform quantization to `bits` bits per coordinate
    /// (plus one f64 scale per message; unbiased).
    Quantize { bits: u32 },
}

impl Compression {
    /// Compress `v` in place. `rng` drives stochastic rounding.
    ///
    /// Convenience wrapper over [`Compression::compress_with`] that pays
    /// a fresh scratch allocation for TopK's magnitude buffer — the hot
    /// paths (the mix kernel, the actor shards, the async runtime) hold
    /// a recycled scratch and call `compress_with` directly so steady
    /// state compresses without touching the heap.
    pub fn compress(&self, v: &mut [f64], rng: &mut Rng) {
        let mut scratch = Vec::new();
        self.compress_with(v, rng, &mut scratch);
    }

    /// Compress `v` in place, using `scratch` for TopK's magnitude sort
    /// (cleared and refilled; grows once to `v.len()` then never again).
    /// Bit-for-bit identical to [`Compression::compress`]: the threshold
    /// is the `keep`-th largest |value|, and an unstable sort of the
    /// magnitudes yields the same sorted *values* as a stable one.
    pub fn compress_with(&self, v: &mut [f64], rng: &mut Rng, scratch: &mut Vec<f64>) {
        match *self {
            Compression::TopK { frac } => {
                assert!((0.0..=1.0).contains(&frac));
                let keep = ((v.len() as f64 * frac).ceil() as usize).clamp(1, v.len());
                if keep == v.len() {
                    return;
                }
                // Threshold = keep-th largest |value|. sort_unstable
                // allocates nothing (pdqsort), unlike slice::sort.
                scratch.clear();
                scratch.extend(v.iter().map(|x| x.abs()));
                scratch.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
                let thresh = scratch[keep - 1];
                let mut kept = 0;
                for x in v.iter_mut() {
                    if x.abs() >= thresh && kept < keep {
                        kept += 1;
                    } else {
                        *x = 0.0;
                    }
                }
            }
            Compression::Quantize { bits } => {
                assert!((1..=16).contains(&bits));
                let scale = v.iter().map(|x| x.abs()).fold(0.0_f64, f64::max);
                if scale == 0.0 {
                    return;
                }
                let levels = ((1u64 << bits) - 1) as f64;
                for x in v.iter_mut() {
                    // Map to [0, levels], stochastic round, map back.
                    let t = (*x / scale + 1.0) / 2.0 * levels;
                    let lo = t.floor();
                    let q = if rng.uniform() < t - lo { lo + 1.0 } else { lo };
                    *x = (q / levels * 2.0 - 1.0) * scale;
                }
            }
        }
    }

    /// Fraction of the uncompressed payload actually transmitted
    /// (coordinates for TopK — indices ignored for simplicity; bits/32
    /// for quantization against f32 baselines).
    pub fn payload_ratio(&self) -> f64 {
        match *self {
            Compression::TopK { frac } => frac,
            Compression::Quantize { bits } => bits as f64 / 32.0,
        }
    }

    /// Communication-time multiplier under a latency floor: even an
    /// infinitely compressed message pays `latency_floor` of a full
    /// link's time for the handshake (paper §1: compression "may not
    /// help if the network latency is high").
    pub fn time_factor(&self, latency_floor: f64) -> f64 {
        self.payload_ratio().max(latency_floor).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_keeps_largest() {
        let mut v = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        Compression::TopK { frac: 0.4 }.compress(&mut v, &mut Rng::new(1));
        assert_eq!(v, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn topk_frac_one_is_identity() {
        let mut v = vec![1.0, -2.0, 3.0];
        let orig = v.clone();
        Compression::TopK { frac: 1.0 }.compress(&mut v, &mut Rng::new(2));
        assert_eq!(v, orig);
    }

    #[test]
    fn quantize_is_unbiased_and_bounded() {
        let mut rng = Rng::new(3);
        let orig = vec![0.7, -0.3, 0.05, -0.92];
        let comp = Compression::Quantize { bits: 4 };
        let mut acc = vec![0.0; orig.len()];
        let n = 20_000;
        for _ in 0..n {
            let mut v = orig.clone();
            comp.compress(&mut v, &mut rng);
            // Quantization error bounded by one level (scale / levels * 2).
            let scale: f64 = orig.iter().map(|x| x.abs()).fold(0.0, f64::max);
            let step = 2.0 * scale / 15.0;
            for (a, b) in v.iter().zip(&orig) {
                assert!((a - b).abs() <= step + 1e-12);
            }
            for (s, &x) in acc.iter_mut().zip(&v) {
                *s += x / n as f64;
            }
        }
        for (mean, &x) in acc.iter().zip(&orig) {
            assert!((mean - x).abs() < 0.01, "bias at {x}: {mean}");
        }
    }

    #[test]
    fn quantize_zero_vector_noop() {
        let mut v = vec![0.0; 5];
        Compression::Quantize { bits: 2 }.compress(&mut v, &mut Rng::new(4));
        assert_eq!(v, vec![0.0; 5]);
    }

    #[test]
    fn compress_with_recycled_scratch_matches_compress() {
        // One scratch buffer reused across messages of varying length
        // must reproduce the allocating path bit-for-bit.
        let comp = Compression::TopK { frac: 0.4 };
        let mut scratch = Vec::new();
        let mut rng = Rng::new(6);
        for n in [1usize, 2, 5, 8, 13] {
            let orig: Vec<f64> = (0..n).map(|i| ((i * 7 % 11) as f64 - 5.0) * 0.3).collect();
            let mut a = orig.clone();
            let mut b = orig;
            comp.compress(&mut a, &mut Rng::new(9));
            comp.compress_with(&mut b, &mut Rng::new(9), &mut scratch);
            assert_eq!(a, b, "n={n}");
        }
        // Quantize ignores the scratch but must accept it.
        let mut v = vec![0.7, -0.3];
        let mut w = v.clone();
        Compression::Quantize { bits: 4 }.compress(&mut v, &mut rng.clone());
        Compression::Quantize { bits: 4 }.compress_with(&mut w, &mut rng, &mut scratch);
        assert_eq!(v, w);
    }

    #[test]
    fn payload_and_time_factors() {
        let c = Compression::TopK { frac: 0.1 };
        assert!((c.payload_ratio() - 0.1).abs() < 1e-12);
        assert!((c.time_factor(0.25) - 0.25).abs() < 1e-12); // latency-bound
        assert!((c.time_factor(0.01) - 0.1).abs() < 1e-12); // bandwidth-bound
        let q = Compression::Quantize { bits: 8 };
        assert!((q.payload_ratio() - 0.25).abs() < 1e-12);
    }
}
