//! Misra & Gries edge coloring — a constructive proof of Vizing's theorem.
//!
//! Properly colors the edges of any simple graph with at most `Δ(G) + 1`
//! colors in `O(|V|·|E|)` time, via maximal fans, cd-path inversions, and
//! fan rotations. This is the decomposition procedure named by the paper
//! (its reference [20]).

use crate::graph::Graph;

/// Per-vertex color table: `at[x][c] = Some(y)` iff edge (x,y) has color c.
struct ColorTable {
    at: Vec<Vec<Option<usize>>>,
    /// edge (normalized) -> color
    edge_color: std::collections::BTreeMap<(usize, usize), usize>,
}

impl ColorTable {
    fn new(m: usize, num_colors: usize) -> Self {
        ColorTable {
            at: vec![vec![None; num_colors]; m],
            edge_color: std::collections::BTreeMap::new(),
        }
    }

    fn norm(u: usize, v: usize) -> (usize, usize) {
        if u < v {
            (u, v)
        } else {
            (v, u)
        }
    }

    fn color_of(&self, u: usize, v: usize) -> Option<usize> {
        self.edge_color.get(&Self::norm(u, v)).copied()
    }

    fn is_free(&self, x: usize, c: usize) -> bool {
        self.at[x][c].is_none()
    }

    /// Smallest color free at `x`. Always exists with Δ+1 colors.
    fn free_color(&self, x: usize) -> usize {
        self.at[x]
            .iter()
            .position(|slot| slot.is_none())
            .expect("Δ+1 colors guarantee a free color at every vertex")
    }

    fn set(&mut self, u: usize, v: usize, c: usize) {
        self.unset(u, v);
        debug_assert!(self.is_free(u, c) && self.is_free(v, c));
        self.at[u][c] = Some(v);
        self.at[v][c] = Some(u);
        self.edge_color.insert(Self::norm(u, v), c);
    }

    fn unset(&mut self, u: usize, v: usize) {
        if let Some(c) = self.edge_color.remove(&Self::norm(u, v)) {
            self.at[u][c] = None;
            self.at[v][c] = None;
        }
    }
}

/// Properly edge-color `g` using at most `Δ(G) + 1` colors.
///
/// Returns one color index per edge, aligned with `g.edges()` order.
pub fn misra_gries_edge_coloring(g: &Graph) -> Vec<usize> {
    let m = g.num_nodes();
    let delta = g.max_degree();
    if g.num_edges() == 0 {
        return vec![];
    }
    let num_colors = delta + 1;
    let mut t = ColorTable::new(m, num_colors);
    let adj = g.adjacency_lists();

    for &(u, v) in g.edges() {
        color_one_edge(u, v, &adj, &mut t);
    }

    g.edges()
        .iter()
        .map(|&(a, b)| t.color_of(a, b).expect("all edges colored"))
        .collect()
}

/// Color the currently-uncolored edge (u, v).
fn color_one_edge(u: usize, v: usize, adj: &[Vec<usize>], t: &mut ColorTable) {
    // --- Build a maximal fan of u starting at v. ---------------------
    // fan[0] = v; fan[i+1] is a neighbor w of u with (u,w) colored and
    // that color free on fan[i]; all fan vertices distinct.
    let fan = build_maximal_fan(u, v, adj, t);
    let k = fan.len() - 1;

    let c = t.free_color(u);
    let d = t.free_color(fan[k]);

    if c != d {
        // --- Invert the cd-path through u. ---------------------------
        // The path starts at u along color d and alternates d, c, d, ...
        invert_cd_path(u, c, d, t);
    }
    // After inversion, d is free on u (u had no c-edge; its d-edge, if
    // any, was recolored to c by the inversion).
    debug_assert!(t.is_free(u, d));

    // --- Find w: a fan prefix fan[0..=w] that is still a fan and has d
    // free on fan[w]. The Misra–Gries lemma guarantees existence. ------
    let w = find_rotation_point(u, &fan, d, t);

    // --- Rotate the prefix fan[0..=w]: shift colors down one slot. ----
    // color(u, fan[i]) <- color(u, fan[i+1]) for i < w; (u, fan[w])
    // becomes uncolored, then takes color d.
    for i in 0..w {
        let next_color = t
            .color_of(u, fan[i + 1])
            .expect("interior fan edges are colored");
        t.unset(u, fan[i + 1]);
        t.set(u, fan[i], next_color);
    }
    t.set(u, fan[w], d);
}

/// Maximal fan of `u` starting at `v` (v's edge to u is uncolored).
fn build_maximal_fan(
    u: usize,
    v: usize,
    adj: &[Vec<usize>],
    t: &ColorTable,
) -> Vec<usize> {
    let mut fan = vec![v];
    let mut in_fan = std::collections::BTreeSet::from([v]);
    loop {
        let last = *fan.last().unwrap();
        let mut extended = false;
        for &w in &adj[u] {
            if in_fan.contains(&w) {
                continue;
            }
            if let Some(cw) = t.color_of(u, w) {
                if t.is_free(last, cw) {
                    fan.push(w);
                    in_fan.insert(w);
                    extended = true;
                    break;
                }
            }
        }
        if !extended {
            return fan;
        }
    }
}

/// Invert the maximal path starting at `u` whose edges alternate colors
/// d, c, d, c, ... (the "cd_u path"). Swaps colors c and d along it.
fn invert_cd_path(u: usize, c: usize, d: usize, t: &mut ColorTable) {
    // Collect path edges first (endpoint walk), then flip.
    let mut path: Vec<(usize, usize)> = Vec::new();
    let mut x = u;
    let mut want = d;
    let mut prev: Option<usize> = None;
    loop {
        match t.at[x][want] {
            Some(y) if Some(y) != prev => {
                path.push((x, y));
                prev = Some(x);
                x = y;
                want = if want == d { c } else { d };
            }
            _ => break,
        }
    }
    // Flip colors along the path. Uncolor all first to avoid transient
    // conflicts, then recolor with the swapped colors.
    let colors: Vec<usize> = path
        .iter()
        .map(|&(a, b)| t.color_of(a, b).expect("path edges colored"))
        .collect();
    for &(a, b) in &path {
        t.unset(a, b);
    }
    for (&(a, b), &col) in path.iter().zip(&colors) {
        let flipped = if col == c { d } else { c };
        t.set(a, b, flipped);
    }
}

/// Find index `w` so that fan[0..=w] is (still) a fan of u and color `d`
/// is free on fan[w], after the cd-path inversion.
fn find_rotation_point(u: usize, fan: &[usize], d: usize, t: &ColorTable) -> usize {
    let mut w: Option<usize> = None;
    for i in 0..fan.len() {
        // Prefix validity: for i ≥ 1, edge (u, fan[i]) must be colored
        // and its color free on fan[i-1] (the fan property).
        if i >= 1 {
            match t.color_of(u, fan[i]) {
                Some(ci) if t.is_free(fan[i - 1], ci) => {}
                _ => break, // prefix stops being a fan here
            }
        }
        if t.is_free(fan[i], d) {
            w = Some(i);
            break; // earliest valid rotation point suffices
        }
    }
    w.expect("Misra–Gries invariant violated: no rotation point found")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{complete, grid, paper_figure1_graph, ring, star};
    use crate::rng::Rng;

    /// A proper edge coloring assigns distinct colors to incident edges.
    fn assert_proper(g: &Graph, colors: &[usize]) {
        assert_eq!(colors.len(), g.num_edges());
        let edges = g.edges();
        for i in 0..edges.len() {
            for j in (i + 1)..edges.len() {
                let (a, b) = edges[i];
                let (c, d) = edges[j];
                let incident = a == c || a == d || b == c || b == d;
                if incident {
                    assert_ne!(
                        colors[i], colors[j],
                        "incident edges {:?} {:?} share color",
                        edges[i], edges[j]
                    );
                }
            }
        }
    }

    fn assert_vizing(g: &Graph, colors: &[usize]) {
        let used = colors.iter().copied().max().map_or(0, |c| c + 1);
        assert!(
            used <= g.max_degree() + 1,
            "used {used} colors > Δ+1 = {}",
            g.max_degree() + 1
        );
    }

    #[test]
    fn colors_named_graphs() {
        for g in [
            paper_figure1_graph(),
            ring(7),
            ring(8),
            star(9),
            complete(6),
            complete(7),
            grid(3, 5),
        ] {
            let colors = misra_gries_edge_coloring(&g);
            assert_proper(&g, &colors);
            assert_vizing(&g, &colors);
        }
    }

    #[test]
    fn empty_graph() {
        let g = Graph::empty(5);
        assert!(misra_gries_edge_coloring(&g).is_empty());
    }

    #[test]
    fn single_edge() {
        let g = Graph::new(2, &[(0, 1)]);
        assert_eq!(misra_gries_edge_coloring(&g), vec![0]);
    }

    #[test]
    fn random_graphs_property() {
        // Property test over many random graphs: proper + Vizing bound.
        let mut rng = Rng::new(777);
        for trial in 0..200 {
            let m = 2 + rng.below(14);
            let p = rng.uniform_in(0.05, 0.9);
            let g = crate::graph::erdos_renyi(m, p, &mut rng);
            let colors = misra_gries_edge_coloring(&g);
            assert_eq!(colors.len(), g.num_edges(), "trial {trial}");
            assert_proper(&g, &colors);
            assert_vizing(&g, &colors);
        }
    }

    #[test]
    fn dense_graphs_property() {
        let mut rng = Rng::new(4242);
        for _ in 0..20 {
            let m = 8 + rng.below(10);
            let g = crate::graph::erdos_renyi(m, 0.95, &mut rng);
            let colors = misra_gries_edge_coloring(&g);
            assert_proper(&g, &colors);
            assert_vizing(&g, &colors);
        }
    }
}
