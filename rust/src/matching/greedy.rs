//! Greedy edge coloring baseline.
//!
//! Assigns each edge the smallest color unused at both endpoints. Needs
//! at most `2Δ − 1` colors (each endpoint blocks at most `Δ − 1` others).
//! Used as an ablation against Misra–Gries: more colors ⇒ more matchings
//! ⇒ more sequential communication rounds under the unit-delay model, so
//! the quality of the decomposition directly costs wall-clock time.

use crate::graph::Graph;

/// Greedy proper edge coloring; returns a color per edge in `g.edges()`
/// order. Uses at most `2Δ(G) − 1` colors.
pub fn greedy_edge_coloring(g: &Graph) -> Vec<usize> {
    let m = g.num_nodes();
    if g.num_edges() == 0 {
        return vec![];
    }
    let max_colors = 2 * g.max_degree();
    // used[x][c] = true iff some edge at x has color c.
    let mut used = vec![vec![false; max_colors]; m];
    let mut colors = Vec::with_capacity(g.num_edges());
    for &(u, v) in g.edges() {
        let c = (0..max_colors)
            .find(|&c| !used[u][c] && !used[v][c])
            .expect("2Δ colors always suffice for greedy");
        used[u][c] = true;
        used[v][c] = true;
        colors.push(c);
    }
    colors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{complete, paper_figure1_graph, ring};
    use crate::rng::Rng;

    fn assert_proper(g: &Graph, colors: &[usize]) {
        let edges = g.edges();
        for i in 0..edges.len() {
            for j in (i + 1)..edges.len() {
                let (a, b) = edges[i];
                let (c, d) = edges[j];
                if a == c || a == d || b == c || b == d {
                    assert_ne!(colors[i], colors[j]);
                }
            }
        }
    }

    #[test]
    fn proper_on_named_graphs() {
        for g in [paper_figure1_graph(), ring(9), complete(6)] {
            let colors = greedy_edge_coloring(&g);
            assert_proper(&g, &colors);
            let used = colors.iter().copied().max().unwrap() + 1;
            assert!(used <= 2 * g.max_degree() - 1 || g.max_degree() <= 1);
        }
    }

    #[test]
    fn random_graphs_property() {
        let mut rng = Rng::new(31);
        for _ in 0..100 {
            let m = 2 + rng.below(12);
            let g = crate::graph::erdos_renyi(m, 0.5, &mut rng);
            let colors = greedy_edge_coloring(&g);
            assert_eq!(colors.len(), g.num_edges());
            assert_proper(&g, &colors);
        }
    }
}
