//! Matching decomposition (Step 1 of MATCHA).
//!
//! The base graph is decomposed into `M` disjoint matchings via proper
//! edge coloring: each color class is a set of node-disjoint links that
//! can all communicate in parallel (1 time unit). The paper uses the
//! Misra & Gries constructive proof of Vizing's theorem, which guarantees
//! `M ≤ Δ(G) + 1`; we implement it in `misra_gries`, plus a simple
//! greedy baseline (`greedy`) used in ablations (greedy may need up to
//! `2Δ − 1` colors).

mod greedy;
mod misra_gries;

pub use greedy::greedy_edge_coloring;
pub use misra_gries::misra_gries_edge_coloring;

use crate::graph::Graph;

/// A decomposition of a base graph into disjoint matchings.
#[derive(Clone, Debug)]
pub struct MatchingDecomposition {
    /// The base graph this decomposes.
    pub base: Graph,
    /// The matchings G_1..G_M (each a subgraph on the same vertex set).
    pub matchings: Vec<Graph>,
}

impl MatchingDecomposition {
    /// Number of matchings `M`.
    pub fn len(&self) -> usize {
        self.matchings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.matchings.is_empty()
    }

    /// Laplacians `L_j` of each matching.
    pub fn laplacians(&self) -> Vec<crate::linalg::Mat> {
        self.matchings.iter().map(|g| g.laplacian()).collect()
    }

    /// Validate the decomposition invariants; used in tests and as a
    /// debug assertion after construction:
    /// 1. every part is a matching,
    /// 2. parts are edge-disjoint,
    /// 3. the union of parts is exactly the base edge set.
    pub fn validate(&self) -> Result<(), String> {
        let mut seen = std::collections::BTreeSet::new();
        for (j, g) in self.matchings.iter().enumerate() {
            if g.num_nodes() != self.base.num_nodes() {
                return Err(format!("matching {j} has wrong node count"));
            }
            if !g.is_matching() {
                return Err(format!("part {j} is not a matching"));
            }
            for &e in g.edges() {
                if !seen.insert(e) {
                    return Err(format!("edge {e:?} appears in two matchings"));
                }
                if !self.base.has_edge(e.0, e.1) {
                    return Err(format!("edge {e:?} not in base graph"));
                }
            }
        }
        if seen.len() != self.base.num_edges() {
            return Err(format!(
                "union covers {} of {} base edges",
                seen.len(),
                self.base.num_edges()
            ));
        }
        Ok(())
    }
}

/// Decompose `g` into disjoint matchings with Misra–Gries edge coloring
/// followed by greedy color compaction.
///
/// Guarantees `M ≤ Δ(G)+1` (Vizing bound) and validates all decomposition
/// invariants. The compaction pass re-homes edges into lower-indexed
/// color classes when legal, which often reaches `M = Δ(G)` on class-1
/// graphs — each saved matching is one less sequential communication
/// round for vanilla DecenSGD.
pub fn decompose(g: &Graph) -> MatchingDecomposition {
    let mut colors = misra_gries_edge_coloring(g);
    compact_colors(g, &mut colors);
    decomposition_from_colors(g, &colors)
}

/// Greedy color compaction: repeatedly move edges to the smallest color
/// legal at both endpoints. Preserves properness; never increases the
/// number of colors. Converges in ≤ `num_colors` passes.
fn compact_colors(g: &Graph, colors: &mut [usize]) {
    if colors.is_empty() {
        return;
    }
    let m = g.num_nodes();
    let num_colors = colors.iter().copied().max().unwrap() + 1;
    // used[x][c] = edge index using color c at vertex x (or usize::MAX).
    let mut used = vec![vec![usize::MAX; num_colors]; m];
    for (e, &(u, v)) in g.edges().iter().enumerate() {
        used[u][colors[e]] = e;
        used[v][colors[e]] = e;
    }
    let mut changed = true;
    let mut passes = 0;
    while changed && passes < num_colors {
        changed = false;
        passes += 1;
        for (e, &(u, v)) in g.edges().iter().enumerate() {
            let cur = colors[e];
            for c in 0..cur {
                if used[u][c] == usize::MAX && used[v][c] == usize::MAX {
                    used[u][cur] = usize::MAX;
                    used[v][cur] = usize::MAX;
                    used[u][c] = e;
                    used[v][c] = e;
                    colors[e] = c;
                    changed = true;
                    break;
                }
            }
        }
    }
    // Renumber so colors are contiguous from 0 (empty classes removed by
    // decomposition_from_colors anyway, but keep indices tidy).
    let mut seen: Vec<usize> = colors.to_vec();
    seen.sort_unstable();
    seen.dedup();
    for c in colors.iter_mut() {
        *c = seen.binary_search(c).unwrap();
    }
}

/// Decompose using the greedy coloring (ablation baseline; may use more
/// matchings than Misra–Gries, i.e. waste communication time).
pub fn decompose_greedy(g: &Graph) -> MatchingDecomposition {
    let colors = greedy_edge_coloring(g);
    decomposition_from_colors(g, &colors)
}

/// Single-edge decomposition (paper §3, "each subgraph can be a single
/// edge in the base graph"): every edge is its own subgraph. Each part is
/// trivially a matching, but nothing communicates in parallel — one unit
/// of time per activated *edge* — so at equal expected communication time
/// the matching decomposition strictly dominates whenever Δ+1 < |E|.
/// Provided for the §3-extension ablation.
pub fn decompose_single_edges(g: &Graph) -> MatchingDecomposition {
    let matchings: Vec<Graph> = g
        .edges()
        .iter()
        .map(|&e| Graph::new(g.num_nodes(), &[e]))
        .collect();
    let d = MatchingDecomposition { base: g.clone(), matchings };
    debug_assert!(d.validate().is_ok());
    d
}

/// Group edges by color into matchings (skipping empty classes).
fn decomposition_from_colors(g: &Graph, colors: &[usize]) -> MatchingDecomposition {
    assert_eq!(colors.len(), g.num_edges());
    let num_colors = colors.iter().copied().max().map_or(0, |c| c + 1);
    let mut classes: Vec<Vec<(usize, usize)>> = vec![Vec::new(); num_colors];
    for (e, &c) in g.edges().iter().zip(colors) {
        classes[c].push(*e);
    }
    let matchings: Vec<Graph> = classes
        .into_iter()
        .filter(|es| !es.is_empty())
        .map(|es| Graph::new(g.num_nodes(), &es))
        .collect();
    let d = MatchingDecomposition { base: g.clone(), matchings };
    debug_assert!(d.validate().is_ok(), "{:?}", d.validate());
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{complete, paper_figure1_graph, ring, star};

    #[test]
    fn figure1_decomposition_within_vizing_bound() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        d.validate().unwrap();
        let delta = g.max_degree();
        assert!(
            d.len() == delta || d.len() == delta + 1,
            "paper: M ∈ {{Δ, Δ+1}}; got M={} Δ={delta}",
            d.len()
        );
    }

    #[test]
    fn star_needs_exactly_delta_matchings() {
        // Every edge of a star shares the center: each matching has 1 edge.
        let g = star(6);
        let d = decompose(&g);
        d.validate().unwrap();
        assert_eq!(d.len(), 5);
        for m in &d.matchings {
            assert_eq!(m.num_edges(), 1);
        }
    }

    #[test]
    fn ring_even_two_matchings() {
        // Even cycle is 2-edge-colorable.
        let d = decompose(&ring(8));
        d.validate().unwrap();
        assert!(d.len() <= 3);
    }

    #[test]
    fn complete_graph_bound() {
        let g = complete(7);
        let d = decompose(&g);
        d.validate().unwrap();
        assert!(d.len() <= g.max_degree() + 1);
    }

    #[test]
    fn greedy_also_valid_but_may_use_more() {
        let g = paper_figure1_graph();
        let dg = decompose_greedy(&g);
        dg.validate().unwrap();
        assert!(dg.len() <= 2 * g.max_degree() - 1);
    }

    #[test]
    fn single_edge_decomposition_shape() {
        let g = paper_figure1_graph();
        let d = decompose_single_edges(&g);
        d.validate().unwrap();
        assert_eq!(d.len(), g.num_edges());
        for m in &d.matchings {
            assert_eq!(m.num_edges(), 1);
        }
    }

    #[test]
    fn compaction_reaches_delta_on_fig1() {
        // Figure-1 graph is class 1 (χ' = Δ = 5); compaction should land
        // exactly on Δ matchings.
        let g = paper_figure1_graph();
        let d = decompose(&g);
        assert_eq!(d.len(), 5);
        d.validate().unwrap();
    }

    #[test]
    fn compaction_preserves_validity_on_random_graphs() {
        let mut rng = crate::rng::Rng::new(2718);
        for _ in 0..100 {
            let m = 3 + rng.below(12);
            let g = crate::graph::erdos_renyi(m, 0.6, &mut rng);
            let d = decompose(&g);
            d.validate().unwrap();
            assert!(d.len() <= g.max_degree() + 1);
        }
    }

    #[test]
    fn laplacians_sum_to_base_laplacian() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let mut sum = crate::linalg::Mat::zeros(8, 8);
        for l in d.laplacians() {
            sum = sum.add(&l);
        }
        assert!(sum.max_abs_diff(&g.laplacian()) < 1e-12);
    }
}
