//! Minimal JSON parser and writer.
//!
//! Used for the AOT artifact metadata (`artifacts/meta.json` written by
//! `python/compile/aot.py`), experiment configuration files, and metric
//! dumps. `serde`/`serde_json` are not available in this offline image;
//! this module implements the subset of JSON we need (objects, arrays,
//! strings with escapes, f64 numbers, booleans, null), strictly enough to
//! reject malformed input.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as `f64` (all our metadata fits).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte position.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Convenience accessors (None on type mismatch).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Build an object from pairs (test/emit helper).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    /// Compact serialization (valid JSON; numbers use shortest-roundtrip).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn reject_malformed() {
        for bad in ["{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\x\"", ""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null],"name":"m\"x","ok":true}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let j = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é café ☕");
    }

    #[test]
    fn as_usize_rejects_negatives_and_fractions() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
    }
}
