//! Deterministic pseudo-random number generation (xoshiro256++).
//!
//! The offline image has no `rand` crate, so we carry our own small,
//! well-tested generator. Every stochastic component in this crate
//! (matching activation, data synthesis, parameter init, geometric/ER
//! graph generation) takes an explicit seed so experiments are exactly
//! reproducible — the paper's schedules are generated "apriori", and so
//! are ours.

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic; excellent
/// statistical quality and tiny state, which is all we need.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64, used to expand a single seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xa076_1d64_78bd_642f)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Multiply-shift; bias negligible for n << 2^64 which always holds.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli(p) draw.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (no cached spare: keeps state simple).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Draw an index from an (unnormalized, nonnegative) weight vector.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_choice: zero total weight");
        let mut r = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn bernoulli_frequency() {
        let mut r = Rng::new(11);
        let p = 0.3;
        let n = 50_000;
        let hits = (0..n).filter(|_| r.bernoulli(p)).count();
        let freq = hits as f64 / n as f64;
        assert!((freq - p).abs() < 0.01, "freq = {freq}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.03, "var = {var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 8);
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(23);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
