//! Multi-node cluster runtime: the gossip workers across
//! transport-separated shards instead of one address space.
//!
//! Every other backend in this crate exchanges gossip through in-process
//! memory; MATCHA's whole premise, though, is that **communication** is
//! the bottleneck, and realizing the algorithm's wall-clock win requires
//! real inter-node links (the AD-PSGD deployment model; see also "From
//! promise to practice", Wang et al., 2024). This subsystem is that
//! step, in three layers:
//!
//! - [`wire`] — a versioned, dependency-free framed binary encoding
//!   (length-prefixed, little-endian `f64` rows) of the actor mode's
//!   message format: phase commands, routed gossip metadata + staged
//!   peer rows, and state replies. Decoding is total — truncation, bad
//!   version bytes and overflowing length prefixes return typed
//!   [`WireError`]s, never panics — and `f64` bit patterns cross
//!   losslessly.
//! - [`transport`] — the [`Transport`] link trait with two
//!   implementations: an in-memory loopback (deterministic; what tests
//!   and parity proofs use) and a real [`std::net::TcpStream`] transport.
//!   Both carry a per-link byte-accounting layer ([`LinkStats`]) and a
//!   [`WireClock`] that converts observed bytes into the delay models'
//!   virtual units, so simulated and wire communication time can be
//!   compared on one scale.
//! - [`driver`] — the shard driver: each shard owns a per-shard
//!   [`crate::state::StateMatrix`] arena segment (the actor pool's
//!   `ActorShard`, unchanged), and the coordinator replays the
//!   materialized [`crate::gossip::RoundPlan`] schedule through the
//!   barrier engine's own drive loop, with phase commands serialized
//!   over the per-shard transports.
//!
//! Because the shards run the identical `MixKernel::fold_worker`
//! arithmetic in the identical order and the wire is lossless, the
//! loopback cluster backend is **bit-for-bit** equal to the actors
//! backend per seed (pinned by `rust/tests/golden.rs`), and a TCP run
//! over localhost executes the same schedule with the same result.
//!
//! Reachable end-to-end as `backend: "cluster"` in an
//! [`crate::experiment::ExperimentSpec`] (JSON: `{"kind": "cluster",
//! "shards": N, "transport": "loopback" | "tcp"}`), from the CLI
//! (`matcha engine --backend cluster --shards N --transport tcp`), and
//! in `benches/cluster_transport.rs`, which measures bytes/iteration and
//! loopback-vs-TCP throughput (`BENCH_cluster.json`).
//!
//! ```
//! use matcha::cluster::{run_cluster, ClusterConfig, TransportKind};
//! use matcha::engine::AnalyticPolicy;
//! use matcha::graph::paper_figure1_graph;
//! use matcha::matching::decompose;
//! use matcha::rng::Rng;
//! use matcha::sim::{QuadraticProblem, RunConfig};
//! use matcha::topology::VanillaSampler;
//!
//! let d = decompose(&paper_figure1_graph());
//! let problem = QuadraticProblem::generate(8, 10, 1.0, 0.1, &mut Rng::new(1));
//! let mut sampler = VanillaSampler::new(d.len());
//! let run = RunConfig { iterations: 20, alpha: 0.1, ..RunConfig::default() };
//! let mut policy = AnalyticPolicy::matching_run_config(&run);
//! let config = ClusterConfig { run, shards: 3, transport: TransportKind::Loopback };
//! let result = run_cluster(&problem, &d.matchings, &mut sampler, &mut policy, &config).unwrap();
//! assert!(result.stats.total_bytes() > 0);
//! ```

pub mod driver;
pub mod transport;
pub mod wire;

pub use driver::{
    run_cluster, run_cluster_observed, run_cluster_traced, ClusterConfig, ClusterResult,
    ClusterStats,
};
pub use transport::{
    loopback_pair, LinkStats, LoopbackTransport, TcpTransport, Transport, TransportKind, WireClock,
};
pub use wire::{
    check_proto, frame_len, WireError, WireMeta, WireMsg, MAX_FRAME_BYTES, PROTO_VERSION,
    WIRE_VERSION,
};
