//! The cluster shard driver: the barrier engine's schedule executed over
//! transport-separated shards.
//!
//! Topology of one run:
//!
//! ```text
//!                       ┌── Transport ──▶ shard 0 (ActorShard: arena
//!   coordinator ────────┤                 segment + RNG streams)
//!   (drive loop +       ├── Transport ──▶ shard 1
//!    RoundPlan replay)  └── Transport ──▶ shard 2 ...
//! ```
//!
//! The coordinator materializes the activation schedule up front
//! ([`RoundPlan`] — the paper's apriori-schedule observation) and then
//! runs the **exact** barrier iteration loop of the engine
//! ([`crate::engine::runner`]'s `drive`): compute phase, per-link delay
//! events, gossip mix, one `Observer` stream. Only the executor differs —
//! `ClusterExec` serializes each phase command into [`super::wire`]
//! frames and ships them over a per-shard [`Transport`] instead of an
//! in-process channel. Each shard owns a per-shard [`StateMatrix`] arena
//! segment (the same `ActorShard` the actor pool runs, so the mix fold is
//! `MixKernel::fold_worker` with unchanged arithmetic order), which makes
//! the loopback cluster backend **bit-for-bit** equal to the actors
//! backend per seed — pinned by `rust/tests/golden.rs` — and the TCP
//! backend byte-identical on the wire.
//!
//! The per-link byte accounting ([`LinkStats`]) comes back in
//! [`ClusterStats`], alongside a [`WireClock`] conversion so the
//! schedule's simulated communication time and the observed bytes-on-wire
//! can be compared on one scale.

use super::transport::{
    loopback_pair, LinkStats, TcpTransport, Transport, TransportKind, WireClock,
};
use super::wire::{WireError, WireMeta, WireMsg};
use crate::engine::actor::{ActorShard, MixBatch, MsgMeta, ShardCmd};
use crate::engine::runner::{drive, route_per_worker, stage_shard_messages, Executor};
use crate::engine::DelayPolicy;
use crate::experiment::{NoopObserver, Observer};
use crate::gossip::{shard_workers, RoundPlan};
use crate::graph::Graph;
use crate::sim::kernel::{init_iterates, worker_streams};
use crate::sim::{Problem, RunConfig, RunResult};
use crate::state::StateMatrix;
use crate::topology::{Round, TopologySampler};
use crate::trace::{Counter, TraceEvent, Tracer};
use std::net::{TcpListener, TcpStream};

/// Configuration of a cluster run: the shared run parameters, the shard
/// count, and which transport carries the frames.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub run: RunConfig,
    /// Shards the workers are partitioned over (round-robin, clamped to
    /// the worker count). Never changes results, only the partition.
    pub shards: usize,
    /// Loopback (deterministic in-memory pipes) or TCP over localhost.
    pub transport: TransportKind,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { run: RunConfig::default(), shards: 2, transport: TransportKind::Loopback }
    }
}

/// Communication observability of a cluster run: what actually crossed
/// each coordinator↔shard link.
///
/// Note on what the counts mean: mix traffic ships as
/// [`WireMsg::MixLocal`] frames, which carry metadata for **every**
/// routed message but stage only the peer rows that genuinely live on
/// another shard — a row whose peer is on the receiving shard is
/// *suppressed* (the shard resolves it from its own pre-mix segment),
/// so its payload bytes never exist on the wire. The raw link counters
/// are therefore already the genuine cross-shard traffic; the bytes the
/// suppression avoided are accounted separately at staging time into
/// [`LinkStats::intra_bytes`] and surface as [`Self::suppressed_bytes`]
/// (the savings line of `matcha engine` and sweep JSON output).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterStats {
    pub transport: TransportKind,
    /// Byte accounting per link, indexed by shard.
    pub per_link: Vec<LinkStats>,
}

impl ClusterStats {
    /// Total bytes on the wire across all links, both directions.
    pub fn total_bytes(&self) -> u64 {
        self.per_link.iter().map(|l| l.total_bytes()).sum()
    }

    /// Bytes that crossed shards. With local-row suppression everything
    /// shipped is genuine cross-shard traffic, so this equals
    /// [`Self::total_bytes`]; kept as the semantic name wire-efficiency
    /// comparisons (and `wire_bytes` in sweep JSON lines) use.
    pub fn remote_bytes(&self) -> u64 {
        self.per_link.iter().map(|l| l.remote_bytes()).sum()
    }

    /// Payload bytes the Mix local-row suppression avoided shipping —
    /// savings relative to the stage-everything protocol, **not** a
    /// component of [`Self::total_bytes`].
    pub fn suppressed_bytes(&self) -> u64 {
        self.per_link.iter().map(|l| l.intra_bytes).sum()
    }

    /// Total frames across all links, both directions.
    pub fn total_frames(&self) -> u64 {
        self.per_link.iter().map(|l| l.frames_sent + l.frames_received).sum()
    }

    /// The observed traffic expressed in the delay models' virtual units
    /// via `clock` — the number to put next to the schedule's simulated
    /// `total_comm_units` when comparing model and wire.
    pub fn wire_units(&self, clock: WireClock) -> f64 {
        clock.units(self.total_bytes())
    }
}

/// Outcome of a cluster run: the standard [`RunResult`] plus the
/// engine-level counters and the per-link wire statistics.
pub struct ClusterResult {
    pub run: RunResult,
    /// Links dropped by failure injection over the whole run.
    pub dropped_links: usize,
    /// Discrete events processed by the queue.
    pub events: u64,
    pub stats: ClusterStats,
}

// ---------------------------------------------------------------------
// Schedule replay
// ---------------------------------------------------------------------

/// Replays a materialized [`RoundPlan`] as a [`TopologySampler`], so the
/// engine's drive loop consumes the cluster's apriori schedule exactly
/// as it would consume the live sampler (same activation sequence: the
/// plan was generated from the same sampler stream). Shared with the
/// remote coordinator ([`crate::node`]), which replays the identical
/// schedule against standalone daemons.
pub(crate) struct PlanReplay<'a> {
    pub(crate) plan: &'a RoundPlan,
}

impl TopologySampler for PlanReplay<'_> {
    fn round(&mut self, k: usize) -> Round {
        Round { activated: self.plan.activated(k).to_vec() }
    }

    fn expected_comm_units(&self) -> f64 {
        if self.plan.is_empty() {
            return 0.0;
        }
        let total: usize = (0..self.plan.len()).map(|k| self.plan.activated(k).len()).sum();
        total as f64 / self.plan.len() as f64
    }

    fn name(&self) -> &'static str {
        "cluster-replay"
    }
}

// ---------------------------------------------------------------------
// Shard node: serve wire commands against an ActorShard
// ---------------------------------------------------------------------

/// Convert one coordinator phase frame (`Step` or `Mix`) into the
/// actor-shard command, recycling `batch` and `ret`. Shared between the
/// in-process serve loop below and the standalone shard-node daemon
/// ([`crate::node`]), so both execute byte-identical frames identically.
pub(crate) fn phase_cmd_from_wire(
    msg: WireMsg,
    dim: usize,
    batch: &mut MixBatch,
    ret: &mut Vec<f64>,
) -> Result<ShardCmd, WireError> {
    match msg {
        WireMsg::Step { lr } => Ok(ShardCmd::Step { lr, ret: std::mem::take(ret) }),
        WireMsg::Mix { k, alpha, dim: d, msgs, staging } => {
            if d as usize != dim {
                return Err(WireError::Inconsistent(format!(
                    "mix frame dim {d} does not match shard dim {dim}"
                )));
            }
            batch.msgs.clear();
            batch.msgs.extend(msgs.iter().map(|m| MsgMeta {
                slot: m.slot as usize,
                matching: m.matching as usize,
                u: m.u as usize,
                v: m.v as usize,
            }));
            batch.staging.clear();
            batch.staging.extend_from_slice(&staging);
            Ok(ShardCmd::Mix {
                k: k as usize,
                alpha,
                batch: std::mem::take(batch),
                ret: std::mem::take(ret),
            })
        }
        WireMsg::MixLocal { .. } => Err(WireError::Inconsistent(
            "mix-local frames are decoded zero-copy by the serve loop \
             (MixLocalRef), never materialized into a phase command"
                .into(),
        )),
        other => Err(WireError::Inconsistent(format!("unexpected phase command {other:?}"))),
    }
}

/// One shard node's serve loop: announce the shard id, then fold wire
/// commands into the owned [`ActorShard`] until `Shutdown`. The frame
/// scratch, state-return and mix-batch buffers are recycled across
/// frames, and mix frames take the zero-copy path: a received
/// [`super::wire::TAG_MIX_LOCAL`] body is viewed through
/// [`super::wire::MixLocalRef`] and its peer rows fold as byte slices
/// borrowed straight from the frame buffer — the rows are never copied
/// into an owned staging vector.
fn serve_shard<P: Problem + ?Sized>(
    mut link: Box<dyn Transport>,
    mut shard: ActorShard<'_, P>,
    shard_id: usize,
    dim: usize,
) -> Result<(), WireError> {
    let mut scratch = Vec::new();
    let mut body = Vec::new();
    let mut ret: Vec<f64> = Vec::new();
    let mut batch = MixBatch::default();
    link.send_msg(
        &WireMsg::Hello { shard: shard_id as u32, proto: super::wire::PROTO_VERSION },
        &mut scratch,
    )?;
    loop {
        link.recv_into(&mut body)?;
        let reply = if super::wire::peek_tag(&body)? == super::wire::TAG_MIX_LOCAL {
            let frame = super::wire::MixLocalRef::decode(&body)?;
            shard.mix_from_frame(&frame, std::mem::take(&mut ret))?
        } else {
            let cmd = match WireMsg::decode(&body)? {
                WireMsg::Shutdown => return Ok(()),
                msg => phase_cmd_from_wire(msg, dim, &mut batch, &mut ret)?,
            };
            shard.handle(cmd)
        };
        if let Some(b) = reply.batch {
            batch = b;
        }
        let msg =
            WireMsg::States { shard: shard_id as u32, dim: dim as u32, states: reply.states };
        link.send_msg(&msg, &mut scratch)?;
        let WireMsg::States { states, .. } = msg else { unreachable!() };
        ret = states;
    }
}

/// Accept-side handshake of one TCP connection: switch the socket to
/// blocking with a short read timeout (so a silent stray connection
/// cannot stall the accept loop), read the `Hello`, clear the timeout,
/// and return the announced shard with its link. Any failure rejects
/// only this connection — the caller keeps accepting.
fn admit_tcp(stream: TcpStream) -> Result<(usize, TcpTransport), String> {
    stream
        .set_nonblocking(false)
        .map_err(|e| format!("blocking mode: {e}"))?;
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(2)))
        .map_err(|e| format!("handshake timeout: {e}"))?;
    let mut link = TcpTransport::new(stream).map_err(|e| e.to_string())?;
    let mut body = Vec::new();
    let hello = link.recv_msg(&mut body).map_err(|e| e.to_string())?;
    let shard = match hello {
        WireMsg::Hello { shard, proto } => {
            if let Err(e) = super::wire::check_proto(proto) {
                // Echo what we speak before dropping the link, so the
                // mismatched peer can log something actionable.
                let reject = WireMsg::VersionReject { supported: super::wire::PROTO_VERSION };
                let _ = link.send_msg(&reject, &mut body);
                return Err(e.to_string());
            }
            shard as usize
        }
        other => return Err(format!("handshake expected Hello, got {other:?}")),
    };
    link.stream()
        .set_read_timeout(None)
        .map_err(|e| format!("clear handshake timeout: {e}"))?;
    Ok((shard, link))
}

// ---------------------------------------------------------------------
// Coordinator executor
// ---------------------------------------------------------------------

/// The coordinator-side executor: the cluster twin of the actor pool's
/// `ActorExec`, with the command/reply cycle serialized through the
/// per-shard transports. Routing, staging order and fold order are
/// identical — the shards run the same `ActorShard::handle` — so the
/// trajectory matches the in-process backends bit-for-bit.
struct ClusterExec<'a> {
    links: &'a mut [Box<dyn Transport>],
    workers: usize,
    dim: usize,
    /// Per-worker `(matching, u, v)` routes of the current round, in
    /// global (activation, edge) order; reused across iterations.
    per: Vec<Vec<(usize, usize, usize)>>,
    /// Recycled encode / decode / staging buffers.
    scratch: Vec<u8>,
    body: Vec<u8>,
    msgs: Vec<WireMeta>,
    staging: Vec<f64>,
    /// Per-link stats snapshot taken at each phase start, so the phase's
    /// wire traffic can be counted as a delta (recycled across phases).
    prev_stats: Vec<LinkStats>,
    /// Per-link count of staged Mix rows whose peer lived on the
    /// receiving shard. Borrowed from the run entry point (drive
    /// consumes the executor) so the intra/remote byte split can be
    /// folded into [`ClusterStats`] after the run.
    intra_rows: &'a mut [u64],
}

impl<'a> ClusterExec<'a> {
    fn new(
        links: &'a mut [Box<dyn Transport>],
        workers: usize,
        dim: usize,
        intra_rows: &'a mut [u64],
    ) -> Self {
        let shards = links.len();
        assert_eq!(intra_rows.len(), shards, "one intra-row counter per link");
        ClusterExec {
            links,
            workers,
            dim,
            per: (0..workers).map(|_| Vec::new()).collect(),
            scratch: Vec::new(),
            body: Vec::new(),
            msgs: Vec::new(),
            staging: Vec::new(),
            prev_stats: vec![LinkStats::default(); shards],
            intra_rows,
        }
    }

    /// Capture every link's running stats at the start of a phase.
    fn snapshot_stats(&mut self) {
        for (s, link) in self.links.iter().enumerate() {
            self.prev_stats[s] = link.stats();
        }
    }

    /// Fold the phase's per-link traffic (since [`Self::snapshot_stats`])
    /// into the registry and emit one frame-traffic marker pair per link.
    fn account_traffic(&mut self, tracer: &mut Tracer<'_>) {
        for (s, link) in self.links.iter().enumerate() {
            let delta = link.stats().delta(&self.prev_stats[s]);
            tracer.count(Counter::WireFramesSent, delta.frames_sent);
            tracer.count(Counter::WireBytesSent, delta.bytes_sent);
            tracer.count(Counter::WireFramesReceived, delta.frames_received);
            tracer.count(Counter::WireBytesReceived, delta.bytes_received);
            tracer.emit(TraceEvent::FrameSent { link: s, bytes: delta.bytes_sent });
            tracer.emit(TraceEvent::FrameReceived { link: s, bytes: delta.bytes_received });
        }
    }

    /// Receive every shard's `States` reply (links are point-to-point
    /// and strictly request/reply, so shard order is fine) and copy the
    /// segments back into the coordinator's arena.
    fn collect(&mut self, xs: &mut StateMatrix) {
        let shards = self.links.len();
        let d = self.dim;
        for (s, link) in self.links.iter_mut().enumerate() {
            let msg = link
                .recv_msg(&mut self.body)
                .unwrap_or_else(|e| panic!("cluster link {s}: {e}"));
            let (shard, dim, states) = match msg {
                WireMsg::States { shard, dim, states } => (shard, dim, states),
                other => panic!("cluster link {s}: expected States reply, got {other:?}"),
            };
            assert_eq!(shard as usize, s, "reply from the wrong shard");
            assert_eq!(dim as usize, d, "reply dim mismatch");
            for (slot, w) in shard_workers(s, shards, self.workers).enumerate() {
                xs.row_mut(w).copy_from_slice(&states[slot * d..(slot + 1) * d]);
            }
        }
    }
}

impl Executor for ClusterExec<'_> {
    fn step(&mut self, _k: usize, lr: f64, xs: &mut StateMatrix, tracer: &mut Tracer<'_>) {
        self.snapshot_stats();
        let msg = WireMsg::Step { lr };
        for (s, link) in self.links.iter_mut().enumerate() {
            link.send_msg(&msg, &mut self.scratch)
                .unwrap_or_else(|e| panic!("cluster link {s}: {e}"));
        }
        self.collect(xs);
        // The shards report their per-reply step counts, but the phase
        // total is fixed by the partition — every worker steps exactly
        // once — so the coordinator accounts it directly (the counter
        // totals match the actor pool's reply-side accounting).
        tracer.count(Counter::ShardSteps, self.workers as u64);
        self.account_traffic(tracer);
    }

    fn mix(
        &mut self,
        k: usize,
        alpha: f64,
        matchings: &[Graph],
        activated: &[usize],
        dead: &[(usize, usize)],
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    ) {
        self.snapshot_stats();
        // One routing + staging implementation shared with the actor
        // executor — the fold-order parity contract lives in one place.
        route_per_worker(&mut self.per, matchings, activated, dead);
        let shards = self.links.len();
        let d = self.dim;
        for s in 0..shards {
            stage_shard_messages(
                s,
                shards,
                self.workers,
                &self.per,
                xs,
                &mut self.msgs,
                &mut self.staging,
                &mut self.intra_rows[s],
                // Suppress local-peer rows: the shard resolves them from
                // its own pre-mix segment, so they never cross the wire.
                true,
                |slot, j, u, v| WireMeta {
                    slot: slot as u32,
                    matching: j as u32,
                    u: u as u32,
                    v: v as u32,
                },
            );
            // Staged-message count is decided here, at routing time, so
            // the coordinator accounts the fold counter the actor pool
            // accounts from its replies — identical totals.
            tracer.count(Counter::ShardMsgsFolded, self.msgs.len() as u64);
            let msg = WireMsg::MixLocal {
                k: k as u64,
                alpha,
                shard: s as u32,
                shards: shards as u32,
                dim: d as u32,
                msgs: std::mem::take(&mut self.msgs),
                staging: std::mem::take(&mut self.staging),
            };
            self.links[s]
                .send_msg(&msg, &mut self.scratch)
                .unwrap_or_else(|e| panic!("cluster link {s}: {e}"));
            let WireMsg::MixLocal { msgs, staging, .. } = msg else { unreachable!() };
            self.msgs = msgs;
            self.staging = staging;
        }
        self.collect(xs);
        self.account_traffic(tracer);
    }
}

// ---------------------------------------------------------------------
// The run entry points
// ---------------------------------------------------------------------

/// Run the cluster backend. Equivalent to [`run_cluster_observed`] with
/// a no-op observer.
pub fn run_cluster<P, S>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &ClusterConfig,
) -> Result<ClusterResult, String>
where
    P: Problem + Sync,
    S: TopologySampler,
{
    run_cluster_observed(problem, matchings, sampler, policy, config, &mut NoopObserver)
}

/// [`run_cluster`] with streaming observation (callbacks run on the
/// coordinator thread, exactly as in the other barrier backends).
///
/// Materializes the [`RoundPlan`], spawns one shard node per partition
/// behind the configured transport, performs the `Hello` handshake, and
/// drives the engine's barrier loop through the wire executor. Errors
/// from setup (socket binding, handshake) surface as `Err`; transport
/// failures mid-run panic the run (the shards hold borrowed state that
/// cannot outlive a half-finished schedule).
pub fn run_cluster_observed<P, S>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &ClusterConfig,
    observer: &mut dyn Observer,
) -> Result<ClusterResult, String>
where
    P: Problem + Sync,
    S: TopologySampler,
{
    run_cluster_traced(
        problem,
        matchings,
        sampler,
        policy,
        config,
        observer,
        &mut Tracer::disabled(),
    )
}

/// [`run_cluster_observed`] with trace emission: the engine loop's
/// compute/link spans plus per-phase wire-frame traffic markers and the
/// wire byte/frame counters flow through `tracer`. With a disabled
/// tracer this **is** the observed run — the trajectory never depends
/// on tracing.
pub fn run_cluster_traced<P, S>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &ClusterConfig,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> Result<ClusterResult, String>
where
    P: Problem + Sync,
    S: TopologySampler,
{
    let m = problem.num_workers();
    let d = problem.dim();
    let shards = config.shards.clamp(1, m);
    let plan = RoundPlan::generate(sampler, matchings, config.run.iterations);
    let xs0 = init_iterates(config.run.seed, m, d);
    let rngs = worker_streams(config.run.seed, m);

    // Sticky shard state, built by the same construction path as the
    // actor pool's shards (identical partition, segments and streams).
    let make_shard = |s: usize| {
        ActorShard::for_partition(
            problem,
            config.run.compression.clone(),
            config.run.seed,
            s,
            shards,
            &xs0,
            &rngs,
        )
    };

    if let TransportKind::Remote { .. } = &config.transport {
        // Remote runs talk to pre-existing shard-node daemons with a
        // pipelined executor; that coordinator lives in `crate::node`
        // (spec-driven runs dispatch there automatically).
        return Err(
            "cluster: the remote transport is driven by the shard-node coordinator \
             (crate::node::run_remote), not run_cluster"
                .into(),
        );
    }
    let listener = match &config.transport {
        TransportKind::Tcp => Some(
            TcpListener::bind(("127.0.0.1", 0))
                .map_err(|e| format!("cluster: bind localhost listener: {e}"))?,
        ),
        _ => None,
    };

    std::thread::scope(|scope| -> Result<ClusterResult, String> {
        // Connect one transport per shard, spawn its serve loop, and
        // handshake: every link announces its shard id, and the links
        // are ordered by id (TCP arrival order is whichever shard
        // dialed in first).
        let mut slots: Vec<Option<Box<dyn Transport>>> = (0..shards).map(|_| None).collect();
        let mut body = Vec::new();
        match &config.transport {
            TransportKind::Remote { .. } => unreachable!("remote rejected above"),
            TransportKind::Loopback => {
                let mut raw: Vec<Box<dyn Transport>> = Vec::with_capacity(shards);
                for s in 0..shards {
                    let (coord, node) = loopback_pair();
                    raw.push(Box::new(coord));
                    let shard = make_shard(s);
                    // A transport error shard-side means the coordinator
                    // hung up (setup error or panic); the coordinator's
                    // own recv/send is the loud failure, so the shard
                    // logs and exits instead of turning a coordinator
                    // Err return into a join panic.
                    scope.spawn(move || {
                        let boxed = Box::new(node) as Box<dyn Transport>;
                        if let Err(e) = serve_shard(boxed, shard, s, d) {
                            eprintln!("cluster shard {s}: link closed: {e}");
                        }
                    });
                }
                for mut link in raw {
                    let hello = link
                        .recv_msg(&mut body)
                        .map_err(|e| format!("cluster: handshake: {e}"))?;
                    let shard = match hello {
                        WireMsg::Hello { shard, proto } => {
                            super::wire::check_proto(proto)
                                .map_err(|e| format!("cluster: handshake: {e}"))?;
                            shard
                        }
                        other => {
                            return Err(format!(
                                "cluster: handshake expected Hello, got {other:?}"
                            ))
                        }
                    };
                    let s = shard as usize;
                    if s >= shards || slots[s].is_some() {
                        return Err(format!("cluster: handshake announced bogus shard {s}"));
                    }
                    slots[s] = Some(link);
                }
            }
            TransportKind::Tcp => {
                let listener = listener.as_ref().expect("tcp listener bound above");
                let addr = listener
                    .local_addr()
                    .map_err(|e| format!("cluster: listener address: {e}"))?;
                for s in 0..shards {
                    let shard = make_shard(s);
                    // Same log-and-exit contract as the loopback shards.
                    // A connect failure also logs and exits: the
                    // deadline on the accept loop below turns the
                    // missing connection into a coordinator-side Err
                    // instead of an unbounded accept() block.
                    scope.spawn(move || {
                        let stream = match TcpStream::connect(addr) {
                            Ok(stream) => stream,
                            Err(e) => {
                                eprintln!("cluster shard {s}: connect failed: {e}");
                                return;
                            }
                        };
                        let link = match TcpTransport::new(stream) {
                            Ok(link) => link,
                            Err(e) => {
                                eprintln!("cluster shard {s}: {e}");
                                return;
                            }
                        };
                        let boxed = Box::new(link) as Box<dyn Transport>;
                        if let Err(e) = serve_shard(boxed, shard, s, d) {
                            eprintln!("cluster shard {s}: link closed: {e}");
                        }
                    });
                }
                // Accept with a deadline: if a shard never dials in (its
                // connect failed), surface an error instead of blocking
                // in accept() forever inside the scope. The ephemeral
                // localhost port is reachable by any local process, so
                // each connection must earn its slot with a valid Hello
                // — strays are rejected and accepting continues.
                listener
                    .set_nonblocking(true)
                    .map_err(|e| format!("cluster: listener nonblocking: {e}"))?;
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
                while slots.iter().any(Option::is_none) {
                    match listener.accept() {
                        Ok((stream, peer)) => match admit_tcp(stream) {
                            Ok((s, link)) if s < shards && slots[s].is_none() => {
                                slots[s] = Some(Box::new(link));
                            }
                            Ok((s, _)) => {
                                eprintln!(
                                    "cluster: rejected connection from {peer} announcing \
                                     bogus or duplicate shard {s}"
                                );
                            }
                            Err(e) => {
                                eprintln!("cluster: rejected connection from {peer}: {e}");
                            }
                        },
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            if std::time::Instant::now() >= deadline {
                                let arrived = slots.iter().filter(|l| l.is_some()).count();
                                return Err(format!(
                                    "cluster: timed out waiting for shard connections \
                                     ({arrived}/{shards} arrived)"
                                ));
                            }
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(e) => return Err(format!("cluster: accept shard connection: {e}")),
                    }
                }
            }
        }
        let mut links: Vec<Box<dyn Transport>> =
            slots.into_iter().map(|l| l.expect("every shard slot handshaken")).collect();

        // The engine's barrier loop, verbatim, over the wire executor.
        let mut intra_rows = vec![0u64; shards];
        let exec = ClusterExec::new(&mut links, m, d, &mut intra_rows);
        let mut replay = PlanReplay { plan: &plan };
        let result =
            drive(problem, matchings, &mut replay, policy, &config.run, exec, observer, tracer);

        let mut scratch = Vec::new();
        for (s, link) in links.iter_mut().enumerate() {
            link.send_msg(&WireMsg::Shutdown, &mut scratch)
                .map_err(|e| format!("cluster: shutdown shard {s}: {e}"))?;
        }
        let stats = ClusterStats {
            transport: config.transport.clone(),
            per_link: links
                .iter()
                .zip(&intra_rows)
                .map(|(l, &rows)| {
                    let mut ls = l.stats();
                    // Each suppressed local-peer row would have carried
                    // 8·dim payload bytes — the savings the MixLocal
                    // frames realized on this link.
                    ls.intra_bytes = rows * 8 * d as u64;
                    ls
                })
                .collect(),
        };
        Ok(ClusterResult {
            run: result.run,
            dropped_links: result.dropped_links,
            events: result.events,
            stats,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{run_engine_analytic, AnalyticPolicy, EngineConfig};
    use crate::matching::decompose;
    use crate::rng::Rng;
    use crate::sim::QuadraticProblem;
    use crate::topology::{MatchaSampler, VanillaSampler};

    fn quad(m: usize) -> QuadraticProblem {
        let mut rng = Rng::new(99);
        QuadraticProblem::generate(m, 10, 1.0, 0.1, &mut rng)
    }

    fn cfg(iterations: usize, alpha: f64, seed: u64) -> RunConfig {
        RunConfig { lr: 0.02, iterations, alpha, seed, ..RunConfig::default() }
    }

    #[test]
    fn loopback_cluster_matches_actor_pool_bit_for_bit() {
        let g = crate::graph::paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        let run_cfg = cfg(60, 0.15, 21);

        let mut s1 = MatchaSampler::new(vec![0.6; d.len()], 4);
        let actors = run_engine_analytic(
            &p,
            &d.matchings,
            &mut s1,
            &EngineConfig { run: run_cfg.clone(), threads: 3 },
        );

        let mut s2 = MatchaSampler::new(vec![0.6; d.len()], 4);
        let mut policy = AnalyticPolicy::matching_run_config(&run_cfg);
        let cluster_cfg =
            ClusterConfig { run: run_cfg, shards: 3, transport: TransportKind::Loopback };
        let cluster =
            run_cluster(&p, &d.matchings, &mut s2, &mut policy, &cluster_cfg).unwrap();

        assert_eq!(cluster.run.final_mean, actors.run.final_mean);
        assert_eq!(cluster.run.final_states, actors.run.final_states);
        assert_eq!(cluster.run.total_time, actors.run.total_time);
        assert_eq!(cluster.run.total_comm_units, actors.run.total_comm_units);
        assert!(cluster.stats.total_bytes() > 0, "traffic must be accounted");
        assert_eq!(cluster.stats.per_link.len(), 3);
    }

    #[test]
    fn shard_count_never_changes_results() {
        let g = crate::graph::ring(9);
        let d = decompose(&g);
        let p = quad(9);
        let run = |shards: usize| {
            let mut sampler = VanillaSampler::new(d.len());
            let run_cfg = cfg(25, 0.2, 3);
            let mut policy = AnalyticPolicy::matching_run_config(&run_cfg);
            let cluster_cfg =
                ClusterConfig { run: run_cfg, shards, transport: TransportKind::Loopback };
            run_cluster(&p, &d.matchings, &mut sampler, &mut policy, &cluster_cfg).unwrap()
        };
        let a = run(1);
        let b = run(4);
        // Shard counts above the worker count clamp harmlessly.
        let c = run(64);
        assert_eq!(a.run.final_mean, b.run.final_mean);
        assert_eq!(a.run.final_mean, c.run.final_mean);
        assert_eq!(a.run.total_time, b.run.total_time);
        assert_eq!(c.stats.per_link.len(), 9, "clamped to one shard per worker");
    }

    #[test]
    fn wire_stats_scale_with_schedule_traffic() {
        // More iterations → strictly more frames and bytes on every link.
        let g = crate::graph::ring(6);
        let d = decompose(&g);
        let p = quad(6);
        let run = |iters: usize| {
            let mut sampler = VanillaSampler::new(d.len());
            let run_cfg = cfg(iters, 0.2, 3);
            let mut policy = AnalyticPolicy::matching_run_config(&run_cfg);
            let cluster_cfg =
                ClusterConfig { run: run_cfg, shards: 2, transport: TransportKind::Loopback };
            run_cluster(&p, &d.matchings, &mut sampler, &mut policy, &cluster_cfg).unwrap()
        };
        let short = run(5);
        let long = run(20);
        assert!(long.stats.total_bytes() > short.stats.total_bytes());
        assert!(long.stats.total_frames() > short.stats.total_frames());
        let clock = WireClock::per_row(10, 1.0);
        assert!(long.stats.wire_units(clock) > short.stats.wire_units(clock));
    }

    #[test]
    fn local_row_suppression_shrinks_wire_bytes() {
        let g = crate::graph::ring(6);
        let d = decompose(&g);
        let p = quad(6);
        let run = |shards: usize| {
            let mut sampler = VanillaSampler::new(d.len());
            let run_cfg = cfg(10, 0.2, 3);
            let mut policy = AnalyticPolicy::matching_run_config(&run_cfg);
            let cluster_cfg =
                ClusterConfig { run: run_cfg, shards, transport: TransportKind::Loopback };
            run_cluster(&p, &d.matchings, &mut sampler, &mut policy, &cluster_cfg).unwrap()
        };
        // Two shards over ring(6): round-robin puts consecutive worker
        // ids on opposite shards, and every ring edge connects
        // consecutive ids — no peer is ever local, nothing suppresses,
        // and every shipped byte is genuine cross-shard traffic.
        let two = run(2);
        assert!(two.stats.total_bytes() > 0);
        assert_eq!(two.stats.suppressed_bytes(), 0);
        assert_eq!(two.stats.remote_bytes(), two.stats.total_bytes());
        // One shard: every peer is local, so every mix payload row is
        // suppressed — only metadata, Step frames and replies cross the
        // link, and the run ships strictly fewer bytes than the
        // two-shard run despite carrying the same schedule.
        let one = run(1);
        assert!(one.stats.suppressed_bytes() > 0, "single-shard rows must suppress");
        assert_eq!(one.stats.remote_bytes(), one.stats.total_bytes());
        assert!(
            one.stats.total_bytes() < two.stats.total_bytes(),
            "suppression must shrink what actually ships"
        );
    }
}
