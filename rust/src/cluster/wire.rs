//! The cluster wire format: a versioned, dependency-free framed binary
//! encoding of the coordinator ↔ shard protocol.
//!
//! This promotes the actor mode's in-memory message format
//! (`engine::actor::MsgMeta` + per-shard flat staging buffers) to bytes
//! that can cross a process or machine boundary. Design rules:
//!
//! - **Framed**: every message is `[len: u32 LE][version: u8][tag: u8]
//!   [payload]`, where `len` counts the bytes after the prefix. A reader
//!   needs exactly one 4-byte header read to know how much to pull off
//!   the stream — no in-band scanning, no delimiters.
//! - **Versioned**: the first body byte is [`WIRE_VERSION`]; a decoder
//!   refuses anything else with a typed error instead of misreading.
//! - **Little-endian `f64` rows**: model state crosses the wire as raw
//!   IEEE-754 bit patterns (`f64::to_le_bytes`), so a loopback or TCP
//!   round-trip is **lossless** — the cluster backend stays bit-for-bit
//!   equal to the in-process actors backend (`rust/tests/golden.rs`).
//! - **Total decode safety**: malformed input (truncation, bad version,
//!   unknown tag, oversized or overflowing length prefixes, inconsistent
//!   interior counts) returns a [`WireError`] — decoding never panics
//!   and never allocates more than the validated frame length.
//!
//! Encoding round-trips exactly (`encode` ∘ `decode` = id), fuzz-tested
//! below over randomized messages and corruptions.

use crate::trace::metrics::{Histogram, MetricsRegistry, HIST_BUCKETS, NUM_COUNTERS, NUM_HISTS};
use crate::trace::{Counter, Hist, NodeTelemetry, ObservatoryHealth, TraceEvent, TraceRecord};

/// Current wire protocol version (first body byte of every frame).
pub const WIRE_VERSION: u8 = 1;

/// Application-level protocol version carried inside [`WireMsg::Hello`].
/// Distinct from [`WIRE_VERSION`]: the frame byte guards the *encoding*,
/// this guards the *conversation* (command set, handshake order). A
/// coordinator that sees a mismatched `proto` answers with
/// [`WireMsg::VersionReject`] echoing what it supports and fails with
/// [`WireError::ProtocolMismatch`].
///
/// v2: telemetry snapshots carry an optional observatory health digest
/// (presence byte + rounds/drift/contraction/windows) between the
/// registry block and the trace-record list.
pub const PROTO_VERSION: u32 = 2;

/// Hard upper bound on a frame body, in bytes (1 GiB). A length prefix
/// above this is rejected before any allocation happens — the guard
/// against hostile or corrupted prefixes like `0xffff_ffff`.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Frame header size on the wire: the `u32` length prefix.
pub const FRAME_HEADER_BYTES: usize = 4;

// Message tags (second body byte).
const TAG_HELLO: u8 = 0x01;
const TAG_STEP: u8 = 0x02;
const TAG_MIX: u8 = 0x03;
const TAG_STATES: u8 = 0x04;
const TAG_SHUTDOWN: u8 = 0x05;
const TAG_ASSIGN: u8 = 0x06;
const TAG_VERSION_REJECT: u8 = 0x07;
const TAG_RESUME: u8 = 0x08;
const TAG_TELEMETRY_PULL: u8 = 0x09;
const TAG_TELEMETRY_SNAPSHOT: u8 = 0x0a;
/// Tag of [`WireMsg::MixLocal`] — `pub(crate)` so receive loops can
/// route these frames to the zero-copy [`MixLocalRef`] decoder after a
/// [`peek_tag`] instead of materializing an owned [`WireMsg`].
pub(crate) const TAG_MIX_LOCAL: u8 = 0x0b;

// Trace-event subtags inside a telemetry snapshot, in
// `TraceEvent` declaration order.
const EV_COMPUTE_BEGIN: u8 = 0;
const EV_COMPUTE_END: u8 = 1;
const EV_LINK_BEGIN: u8 = 2;
const EV_LINK_END: u8 = 3;
const EV_MIX_APPLIED: u8 = 4;
const EV_ROUND_BARRIER: u8 = 5;
const EV_FRAME_SENT: u8 = 6;
const EV_FRAME_RECEIVED: u8 = 7;
const EV_RECONNECT: u8 = 8;
const EV_STALE_EXCHANGE: u8 = 9;

/// Minimum encoded size of one telemetry trace record: subtag byte +
/// `vt` + `wall_ns` (the allocation guard for record counts).
const MIN_RECORD_BYTES: usize = 17;

/// Typed decode/transport failure. Every malformed input maps to one of
/// these — the wire layer never panics on bytes it did not produce.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The input ended before the declared or required length.
    Truncated { needed: usize, got: usize },
    /// The version byte is not [`WIRE_VERSION`].
    BadVersion(u8),
    /// Unknown message tag byte.
    BadTag(u8),
    /// A length prefix (or an interior count scaled to bytes) exceeds
    /// [`MAX_FRAME_BYTES`] or overflows `usize`.
    FrameTooLarge(u64),
    /// Interior structure disagrees with itself (e.g. staging bytes not
    /// a multiple of the row width, or trailing bytes after the payload).
    Inconsistent(String),
    /// Transport-level I/O failure (TCP reset, closed channel, ...).
    Io(String),
    /// A read or write exceeded the transport's configured deadline —
    /// the peer is silent or gone, distinct from a hard I/O failure so
    /// lifecycle code can choose to reconnect instead of abort.
    TimedOut,
    /// The peer's [`WireMsg::Hello`] carried an application protocol
    /// version other than [`PROTO_VERSION`].
    ProtocolMismatch { got: u32, supported: u32 },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, got } => {
                write!(f, "wire: truncated frame (needed {needed} bytes, got {got})")
            }
            WireError::BadVersion(v) => {
                write!(f, "wire: unsupported version {v} (expected {WIRE_VERSION})")
            }
            WireError::BadTag(t) => write!(f, "wire: unknown message tag {t:#04x}"),
            WireError::FrameTooLarge(n) => {
                write!(f, "wire: length {n} exceeds the {MAX_FRAME_BYTES}-byte frame bound")
            }
            WireError::Inconsistent(msg) => write!(f, "wire: inconsistent frame: {msg}"),
            WireError::Io(msg) => write!(f, "wire: transport I/O: {msg}"),
            WireError::TimedOut => write!(f, "wire: peer deadline exceeded (timed out)"),
            WireError::ProtocolMismatch { got, supported } => {
                write!(f, "wire: protocol version {got} not supported (coordinator speaks {supported})")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// One routed gossip message's metadata on the wire: the wire twin of
/// the actor mode's `MsgMeta` (owner slot within the shard, matching
/// index, canonical `u < v` edge). The peer row itself lives at the
/// message's index in the enclosing [`WireMsg::Mix`] staging buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireMeta {
    pub slot: u32,
    pub matching: u32,
    pub u: u32,
    pub v: u32,
}

/// The coordinator ↔ shard protocol. `Hello`/`Step`/`Mix`/`Shutdown`
/// travel coordinator-bound or shard-bound as noted; `States` is the
/// single reply shape (one per command, post-phase iterates in slot
/// order).
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Shard → coordinator, once per connection: identifies which shard
    /// this link belongs to (TCP accept order is nondeterministic) and
    /// the application protocol version it speaks ([`PROTO_VERSION`]).
    Hello { shard: u32, proto: u32 },
    /// Coordinator → shard: run one local SGD step on every owned
    /// worker at learning rate `lr`.
    Step { lr: f64 },
    /// Coordinator → shard: apply the gossip mix of iteration `k`.
    /// `msgs` are sorted by owner slot (global (activation, edge) order
    /// within a slot); message `i`'s peer row is
    /// `staging[i*dim..(i+1)*dim]`.
    Mix { k: u64, alpha: f64, dim: u32, msgs: Vec<WireMeta>, staging: Vec<f64> },
    /// Coordinator → shard: the gossip mix of iteration `k` with
    /// **intra-shard rows suppressed**. Metadata still covers every
    /// routed message, but the staging payload carries only the rows of
    /// *remote* peers (peers owned by another shard), in message order.
    /// A peer is local iff `peer % shards == shard` under the shared
    /// round-robin partition; the receiving shard resolves suppressed
    /// rows from a pre-mix snapshot of its own post-step segment — the
    /// exact values the coordinator would have staged — so results stay
    /// bit-for-bit while the frames physically shrink.
    MixLocal {
        k: u64,
        alpha: f64,
        shard: u32,
        shards: u32,
        dim: u32,
        msgs: Vec<WireMeta>,
        staging: Vec<f64>,
    },
    /// Shard → coordinator: the post-phase iterates of every owned
    /// worker, flat `rows × dim` in slot order.
    States { shard: u32, dim: u32, states: Vec<f64> },
    /// Coordinator → shard: the run is over; close the link.
    Shutdown,
    /// Coordinator → standalone node, first frame of every connection:
    /// which shard of how many this node is, plus the full experiment
    /// spec as JSON so the node can rebuild the identical workload and
    /// initial iterates (the bit-for-bit contract needs the node to
    /// derive everything from the same seeds).
    Assign { shard: u32, shards: u32, spec_json: String },
    /// Coordinator → node, instead of proceeding past a `Hello` whose
    /// `proto` it cannot speak: echoes the supported version so the
    /// node can log a useful error before the link closes.
    VersionReject { supported: u32 },
    /// Node → coordinator, right after `Hello`: the node's cumulative
    /// progress (`done` commands executed, shard-side step/fold work
    /// counters) and its current iterates, so a coordinator can resume
    /// a rejoining shard from the last fully-acked round instead of
    /// restarting the run.
    Resume { done: u64, steps: u64, folded: u64, dim: u32, states: Vec<f64> },
    /// Puller → daemon: ask for a telemetry snapshot. Never a phase
    /// command — it does not advance the daemon's `done` counter and
    /// never enters the coordinator's pending/replay machinery.
    /// `drain: true` (coordinator harvest) empties the daemon's trace
    /// ring into the reply; `drain: false` (`matcha status`) leaves the
    /// ring intact and ships health + metrics only.
    TelemetryPull { drain: bool },
    /// Daemon → puller: session health, the cumulative metric registry
    /// and (on draining pulls) the ring's trace records.
    TelemetrySnapshot { telemetry: NodeTelemetry },
}

impl WireMsg {
    /// Append the full frame (length prefix included) to `out`. `out` is
    /// not cleared — callers recycle one buffer per link and clear it
    /// themselves, so the steady state allocates nothing per frame.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let at = out.len();
        out.extend_from_slice(&[0, 0, 0, 0]); // length prefix backpatched below
        out.push(WIRE_VERSION);
        match self {
            WireMsg::Hello { shard, proto } => {
                out.push(TAG_HELLO);
                put_u32(out, *shard);
                put_u32(out, *proto);
            }
            WireMsg::Step { lr } => {
                out.push(TAG_STEP);
                put_f64(out, *lr);
            }
            WireMsg::Mix { k, alpha, dim, msgs, staging } => {
                out.push(TAG_MIX);
                put_u64(out, *k);
                put_f64(out, *alpha);
                put_u32(out, *dim);
                put_u32(out, u32::try_from(msgs.len()).expect("mix message count fits u32"));
                for m in msgs {
                    put_u32(out, m.slot);
                    put_u32(out, m.matching);
                    put_u32(out, m.u);
                    put_u32(out, m.v);
                }
                debug_assert_eq!(staging.len(), msgs.len() * *dim as usize);
                for &x in staging {
                    put_f64(out, x);
                }
            }
            WireMsg::MixLocal { k, alpha, shard, shards, dim, msgs, staging } => {
                out.push(TAG_MIX_LOCAL);
                put_u64(out, *k);
                put_f64(out, *alpha);
                put_u32(out, *shard);
                put_u32(out, *shards);
                put_u32(out, *dim);
                put_u32(out, u32::try_from(msgs.len()).expect("mix message count fits u32"));
                for m in msgs {
                    put_u32(out, m.slot);
                    put_u32(out, m.matching);
                    put_u32(out, m.u);
                    put_u32(out, m.v);
                }
                debug_assert_eq!(
                    staging.len(),
                    msgs.iter().filter(|m| !peer_is_local(*shard, *shards, m)).count()
                        * *dim as usize,
                    "staging must hold exactly the remote-peer rows"
                );
                for &x in staging {
                    put_f64(out, x);
                }
            }
            WireMsg::States { shard, dim, states } => {
                out.push(TAG_STATES);
                put_u32(out, *shard);
                put_u32(out, *dim);
                put_u32(out, u32::try_from(states.len()).expect("state length fits u32"));
                for &x in states {
                    put_f64(out, x);
                }
            }
            WireMsg::Shutdown => out.push(TAG_SHUTDOWN),
            WireMsg::Assign { shard, shards, spec_json } => {
                out.push(TAG_ASSIGN);
                put_u32(out, *shard);
                put_u32(out, *shards);
                put_str(out, spec_json);
            }
            WireMsg::VersionReject { supported } => {
                out.push(TAG_VERSION_REJECT);
                put_u32(out, *supported);
            }
            WireMsg::Resume { done, steps, folded, dim, states } => {
                out.push(TAG_RESUME);
                put_u64(out, *done);
                put_u64(out, *steps);
                put_u64(out, *folded);
                put_u32(out, *dim);
                put_u32(out, u32::try_from(states.len()).expect("state length fits u32"));
                for &x in states {
                    put_f64(out, x);
                }
            }
            WireMsg::TelemetryPull { drain } => {
                out.push(TAG_TELEMETRY_PULL);
                out.push(u8::from(*drain));
            }
            WireMsg::TelemetrySnapshot { telemetry } => {
                out.push(TAG_TELEMETRY_SNAPSHOT);
                put_telemetry(out, telemetry);
            }
        }
        let body = out.len() - at - FRAME_HEADER_BYTES;
        assert!(body <= MAX_FRAME_BYTES, "frame body {body} exceeds MAX_FRAME_BYTES");
        out[at..at + 4].copy_from_slice(&(body as u32).to_le_bytes());
    }

    /// Decode one frame **body** (everything after the length prefix —
    /// transports strip and validate the prefix via [`frame_len`]).
    /// Total: every malformed input returns a [`WireError`].
    pub fn decode(body: &[u8]) -> Result<WireMsg, WireError> {
        let mut r = Reader { buf: body, at: 0 };
        let version = r.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion(version));
        }
        let tag = r.u8()?;
        let msg = match tag {
            TAG_HELLO => WireMsg::Hello { shard: r.u32()?, proto: r.u32()? },
            TAG_STEP => WireMsg::Step { lr: r.f64()? },
            TAG_MIX => {
                let k = r.u64()?;
                let alpha = r.f64()?;
                let dim = r.u32()?;
                let count = r.u32()? as usize;
                // Guard the count before allocating or looping: the
                // metadata alone must fit the remaining bytes.
                r.need(count, 16)?;
                let mut msgs = Vec::with_capacity(count);
                for _ in 0..count {
                    msgs.push(WireMeta {
                        slot: r.u32()?,
                        matching: r.u32()?,
                        u: r.u32()?,
                        v: r.u32()?,
                    });
                }
                let rows = count
                    .checked_mul(dim as usize)
                    .ok_or(WireError::FrameTooLarge(u64::MAX))?;
                r.need(rows, 8)?;
                let mut staging = Vec::with_capacity(rows);
                for _ in 0..rows {
                    staging.push(r.f64()?);
                }
                WireMsg::Mix { k, alpha, dim, msgs, staging }
            }
            TAG_MIX_LOCAL => {
                let k = r.u64()?;
                let alpha = r.f64()?;
                let shard = r.u32()?;
                let shards = r.u32()?;
                let dim = r.u32()?;
                if shards == 0 || shard >= shards {
                    return Err(WireError::Inconsistent(format!(
                        "mix-local addressed to shard {shard} of {shards}"
                    )));
                }
                let count = r.u32()? as usize;
                r.need(count, 16)?;
                let mut msgs = Vec::with_capacity(count);
                let mut remote = 0usize;
                for _ in 0..count {
                    let m = WireMeta {
                        slot: r.u32()?,
                        matching: r.u32()?,
                        u: r.u32()?,
                        v: r.u32()?,
                    };
                    if !peer_is_local(shard, shards, &m) {
                        remote += 1;
                    }
                    msgs.push(m);
                }
                let rows = remote
                    .checked_mul(dim as usize)
                    .ok_or(WireError::FrameTooLarge(u64::MAX))?;
                r.need(rows, 8)?;
                let mut staging = Vec::with_capacity(rows);
                for _ in 0..rows {
                    staging.push(r.f64()?);
                }
                WireMsg::MixLocal { k, alpha, shard, shards, dim, msgs, staging }
            }
            TAG_STATES => {
                let shard = r.u32()?;
                let dim = r.u32()?;
                let count = r.u32()? as usize;
                if dim > 0 && count % dim as usize != 0 {
                    return Err(WireError::Inconsistent(format!(
                        "state length {count} is not a multiple of dim {dim}"
                    )));
                }
                r.need(count, 8)?;
                let mut states = Vec::with_capacity(count);
                for _ in 0..count {
                    states.push(r.f64()?);
                }
                WireMsg::States { shard, dim, states }
            }
            TAG_SHUTDOWN => WireMsg::Shutdown,
            TAG_ASSIGN => {
                let shard = r.u32()?;
                let shards = r.u32()?;
                let spec_json = r.string()?;
                WireMsg::Assign { shard, shards, spec_json }
            }
            TAG_VERSION_REJECT => WireMsg::VersionReject { supported: r.u32()? },
            TAG_RESUME => {
                let done = r.u64()?;
                let steps = r.u64()?;
                let folded = r.u64()?;
                let dim = r.u32()?;
                let count = r.u32()? as usize;
                if dim > 0 && count % dim as usize != 0 {
                    return Err(WireError::Inconsistent(format!(
                        "resume state length {count} is not a multiple of dim {dim}"
                    )));
                }
                r.need(count, 8)?;
                let mut states = Vec::with_capacity(count);
                for _ in 0..count {
                    states.push(r.f64()?);
                }
                WireMsg::Resume { done, steps, folded, dim, states }
            }
            TAG_TELEMETRY_PULL => WireMsg::TelemetryPull { drain: r.u8()? != 0 },
            TAG_TELEMETRY_SNAPSHOT => {
                WireMsg::TelemetrySnapshot { telemetry: read_telemetry(&mut r)? }
            }
            other => return Err(WireError::BadTag(other)),
        };
        if r.at != body.len() {
            return Err(WireError::Inconsistent(format!(
                "{} trailing bytes after the payload",
                body.len() - r.at
            )));
        }
        Ok(msg)
    }
}

/// Validate a peer `Hello`'s application protocol version against
/// [`PROTO_VERSION`]. Callers that hold the link (the coordinator, the
/// shard-node daemon) send a [`WireMsg::VersionReject`] echoing the
/// supported version before surfacing the error.
pub fn check_proto(proto: u32) -> Result<(), WireError> {
    if proto == PROTO_VERSION {
        Ok(())
    } else {
        Err(WireError::ProtocolMismatch { got: proto, supported: PROTO_VERSION })
    }
}

/// Validate a frame's length prefix and return the body length. Shared
/// by every transport so the [`MAX_FRAME_BYTES`] bound is enforced
/// before a single body byte is read or allocated.
pub fn frame_len(header: [u8; FRAME_HEADER_BYTES]) -> Result<usize, WireError> {
    let len = u32::from_le_bytes(header) as u64;
    if len as usize > MAX_FRAME_BYTES {
        return Err(WireError::FrameTooLarge(len));
    }
    Ok(len as usize)
}

/// Validate a frame body's version byte and return its tag without
/// decoding the payload. Receive loops use this to route mix frames to
/// the zero-copy [`MixLocalRef`] decoder while everything else takes
/// the owned [`WireMsg::decode`] path.
pub fn peek_tag(body: &[u8]) -> Result<u8, WireError> {
    match body {
        [] => Err(WireError::Truncated { needed: 1, got: 0 }),
        [v, ..] if *v != WIRE_VERSION => Err(WireError::BadVersion(*v)),
        [_] => Err(WireError::Truncated { needed: 2, got: 1 }),
        [_, tag, ..] => Ok(*tag),
    }
}

/// Is a routed message's *peer* owned by the destination shard itself
/// (and therefore suppressed from a [`WireMsg::MixLocal`] staging
/// payload)? Pure function of the metadata under the shared round-robin
/// partition: the `slot`-th worker of `shard` is `shard + slot·shards`,
/// its peer is the other endpoint of `(u, v)`, and a worker `w` lives on
/// shard `w % shards`. All math in `u64` so hostile metadata cannot
/// overflow; encode, decode and the streaming view all call this one
/// definition, so they can never disagree about which rows are present.
pub(crate) fn peer_is_local(shard: u32, shards: u32, m: &WireMeta) -> bool {
    debug_assert!(shards > 0);
    let w = shard as u64 + m.slot as u64 * shards as u64;
    let peer = if w == m.u as u64 { m.v as u64 } else { m.u as u64 };
    peer % shards as u64 == shard as u64
}

/// Zero-copy view of a [`WireMsg::MixLocal`] frame body: the header is
/// parsed once, message metadata is read on the fly, and remote peer
/// rows are **borrowed** from the receive buffer as little-endian
/// `f64` bytes ([`crate::state::RowSource::Wire`]) — decoding a mix
/// frame allocates nothing and copies no row. [`MixLocalRef::decode`]
/// performs the same total validation as [`WireMsg::decode`] on the
/// same bytes (truncation, counts, trailing garbage), so iteration is
/// infallible afterwards.
pub struct MixLocalRef<'a> {
    /// Iteration index of the mix.
    pub k: u64,
    /// Mixing step size α.
    pub alpha: f64,
    /// Destination shard (validated `< shards`).
    pub shard: u32,
    /// Total shard count of the round-robin partition.
    pub shards: u32,
    /// Row width in elements.
    pub dim: u32,
    count: usize,
    meta: &'a [u8],
    staging: &'a [u8],
}

impl<'a> MixLocalRef<'a> {
    /// Decode a frame **body** (after the length prefix) as a borrowed
    /// view. Returns [`WireError::BadTag`] for non-`MixLocal` frames —
    /// callers route on [`peek_tag`] first.
    pub fn decode(body: &'a [u8]) -> Result<MixLocalRef<'a>, WireError> {
        let mut r = Reader { buf: body, at: 0 };
        let version = r.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion(version));
        }
        let tag = r.u8()?;
        if tag != TAG_MIX_LOCAL {
            return Err(WireError::BadTag(tag));
        }
        let k = r.u64()?;
        let alpha = r.f64()?;
        let shard = r.u32()?;
        let shards = r.u32()?;
        let dim = r.u32()?;
        if shards == 0 || shard >= shards {
            return Err(WireError::Inconsistent(format!(
                "mix-local addressed to shard {shard} of {shards}"
            )));
        }
        let count = r.u32()? as usize;
        r.need(count, 16)?;
        let meta_at = r.at;
        let meta = r.take(count * 16)?;
        let mut remote = 0usize;
        for i in 0..count {
            if !peer_is_local(shard, shards, &meta_entry(meta, i)) {
                remote += 1;
            }
        }
        let rows = remote
            .checked_mul(dim as usize)
            .ok_or(WireError::FrameTooLarge(u64::MAX))?;
        r.need(rows, 8)?;
        let staging = r.take(rows * 8)?;
        if r.at != body.len() {
            return Err(WireError::Inconsistent(format!(
                "{} trailing bytes after the payload",
                body.len() - r.at
            )));
        }
        debug_assert_eq!(meta_at + count * 16 + rows * 8, body.len());
        Ok(MixLocalRef { k, alpha, shard, shards, dim, count, meta, staging })
    }

    /// Number of routed messages (local and remote) in the frame.
    pub fn msg_count(&self) -> usize {
        self.count
    }

    /// Number of suppressed (local-peer) messages — rows that did not
    /// travel in the staging payload.
    pub fn suppressed(&self) -> usize {
        (0..self.count)
            .filter(|&i| peer_is_local(self.shard, self.shards, &meta_entry(self.meta, i)))
            .count()
    }

    /// Iterate `(meta, peer_row_bytes)` in message order. `None` marks a
    /// suppressed local peer (resolve it from the shard's own pre-mix
    /// segment snapshot); `Some(bytes)` is the remote peer's row,
    /// `8 × dim` little-endian bytes borrowed from the frame.
    pub fn msgs(&self) -> MixLocalMsgs<'a> {
        MixLocalMsgs {
            meta: self.meta,
            staging: self.staging,
            shard: self.shard,
            shards: self.shards,
            row_bytes: self.dim as usize * 8,
            count: self.count,
            i: 0,
            at: 0,
        }
    }
}

/// Streaming message iterator of a [`MixLocalRef`] — see
/// [`MixLocalRef::msgs`].
pub struct MixLocalMsgs<'a> {
    meta: &'a [u8],
    staging: &'a [u8],
    shard: u32,
    shards: u32,
    row_bytes: usize,
    count: usize,
    i: usize,
    at: usize,
}

impl<'a> Iterator for MixLocalMsgs<'a> {
    type Item = (WireMeta, Option<&'a [u8]>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.i == self.count {
            return None;
        }
        let m = meta_entry(self.meta, self.i);
        self.i += 1;
        if peer_is_local(self.shard, self.shards, &m) {
            Some((m, None))
        } else {
            let row = &self.staging[self.at..self.at + self.row_bytes];
            self.at += self.row_bytes;
            Some((m, Some(row)))
        }
    }
}

/// The `i`-th 16-byte metadata entry of a mix frame's meta section.
fn meta_entry(meta: &[u8], i: usize) -> WireMeta {
    let b = &meta[i * 16..i * 16 + 16];
    let f = |o: usize| u32::from_le_bytes(b[o..o + 4].try_into().expect("4-byte field"));
    WireMeta { slot: f(0), matching: f(4), u: f(8), v: f(12) }
}

// -- little-endian primitives -----------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Strings travel as `[len: u64 LE][UTF-8 bytes]` — used only for the
/// spec JSON in [`WireMsg::Assign`], which is small and infrequent.
fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

// -- telemetry payload ------------------------------------------------
//
// Layout: shard u32; five health u64s (rounds_done, reconnects,
// uptime_ms, ring_dropped, wall_now_ns); the fixed-slot registry
// (NUM_COUNTERS u64s in `Counter::ALL` order, then NUM_HISTS
// histograms as count u64, sum/min/max f64, HIST_BUCKETS u64s); an
// observatory presence u8 followed (when 1) by rounds u64, drift f64,
// contraction f64, windows u64; then a u32 record count and each
// record as [subtag u8][fields][vt f64][wall_ns u64]. Everything is
// fixed-width except the record list.

fn put_telemetry(out: &mut Vec<u8>, t: &NodeTelemetry) {
    put_u32(out, t.shard);
    put_u64(out, t.rounds_done);
    put_u64(out, t.reconnects);
    put_u64(out, t.uptime_ms);
    put_u64(out, t.ring_dropped);
    put_u64(out, t.wall_now_ns);
    for c in Counter::ALL {
        put_u64(out, t.registry.counter(c));
    }
    for h in Hist::ALL {
        let hist = t.registry.hist(h);
        put_u64(out, hist.count);
        put_f64(out, hist.sum);
        put_f64(out, hist.min);
        put_f64(out, hist.max);
        for &b in hist.buckets() {
            put_u64(out, b);
        }
    }
    match &t.observatory {
        Some(obs) => {
            out.push(1);
            put_u64(out, obs.rounds);
            put_f64(out, obs.drift_score);
            put_f64(out, obs.contraction_rate);
            put_u64(out, obs.windows);
        }
        None => out.push(0),
    }
    put_u32(out, u32::try_from(t.records.len()).expect("telemetry record count fits u32"));
    for rec in &t.records {
        put_record(out, rec);
    }
}

fn put_record(out: &mut Vec<u8>, rec: &TraceRecord) {
    match rec.ev {
        TraceEvent::ComputeBegin { worker, k } => {
            out.push(EV_COMPUTE_BEGIN);
            put_u64(out, worker as u64);
            put_u64(out, k as u64);
        }
        TraceEvent::ComputeEnd { worker, k } => {
            out.push(EV_COMPUTE_END);
            put_u64(out, worker as u64);
            put_u64(out, k as u64);
        }
        TraceEvent::LinkBegin { matching, u, v, k } => {
            out.push(EV_LINK_BEGIN);
            put_u64(out, matching as u64);
            put_u64(out, u as u64);
            put_u64(out, v as u64);
            put_u64(out, k as u64);
        }
        TraceEvent::LinkEnd { matching, u, v, k, failed } => {
            out.push(EV_LINK_END);
            put_u64(out, matching as u64);
            put_u64(out, u as u64);
            put_u64(out, v as u64);
            put_u64(out, k as u64);
            out.push(u8::from(failed));
        }
        TraceEvent::MixApplied { k, activated } => {
            out.push(EV_MIX_APPLIED);
            put_u64(out, k as u64);
            put_u64(out, activated as u64);
        }
        TraceEvent::RoundBarrier { k } => {
            out.push(EV_ROUND_BARRIER);
            put_u64(out, k as u64);
        }
        TraceEvent::FrameSent { link, bytes } => {
            out.push(EV_FRAME_SENT);
            put_u64(out, link as u64);
            put_u64(out, bytes);
        }
        TraceEvent::FrameReceived { link, bytes } => {
            out.push(EV_FRAME_RECEIVED);
            put_u64(out, link as u64);
            put_u64(out, bytes);
        }
        TraceEvent::Reconnect { link, resumed } => {
            out.push(EV_RECONNECT);
            put_u64(out, link as u64);
            put_u64(out, resumed);
        }
        TraceEvent::StaleExchange { worker, peer, staleness, k } => {
            out.push(EV_STALE_EXCHANGE);
            put_u64(out, worker as u64);
            put_u64(out, peer as u64);
            put_u64(out, staleness as u64);
            put_u64(out, k as u64);
        }
    }
    put_f64(out, rec.vt);
    put_u64(out, rec.wall_ns);
}

fn read_telemetry(r: &mut Reader<'_>) -> Result<NodeTelemetry, WireError> {
    let shard = r.u32()?;
    let rounds_done = r.u64()?;
    let reconnects = r.u64()?;
    let uptime_ms = r.u64()?;
    let ring_dropped = r.u64()?;
    let wall_now_ns = r.u64()?;
    let mut counters = [0u64; NUM_COUNTERS];
    for c in counters.iter_mut() {
        *c = r.u64()?;
    }
    let mut hists = [Histogram::default(); NUM_HISTS];
    for h in hists.iter_mut() {
        let count = r.u64()?;
        let sum = r.f64()?;
        let min = r.f64()?;
        let max = r.f64()?;
        let mut buckets = [0u64; HIST_BUCKETS];
        for b in buckets.iter_mut() {
            *b = r.u64()?;
        }
        *h = Histogram::from_parts(count, sum, min, max, buckets);
    }
    let registry = MetricsRegistry::from_parts(counters, hists);
    let observatory = if r.u8()? != 0 {
        Some(ObservatoryHealth {
            rounds: r.u64()?,
            drift_score: r.f64()?,
            contraction_rate: r.f64()?,
            windows: r.u64()?,
        })
    } else {
        None
    };
    let count = r.u32()? as usize;
    r.need(count, MIN_RECORD_BYTES)?;
    let mut records = Vec::with_capacity(count);
    for _ in 0..count {
        records.push(read_record(r)?);
    }
    Ok(NodeTelemetry {
        shard,
        rounds_done,
        reconnects,
        uptime_ms,
        ring_dropped,
        wall_now_ns,
        records,
        registry,
        observatory,
    })
}

fn read_record(r: &mut Reader<'_>) -> Result<TraceRecord, WireError> {
    let subtag = r.u8()?;
    let ev = match subtag {
        EV_COMPUTE_BEGIN => {
            TraceEvent::ComputeBegin { worker: r.u64()? as usize, k: r.u64()? as usize }
        }
        EV_COMPUTE_END => {
            TraceEvent::ComputeEnd { worker: r.u64()? as usize, k: r.u64()? as usize }
        }
        EV_LINK_BEGIN => TraceEvent::LinkBegin {
            matching: r.u64()? as usize,
            u: r.u64()? as usize,
            v: r.u64()? as usize,
            k: r.u64()? as usize,
        },
        EV_LINK_END => TraceEvent::LinkEnd {
            matching: r.u64()? as usize,
            u: r.u64()? as usize,
            v: r.u64()? as usize,
            k: r.u64()? as usize,
            failed: r.u8()? != 0,
        },
        EV_MIX_APPLIED => {
            TraceEvent::MixApplied { k: r.u64()? as usize, activated: r.u64()? as usize }
        }
        EV_ROUND_BARRIER => TraceEvent::RoundBarrier { k: r.u64()? as usize },
        EV_FRAME_SENT => TraceEvent::FrameSent { link: r.u64()? as usize, bytes: r.u64()? },
        EV_FRAME_RECEIVED => {
            TraceEvent::FrameReceived { link: r.u64()? as usize, bytes: r.u64()? }
        }
        EV_RECONNECT => TraceEvent::Reconnect { link: r.u64()? as usize, resumed: r.u64()? },
        EV_STALE_EXCHANGE => TraceEvent::StaleExchange {
            worker: r.u64()? as usize,
            peer: r.u64()? as usize,
            staleness: r.u64()? as usize,
            k: r.u64()? as usize,
        },
        other => {
            return Err(WireError::Inconsistent(format!(
                "unknown telemetry event subtag {other:#04x}"
            )))
        }
    };
    Ok(TraceRecord { ev, vt: r.f64()?, wall_ns: r.u64()? })
}

/// Bounds-checked cursor over a frame body.
struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8], WireError> {
        if self.at + n > self.buf.len() {
            return Err(WireError::Truncated { needed: self.at + n, got: self.buf.len() });
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    /// Require `count` items of `width` bytes each to remain, with
    /// overflow-safe arithmetic (the length-prefix overflow guard for
    /// interior counts).
    fn need(&self, count: usize, width: usize) -> Result<(), WireError> {
        let bytes = count
            .checked_mul(width)
            .ok_or(WireError::FrameTooLarge(u64::MAX))?;
        let end = self
            .at
            .checked_add(bytes)
            .ok_or(WireError::FrameTooLarge(u64::MAX))?;
        if end > self.buf.len() {
            return Err(WireError::Truncated { needed: end, got: self.buf.len() });
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn string(&mut self) -> Result<String, WireError> {
        let len = self.u64()?;
        let len = usize::try_from(len).map_err(|_| WireError::FrameTooLarge(len))?;
        if len > MAX_FRAME_BYTES {
            return Err(WireError::FrameTooLarge(len as u64));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Inconsistent("string payload is not UTF-8".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn roundtrip(msg: &WireMsg) -> WireMsg {
        let mut frame = Vec::new();
        msg.encode(&mut frame);
        let len = frame_len(frame[..4].try_into().unwrap()).expect("valid prefix");
        assert_eq!(len, frame.len() - FRAME_HEADER_BYTES, "prefix must cover the body");
        WireMsg::decode(&frame[FRAME_HEADER_BYTES..]).expect("decode of own encoding")
    }

    /// A structurally valid random `MixLocal`: every meta names its
    /// owning worker (`shard + slot·shards`) as one endpoint, and the
    /// staging payload holds exactly the remote-peer rows.
    fn random_mix_local(rng: &mut Rng) -> WireMsg {
        let shards = (rng.next_u64() % 3) as u32 + 1;
        let shard = (rng.next_u64() % shards as u64) as u32;
        let dim = (rng.next_u64() % 6) as usize + 1;
        let n = (rng.next_u64() % 9) as usize;
        let mut msgs = Vec::with_capacity(n);
        let mut staging = Vec::new();
        for _ in 0..n {
            let slot = (rng.next_u64() % 5) as u32;
            let w = shard + slot * shards;
            let mut peer = (rng.next_u64() % 16) as u32;
            if peer == w {
                peer += 1;
            }
            let m = WireMeta {
                slot,
                matching: (rng.next_u64() % 8) as u32,
                u: w.min(peer),
                v: w.max(peer),
            };
            if !peer_is_local(shard, shards, &m) {
                staging.extend((0..dim).map(|_| rng.normal()));
            }
            msgs.push(m);
        }
        WireMsg::MixLocal {
            k: rng.next_u64() % (1 << 40),
            alpha: rng.normal(),
            shard,
            shards,
            dim: dim as u32,
            msgs,
            staging,
        }
    }

    fn random_msg(rng: &mut Rng) -> WireMsg {
        match rng.next_u64() % 11 {
            0 => WireMsg::Hello {
                shard: (rng.next_u64() % 1000) as u32,
                proto: (rng.next_u64() % 4) as u32,
            },
            1 => WireMsg::Step { lr: rng.normal() },
            2 => {
                let dim = (rng.next_u64() % 7) as usize + 1;
                let n = (rng.next_u64() % 9) as usize;
                let msgs: Vec<WireMeta> = (0..n)
                    .map(|_| WireMeta {
                        slot: (rng.next_u64() % 64) as u32,
                        matching: (rng.next_u64() % 16) as u32,
                        u: (rng.next_u64() % 128) as u32,
                        v: (rng.next_u64() % 128) as u32,
                    })
                    .collect();
                let staging: Vec<f64> = (0..n * dim).map(|_| rng.normal()).collect();
                WireMsg::Mix {
                    k: rng.next_u64() % (1 << 40),
                    alpha: rng.normal(),
                    dim: dim as u32,
                    msgs,
                    staging,
                }
            }
            3 => {
                let dim = (rng.next_u64() % 5) as usize + 1;
                let rows = (rng.next_u64() % 6) as usize;
                WireMsg::States {
                    shard: (rng.next_u64() % 32) as u32,
                    dim: dim as u32,
                    states: (0..rows * dim).map(|_| rng.normal()).collect(),
                }
            }
            4 => {
                let len = (rng.next_u64() % 48) as usize;
                let spec_json: String =
                    (0..len).map(|_| (b'a' + (rng.next_u64() % 26) as u8) as char).collect();
                WireMsg::Assign {
                    shard: (rng.next_u64() % 32) as u32,
                    shards: (rng.next_u64() % 32) as u32 + 1,
                    spec_json,
                }
            }
            5 => WireMsg::VersionReject { supported: (rng.next_u64() % 8) as u32 },
            6 => {
                let dim = (rng.next_u64() % 5) as usize + 1;
                let rows = (rng.next_u64() % 6) as usize;
                WireMsg::Resume {
                    done: rng.next_u64() % (1 << 40),
                    steps: rng.next_u64() % (1 << 40),
                    folded: rng.next_u64() % (1 << 40),
                    dim: dim as u32,
                    states: (0..rows * dim).map(|_| rng.normal()).collect(),
                }
            }
            7 => WireMsg::TelemetryPull { drain: rng.next_u64() % 2 == 0 },
            8 => WireMsg::TelemetrySnapshot { telemetry: random_telemetry(rng) },
            9 => random_mix_local(rng),
            _ => WireMsg::Shutdown,
        }
    }

    fn random_record(rng: &mut Rng) -> TraceRecord {
        let w = (rng.next_u64() % 64) as usize;
        let k = (rng.next_u64() % 1000) as usize;
        let ev = match rng.next_u64() % 10 {
            0 => TraceEvent::ComputeBegin { worker: w, k },
            1 => TraceEvent::ComputeEnd { worker: w, k },
            2 => TraceEvent::LinkBegin { matching: w % 8, u: w, v: w + 1, k },
            3 => TraceEvent::LinkEnd {
                matching: w % 8,
                u: w,
                v: w + 1,
                k,
                failed: rng.next_u64() % 2 == 0,
            },
            4 => TraceEvent::MixApplied { k, activated: w % 4 },
            5 => TraceEvent::RoundBarrier { k },
            6 => TraceEvent::FrameSent { link: w % 4, bytes: rng.next_u64() % (1 << 32) },
            7 => TraceEvent::FrameReceived { link: w % 4, bytes: rng.next_u64() % (1 << 32) },
            8 => TraceEvent::Reconnect { link: w % 4, resumed: rng.next_u64() % 64 },
            _ => TraceEvent::StaleExchange { worker: w, peer: w + 1, staleness: k % 7, k },
        };
        TraceRecord { ev, vt: rng.normal(), wall_ns: rng.next_u64() % (1 << 50) }
    }

    fn random_telemetry(rng: &mut Rng) -> NodeTelemetry {
        let mut registry = MetricsRegistry::new();
        for c in Counter::ALL {
            registry.count(c, rng.next_u64() % 10_000);
        }
        for h in Hist::ALL {
            for _ in 0..rng.next_u64() % 5 {
                registry.observe(h, rng.normal().abs() * 10.0);
            }
        }
        let n = (rng.next_u64() % 12) as usize;
        let observatory = if rng.next_u64() % 2 == 0 {
            Some(ObservatoryHealth {
                rounds: rng.next_u64() % (1 << 40),
                drift_score: rng.normal().abs(),
                contraction_rate: rng.normal().abs(),
                windows: rng.next_u64() % 100,
            })
        } else {
            None
        };
        NodeTelemetry {
            shard: (rng.next_u64() % 64) as u32,
            rounds_done: rng.next_u64() % (1 << 40),
            reconnects: rng.next_u64() % 16,
            uptime_ms: rng.next_u64() % (1 << 40),
            ring_dropped: rng.next_u64() % 1000,
            wall_now_ns: rng.next_u64() % (1 << 50),
            records: (0..n).map(|_| random_record(rng)).collect(),
            registry,
            observatory,
        }
    }

    #[test]
    fn every_variant_roundtrips() {
        let msgs = [
            WireMsg::Hello { shard: 7, proto: PROTO_VERSION },
            WireMsg::Step { lr: 0.03 },
            WireMsg::Mix {
                k: 42,
                alpha: 0.25,
                dim: 2,
                msgs: vec![WireMeta { slot: 0, matching: 1, u: 0, v: 3 }],
                staging: vec![1.5, -2.5],
            },
            // Worker 3 (slot 1 of shard 1 in a 2-shard partition) hears
            // from remote peer 2 (row shipped) and local peer 5 (row
            // suppressed — only metadata travels).
            WireMsg::MixLocal {
                k: 17,
                alpha: 0.125,
                shard: 1,
                shards: 2,
                dim: 2,
                msgs: vec![
                    WireMeta { slot: 1, matching: 0, u: 2, v: 3 },
                    WireMeta { slot: 1, matching: 2, u: 3, v: 5 },
                ],
                staging: vec![0.75, -1.25],
            },
            // Degenerate single-shard case: every peer is local, so the
            // frame carries metadata only.
            WireMsg::MixLocal {
                k: 3,
                alpha: 0.5,
                shard: 0,
                shards: 1,
                dim: 4,
                msgs: vec![WireMeta { slot: 0, matching: 1, u: 0, v: 1 }],
                staging: vec![],
            },
            WireMsg::States { shard: 1, dim: 3, states: vec![0.0, f64::MIN, f64::MAX] },
            WireMsg::Shutdown,
            WireMsg::Assign {
                shard: 1,
                shards: 2,
                spec_json: "{\"graph\": \"ring:8\", \"α\": true}".into(),
            },
            WireMsg::VersionReject { supported: PROTO_VERSION },
            WireMsg::Resume {
                done: 120,
                steps: 480,
                folded: 96,
                dim: 2,
                states: vec![1.0, -0.5, 3.25, 0.0],
            },
            WireMsg::TelemetryPull { drain: true },
            WireMsg::TelemetryPull { drain: false },
            WireMsg::TelemetrySnapshot {
                telemetry: {
                    let mut registry = MetricsRegistry::new();
                    registry.count(Counter::ShardSteps, 360);
                    registry.count(Counter::ShardMsgsFolded, 90);
                    registry.observe(Hist::QueueDepth, 3.0);
                    NodeTelemetry {
                        shard: 1,
                        rounds_done: 60,
                        reconnects: 2,
                        uptime_ms: 1234,
                        ring_dropped: 7,
                        wall_now_ns: 987_654_321,
                        records: vec![
                            TraceRecord {
                                ev: TraceEvent::ComputeBegin { worker: 1, k: 5 },
                                vt: 5.0,
                                wall_ns: 100,
                            },
                            TraceRecord {
                                ev: TraceEvent::ComputeEnd { worker: 1, k: 5 },
                                vt: 6.0,
                                wall_ns: 250,
                            },
                            TraceRecord {
                                ev: TraceEvent::MixApplied { k: 5, activated: 2 },
                                vt: 6.0,
                                wall_ns: 300,
                            },
                        ],
                        registry,
                        observatory: Some(ObservatoryHealth {
                            rounds: 60,
                            drift_score: 0.75,
                            contraction_rate: 0.98,
                            windows: 3,
                        }),
                    }
                },
            },
            WireMsg::TelemetrySnapshot { telemetry: NodeTelemetry::default() },
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn telemetry_snapshot_covers_every_event_kind() {
        // One record per TraceEvent variant must survive the wire.
        let records = vec![
            TraceRecord { ev: TraceEvent::ComputeBegin { worker: 0, k: 1 }, vt: 0.5, wall_ns: 1 },
            TraceRecord { ev: TraceEvent::ComputeEnd { worker: 0, k: 1 }, vt: 1.5, wall_ns: 2 },
            TraceRecord {
                ev: TraceEvent::LinkBegin { matching: 2, u: 0, v: 3, k: 1 },
                vt: 1.5,
                wall_ns: 3,
            },
            TraceRecord {
                ev: TraceEvent::LinkEnd { matching: 2, u: 0, v: 3, k: 1, failed: true },
                vt: 2.0,
                wall_ns: 4,
            },
            TraceRecord { ev: TraceEvent::MixApplied { k: 1, activated: 3 }, vt: 2.0, wall_ns: 5 },
            TraceRecord { ev: TraceEvent::RoundBarrier { k: 1 }, vt: 2.0, wall_ns: 6 },
            TraceRecord { ev: TraceEvent::FrameSent { link: 1, bytes: 640 }, vt: 2.0, wall_ns: 7 },
            TraceRecord {
                ev: TraceEvent::FrameReceived { link: 1, bytes: 320 },
                vt: 2.0,
                wall_ns: 8,
            },
            TraceRecord { ev: TraceEvent::Reconnect { link: 1, resumed: 4 }, vt: 2.5, wall_ns: 9 },
            TraceRecord {
                ev: TraceEvent::StaleExchange { worker: 0, peer: 3, staleness: 2, k: 1 },
                vt: 3.0,
                wall_ns: 10,
            },
        ];
        let telemetry = NodeTelemetry { records, ..NodeTelemetry::default() };
        let msg = WireMsg::TelemetrySnapshot { telemetry };
        assert_eq!(roundtrip(&msg), msg);
    }

    #[test]
    fn telemetry_truncation_at_every_length_is_a_typed_error() {
        let mut rng = Rng::new(0x7e1e);
        let msg = WireMsg::TelemetrySnapshot { telemetry: random_telemetry(&mut rng) };
        let mut frame = Vec::new();
        msg.encode(&mut frame);
        let body = &frame[FRAME_HEADER_BYTES..];
        for cut in 0..body.len() {
            match WireMsg::decode(&body[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn telemetry_unknown_event_subtag_is_rejected() {
        let telemetry = NodeTelemetry {
            records: vec![TraceRecord {
                ev: TraceEvent::RoundBarrier { k: 0 },
                vt: 0.0,
                wall_ns: 0,
            }],
            ..NodeTelemetry::default()
        };
        let mut frame = Vec::new();
        WireMsg::TelemetrySnapshot { telemetry }.encode(&mut frame);
        let mut body = frame[FRAME_HEADER_BYTES..].to_vec();
        // The record list is the trailing 25 bytes; its first byte is
        // the subtag.
        let subtag_at = body.len() - 25;
        assert_eq!(body[subtag_at], 5, "round_barrier subtag moved — update this test");
        body[subtag_at] = 0xce;
        match WireMsg::decode(&body) {
            Err(WireError::Inconsistent(msg)) => assert!(msg.contains("subtag"), "{msg}"),
            other => panic!("expected Inconsistent, got {other:?}"),
        }
    }

    #[test]
    fn fuzz_roundtrip_randomized_messages() {
        let mut rng = Rng::new(0x173e);
        for _ in 0..500 {
            let msg = random_msg(&mut rng);
            assert_eq!(roundtrip(&msg), msg);
        }
    }

    #[test]
    fn f64_bit_patterns_survive_the_wire() {
        // Non-finite and denormal payloads must cross losslessly — the
        // cluster backend's bit-for-bit guarantee rides on this.
        let specials =
            [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0, f64::MIN_POSITIVE / 2.0];
        let msg = WireMsg::States { shard: 0, dim: 5, states: specials.to_vec() };
        let WireMsg::States { states, .. } = roundtrip(&msg) else {
            panic!("variant changed in flight")
        };
        for (a, b) in specials.iter().zip(&states) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn truncation_at_every_length_is_a_typed_error() {
        let msg = WireMsg::Mix {
            k: 3,
            alpha: 0.5,
            dim: 2,
            msgs: vec![
                WireMeta { slot: 0, matching: 0, u: 0, v: 1 },
                WireMeta { slot: 1, matching: 0, u: 0, v: 1 },
            ],
            staging: vec![1.0, 2.0, 3.0, 4.0],
        };
        let mut frame = Vec::new();
        msg.encode(&mut frame);
        let body = &frame[FRAME_HEADER_BYTES..];
        for cut in 0..body.len() {
            match WireMsg::decode(&body[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    /// A canonical two-shard MixLocal frame: slot 0 of shard 0 (worker
    /// 0) hears from remote worker 1 and local worker 2; slot 1 (worker
    /// 2) hears from remote worker 3. Two rows ship, one is suppressed.
    fn sample_mix_local() -> WireMsg {
        WireMsg::MixLocal {
            k: 9,
            alpha: 0.25,
            shard: 0,
            shards: 2,
            dim: 3,
            msgs: vec![
                WireMeta { slot: 0, matching: 0, u: 0, v: 1 },
                WireMeta { slot: 0, matching: 1, u: 0, v: 2 },
                WireMeta { slot: 1, matching: 0, u: 2, v: 3 },
            ],
            staging: vec![1.0, 2.0, 3.0, -1.0, -2.0, -3.0],
        }
    }

    #[test]
    fn mix_local_truncation_at_every_length_is_a_typed_error() {
        let msg = sample_mix_local();
        let mut frame = Vec::new();
        msg.encode(&mut frame);
        let body = &frame[FRAME_HEADER_BYTES..];
        for cut in 0..body.len() {
            match WireMsg::decode(&body[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => panic!("owned cut at {cut}: expected Truncated, got {other:?}"),
            }
            match MixLocalRef::decode(&body[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => panic!("borrowed cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn mix_local_rejects_bad_version_and_foreign_tags() {
        let mut frame = Vec::new();
        sample_mix_local().encode(&mut frame);
        let mut body = frame[FRAME_HEADER_BYTES..].to_vec();
        body[0] = WIRE_VERSION + 1;
        assert!(matches!(
            MixLocalRef::decode(&body),
            Err(WireError::BadVersion(v)) if v == WIRE_VERSION + 1
        ));
        assert!(matches!(peek_tag(&body), Err(WireError::BadVersion(_))));
        // A well-formed frame of a different type is a BadTag for the
        // borrowed decoder — receive loops must route on peek_tag.
        let mut step = Vec::new();
        WireMsg::Step { lr: 0.1 }.encode(&mut step);
        match MixLocalRef::decode(&step[FRAME_HEADER_BYTES..]) {
            Err(WireError::BadTag(t)) => assert_eq!(t, TAG_STEP),
            other => panic!("expected BadTag, got {other:?}"),
        }
        assert_eq!(peek_tag(&step[FRAME_HEADER_BYTES..]), Ok(TAG_STEP));
        assert!(matches!(peek_tag(&[]), Err(WireError::Truncated { .. })));
        assert!(matches!(peek_tag(&[WIRE_VERSION]), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn mix_local_bogus_shard_addressing_is_rejected() {
        // shard >= shards (and shards == 0) can never be a valid
        // round-robin address; both decoders refuse before touching the
        // payload.
        for (shard, shards) in [(2u32, 2u32), (5, 1), (0, 0)] {
            let mut body = vec![WIRE_VERSION, TAG_MIX_LOCAL];
            body.extend_from_slice(&7u64.to_le_bytes()); // k
            body.extend_from_slice(&0.5f64.to_le_bytes()); // alpha
            body.extend_from_slice(&shard.to_le_bytes());
            body.extend_from_slice(&shards.to_le_bytes());
            body.extend_from_slice(&3u32.to_le_bytes()); // dim
            body.extend_from_slice(&0u32.to_le_bytes()); // count
            for decode in [
                |b: &[u8]| WireMsg::decode(b).map(|_| ()),
                |b: &[u8]| MixLocalRef::decode(b).map(|_| ()),
            ] {
                match decode(&body) {
                    Err(WireError::Inconsistent(msg)) => {
                        assert!(msg.contains("shard"), "{msg}")
                    }
                    other => panic!("shard {shard}/{shards}: expected Inconsistent, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn mix_local_trailing_staging_is_rejected() {
        // One extra row beyond the remote count is trailing garbage —
        // the suppressed slots must not be "fillable" from the wire.
        let mut frame = Vec::new();
        sample_mix_local().encode(&mut frame);
        let mut body = frame[FRAME_HEADER_BYTES..].to_vec();
        body.extend_from_slice(&[0u8; 24]); // a fourth dim=3 row
        for result in
            [WireMsg::decode(&body).map(|_| ()), MixLocalRef::decode(&body).map(|_| ())]
        {
            match result {
                Err(WireError::Inconsistent(msg)) => assert!(msg.contains("trailing"), "{msg}"),
                other => panic!("expected Inconsistent, got {other:?}"),
            }
        }
    }

    #[test]
    fn mix_local_borrowed_view_matches_owned_decode() {
        let mut rng = Rng::new(0xabc1);
        for _ in 0..200 {
            let msg = random_mix_local(&mut rng);
            let mut frame = Vec::new();
            msg.encode(&mut frame);
            let body = &frame[FRAME_HEADER_BYTES..];
            assert_eq!(peek_tag(body), Ok(TAG_MIX_LOCAL));
            let WireMsg::MixLocal { k, alpha, shard, shards, dim, msgs, staging } =
                WireMsg::decode(body).expect("owned decode")
            else {
                panic!("variant changed in flight")
            };
            let view = MixLocalRef::decode(body).expect("borrowed decode");
            assert_eq!((view.k, view.alpha.to_bits()), (k, alpha.to_bits()));
            assert_eq!((view.shard, view.shards, view.dim), (shard, shards, dim));
            assert_eq!(view.msg_count(), msgs.len());
            let d = dim as usize;
            let mut at = 0usize;
            let mut suppressed = 0usize;
            for (i, (meta, row)) in view.msgs().enumerate() {
                assert_eq!(meta, msgs[i]);
                match row {
                    Some(bytes) => {
                        // The borrowed bytes must be the exact LE image
                        // of the owned staging row.
                        assert_eq!(bytes.len(), d * 8);
                        for (e, x) in bytes.chunks_exact(8).zip(&staging[at..at + d]) {
                            assert_eq!(
                                f64::from_le_bytes(e.try_into().unwrap()).to_bits(),
                                x.to_bits()
                            );
                        }
                        at += d;
                    }
                    None => suppressed += 1,
                }
            }
            assert_eq!(at, staging.len(), "view must consume every staged row");
            assert_eq!(view.suppressed(), suppressed);
        }
    }

    #[test]
    fn bad_version_byte_is_rejected() {
        let mut frame = Vec::new();
        WireMsg::Step { lr: 0.1 }.encode(&mut frame);
        let mut body = frame[FRAME_HEADER_BYTES..].to_vec();
        body[0] = WIRE_VERSION + 1;
        assert_eq!(WireMsg::decode(&body), Err(WireError::BadVersion(WIRE_VERSION + 1)));
        body[0] = 0;
        assert_eq!(WireMsg::decode(&body), Err(WireError::BadVersion(0)));
    }

    #[test]
    fn unknown_tag_is_rejected() {
        let body = [WIRE_VERSION, 0xee];
        assert_eq!(WireMsg::decode(&body), Err(WireError::BadTag(0xee)));
    }

    #[test]
    fn length_prefix_overflow_is_rejected_before_allocation() {
        // A hostile 4 GiB prefix must die in frame_len, not in a Vec
        // reservation.
        assert_eq!(
            frame_len(u32::MAX.to_le_bytes()),
            Err(WireError::FrameTooLarge(u32::MAX as u64))
        );
        assert_eq!(
            frame_len(((MAX_FRAME_BYTES as u32) + 1).to_le_bytes()),
            Err(WireError::FrameTooLarge(MAX_FRAME_BYTES as u64 + 1))
        );
        assert_eq!(frame_len(8u32.to_le_bytes()), Ok(8));
    }

    #[test]
    fn interior_count_overflow_is_rejected() {
        // A Mix frame claiming u32::MAX messages with a large dim would
        // overflow count*dim on 32-bit math; the decoder must refuse
        // without reserving memory for it.
        let mut body = vec![WIRE_VERSION, TAG_MIX];
        body.extend_from_slice(&0u64.to_le_bytes()); // k
        body.extend_from_slice(&0.5f64.to_le_bytes()); // alpha
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // dim
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // count
        match WireMsg::decode(&body) {
            Err(WireError::Truncated { .. }) | Err(WireError::FrameTooLarge(_)) => {}
            other => panic!("expected overflow rejection, got {other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut frame = Vec::new();
        WireMsg::Shutdown.encode(&mut frame);
        let mut body = frame[FRAME_HEADER_BYTES..].to_vec();
        body.push(0);
        match WireMsg::decode(&body) {
            Err(WireError::Inconsistent(msg)) => assert!(msg.contains("trailing"), "{msg}"),
            other => panic!("expected Inconsistent, got {other:?}"),
        }
    }

    #[test]
    fn inconsistent_states_length_is_rejected() {
        let mut body = vec![WIRE_VERSION, TAG_STATES];
        body.extend_from_slice(&0u32.to_le_bytes()); // shard
        body.extend_from_slice(&3u32.to_le_bytes()); // dim
        body.extend_from_slice(&4u32.to_le_bytes()); // count: not a multiple of 3
        body.extend_from_slice(&[0u8; 32]);
        match WireMsg::decode(&body) {
            Err(WireError::Inconsistent(msg)) => assert!(msg.contains("multiple"), "{msg}"),
            other => panic!("expected Inconsistent, got {other:?}"),
        }
    }

    #[test]
    fn hello_with_wrong_proto_version_is_a_typed_error() {
        // The frame-level version byte is fine — the *application*
        // version inside the Hello is what mismatches. check_proto is
        // the coordinator/daemon-side gate.
        let msg = WireMsg::Hello { shard: 2, proto: PROTO_VERSION + 9 };
        let WireMsg::Hello { proto, .. } = roundtrip(&msg) else {
            panic!("variant changed in flight")
        };
        assert_eq!(
            check_proto(proto),
            Err(WireError::ProtocolMismatch {
                got: PROTO_VERSION + 9,
                supported: PROTO_VERSION
            })
        );
        assert_eq!(check_proto(PROTO_VERSION), Ok(()));
        // The rejection frame a coordinator answers with round-trips.
        assert_eq!(
            roundtrip(&WireMsg::VersionReject { supported: PROTO_VERSION }),
            WireMsg::VersionReject { supported: PROTO_VERSION }
        );
    }

    #[test]
    fn assign_rejects_non_utf8_spec_payload() {
        let mut frame = Vec::new();
        WireMsg::Assign { shard: 0, shards: 1, spec_json: "ok".into() }.encode(&mut frame);
        let mut body = frame[FRAME_HEADER_BYTES..].to_vec();
        let n = body.len();
        body[n - 1] = 0xff; // continuation byte with no lead → invalid UTF-8
        match WireMsg::decode(&body) {
            Err(WireError::Inconsistent(msg)) => assert!(msg.contains("UTF-8"), "{msg}"),
            other => panic!("expected Inconsistent, got {other:?}"),
        }
    }

    #[test]
    fn resume_with_inconsistent_state_length_is_rejected() {
        let mut body = vec![WIRE_VERSION, TAG_RESUME];
        body.extend_from_slice(&1u64.to_le_bytes()); // done
        body.extend_from_slice(&2u64.to_le_bytes()); // steps
        body.extend_from_slice(&3u64.to_le_bytes()); // folded
        body.extend_from_slice(&3u32.to_le_bytes()); // dim
        body.extend_from_slice(&4u32.to_le_bytes()); // count: not a multiple of 3
        body.extend_from_slice(&[0u8; 32]);
        match WireMsg::decode(&body) {
            Err(WireError::Inconsistent(msg)) => assert!(msg.contains("multiple"), "{msg}"),
            other => panic!("expected Inconsistent, got {other:?}"),
        }
    }

    #[test]
    fn fuzz_random_bytes_never_panic() {
        // Arbitrary garbage must decode to Ok or a typed error — never a
        // panic. (Running under `cargo test` catches panics as failures.)
        let mut rng = Rng::new(77);
        for _ in 0..2000 {
            let len = (rng.next_u64() % 96) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
            let _ = WireMsg::decode(&bytes);
            let _ = MixLocalRef::decode(&bytes);
            let _ = peek_tag(&bytes);
        }
    }

    #[test]
    fn corrupted_encodings_never_panic() {
        // Flip one byte at a time in valid frames: decode must return
        // either Ok (the flip hit a payload float) or a typed error.
        let mut rng = Rng::new(5);
        for _ in 0..60 {
            let msg = random_msg(&mut rng);
            let mut frame = Vec::new();
            msg.encode(&mut frame);
            for i in FRAME_HEADER_BYTES..frame.len() {
                let mut corrupt = frame[FRAME_HEADER_BYTES..].to_vec();
                corrupt[i - FRAME_HEADER_BYTES] ^= 0xff;
                let _ = WireMsg::decode(&corrupt);
                // The borrowed decoder shares the parser internals but
                // not the code path — fuzz it against the same flips.
                if let Ok(view) = MixLocalRef::decode(&corrupt) {
                    for (_, row) in view.msgs() {
                        let _ = row.map(<[u8]>::len);
                    }
                }
            }
        }
    }
}
