//! Cluster transports: how wire frames move between the coordinator and
//! a shard.
//!
//! [`Transport`] is one duplex, ordered, reliable link carrying the
//! frames of [`super::wire`]. Two implementations:
//!
//! - [`LoopbackTransport`] — in-memory channels. Deterministic and
//!   dependency-free; what the parity tests use to prove the cluster
//!   backend is **bit-for-bit** equal to the in-process actors backend
//!   (the bytes are identical to what TCP would carry — the whole wire
//!   layer is exercised, only the pipe differs).
//! - [`TcpTransport`] — a real [`std::net::TcpStream`] (`TCP_NODELAY`),
//!   the production shape: shards in other processes or on other
//!   machines, coordinator dialed in over the network.
//!
//! Every transport carries a **byte-accounting layer** ([`LinkStats`]):
//! frames and bytes in each direction, counted at the link. This is the
//! bridge between the paper's simulated communication model and real
//! deployment: [`WireClock`] converts accumulated bytes into the same
//! virtual units the [`crate::engine::DelayPolicy`] clock charges, so a
//! run can report, side by side, what the activation schedule *predicts*
//! communication costs and what the serialized model rows *actually* put
//! on the wire (`ClusterStats` in [`super::driver`]).

use super::wire::{frame_len, WireError, WireMsg, FRAME_HEADER_BYTES};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Per-link byte accounting: every frame and byte that crossed this
/// link, per direction. Counted where the link is held, so loopback and
/// TCP report identical numbers for identical traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    pub frames_sent: u64,
    pub bytes_sent: u64,
    pub frames_received: u64,
    pub bytes_received: u64,
    /// Payload bytes the Mix local-row suppression **avoided** shipping
    /// on this link: rows whose peer lives on the receiving shard are
    /// omitted from `MixLocal` frames (the shard resolves them from its
    /// own pre-mix segment), so these bytes are savings relative to the
    /// stage-everything protocol, **not** a component of the raw
    /// counters above. Transports cannot know this — the driver folds
    /// it in after the run from staging-time accounting.
    pub intra_bytes: u64,
}

impl LinkStats {
    /// Total traffic in both directions, in bytes.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent + self.bytes_received
    }

    /// Traffic that crossed shards. Local-row suppression keeps
    /// intra-shard payload off the wire entirely, so everything the
    /// link carried is genuine cross-shard traffic and this equals
    /// [`Self::total_bytes`] — kept as the semantic name
    /// wire-efficiency comparisons use (`wire_bytes` in sweep JSON
    /// lines).
    pub fn remote_bytes(&self) -> u64 {
        self.total_bytes()
    }

    /// Field-wise difference `self − prev`: the traffic that crossed the
    /// link since `prev` was captured. Used for per-phase frame
    /// accounting in the cluster driver's trace emission.
    pub fn delta(&self, prev: &LinkStats) -> LinkStats {
        LinkStats {
            frames_sent: self.frames_sent - prev.frames_sent,
            bytes_sent: self.bytes_sent - prev.bytes_sent,
            frames_received: self.frames_received - prev.frames_received,
            bytes_received: self.bytes_received - prev.bytes_received,
            intra_bytes: self.intra_bytes - prev.intra_bytes,
        }
    }
}

/// Convert accumulated wire bytes into the virtual time units of the
/// delay models: a link moving `bytes_per_unit` bytes per unit needs
/// `bytes / bytes_per_unit` units to drain the observed traffic. With
/// `bytes_per_unit = 8 · dim / link_time` (one model row per link
/// activation), wire-clock time and the schedule's analytic
/// communication time land on the same scale and can be compared
/// directly.
#[derive(Clone, Copy, Debug)]
pub struct WireClock {
    bytes_per_unit: f64,
}

impl WireClock {
    /// A clock rating the link at `bytes_per_unit` bytes per virtual
    /// delay unit (must be positive and finite).
    pub fn new(bytes_per_unit: f64) -> WireClock {
        assert!(
            bytes_per_unit.is_finite() && bytes_per_unit > 0.0,
            "wire clock needs a positive finite bandwidth"
        );
        WireClock { bytes_per_unit }
    }

    /// A clock calibrated so one `dim`-row payload costs one `link_time`
    /// unit — the delay models' per-link charge. Degenerate inputs never
    /// panic: an infinite `link_time` (a link that never delivers) rates
    /// the link maximally slow, while a zero, negative or NaN one rates
    /// it effectively free.
    pub fn per_row(dim: usize, link_time: f64) -> WireClock {
        let bytes = 8.0 * dim.max(1) as f64;
        let bytes_per_unit = if link_time.is_finite() && link_time > 0.0 {
            (bytes / link_time).clamp(f64::MIN_POSITIVE, f64::MAX)
        } else if link_time == f64::INFINITY {
            f64::MIN_POSITIVE
        } else {
            f64::MAX
        };
        WireClock::new(bytes_per_unit)
    }

    /// Virtual units the given byte count costs on this clock.
    pub fn units(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bytes_per_unit
    }
}

/// One duplex, ordered, reliable frame link. `send` ships one complete
/// frame (length prefix included, as produced by [`WireMsg::encode`]);
/// `recv_into` blocks for the next frame and leaves its **body** (prefix
/// stripped and validated) in `body`.
pub trait Transport: Send {
    fn send(&mut self, frame: &[u8]) -> Result<(), WireError>;
    fn recv_into(&mut self, body: &mut Vec<u8>) -> Result<(), WireError>;
    fn stats(&self) -> LinkStats;

    /// Encode and ship `msg`, recycling `scratch` as the frame buffer
    /// (the encode side allocates nothing per frame at steady state;
    /// the decode side of [`Transport::recv_msg`] materializes the
    /// message's vectors — an accepted cost on a transport-bound path).
    fn send_msg(&mut self, msg: &WireMsg, scratch: &mut Vec<u8>) -> Result<(), WireError> {
        scratch.clear();
        msg.encode(scratch);
        self.send(scratch)
    }

    /// Receive and decode the next message, recycling `scratch` as the
    /// body buffer.
    fn recv_msg(&mut self, scratch: &mut Vec<u8>) -> Result<WireMsg, WireError> {
        self.recv_into(scratch)?;
        WireMsg::decode(scratch)
    }
}

// ---------------------------------------------------------------------
// Loopback: in-memory channels
// ---------------------------------------------------------------------

/// In-memory transport endpoint: frames travel over `mpsc` channels as
/// owned byte vectors, in order, with the same framing and accounting as
/// TCP. Used by tests and the deterministic loopback cluster backend.
pub struct LoopbackTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    stats: LinkStats,
}

/// A connected pair of loopback endpoints (coordinator side, shard
/// side).
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    let (atx, brx) = channel();
    let (btx, arx) = channel();
    (
        LoopbackTransport { tx: atx, rx: arx, stats: LinkStats::default() },
        LoopbackTransport { tx: btx, rx: brx, stats: LinkStats::default() },
    )
}

impl Transport for LoopbackTransport {
    fn send(&mut self, frame: &[u8]) -> Result<(), WireError> {
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += frame.len() as u64;
        self.tx
            .send(frame.to_vec())
            .map_err(|_| WireError::Io("loopback peer hung up".into()))
    }

    fn recv_into(&mut self, body: &mut Vec<u8>) -> Result<(), WireError> {
        let frame = self
            .rx
            .recv()
            .map_err(|_| WireError::Io("loopback peer hung up".into()))?;
        if frame.len() < FRAME_HEADER_BYTES {
            return Err(WireError::Truncated { needed: FRAME_HEADER_BYTES, got: frame.len() });
        }
        let len = frame_len(frame[..FRAME_HEADER_BYTES].try_into().expect("4-byte header"))?;
        if frame.len() != FRAME_HEADER_BYTES + len {
            return Err(WireError::Truncated {
                needed: FRAME_HEADER_BYTES + len,
                got: frame.len(),
            });
        }
        self.stats.frames_received += 1;
        self.stats.bytes_received += frame.len() as u64;
        body.clear();
        body.extend_from_slice(&frame[FRAME_HEADER_BYTES..]);
        Ok(())
    }

    fn stats(&self) -> LinkStats {
        self.stats
    }
}

// ---------------------------------------------------------------------
// TCP: std::net::TcpStream
// ---------------------------------------------------------------------

/// A frame link over one TCP connection. `TCP_NODELAY` is set — the
/// protocol is strictly request/reply per phase, and Nagle batching
/// would serialize the whole cluster on the ACK clock.
pub struct TcpTransport {
    stream: TcpStream,
    stats: LinkStats,
}

impl TcpTransport {
    /// Wrap a connected stream (either end of the connection).
    pub fn new(stream: TcpStream) -> Result<TcpTransport, WireError> {
        stream
            .set_nodelay(true)
            .map_err(|e| WireError::Io(format!("set_nodelay: {e}")))?;
        Ok(TcpTransport { stream, stats: LinkStats::default() })
    }

    /// The underlying stream, for socket-option tweaks (e.g. a read
    /// timeout while handshaking an unauthenticated connection).
    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    /// Set (or with `None` clear) a deadline on both reads and writes.
    /// Once armed, a peer that stays silent past the deadline surfaces
    /// as [`WireError::TimedOut`] from `send`/`recv_into` instead of
    /// blocking forever — what the shard-node lifecycle handling keys
    /// its reconnect/abort decisions on.
    pub fn set_io_timeout(&mut self, timeout: Option<std::time::Duration>) -> Result<(), WireError> {
        self.stream
            .set_read_timeout(timeout)
            .and_then(|()| self.stream.set_write_timeout(timeout))
            .map_err(|e| WireError::Io(format!("set timeout: {e}")))
    }
}

/// Classify a TCP I/O failure: deadline expiries become the typed
/// [`WireError::TimedOut`] (platforms report them as either `WouldBlock`
/// or `TimedOut`), everything else stays a transport [`WireError::Io`].
fn tcp_io_error(what: &str, e: std::io::Error) -> WireError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => WireError::TimedOut,
        _ => WireError::Io(format!("{what}: {e}")),
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &[u8]) -> Result<(), WireError> {
        self.stream.write_all(frame).map_err(|e| tcp_io_error("send", e))?;
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += frame.len() as u64;
        Ok(())
    }

    fn recv_into(&mut self, body: &mut Vec<u8>) -> Result<(), WireError> {
        let mut header = [0u8; FRAME_HEADER_BYTES];
        self.stream
            .read_exact(&mut header)
            .map_err(|e| tcp_io_error("recv header", e))?;
        let len = frame_len(header)?;
        body.clear();
        body.resize(len, 0);
        self.stream
            .read_exact(body)
            .map_err(|e| tcp_io_error("recv body", e))?;
        self.stats.frames_received += 1;
        self.stats.bytes_received += (FRAME_HEADER_BYTES + len) as u64;
        Ok(())
    }

    fn stats(&self) -> LinkStats {
        self.stats
    }
}

/// Which transport a cluster run uses. `Loopback` is deterministic and
/// in-process (tests, parity proofs); `Tcp` runs the same protocol over
/// localhost sockets the driver spawns itself — the deployment shape,
/// exercised end-to-end by `rust/tests/cluster.rs` and
/// `benches/cluster_transport.rs`. `Remote` dials **pre-existing**
/// `matcha shard-node` daemons at the listed addresses and replays the
/// schedule over them with pipelined commands ([`crate::node`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportKind {
    Loopback,
    Tcp,
    /// One `host:port` per shard, in shard order.
    Remote { addrs: Vec<String> },
}

impl TransportKind {
    /// Short name for logs and JSON (`loopback`, `tcp`, `remote`).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Loopback => "loopback",
            TransportKind::Tcp => "tcp",
            TransportKind::Remote { .. } => "remote",
        }
    }

    /// Parse a spec/CLI transport name. `Remote` is not nameable here —
    /// it needs its address list, spelled `{"tcp": ["host:port", ...]}`
    /// in spec JSON.
    pub fn parse(s: &str) -> Result<TransportKind, String> {
        match s {
            "loopback" => Ok(TransportKind::Loopback),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(format!("unknown transport '{other}' (expected loopback | tcp)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise_pair(mut a: Box<dyn Transport>, mut b: Box<dyn Transport>) {
        let mut scratch = Vec::new();
        let mut body = Vec::new();
        let msg = WireMsg::States { shard: 3, dim: 2, states: vec![1.0, -2.0, 3.5, 0.25] };
        a.send_msg(&msg, &mut scratch).unwrap();
        a.send_msg(&WireMsg::Shutdown, &mut scratch).unwrap();
        assert_eq!(b.recv_msg(&mut body).unwrap(), msg, "frames arrive in order");
        assert_eq!(b.recv_msg(&mut body).unwrap(), WireMsg::Shutdown);

        let hello = WireMsg::Hello { shard: 3, proto: crate::cluster::wire::PROTO_VERSION };
        b.send_msg(&hello, &mut scratch).unwrap();
        assert_eq!(a.recv_msg(&mut body).unwrap(), hello);

        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa.frames_sent, 2);
        assert_eq!(sb.frames_received, 2);
        assert_eq!(sa.bytes_sent, sb.bytes_received, "both ends count the same bytes");
        assert_eq!(sb.bytes_sent, sa.bytes_received);
        assert!(sa.total_bytes() > 0);
    }

    #[test]
    fn loopback_duplex_ordered_and_accounted() {
        let (a, b) = loopback_pair();
        exercise_pair(Box::new(a), Box::new(b));
    }

    #[test]
    fn tcp_duplex_ordered_and_accounted() {
        let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).expect("bind localhost");
        let addr = listener.local_addr().unwrap();
        let dial = std::thread::spawn(move || {
            TcpTransport::new(TcpStream::connect(addr).expect("connect")).unwrap()
        });
        let (accepted, _) = listener.accept().expect("accept");
        let a = TcpTransport::new(accepted).unwrap();
        let b = dial.join().expect("dial thread");
        exercise_pair(Box::new(a), Box::new(b));
    }

    #[test]
    fn loopback_rejects_corrupt_frames_with_typed_errors() {
        let (mut a, mut b) = loopback_pair();
        // Undersized frame: shorter than the header itself.
        a.send(&[1, 2]).unwrap();
        // Length prefix claiming more than the carried body.
        let mut frame = Vec::new();
        WireMsg::Shutdown.encode(&mut frame);
        frame.truncate(frame.len() - 1);
        a.send(&frame).unwrap();
        let mut body = Vec::new();
        assert!(matches!(b.recv_into(&mut body), Err(WireError::Truncated { .. })));
        assert!(matches!(b.recv_into(&mut body), Err(WireError::Truncated { .. })));
        // Hung-up peer surfaces as Io, not a panic.
        drop(a);
        assert!(matches!(b.recv_into(&mut body), Err(WireError::Io(_))));
    }

    #[test]
    fn wire_clock_converts_bytes_to_delay_units() {
        let clock = WireClock::per_row(16, 1.0); // one 16-dim row per unit
        assert_eq!(clock.units(128), 1.0);
        assert_eq!(clock.units(256), 2.0);
        let faster = WireClock::new(1024.0);
        assert!(faster.units(128) < clock.units(128));
        // Degenerate link times never panic: zero/negative/NaN rate the
        // link as free, an infinite link time as maximally slow.
        for bad in [0.0, -1.0, f64::NAN] {
            let units = WireClock::per_row(64, bad).units(1 << 20);
            assert!(units >= 0.0 && units < 1e-290, "link_time {bad}: units {units}");
        }
        assert!(WireClock::per_row(64, f64::INFINITY).units(1 << 20) > 1e290);
    }

    #[test]
    fn link_stats_delta_is_fieldwise() {
        let prev = LinkStats {
            frames_sent: 2,
            bytes_sent: 100,
            frames_received: 1,
            bytes_received: 40,
            intra_bytes: 8,
        };
        let cur = LinkStats {
            frames_sent: 5,
            bytes_sent: 260,
            frames_received: 4,
            bytes_received: 90,
            intra_bytes: 24,
        };
        let d = cur.delta(&prev);
        assert_eq!(
            d,
            LinkStats {
                frames_sent: 3,
                bytes_sent: 160,
                frames_received: 3,
                bytes_received: 50,
                intra_bytes: 16,
            }
        );
        assert_eq!(cur.delta(&cur), LinkStats::default());
    }

    #[test]
    fn link_stats_intra_bytes_are_savings_not_traffic() {
        let mut s = LinkStats {
            frames_sent: 1,
            bytes_sent: 100,
            frames_received: 1,
            bytes_received: 60,
            intra_bytes: 0,
        };
        assert_eq!(s.total_bytes(), 160);
        assert_eq!(s.remote_bytes(), s.total_bytes());
        // Suppressed rows never existed on the wire: recording them
        // changes the savings ledger, not the traffic counters.
        s.intra_bytes = 48;
        assert_eq!(s.total_bytes(), 160, "raw counters keep link semantics");
        assert_eq!(s.remote_bytes(), 160);
    }

    #[test]
    fn tcp_read_on_a_silent_peer_times_out_with_typed_error() {
        let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).expect("bind localhost");
        let addr = listener.local_addr().unwrap();
        let dial = std::thread::spawn(move || {
            TcpTransport::new(TcpStream::connect(addr).expect("connect")).unwrap()
        });
        // Accept the connection but never write a byte: a silent peer.
        let (accepted, _) = listener.accept().expect("accept");
        let _silent = TcpTransport::new(accepted).unwrap();
        let mut t = dial.join().expect("dial thread");
        t.set_io_timeout(Some(std::time::Duration::from_millis(40))).unwrap();
        let mut body = Vec::new();
        let t0 = std::time::Instant::now();
        assert_eq!(t.recv_into(&mut body), Err(WireError::TimedOut));
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "timeout must fire promptly, not hang"
        );
        // Clearing the deadline restores blocking semantics (smoke: the
        // call itself succeeds).
        t.set_io_timeout(None).unwrap();
    }

    #[test]
    fn transport_kind_names_roundtrip() {
        for kind in [TransportKind::Loopback, TransportKind::Tcp] {
            let name = kind.name();
            assert_eq!(TransportKind::parse(name), Ok(kind));
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        // Remote has a name for logs but is not nameable by string —
        // its address list only exists in the spec's object form.
        let remote = TransportKind::Remote { addrs: vec!["127.0.0.1:7701".into()] };
        assert_eq!(remote.name(), "remote");
        assert!(TransportKind::parse("remote").is_err());
    }
}
