fn main() {
    matcha::cli::main();
}
