//! Barrier-free asynchronous gossip runtime with staleness-aware mixing.
//!
//! MATCHA's wall-clock win comes from parallelizing communication over
//! sampled matchings, but the barrier engine ([`crate::engine`]) still
//! synchronizes every worker once per iteration — the slowest link gates
//! everyone, exactly the straggler effect asynchronous gossip (AD-PSGD,
//! Lian et al., 1705.09056) removes. This subsystem executes the same
//! DecenSGD recursion with **no barrier at all**:
//!
//! - [`runtime`] — the barrier-free scheduler: each worker advances
//!   through compute/gossip events on its own virtual clock (reusing the
//!   engine's deterministic event queue and [`crate::engine::DelayPolicy`]
//!   durations), with AD-PSGD-style pairwise averaging over the sampled
//!   matching, per-edge model-version tracking, a `1 / (1 + τ)` staleness
//!   damping, and a configurable `max_staleness` bound that degrades
//!   gracefully to the synchronous kernel at staleness 0 (bit-for-bit
//!   parity with [`crate::sim::run_decentralized`], property-tested in
//!   `rust/tests/gossip.rs`).
//! - [`pool`] — the bounded worker pool: N logical workers multiplexed
//!   over `threads` OS threads with sticky per-worker state. Shared with
//!   the barrier engine's actor mode, which no longer spawns one thread
//!   per worker (and no longer falls back to sequential above 256
//!   workers).
//! - [`rounds`] — the apriori activation sequence, flattened to
//!   per-round edge lists in the global fold order both runtimes share.
//!
//! Reachable end-to-end as `backend: "async"` in an
//! [`crate::experiment::ExperimentSpec`] (JSON:
//! `{"kind": "async", "threads": T, "max_staleness": S}`), from the CLI
//! (`matcha engine --backend async`, `matcha run --spec ...`), and in
//! `benches/async_vs_barrier.rs`, which measures the async speedup over
//! barrier mode under straggler and flaky-link policies.
//!
//! ```
//! use matcha::engine::AnalyticPolicy;
//! use matcha::gossip::{run_async, AsyncConfig};
//! use matcha::graph::paper_figure1_graph;
//! use matcha::matching::decompose;
//! use matcha::rng::Rng;
//! use matcha::sim::{QuadraticProblem, RunConfig};
//! use matcha::topology::VanillaSampler;
//!
//! let d = decompose(&paper_figure1_graph());
//! let problem = QuadraticProblem::generate(8, 10, 1.0, 0.1, &mut Rng::new(1));
//! let mut sampler = VanillaSampler::new(d.len());
//! let run = RunConfig { iterations: 50, alpha: 0.1, ..RunConfig::default() };
//! let mut policy = AnalyticPolicy::matching_run_config(&run);
//! let config = AsyncConfig { run, threads: 2, max_staleness: 4 };
//! let result = run_async(&problem, &d.matchings, &mut sampler, &mut policy, &config);
//! assert!(result.stats.max_staleness() <= 4);
//! ```

pub mod pool;
pub mod rounds;
pub mod runtime;

pub use pool::{shard_of, shard_slot, shard_workers, ShardedPool};
pub use rounds::{RoundEdge, RoundPlan};
pub use runtime::{
    run_async, run_async_observed, run_async_traced, AsyncConfig, AsyncResult, AsyncStats,
    WorkerStats, DEFAULT_MAX_STALENESS, UNBOUNDED_STALENESS,
};
