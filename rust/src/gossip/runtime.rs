//! The barrier-free asynchronous gossip runtime.
//!
//! The barrier engine ([`crate::engine`]) synchronizes every worker at a
//! per-iteration barrier: the slowest link gates everyone. This runtime
//! removes the barrier — each worker advances through its own
//! compute/gossip events on its **own virtual clock** (reusing the
//! engine's deterministic event queue and [`DelayPolicy`] durations), in
//! the spirit of AD-PSGD (Lian et al., 1705.09056):
//!
//! - **Compute** overlaps communication: a worker starts its next local
//!   SGD step while its previous round's exchanges are still in flight.
//!   Gradients are evaluated at the compute-*start* state (the AD-PSGD
//!   stale-gradient model); deltas arriving mid-step apply to the live
//!   iterate.
//! - **Gossip** is pairwise per activated edge: edge `(u, v)` of round
//!   `k` is a rendezvous that starts once both endpoints have produced
//!   their round-`k` post-step iterate and both link ports are free
//!   (links at one node serialize, node-disjoint links run in parallel —
//!   the paper's §2 delay model at per-edge granularity, without the
//!   global barrier).
//! - **Staleness-aware mixing**: each exchange's model-version drift
//!   `τ = max(version_u, version_v) − (k + 1)` is tracked per edge and
//!   the pairwise update is damped to `α / (1 + τ)` — stale exchanges
//!   pull less. A configurable `max_staleness` bound gates how far a
//!   worker may run ahead of its own unapplied rounds; at
//!   `max_staleness = 0` every worker waits for its round's exchanges
//!   before stepping again, `τ ≡ 0`, and the runtime **degrades to the
//!   synchronous kernel**: trajectories are bit-for-bit equal to
//!   [`crate::sim::run_decentralized`] per seed (property-tested in
//!   `rust/tests/gossip.rs`).
//! - **Bounded worker pool**: `threads` OS threads multiplex all logical
//!   workers ([`ShardedPool`]); per-worker RNG streams make the result
//!   independent of the pool size.
//!
//! Determinism: the event queue's `(time, seq)` order, the per-worker
//! gradient streams, the per-edge compression RNG and the fixed global
//! fold order of each round's contributions make the whole simulation a
//! pure function of the spec — rerunning a seed reproduces trajectories,
//! timings and staleness statistics exactly, at any thread count.

use super::pool::{shard_of, shard_slot, shard_workers, ShardedPool};
use super::rounds::RoundPlan;
use crate::delay::DelayModel;
use crate::engine::{DelayPolicy, EventKind, EventQueue};
use crate::experiment::{NoopObserver, Observer};
use crate::metrics::Recorder;
use crate::rng::Rng;
use crate::sim::kernel::{edge_diff_message_src, init_iterates, record_metrics, worker_streams};
use crate::sim::{Problem, RunConfig, RunResult};
use crate::state::{RowSource, SnapshotPool, StateMatrix};
use crate::topology::TopologySampler;
use crate::trace::{Counter, Hist, TraceEvent, Tracer};
use std::collections::{BTreeMap, VecDeque};

/// Default version-drift bound used by spec defaults and the CLI.
pub const DEFAULT_MAX_STALENESS: usize = 4;

/// Sentinel bound for the **unbounded** AD-PSGD mode: the staleness gate
/// is skipped entirely and workers run ahead as far as the event
/// schedule lets them (throughput-oriented runs; the `1/(1+τ)` damping
/// still scales stale exchanges down). Selected in a spec with
/// `"max_staleness": null`. Still a pure function of the seed: the event
/// queue's deterministic order makes the unbounded run reproducible at
/// any thread count (tested in `rust/tests/gossip.rs`).
pub const UNBOUNDED_STALENESS: usize = usize::MAX;

/// Configuration of an asynchronous run: the shared run parameters, the
/// bounded pool size, and the staleness bound.
#[derive(Clone, Debug)]
pub struct AsyncConfig {
    pub run: RunConfig,
    /// OS threads multiplexing the logical workers (clamped to the
    /// worker count; `<= 1` computes in-process). Changes wall-clock
    /// only, never results.
    pub threads: usize,
    /// How many rounds a worker may run ahead of its oldest unapplied
    /// gossip round. `0` reproduces the synchronous kernel exactly;
    /// [`UNBOUNDED_STALENESS`] skips the gate entirely (pure AD-PSGD).
    pub max_staleness: usize,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            run: RunConfig::default(),
            threads: 1,
            max_staleness: DEFAULT_MAX_STALENESS,
        }
    }
}

/// Per-worker observability counters of an asynchronous run.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerStats {
    /// Edge exchanges this worker participated in (failed ones included).
    pub exchanges: usize,
    /// Sum of per-exchange staleness values (for the mean).
    pub staleness_sum: usize,
    /// Largest per-exchange staleness observed.
    pub max_staleness: usize,
    /// Virtual time spent blocked on the staleness gate.
    pub idle_time: f64,
    /// Virtual time at which this worker finished its last round.
    pub finish_time: f64,
}

impl WorkerStats {
    /// Mean staleness over this worker's exchanges (0 when it had none).
    pub fn mean_staleness(&self) -> f64 {
        if self.exchanges == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.exchanges as f64
        }
    }
}

/// Staleness / idle-time statistics of an asynchronous run.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncStats {
    pub per_worker: Vec<WorkerStats>,
}

impl AsyncStats {
    /// Mean staleness over every exchange of the run.
    pub fn mean_staleness(&self) -> f64 {
        let (sum, n) = self
            .per_worker
            .iter()
            .fold((0usize, 0usize), |(s, n), w| (s + w.staleness_sum, n + w.exchanges));
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }

    /// Largest staleness observed on any exchange.
    pub fn max_staleness(&self) -> usize {
        self.per_worker.iter().map(|w| w.max_staleness).max().unwrap_or(0)
    }

    /// Total virtual idle time across workers (staleness-gate waits).
    pub fn total_idle(&self) -> f64 {
        self.per_worker.iter().map(|w| w.idle_time).sum()
    }

    /// Total exchanges across workers (each edge counts once per
    /// endpoint).
    pub fn total_exchanges(&self) -> usize {
        self.per_worker.iter().map(|w| w.exchanges).sum()
    }
}

/// Outcome of an asynchronous run: the standard [`RunResult`] plus
/// engine-level counters and the staleness statistics.
///
/// Metric semantics vs the barrier backends: `run.total_time` is the
/// same quantity (virtual time until the last worker finishes) and is
/// directly comparable. `run.total_comm_units` is **not**: the barrier
/// engine charges the per-iteration critical path (max link time per
/// matching, matchings serialized), while the barrier-free runtime has
/// no global critical path and instead accumulates every link's busy
/// time — an aggregate-bandwidth figure that upper-bounds any
/// serialization of the same exchanges.
pub struct AsyncResult {
    pub run: RunResult,
    /// Links dropped by failure injection over the whole run.
    pub dropped_links: usize,
    /// Discrete events processed by the queue.
    pub events: u64,
    pub stats: AsyncStats,
}

// ---------------------------------------------------------------------
// Gradient execution: inline or on the bounded pool.
// ---------------------------------------------------------------------

/// Where local gradient steps execute. Gradients are evaluated from the
/// compute-start iterate with the worker's private RNG stream, so the
/// result is identical whichever implementation runs it. `harvest_into`
/// copies the finished gradient into the caller's scratch row — the
/// gradient buffers themselves are arena rows (inline) or recycled
/// vectors (pool), so the steady state allocates nothing per step.
trait GradSource {
    fn dispatch(&mut self, worker: usize, round: usize, x: &[f64]);
    fn harvest_into(&mut self, worker: usize, round: usize, out: &mut [f64]);
}

struct InlineGrad<'p, P: Problem + ?Sized> {
    problem: &'p P,
    rngs: Vec<Rng>,
    /// One arena row per worker holds its in-flight gradient.
    grads: StateMatrix,
    /// The round each worker's gradient row belongs to.
    ready: Vec<Option<usize>>,
}

impl<P: Problem + ?Sized> GradSource for InlineGrad<'_, P> {
    fn dispatch(&mut self, worker: usize, round: usize, x: &[f64]) {
        self.problem.stoch_grad(worker, x, &mut self.rngs[worker], self.grads.row_mut(worker));
        self.ready[worker] = Some(round);
    }

    fn harvest_into(&mut self, worker: usize, round: usize, out: &mut [f64]) {
        let r = self.ready[worker].take().expect("gradient not dispatched");
        assert_eq!(r, round, "gradient round mismatch");
        out.copy_from_slice(self.grads.row(worker));
    }
}

struct GradCmd {
    worker: usize,
    round: usize,
    x: Vec<f64>,
}

struct GradReply {
    worker: usize,
    round: usize,
    grad: Vec<f64>,
}

struct GradShard<'p, P: Problem + ?Sized> {
    problem: &'p P,
    shards: usize,
    /// RNG streams of the workers this shard owns, in slot order.
    rngs: Vec<Rng>,
    /// Gradient scratch (the command's `x` buffer is recycled as the
    /// reply's `grad` buffer).
    scratch: Vec<f64>,
}

impl<P: Problem + ?Sized> GradShard<'_, P> {
    fn handle(&mut self, cmd: GradCmd) -> GradReply {
        let GradCmd { worker, round, mut x } = cmd;
        let slot = shard_slot(worker, self.shards);
        self.problem.stoch_grad(worker, &x, &mut self.rngs[slot], &mut self.scratch);
        x.copy_from_slice(&self.scratch);
        GradReply { worker, round, grad: x }
    }
}

struct PoolGrad<'a> {
    pool: &'a ShardedPool<GradCmd, GradReply>,
    shards: usize,
    stash: BTreeMap<(usize, usize), Vec<f64>>,
    /// Recycled dispatch/reply buffers.
    spare: Vec<Vec<f64>>,
}

impl GradSource for PoolGrad<'_> {
    fn dispatch(&mut self, worker: usize, round: usize, x: &[f64]) {
        let mut buf = self.spare.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(x);
        self.pool.send(shard_of(worker, self.shards), GradCmd { worker, round, x: buf });
    }

    fn harvest_into(&mut self, worker: usize, round: usize, out: &mut [f64]) {
        loop {
            if let Some(g) = self.stash.remove(&(worker, round)) {
                out.copy_from_slice(&g);
                self.spare.push(g);
                return;
            }
            let reply = self.pool.recv();
            self.stash.insert((reply.worker, reply.round), reply.grad);
        }
    }
}

// ---------------------------------------------------------------------
// The discrete-event coordinator.
// ---------------------------------------------------------------------

/// One arrived-but-unapplied round of a worker: the post-step snapshot
/// the exchanges read from, and the per-edge mix contributions collected
/// until every incident edge completes. All model-sized buffers are rows
/// borrowed from the driver's [`SnapshotPool`] and recycled when the
/// round applies — no per-round heap allocation at steady state.
struct RoundMix {
    /// Post-step, pre-mix iterate of this worker at this round (pool
    /// row).
    snapshot: usize,
    /// Virtual time the snapshot was produced (exchange lower bound).
    ready: f64,
    /// This worker's incident edge indices into the round's global edge
    /// list, ascending.
    incident: Vec<usize>,
    /// Signed, staleness-damped diff per incident edge (pool rows),
    /// filled as links complete; folded in `incident` order at
    /// application so the fold matches the synchronous kernel regardless
    /// of completion order.
    slots: Vec<Option<usize>>,
    remaining: usize,
}

struct Worker {
    lr: f64,
    /// Next round this worker will compute.
    next_round: usize,
    /// Completed compute steps (the model version).
    ver: usize,
    /// First round whose mix is not yet applied (rounds `< through` are
    /// fully absorbed).
    through: usize,
    computing: bool,
    /// When this worker's link port is next free (its exchanges
    /// serialize; they overlap with its own compute).
    port_free: f64,
    blocked_since: Option<f64>,
    /// Unfinished exchanges as `(round, edge index)`, in global order.
    pending: VecDeque<(usize, usize)>,
    /// Arrived, unapplied rounds.
    open: BTreeMap<usize, RoundMix>,
    exchanges: usize,
    staleness_sum: usize,
    staleness_max: usize,
    idle: f64,
    finish: f64,
}

impl Worker {
    fn new(lr: f64) -> Worker {
        Worker {
            lr,
            next_round: 0,
            ver: 0,
            through: 0,
            computing: false,
            port_free: 0.0,
            blocked_since: None,
            pending: VecDeque::new(),
            open: BTreeMap::new(),
            exchanges: 0,
            staleness_sum: 0,
            staleness_max: 0,
            idle: 0.0,
            finish: 0.0,
        }
    }
}

struct Driver<'a, P: Problem + ?Sized> {
    problem: &'a P,
    plan: &'a RoundPlan,
    policy: &'a mut dyn DelayPolicy,
    cfg: &'a RunConfig,
    max_staleness: usize,
    iterations: usize,
    m: usize,
    /// Compression time factor applied to every link duration (event
    /// timestamps are authoritative here, unlike the barrier engine).
    comm_scale: f64,
    workers: Vec<Worker>,
    /// Every worker's live iterate, one arena row per worker.
    arena: StateMatrix,
    /// Recycled rows for round snapshots, staged per-edge contributions
    /// and record snapshots.
    snap: SnapshotPool,
    queue: EventQueue,
    metrics: Recorder,
    /// Per record-round: each worker's iterate (pool row) captured when
    /// its `through` first passed that round.
    record_snaps: BTreeMap<usize, Vec<Option<usize>>>,
    /// Staging arena the completed record snapshots are gathered into
    /// before metrics run (worker order).
    record_stage: StateMatrix,
    /// Rounds fully applied by every worker (drives `on_iteration`).
    global_through: usize,
    total_comm: f64,
    dropped: usize,
    max_time: f64,
    grad: Vec<f64>,
    diff: Vec<f64>,
    delta: Vec<f64>,
    /// Recycled TopK magnitude scratch for message compression.
    comp: Vec<f64>,
}

impl<P: Problem + ?Sized> Driver<'_, P> {
    fn is_record_round(&self, r: usize) -> bool {
        (r + 1) % self.cfg.record_every == 0 || r + 1 == self.iterations
    }

    /// Start worker `w`'s next compute step if it is free, has rounds
    /// left, and the staleness gate allows it.
    fn start_compute(
        &mut self,
        w: usize,
        now: f64,
        grads: &mut dyn GradSource,
        tracer: &mut Tracer<'_>,
    ) {
        let (r, gate_ok) = {
            let wk = &self.workers[w];
            if wk.computing || wk.next_round >= self.iterations {
                return;
            }
            let r = wk.next_round;
            // `UNBOUNDED_STALENESS` saturates the bound: the gate never
            // closes and the run degenerates to pure AD-PSGD.
            let ok = match wk.open.keys().next() {
                Some(&oldest) => r <= oldest.saturating_add(self.max_staleness),
                None => true,
            };
            (r, ok)
        };
        if !gate_ok {
            if self.workers[w].blocked_since.is_none() {
                self.workers[w].blocked_since = Some(now);
            }
            return;
        }
        if let Some(t0) = self.workers[w].blocked_since.take() {
            self.workers[w].idle += (now - t0).max(0.0);
            tracer.observe(Hist::IdleUnits, (now - t0).max(0.0));
        }
        let ct = self.policy.compute_time(w, r);
        tracer.observatory.on_compute(w, ct);
        grads.dispatch(w, r, self.arena.row(w));
        self.workers[w].computing = true;
        tracer.emit_at(now, TraceEvent::ComputeBegin { worker: w, k: r });
        self.queue.schedule(now + ct, EventKind::ComputeDone { worker: w, k: r });
    }

    fn on_compute_done(
        &mut self,
        w: usize,
        r: usize,
        t: f64,
        grads: &mut dyn GradSource,
        observer: &mut dyn Observer,
        tracer: &mut Tracer<'_>,
    ) {
        tracer.emit_at(t, TraceEvent::ComputeEnd { worker: w, k: r });
        tracer.count(Counter::ComputeEvents, 1);
        let plan = self.plan;
        {
            let mut grad = std::mem::take(&mut self.grad);
            grads.harvest_into(w, r, &mut grad);
            let wk = &mut self.workers[w];
            wk.computing = false;
            wk.ver = r + 1;
            let lr = wk.lr;
            for (xi, &gi) in self.arena.row_mut(w).iter_mut().zip(grad.iter()) {
                *xi -= lr * gi;
            }
            self.grad = grad;
            let wk = &mut self.workers[w];
            if (r + 1) % self.cfg.lr_decay_every == 0 {
                wk.lr *= self.cfg.lr_decay;
            }
            wk.next_round = r + 1;
        }
        let incident = plan.incident(r, w);
        let round_active = !plan.rounds[r].is_empty();
        if incident.is_empty() {
            if round_active {
                // The synchronous kernel adds `α · 0` to non-incident
                // workers of an active round; replay that exactly.
                let alpha = self.cfg.alpha;
                for xi in self.arena.row_mut(w).iter_mut() {
                    *xi += alpha * 0.0;
                }
            }
            self.after_round_applied(w, t, observer, tracer);
        } else {
            let n = incident.len();
            let snapshot = self.snap.alloc_from(self.arena.row(w));
            {
                let wk = &mut self.workers[w];
                for &idx in &incident {
                    wk.pending.push_back((r, idx));
                }
                wk.open.insert(
                    r,
                    RoundMix { snapshot, ready: t, incident, slots: vec![None; n], remaining: n },
                );
            }
            self.try_launch(w, tracer);
        }
        self.start_compute(w, t, grads, tracer);
    }

    /// Launch every rendezvous that just became enabled, cascading: an
    /// edge starts when it heads both endpoints' pending queues and both
    /// round snapshots exist. Ports serialize a worker's own exchanges;
    /// the global `(round, edge)` order of the queues makes the cascade
    /// deadlock-free.
    fn try_launch(&mut self, w0: usize, tracer: &mut Tracer<'_>) {
        let plan = self.plan;
        let mut stack = vec![w0];
        while let Some(a) = stack.pop() {
            loop {
                let Some(&(k, idx)) = self.workers[a].pending.front() else { break };
                let (j, u, v) = plan.rounds[k][idx];
                let peer = if a == u { v } else { u };
                if !self.workers[peer].open.contains_key(&k) {
                    break;
                }
                if self.workers[peer].pending.front() != Some(&(k, idx)) {
                    break;
                }
                self.workers[a].pending.pop_front();
                self.workers[peer].pending.pop_front();
                let start = self.workers[a]
                    .port_free
                    .max(self.workers[peer].port_free)
                    .max(self.workers[a].open[&k].ready)
                    .max(self.workers[peer].open[&k].ready);
                let failed = self.policy.link_fails(u, v, k);
                let lt = self.policy.link_time(j, u, v, k) * self.comm_scale;
                let done = start + lt;
                tracer.emit_at(start, TraceEvent::LinkBegin { matching: j, u, v, k });
                self.workers[a].port_free = done;
                self.workers[peer].port_free = done;
                self.total_comm += lt;
                self.queue
                    .schedule(done, EventKind::LinkDone { matching: j, edge: (u, v), k, failed });
                stack.push(peer);
            }
        }
    }

    fn on_link_done(
        &mut self,
        j: usize,
        (u, v): (usize, usize),
        k: usize,
        failed: bool,
        t: f64,
        grads: &mut dyn GradSource,
        observer: &mut dyn Observer,
        tracer: &mut Tracer<'_>,
    ) {
        if failed {
            self.dropped += 1;
            tracer.count(Counter::DroppedLinks, 1);
        }
        tracer.emit_at(t, TraceEvent::LinkEnd { matching: j, u, v, k, failed });
        tracer.count(Counter::LinkEvents, 1);
        // Per-edge model-version drift: how many steps past round k the
        // faster endpoint already is. Bounded by `max_staleness` via the
        // compute gate.
        let tau = self.workers[u].ver.max(self.workers[v].ver).saturating_sub(k + 1);
        tracer.emit_at(t, TraceEvent::StaleExchange { worker: u, peer: v, staleness: tau, k });
        tracer.count(Counter::Exchanges, 1);
        tracer.observe(Hist::Staleness, tau as f64);
        tracer.observatory.on_stale_exchange(u, v, tau);
        for w in [u, v] {
            let wk = &mut self.workers[w];
            wk.exchanges += 1;
            wk.staleness_sum += tau;
            wk.staleness_max = wk.staleness_max.max(tau);
        }
        if !failed {
            tracer.observatory.on_link(j, u, v);
            let su = self.workers[u].open[&k].snapshot;
            let sv = self.workers[v].open[&k].snapshot;
            let mut diff = std::mem::take(&mut self.diff);
            let mut comp = std::mem::take(&mut self.comp);
            edge_diff_message_src(
                RowSource::Host(self.snap.row(su)),
                RowSource::Host(self.snap.row(sv)),
                &mut diff,
                self.cfg.compression.as_ref(),
                &mut comp,
                self.cfg.seed,
                k,
                j,
                u,
                v,
            );
            self.comp = comp;
            // Staleness-aware pairwise rule: damp the exchange by
            // 1 / (1 + τ). τ = 0 leaves the synchronous update intact
            // (±1.0 · diff is bit-exact).
            let damp = 1.0 / (1.0 + tau as f64);
            let plan = self.plan;
            for (w, sign) in [(u, 1.0), (v, -1.0)] {
                let staged = self.snap.alloc();
                for (o, &di) in self.snap.row_mut(staged).iter_mut().zip(diff.iter()) {
                    *o = sign * damp * di;
                }
                let rm = self.workers[w].open.get_mut(&k).expect("round open");
                let pos = rm
                    .incident
                    .iter()
                    .position(|&e| plan.rounds[k][e] == (j, u, v))
                    .expect("edge incident to endpoint");
                rm.slots[pos] = Some(staged);
            }
            self.diff = diff;
        }
        for w in [u, v] {
            let complete = {
                let rm = self.workers[w].open.get_mut(&k).expect("round open");
                rm.remaining -= 1;
                rm.remaining == 0
            };
            if complete {
                self.apply_round(w, k, t, observer, tracer);
                self.start_compute(w, t, grads, tracer);
            }
        }
    }

    /// All of `w`'s round-`k` exchanges completed: fold the collected
    /// contributions in global edge order and apply the mix to the live
    /// iterate (which may already include later compute steps — the
    /// AD-PSGD delayed update).
    fn apply_round(
        &mut self,
        w: usize,
        k: usize,
        t: f64,
        observer: &mut dyn Observer,
        tracer: &mut Tracer<'_>,
    ) {
        let rm = self.workers[w].open.remove(&k).expect("round open");
        let mut delta = std::mem::take(&mut self.delta);
        delta.iter_mut().for_each(|v| *v = 0.0);
        for &staged in rm.slots.iter().flatten() {
            for (di, &ci) in delta.iter_mut().zip(self.snap.row(staged)) {
                *di += ci;
            }
        }
        let alpha = self.cfg.alpha;
        for (xi, &di) in self.arena.row_mut(w).iter_mut().zip(&delta) {
            *xi += alpha * di;
        }
        self.delta = delta;
        // The round is absorbed: recycle its pool rows.
        self.snap.release(rm.snapshot);
        for staged in rm.slots.into_iter().flatten() {
            self.snap.release(staged);
        }
        self.after_round_applied(w, t, observer, tracer);
    }

    /// Advance `through`, capture record snapshots, and fire the
    /// streaming callbacks for rounds that just became globally applied.
    fn after_round_applied(
        &mut self,
        w: usize,
        t: f64,
        observer: &mut dyn Observer,
        tracer: &mut Tracer<'_>,
    ) {
        let new_through = {
            let wk = &self.workers[w];
            wk.open.keys().next().copied().unwrap_or(wk.next_round)
        };
        let old = self.workers[w].through;
        if new_through <= old {
            return;
        }
        self.workers[w].through = new_through;
        for r in old..new_through {
            if self.is_record_round(r) {
                let row = self.snap.alloc_from(self.arena.row(w));
                let m = self.m;
                let entry = self.record_snaps.entry(r).or_insert_with(|| vec![None; m]);
                entry[w] = Some(row);
                if entry.iter().all(Option::is_some) {
                    let rows = self.record_snaps.remove(&r).expect("record entry");
                    for (wi, row) in rows.into_iter().enumerate() {
                        let row = row.expect("snapshot");
                        self.record_stage.row_mut(wi).copy_from_slice(self.snap.row(row));
                        self.snap.release(row);
                    }
                    if let Some(wstats) = record_metrics(
                        self.problem,
                        r + 1,
                        t,
                        self.total_comm,
                        &self.record_stage,
                        &mut self.metrics,
                        tracer,
                    ) {
                        observer.on_window(&wstats);
                    }
                    observer.on_record(r + 1, t, &self.metrics);
                }
            }
        }
        let new_global = self.workers.iter().map(|wk| wk.through).min().unwrap_or(0);
        while self.global_through < new_global {
            // Ledger matching counts advance with the globally applied
            // frontier, so every round is absorbed exactly once; links
            // are counted per completed exchange in `on_link_done`.
            tracer.observatory.on_matchings(self.plan.activated(self.global_through));
            self.global_through += 1;
            observer.on_iteration(self.global_through, t, self.total_comm);
        }
        if self.workers[w].through == self.iterations {
            self.workers[w].finish = t;
        }
    }
}

fn drive_async<P: Problem + ?Sized>(
    problem: &P,
    plan: &RoundPlan,
    policy: &mut dyn DelayPolicy,
    config: &AsyncConfig,
    grads: &mut dyn GradSource,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> AsyncResult {
    let cfg = &config.run;
    assert!(
        !matches!(cfg.delay, DelayModel::MaxDegree),
        "the async runtime needs a link-granular delay model (unit or stochastic); \
         maxdeg has no per-link schedule"
    );
    let m = problem.num_workers();
    let d = problem.dim();
    let xs0 = init_iterates(cfg.seed, m, d);
    let mut metrics = Recorder::new();
    if let Some(w) = record_metrics(problem, 0, 0.0, 0.0, &xs0, &mut metrics, tracer) {
        observer.on_window(&w);
    }
    observer.on_record(0, 0.0, &metrics);

    let comm_scale = match &cfg.compression {
        Some(c) => c.time_factor(cfg.latency_floor),
        None => 1.0,
    };
    let mut driver = Driver {
        problem,
        plan,
        policy,
        cfg,
        max_staleness: config.max_staleness,
        iterations: cfg.iterations,
        m,
        comm_scale,
        workers: (0..m).map(|_| Worker::new(cfg.lr)).collect(),
        arena: xs0,
        snap: SnapshotPool::new(d),
        queue: EventQueue::new(),
        metrics,
        record_snaps: BTreeMap::new(),
        record_stage: StateMatrix::zeros(m, d),
        global_through: 0,
        total_comm: 0.0,
        dropped: 0,
        max_time: 0.0,
        grad: vec![0.0; d],
        diff: vec![0.0; d],
        delta: vec![0.0; d],
        comp: Vec::with_capacity(d),
    };

    for w in 0..m {
        driver.start_compute(w, 0.0, grads, tracer);
    }
    loop {
        let Some(ev) = driver.queue.pop() else { break };
        tracer.observe(Hist::QueueDepth, driver.queue.len() as f64);
        driver.max_time = driver.max_time.max(ev.time);
        match ev.kind {
            EventKind::ComputeDone { worker, k } => {
                driver.on_compute_done(worker, k, ev.time, grads, observer, tracer)
            }
            EventKind::LinkDone { matching, edge, k, failed } => {
                driver.on_link_done(matching, edge, k, failed, ev.time, grads, observer, tracer)
            }
        }
    }
    for (w, wk) in driver.workers.iter().enumerate() {
        assert!(
            wk.through == driver.iterations
                && !wk.computing
                && wk.open.is_empty()
                && wk.pending.is_empty(),
            "async runtime stalled: worker {w} stopped at round {}/{}",
            wk.through,
            driver.iterations
        );
    }

    let stats = AsyncStats {
        per_worker: driver
            .workers
            .iter()
            .map(|wk| WorkerStats {
                exchanges: wk.exchanges,
                staleness_sum: wk.staleness_sum,
                max_staleness: wk.staleness_max,
                idle_time: wk.idle,
                finish_time: wk.finish,
            })
            .collect(),
    };
    AsyncResult {
        run: RunResult {
            final_mean: driver.arena.mean(),
            final_states: driver.arena,
            total_time: driver.max_time,
            total_comm_units: driver.total_comm,
            metrics: driver.metrics,
        },
        dropped_links: driver.dropped,
        events: driver.queue.processed(),
        stats,
    }
}

/// Run the asynchronous gossip runtime. Equivalent to
/// [`run_async_observed`] with a no-op observer.
pub fn run_async<P, S>(
    problem: &P,
    matchings: &[crate::graph::Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &AsyncConfig,
) -> AsyncResult
where
    P: Problem + Sync,
    S: TopologySampler,
{
    run_async_observed(problem, matchings, sampler, policy, config, &mut NoopObserver)
}

/// [`run_async`] with streaming observation: `observer.on_iteration`
/// fires as each round becomes globally applied, `observer.on_record` at
/// each metrics record (captured per worker as its own clock passes the
/// record round). All callbacks run on the driving thread.
pub fn run_async_observed<P, S>(
    problem: &P,
    matchings: &[crate::graph::Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &AsyncConfig,
    observer: &mut dyn Observer,
) -> AsyncResult
where
    P: Problem + Sync,
    S: TopologySampler,
{
    run_async_traced(problem, matchings, sampler, policy, config, observer, &mut Tracer::disabled())
}

/// [`run_async_observed`] with trace emission: compute/link spans,
/// stale-exchange markers and run counters/histograms flow through
/// `tracer`. With a disabled tracer this **is** the observed run — the
/// trajectory never depends on tracing.
pub fn run_async_traced<P, S>(
    problem: &P,
    matchings: &[crate::graph::Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &AsyncConfig,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> AsyncResult
where
    P: Problem + Sync,
    S: TopologySampler,
{
    let m = problem.num_workers();
    let d = problem.dim();
    let plan = RoundPlan::generate(sampler, matchings, config.run.iterations);
    let threads = config.threads.min(m);
    if threads <= 1 {
        let mut grads = InlineGrad {
            problem,
            rngs: worker_streams(config.run.seed, m),
            grads: StateMatrix::zeros(m, d),
            ready: (0..m).map(|_| None).collect(),
        };
        drive_async(problem, &plan, policy, config, &mut grads, observer, tracer)
    } else {
        std::thread::scope(|scope| {
            let all_rngs = worker_streams(config.run.seed, m);
            let shards: Vec<GradShard<'_, P>> = (0..threads)
                .map(|s| GradShard {
                    problem,
                    shards: threads,
                    rngs: shard_workers(s, threads, m).map(|w| all_rngs[w].clone()).collect(),
                    scratch: vec![0.0; d],
                })
                .collect();
            let pool =
                ShardedPool::spawn(scope, shards, |st: &mut GradShard<'_, P>, c: GradCmd| {
                    st.handle(c)
                });
            let mut grads = PoolGrad {
                pool: &pool,
                shards: threads,
                stash: BTreeMap::new(),
                spare: Vec::new(),
            };
            let result = drive_async(problem, &plan, policy, config, &mut grads, observer, tracer);
            drop(grads);
            drop(pool);
            result
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::optimize_activation_probabilities;
    use crate::engine::AnalyticPolicy;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::mixing::optimize_alpha;
    use crate::sim::{run_decentralized, QuadraticProblem};
    use crate::topology::{MatchaSampler, VanillaSampler};

    fn quad(m: usize) -> QuadraticProblem {
        let mut rng = Rng::new(99);
        QuadraticProblem::generate(m, 10, 1.0, 0.1, &mut rng)
    }

    fn cfg(iterations: usize, alpha: f64, seed: u64) -> RunConfig {
        RunConfig { lr: 0.02, iterations, alpha, seed, ..RunConfig::default() }
    }

    #[test]
    fn staleness_zero_matches_sim_bit_for_bit() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.5);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let p = quad(8);
        let run_cfg = cfg(200, mix.alpha, 12);

        let mut s1 = MatchaSampler::new(probs.probabilities.clone(), 4);
        let reference = run_decentralized(&p, &d.matchings, &mut s1, &run_cfg);

        let mut s2 = MatchaSampler::new(probs.probabilities.clone(), 4);
        let mut policy = AnalyticPolicy::matching_run_config(&run_cfg);
        let async_cfg = AsyncConfig { run: run_cfg, threads: 1, max_staleness: 0 };
        let res = run_async(&p, &d.matchings, &mut s2, &mut policy, &async_cfg);

        assert_eq!(res.run.final_mean, reference.final_mean);
        let a = res.run.metrics.get("loss_vs_iter");
        let b = reference.metrics.get("loss_vs_iter");
        assert_eq!(a.len(), b.len());
        for (pa, pb) in a.iter().zip(b) {
            assert_eq!(pa.x, pb.x);
            assert_eq!(pa.y, pb.y);
        }
        assert_eq!(res.stats.max_staleness(), 0);
        assert!(res.events > 0);
    }

    #[test]
    fn thread_count_never_changes_results() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        for staleness in [0usize, 3] {
            let run = |threads: usize| {
                let mut sampler = VanillaSampler::new(d.len());
                let run_cfg = cfg(120, 0.12, 7);
                let mut policy = AnalyticPolicy::matching_run_config(&run_cfg);
                let async_cfg = AsyncConfig { run: run_cfg, threads, max_staleness: staleness };
                run_async(&p, &d.matchings, &mut sampler, &mut policy, &async_cfg)
            };
            let a = run(1);
            let b = run(4);
            assert_eq!(a.run.final_mean, b.run.final_mean, "staleness {staleness}");
            assert_eq!(a.run.total_time, b.run.total_time, "staleness {staleness}");
            assert_eq!(a.stats, b.stats, "staleness {staleness}");
        }
    }

    #[test]
    fn staleness_respects_the_configured_bound() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        for bound in [0usize, 1, 2, 5] {
            let mut sampler = VanillaSampler::new(d.len());
            let run_cfg = cfg(150, 0.1, 3);
            let mut policy = crate::engine::StragglerPolicy::new(
                AnalyticPolicy::matching_run_config(&run_cfg),
                vec![2],
                5.0,
            );
            let async_cfg = AsyncConfig { run: run_cfg, threads: 1, max_staleness: bound };
            let res = run_async(&p, &d.matchings, &mut sampler, &mut policy, &async_cfg);
            assert!(
                res.stats.max_staleness() <= bound,
                "bound {bound} violated: {}",
                res.stats.max_staleness()
            );
        }
    }

    #[test]
    fn straggler_run_is_faster_without_the_barrier() {
        // Barrier mode pays (straggler compute + full comm) per
        // iteration; async overlaps the straggler's compute with its
        // (shorter) communication, so virtual time strictly drops.
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        let iters = 120;
        let run_cfg = cfg(iters, 0.1, 5);

        let mut s1 = VanillaSampler::new(d.len());
        let mut barrier_policy = crate::engine::StragglerPolicy::new(
            AnalyticPolicy::matching_run_config(&run_cfg),
            vec![0],
            8.0,
        );
        let barrier = crate::engine::run_engine(
            &p,
            &d.matchings,
            &mut s1,
            &mut barrier_policy,
            &crate::engine::EngineConfig { run: run_cfg.clone(), threads: 1 },
        );

        let mut s2 = VanillaSampler::new(d.len());
        let mut async_policy = crate::engine::StragglerPolicy::new(
            AnalyticPolicy::matching_run_config(&run_cfg),
            vec![0],
            8.0,
        );
        let async_cfg = AsyncConfig { run: run_cfg, threads: 1, max_staleness: 8 };
        let res = run_async(&p, &d.matchings, &mut s2, &mut async_policy, &async_cfg);

        assert!(
            res.run.total_time < barrier.run.total_time,
            "async {} vs barrier {}",
            res.run.total_time,
            barrier.run.total_time
        );
        assert!(res.stats.mean_staleness() > 0.0, "straggler should induce staleness");
        assert!(res.stats.total_idle() > 0.0, "fast workers should log gate waits");
    }

    #[test]
    fn flaky_links_drop_but_still_converge() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        let run_cfg = cfg(400, 0.15, 3);
        let mut sampler = VanillaSampler::new(d.len());
        let mut policy = crate::engine::FlakyLinkPolicy::new(
            AnalyticPolicy::matching_run_config(&run_cfg),
            0.3,
            11,
        );
        let async_cfg = AsyncConfig { run: run_cfg, threads: 2, max_staleness: 2 };
        let res = run_async(&p, &d.matchings, &mut sampler, &mut policy, &async_cfg);
        assert!(res.dropped_links > 0, "failure injection must trigger");
        let sub0 = res.run.metrics.get("subopt_vs_iter")[0].y;
        let subf = res.run.metrics.last("subopt_vs_iter").unwrap();
        assert!(subf < 0.2 * sub0, "no convergence under flaky links: {sub0} -> {subf}");
    }
}
