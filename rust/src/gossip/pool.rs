//! Bounded sharded worker pool: multiplex N logical workers over a fixed
//! number of OS threads.
//!
//! The engine's original actor mode spawned **one thread per worker**,
//! which forced a sequential fallback above 256 workers. This pool
//! removes that cap: logical workers are sharded round-robin across
//! `threads` OS threads (`shard_of`), each shard owning the sticky
//! per-worker state (iterates, RNG streams) for its workers. Commands for
//! one worker are always handled by the same shard thread **in send
//! order**, so per-worker RNG streams advance deterministically and
//! results are independent of the pool size — the property both users of
//! the pool (the barrier engine's actor executor and the asynchronous
//! gossip runtime of [`crate::gossip::runtime`]) rely on for bit-for-bit
//! reproducibility.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::Scope;

/// Which shard owns logical worker `worker` in a pool of `shards` threads.
pub fn shard_of(worker: usize, shards: usize) -> usize {
    worker % shards
}

/// The slot index of `worker` within its shard's worker list (shards own
/// workers `s, s + shards, s + 2·shards, ...` in ascending order).
pub fn shard_slot(worker: usize, shards: usize) -> usize {
    worker / shards
}

/// The workers shard `shard` owns out of `m`, in slot order. The single
/// source of truth for the round-robin assignment: every pool user must
/// build its per-shard state with this iterator so that
/// [`shard_of`]/[`shard_slot`] routing stays consistent (bit-for-bit
/// reproducibility depends on each worker's sticky state — RNG stream,
/// iterate — living at exactly this slot).
pub fn shard_workers(shard: usize, shards: usize, m: usize) -> impl Iterator<Item = usize> {
    (shard..m).step_by(shards)
}

/// A pool of shard threads, each folding commands into its private state
/// with a shared handler function. One reply per command; replies arrive
/// on a single channel in completion order.
pub struct ShardedPool<C, R> {
    txs: Vec<Sender<C>>,
    rx: Receiver<R>,
}

impl<C: Send, R: Send> ShardedPool<C, R> {
    /// Spawn one thread per element of `shards` inside `scope`. Each
    /// thread loops `reply = handler(&mut state, cmd)` until the pool is
    /// dropped (which closes the command channels).
    ///
    /// Dropping the pool before the scope ends is what lets the scope
    /// join: keep it alive only as long as commands are in flight.
    pub fn spawn<'scope, 'env, S, F>(
        scope: &'scope Scope<'scope, 'env>,
        shards: Vec<S>,
        handler: F,
    ) -> Self
    where
        S: Send + 'scope,
        C: 'scope,
        R: 'scope,
        F: Fn(&mut S, C) -> R + Send + Clone + 'scope,
    {
        let (reply_tx, reply_rx) = channel::<R>();
        let mut txs = Vec::with_capacity(shards.len());
        for state in shards {
            let (tx, rx) = channel::<C>();
            txs.push(tx);
            let rtx = reply_tx.clone();
            let f = handler.clone();
            scope.spawn(move || {
                let mut state = state;
                while let Ok(cmd) = rx.recv() {
                    if rtx.send(f(&mut state, cmd)).is_err() {
                        return;
                    }
                }
            });
        }
        ShardedPool { txs, rx: reply_rx }
    }

    /// Send a command to shard `shard`.
    pub fn send(&self, shard: usize, cmd: C) {
        self.txs[shard].send(cmd).expect("pool shard thread died");
    }

    /// Receive the next reply (blocking), in completion order across
    /// shards.
    pub fn recv(&self) -> R {
        self.rx.recv().expect("pool shard thread died")
    }

    /// Number of shard threads.
    pub fn num_shards(&self) -> usize {
        self.txs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_assignment_round_robin() {
        assert_eq!(shard_of(0, 4), 0);
        assert_eq!(shard_of(5, 4), 1);
        assert_eq!(shard_slot(0, 4), 0);
        assert_eq!(shard_slot(5, 4), 1);
        assert_eq!(shard_slot(9, 4), 2);
    }

    #[test]
    fn pool_routes_commands_to_sticky_state() {
        // Each shard's state is a counter; commands increment it and
        // return (shard id, count). Worker stickiness means each shard
        // sees exactly its own commands, in order.
        std::thread::scope(|scope| {
            let shards = vec![(0usize, 0usize), (1usize, 0usize)];
            let pool = ShardedPool::spawn(scope, shards, |st: &mut (usize, usize), add: usize| {
                st.1 += add;
                (st.0, st.1)
            });
            pool.send(0, 1);
            pool.send(1, 10);
            pool.send(0, 2);
            pool.send(1, 20);
            let mut finals = [0usize; 2];
            for _ in 0..4 {
                let (shard, count) = pool.recv();
                finals[shard] = finals[shard].max(count);
            }
            assert_eq!(finals, [3, 30]);
            drop(pool);
        });
    }

    #[test]
    fn pool_handles_many_workers_on_few_threads() {
        // 300 logical workers multiplexed over 3 shard threads — the
        // scenario the old one-thread-per-worker actor mode could not run.
        let workers = 300usize;
        let threads = 3usize;
        std::thread::scope(|scope| {
            let shards: Vec<Vec<usize>> = (0..threads)
                .map(|s| (s..workers).step_by(threads).collect())
                .collect();
            let pool = ShardedPool::spawn(scope, shards, |owned: &mut Vec<usize>, w: usize| {
                assert!(owned.contains(&w), "worker routed to wrong shard");
                w * 2
            });
            for w in 0..workers {
                pool.send(shard_of(w, threads), w);
            }
            let mut sum = 0usize;
            for _ in 0..workers {
                sum += pool.recv();
            }
            assert_eq!(sum, workers * (workers - 1));
            drop(pool);
        });
    }
}
