//! Precomputed activation rounds for the asynchronous runtime.
//!
//! The barrier engine consumes the [`crate::topology::TopologySampler`]
//! round-by-round on a single driving thread. The asynchronous runtime
//! has no such global loop — workers reach a given round at different
//! times — so the whole activation sequence is materialized up front
//! (the paper's "apriori schedule" observation makes this free) and every
//! round's activated edges are flattened into one list in global
//! **(activation order, edge order)**. That order is load-bearing: it is
//! the accumulation order of the shared gossip kernel
//! ([`crate::sim::kernel::apply_gossip`]), and the runtime folds each
//! worker's per-round mix contributions in exactly this order to stay
//! bit-for-bit compatible with the synchronous paths at staleness 0.

use crate::graph::Graph;
use crate::topology::TopologySampler;

/// One activated edge: `(matching, u, v)` with the canonical `u < v`
/// orientation of the matching storage.
pub type RoundEdge = (usize, usize, usize);

/// The full activation sequence of a run, flattened to per-round edge
/// lists.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// `rounds[k]` = activated edges of iteration `k` in global
    /// (activation order, edge order). Empty when the sampler activated
    /// nothing that round (e.g. P-DecenSGD off-rounds).
    pub rounds: Vec<Vec<RoundEdge>>,
    /// `activated[k]` = the matching indices the sampler activated at
    /// round `k`, in activation order — the pre-flattening view. The
    /// cluster coordinator replays these through the barrier engine's
    /// drive loop ([`RoundPlan::activated`]).
    activated: Vec<Vec<usize>>,
    /// Per round: `(worker, incident edge indices)` pairs sorted by
    /// worker; only workers with at least one incident edge appear.
    /// Built once in [`RoundPlan::generate`] so [`RoundPlan::incident`]
    /// costs a binary search instead of a scan of the whole edge list.
    incidence: Vec<Vec<(usize, Vec<usize>)>>,
}

impl RoundPlan {
    /// Materialize `iterations` rounds from the sampler. Consumes the
    /// sampler's RNG stream exactly as the synchronous loops do (one
    /// `round(k)` call per iteration, in order), so a given
    /// `(sampler seed, iterations)` yields the same activation sequence
    /// on every backend.
    pub fn generate<S: TopologySampler + ?Sized>(
        sampler: &mut S,
        matchings: &[Graph],
        iterations: usize,
    ) -> RoundPlan {
        let mut rounds = Vec::with_capacity(iterations);
        let mut incidence = Vec::with_capacity(iterations);
        let mut activated = Vec::with_capacity(iterations);
        for k in 0..iterations {
            let round = sampler.round(k);
            let mut edges = Vec::new();
            for &j in &round.activated {
                for &(u, v) in matchings[j].edges() {
                    edges.push((j, u, v));
                }
            }
            activated.push(round.activated);
            let mut by_worker: std::collections::BTreeMap<usize, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (i, &(_, u, v)) in edges.iter().enumerate() {
                by_worker.entry(u).or_default().push(i);
                by_worker.entry(v).or_default().push(i);
            }
            rounds.push(edges);
            incidence.push(by_worker.into_iter().collect());
        }
        RoundPlan { rounds, activated, incidence }
    }

    /// The matching indices activated at round `k`, in activation order
    /// (exactly what the sampler returned — the input the barrier
    /// engine's drive loop expects per round).
    pub fn activated(&self, k: usize) -> &[usize] {
        &self.activated[k]
    }

    /// Indices (into `rounds[k]`) of the edges incident to `worker` at
    /// round `k`, in global order.
    pub fn incident(&self, k: usize, worker: usize) -> Vec<usize> {
        let row = &self.incidence[k];
        match row.binary_search_by_key(&worker, |&(w, _)| w) {
            Ok(i) => row[i].1.clone(),
            Err(_) => Vec::new(),
        }
    }

    /// Number of rounds.
    pub fn len(&self) -> usize {
        self.rounds.len()
    }

    /// True when the plan holds no rounds.
    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::topology::{MatchaSampler, VanillaSampler};

    #[test]
    fn vanilla_plan_lists_every_edge_every_round() {
        let d = decompose(&paper_figure1_graph());
        let total_edges: usize = d.matchings.iter().map(|m| m.edges().len()).sum();
        let mut s = VanillaSampler::new(d.len());
        let plan = RoundPlan::generate(&mut s, &d.matchings, 5);
        assert_eq!(plan.len(), 5);
        for k in 0..5 {
            assert_eq!(plan.rounds[k].len(), total_edges);
        }
    }

    #[test]
    fn plan_matches_sampler_stream() {
        let d = decompose(&paper_figure1_graph());
        let probs = vec![0.5; d.len()];
        let mut s1 = MatchaSampler::new(probs.clone(), 7);
        let plan = RoundPlan::generate(&mut s1, &d.matchings, 50);
        let mut s2 = MatchaSampler::new(probs, 7);
        for k in 0..50 {
            let round = s2.round(k);
            let mut expect = Vec::new();
            for &j in &round.activated {
                for &(u, v) in d.matchings[j].edges() {
                    expect.push((j, u, v));
                }
            }
            assert_eq!(plan.rounds[k], expect, "round {k}");
            assert_eq!(plan.activated(k), &round.activated[..], "activated {k}");
        }
    }

    #[test]
    fn incident_edges_are_in_global_order() {
        let d = decompose(&paper_figure1_graph());
        let mut s = VanillaSampler::new(d.len());
        let plan = RoundPlan::generate(&mut s, &d.matchings, 1);
        for w in 0..8 {
            let inc = plan.incident(0, w);
            assert!(inc.windows(2).all(|p| p[0] < p[1]), "unsorted incidence");
            for &i in &inc {
                let (_, u, v) = plan.rounds[0][i];
                assert!(u == w || v == w);
            }
        }
    }
}
