//! The decentralized NN-training coordinator (L3 over the XLA runtime)
//! and the strategy *planners* shared with the pure-Rust paths.
//!
//! Planning (decompose → probabilities → α → apriori schedule) is pure
//! Rust and always available; the `Trainer` that executes AOT-compiled
//! XLA artifacts lives behind the `xla` feature because the offline image
//! cannot build the `xla`/`anyhow` crates (see `Cargo.toml`).

use crate::delay::DelayModel;
use crate::graph::Graph;
use crate::matching::MatchingDecomposition;
use crate::metrics::Recorder;
use crate::topology::Schedule;

#[cfg(feature = "xla")]
mod trainer;
#[cfg(feature = "xla")]
pub use trainer::Trainer;

/// Configuration for one coordinated training run.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// Total iterations to run (bounded by the schedule length).
    pub steps: usize,
    pub lr: f32,
    /// Multiply lr by `lr_decay` every `lr_decay_every` steps.
    pub lr_decay: f32,
    pub lr_decay_every: usize,
    /// Evaluate held-out loss every this many steps.
    pub eval_every: usize,
    /// Use the Pallas-kernel train_step artifact (vs the XLA-fused one).
    pub use_pallas: bool,
    /// Computation time per iteration in delay units (relative to one
    /// link's communication time; the paper's CIFAR runs are
    /// communication-dominated, i.e. small values here).
    pub compute_units: f64,
    pub delay: DelayModel,
    /// Tokens per worker shard in the synthetic corpus.
    pub tokens_per_worker: usize,
    pub non_iid: bool,
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            steps: 200,
            lr: 0.5,
            lr_decay: 1.0,
            lr_decay_every: usize::MAX,
            eval_every: 50,
            use_pallas: false,
            compute_units: 1.0,
            delay: DelayModel::UnitPerMatching,
            tokens_per_worker: 20_000,
            non_iid: false,
            seed: 0,
        }
    }
}

/// Outcome of a coordinated run.
pub struct TrainReport {
    pub metrics: Recorder,
    pub final_train_loss: f64,
    pub final_eval_loss: f64,
    pub total_time_units: f64,
    pub total_comm_units: f64,
    pub wallclock_secs: f64,
}

/// **Legacy path.** The planning math now lives in
/// [`crate::experiment::Plan`]; this struct and the `plan_*` helpers
/// below are thin wrappers kept for the XLA `Trainer` path and older
/// harnesses. New code should build an
/// [`crate::experiment::ExperimentSpec`] and call
/// [`crate::experiment::plan()`].
pub struct MatchaPlan {
    pub decomposition: MatchingDecomposition,
    pub probabilities: Vec<f64>,
    pub lambda2: f64,
    pub alpha: f64,
    pub rho: f64,
    pub schedule: Schedule,
}

fn plan_with(
    base: &Graph,
    strategy: crate::experiment::Strategy,
    steps: usize,
    seed: u64,
) -> MatchaPlan {
    // Infallible signature kept for legacy callers; invalid inputs (bad
    // budget, disconnected graph) panicked here historically too, via the
    // optimizer's own asserts.
    let plan = crate::experiment::Plan::for_graph(base.clone(), strategy)
        .unwrap_or_else(|e| panic!("legacy plan_* helper: {e}"));
    let schedule = plan.schedule(steps, seed);
    MatchaPlan {
        decomposition: plan.decomposition,
        probabilities: plan.probabilities,
        lambda2: plan.lambda2,
        alpha: plan.alpha,
        rho: plan.rho,
        schedule,
    }
}

/// **Legacy.** MATCHA plan: decomposition, optimized activation
/// probabilities at budget `cb`, optimized mixing weight, and a
/// pregenerated `steps`-round schedule. Delegates to
/// [`crate::experiment::Plan::for_graph`].
pub fn plan_matcha(base: &Graph, cb: f64, steps: usize, seed: u64) -> MatchaPlan {
    plan_with(base, crate::experiment::Strategy::Matcha { budget: cb }, steps, seed)
}

/// **Legacy.** Vanilla-DecenSGD plan (all matchings every round,
/// closed-form optimal α). Delegates to
/// [`crate::experiment::Plan::for_graph`].
pub fn plan_vanilla(base: &Graph, steps: usize) -> MatchaPlan {
    plan_with(base, crate::experiment::Strategy::Vanilla, steps, 0)
}

/// **Legacy.** P-DecenSGD plan at budget `cb` (full graph every ⌈1/cb⌉
/// rounds, α optimized for the correlated activation model). Delegates to
/// [`crate::experiment::Plan::for_graph`].
pub fn plan_periodic(base: &Graph, cb: f64, steps: usize) -> MatchaPlan {
    plan_with(base, crate::experiment::Strategy::Periodic { budget: cb }, steps, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;

    #[test]
    fn plan_matcha_produces_consistent_artifacts() {
        let g = paper_figure1_graph();
        let plan = plan_matcha(&g, 0.5, 100, 1);
        assert_eq!(plan.schedule.rounds.len(), 100);
        assert!(plan.rho < 1.0);
        assert!(plan.alpha > 0.0);
        assert!(plan.lambda2 > 0.0);
        // Expected comm of the schedule tracks Σp.
        let target: f64 = plan.probabilities.iter().sum();
        let got = plan.schedule.mean_comm_units();
        assert!((got - target).abs() < 0.8, "schedule comm {got} vs Σp {target}");
    }

    #[test]
    fn plan_vanilla_activates_everything() {
        let g = paper_figure1_graph();
        let plan = plan_vanilla(&g, 10);
        for r in &plan.schedule.rounds {
            assert_eq!(r.activated.len(), plan.decomposition.len());
        }
    }

    #[test]
    fn plan_periodic_budget() {
        let g = paper_figure1_graph();
        let plan = plan_periodic(&g, 0.25, 100);
        let mean = plan.schedule.mean_comm_units();
        let full = plan.decomposition.len() as f64;
        assert!((mean - 0.25 * full).abs() < 0.05 * full, "mean {mean} vs {}", 0.25 * full);
    }

    #[test]
    fn mixing_w_construction_matches_linalg() {
        // Compare the coordinator-style dense-W construction against
        // topology::mixing_matrix.
        use crate::topology::mixing_matrix;
        let g = paper_figure1_graph();
        let plan = plan_matcha(&g, 0.4, 1, 2);
        let m = g.num_nodes();
        let alpha = plan.alpha;
        let activated: Vec<usize> = (0..plan.decomposition.len()).collect();
        let mut w = vec![0.0f32; m * m];
        for i in 0..m {
            w[i * m + i] = 1.0;
        }
        for &j in &activated {
            for &(u, v) in plan.decomposition.matchings[j].edges() {
                w[u * m + u] -= alpha as f32;
                w[v * m + v] -= alpha as f32;
                w[u * m + v] += alpha as f32;
                w[v * m + u] += alpha as f32;
            }
        }
        let wm = mixing_matrix(&plan.decomposition.laplacians(), &activated, alpha);
        for i in 0..m {
            for j in 0..m {
                assert!(
                    (wm.get(i, j) - w[i * m + j] as f64).abs() < 1e-6,
                    "W mismatch at ({i},{j})"
                );
            }
        }
    }
}
