//! The XLA-backed trainer: executes the paper's training loop on the real
//! model (only compiled with the `xla` feature).
//!
//! Each of the `m` workers holds a flat parameter vector; per iteration
//! every worker runs the AOT-compiled `train_step` on a batch from its
//! own corpus shard (paper eq. (2)'s local gradient step), then the
//! activated topology's mixing matrix is applied through the AOT `mix`
//! computation (the consensus step). The schedule is pregenerated
//! (apriori, §1), runtime does zero scheduling work, and the virtual
//! clock charges the paper's delay model.

use super::{TrainReport, TrainerConfig};
use crate::config::{ArtifactPaths, ModelMeta};
use crate::data::{BatchIter, Corpus};
use crate::delay::VirtualClock;
use crate::matching::MatchingDecomposition;
use crate::metrics::Recorder;
use crate::rng::Rng;
use crate::runtime::{
    literal_f32, literal_i32, literal_scalar_f32, to_scalar_f32, to_vec_f32, Executable,
    Runtime,
};
use crate::topology::Schedule;
use anyhow::{Context, Result};

/// The coordinator: owns the runtime, the compiled executables, the
/// worker states, and the data pipeline.
pub struct Trainer {
    meta: ModelMeta,
    train_exe: Executable,
    eval_exe: Executable,
    mix_exe: Executable,
    decomp: MatchingDecomposition,
    config: TrainerConfig,
}

impl Trainer {
    /// Load artifacts and compile the three computations.
    pub fn new(
        artifacts: &ArtifactPaths,
        decomp: MatchingDecomposition,
        config: TrainerConfig,
    ) -> Result<Trainer> {
        let meta = ModelMeta::load(&artifacts.meta()).map_err(anyhow::Error::msg)?;
        anyhow::ensure!(
            decomp.base.num_nodes() == meta.workers,
            "graph has {} nodes but artifacts were compiled for {} workers \
             (re-run `make artifacts WORKERS={}`)",
            decomp.base.num_nodes(),
            meta.workers,
            decomp.base.num_nodes()
        );
        let rt = Runtime::cpu()?;
        let train_exe = rt.load_hlo(&artifacts.train_step(config.use_pallas))?;
        let eval_exe = rt.load_hlo(&artifacts.eval_step())?;
        let mix_exe = rt.load_hlo(&artifacts.mix(config.use_pallas))?;
        Ok(Trainer { meta, train_exe, eval_exe, mix_exe, decomp, config })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Build the dense mixing matrix W = I − α Σ_{j∈activated} L_j as a
    /// row-major f32 buffer for the mix executable.
    fn mixing_w(&self, activated: &[usize], alpha: f64) -> Vec<f32> {
        let m = self.meta.workers;
        let mut w = vec![0.0f32; m * m];
        for i in 0..m {
            w[i * m + i] = 1.0;
        }
        for &j in activated {
            for &(u, v) in self.decomp.matchings[j].edges() {
                w[u * m + u] -= alpha as f32;
                w[v * m + v] -= alpha as f32;
                w[u * m + v] += alpha as f32;
                w[v * m + u] += alpha as f32;
            }
        }
        w
    }

    /// Run the schedule. `schedule.alpha` supplies α; iterations are
    /// `min(config.steps, schedule.rounds.len())`.
    pub fn run(&self, schedule: &Schedule) -> Result<TrainReport> {
        let cfg = &self.config;
        let meta = &self.meta;
        let m = meta.workers;
        let d = meta.param_count;
        let steps = cfg.steps.min(schedule.rounds.len());
        anyhow::ensure!(steps > 0, "empty schedule");

        // --- data ----------------------------------------------------
        let corpus = Corpus::synthesize(
            m,
            cfg.tokens_per_worker,
            (meta.batch * meta.seq_len * 4).max(4096),
            cfg.non_iid,
            cfg.seed,
        );
        let mut iters: Vec<BatchIter> = corpus
            .shards
            .iter()
            .enumerate()
            .map(|(w, s)| BatchIter::new(&s.tokens, meta.batch, meta.seq_len, cfg.seed ^ w as u64))
            .collect();
        let mut eval_iter =
            BatchIter::new(&corpus.eval, meta.batch, meta.seq_len, cfg.seed ^ 0xe7a1);
        // Fixed eval batches for a stable eval metric.
        let eval_batches: Vec<(Vec<i32>, Vec<i32>)> =
            (0..4).map(|_| eval_iter.next_batch()).collect();

        // --- worker states --------------------------------------------
        // All workers start from the same point (Theorem 1 initialization).
        let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
        let x0 = meta.init_params(&mut init_rng);
        let mut workers: Vec<Vec<f32>> = vec![x0; m];

        // --- loop ------------------------------------------------------
        let mut clock = VirtualClock::new(cfg.compute_units);
        let mut delay_rng = Rng::new(cfg.seed ^ 0xde1a);
        let mut metrics = Recorder::new();
        let mut total_comm = 0.0f64;
        let mut lr = cfg.lr;
        let batch_dims = [meta.batch as i64, meta.seq_len as i64];
        let wall_start = std::time::Instant::now();

        for k in 0..steps {
            // Local SGD step on every worker.
            let mut mean_loss = 0.0f64;
            for w in 0..m {
                let (xs, ys) = iters[w].next_batch();
                let inputs = [
                    literal_f32(&workers[w], &[d as i64])?,
                    literal_i32(&xs, &batch_dims)?,
                    literal_i32(&ys, &batch_dims)?,
                    literal_scalar_f32(lr),
                ];
                let outs = self
                    .train_exe
                    .run(&inputs)
                    .with_context(|| format!("train step k={k} worker={w}"))?;
                workers[w] = to_vec_f32(&outs[0])?;
                mean_loss += to_scalar_f32(&outs[1])? as f64 / m as f64;
            }

            // Consensus over the activated topology via the mix artifact.
            let round = &schedule.rounds[k];
            if !round.activated.is_empty() {
                let w_mat = self.mixing_w(&round.activated, schedule.alpha);
                let mut stacked = Vec::with_capacity(m * d);
                for wvec in &workers {
                    stacked.extend_from_slice(wvec);
                }
                let outs = self
                    .mix_exe
                    .run(&[
                        literal_f32(&w_mat, &[m as i64, m as i64])?,
                        literal_f32(&stacked, &[m as i64, d as i64])?,
                    ])
                    .with_context(|| format!("mix step k={k}"))?;
                let mixed = to_vec_f32(&outs[0])?;
                for (w, wvec) in workers.iter_mut().enumerate() {
                    wvec.copy_from_slice(&mixed[w * d..(w + 1) * d]);
                }
            }

            // Time accounting + metrics.
            let comm_t =
                cfg.delay
                    .comm_time(&self.decomp.matchings, &round.activated, &mut delay_rng);
            total_comm += comm_t;
            let now = clock.tick(comm_t);
            metrics.push("train_loss_vs_iter", k as f64, mean_loss);
            metrics.push("train_loss_vs_time", now, mean_loss);
            metrics.push("comm_units_vs_iter", k as f64, total_comm);

            if (k + 1) % cfg.lr_decay_every == 0 {
                lr *= cfg.lr_decay;
            }
            if (k + 1) % cfg.eval_every == 0 || k + 1 == steps {
                let eval = self.evaluate(&workers, &eval_batches, &batch_dims)?;
                metrics.push("eval_loss_vs_iter", (k + 1) as f64, eval);
                metrics.push("eval_loss_vs_time", now, eval);
            }
        }

        let final_eval = metrics.last("eval_loss_vs_iter").unwrap_or(f64::NAN);
        Ok(TrainReport {
            final_train_loss: metrics.last("train_loss_vs_iter").unwrap_or(f64::NAN),
            final_eval_loss: final_eval,
            total_time_units: clock.elapsed(),
            total_comm_units: total_comm,
            wallclock_secs: wall_start.elapsed().as_secs_f64(),
            metrics,
        })
    }

    /// Held-out loss of the averaged iterate x̄ (the paper's reported
    /// quantity is a function of the averaged model).
    fn evaluate(
        &self,
        workers: &[Vec<f32>],
        eval_batches: &[(Vec<i32>, Vec<i32>)],
        batch_dims: &[i64],
    ) -> Result<f64> {
        let d = self.meta.param_count;
        let m = workers.len();
        let mut mean = vec![0.0f32; d];
        for w in workers {
            for (a, &b) in mean.iter_mut().zip(w) {
                *a += b / m as f32;
            }
        }
        let mut acc = 0.0f64;
        for (xs, ys) in eval_batches {
            let outs = self.eval_exe.run(&[
                literal_f32(&mean, &[d as i64])?,
                literal_i32(xs, batch_dims)?,
                literal_i32(ys, batch_dims)?,
            ])?;
            acc += to_scalar_f32(&outs[0])? as f64 / eval_batches.len() as f64;
        }
        Ok(acc)
    }
}
