//! # matcha — MATCHA: Matching Decomposition Sampling for Decentralized SGD
//!
//! A production-grade reproduction of *“MATCHA: Speeding Up Decentralized
//! SGD via Matching Decomposition Sampling”* (Wang, Sahu, Yang, Joshi,
//! Kar, 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's contribution: matching
//!   decomposition ([`matching`]), activation-probability optimization
//!   ([`budget`]), mixing-weight optimization and spectral-norm analysis
//!   ([`mixing`]), the random topology scheduler ([`topology`]), the
//!   communication delay model ([`delay`]), a pure-Rust decentralized SGD
//!   simulator ([`sim`]), and the NN training coordinator
//!   ([`coordinator`]) that executes AOT-compiled XLA artifacts through
//!   [`runtime`].
//! - **L2/L1 (build-time Python, `python/compile/`)** — a flat-parameter
//!   transformer LM and Pallas kernels, lowered once to HLO text in
//!   `artifacts/`; Python is never on the training path.
//!
//! Quick tour (`no_run` only because rustdoc's test binary misses the
//! xla_extension rpath in this offline image; the same code is exercised
//! by `rust/tests/integration.rs`):
//!
//! ```no_run
//! use matcha::graph::paper_figure1_graph;
//! use matcha::matching::decompose;
//! use matcha::budget::optimize_activation_probabilities;
//! use matcha::mixing::optimize_alpha;
//!
//! let g = paper_figure1_graph();
//! let decomp = decompose(&g);                  // Step 1: matchings
//! let probs = optimize_activation_probabilities(&decomp, 0.5); // Step 2
//! let mix = optimize_alpha(&decomp, &probs.probabilities);     // Step 3
//! assert!(mix.rho < 1.0); // Theorem 2: convergence guaranteed
//! ```

pub mod benchkit;
pub mod budget;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod graph;
pub mod json;
pub mod linalg;
pub mod matching;
pub mod metrics;
pub mod mixing;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod topology;
