//! # matcha — MATCHA: Matching Decomposition Sampling for Decentralized SGD
//!
//! A production-grade reproduction of *“MATCHA: Speeding Up Decentralized
//! SGD via Matching Decomposition Sampling”* (Wang, Sahu, Yang, Joshi,
//! Kar, 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's contribution: matching
//!   decomposition ([`matching`]), activation-probability optimization
//!   ([`budget`]), mixing-weight optimization and spectral-norm analysis
//!   ([`mixing`]), the random topology scheduler ([`topology`]), the
//!   communication delay model ([`delay`]), a pure-Rust decentralized SGD
//!   simulator ([`sim`]), the **event-driven parallel execution engine**
//!   ([`engine`]), and the NN training coordinator ([`coordinator`]) that
//!   executes AOT-compiled XLA artifacts through `runtime` (gated behind
//!   the `xla` feature — the offline image cannot build the XLA crates).
//! - **L2/L1 (build-time Python, `python/compile/`)** — a flat-parameter
//!   transformer LM and Pallas kernels, lowered once to HLO text in
//!   `artifacts/`; Python is never on the training path.
//!
//! ## Execution paths
//!
//! Two paths run the DecenSGD recursion and share one step/mix kernel
//! ([`sim::kernel`]), so they agree **bit-for-bit** per seed:
//!
//! - [`sim::run_decentralized`] — the sequential reference loop with
//!   closed-form time accounting ([`delay::DelayModel`]).
//! - [`engine::run_engine`] — a discrete-event engine (event queue at
//!   per-link granularity, [`engine::DelayPolicy`] time models for
//!   stragglers / heterogeneous links / link failures) whose parallel
//!   mode runs each worker as an actor on a `std::thread`, exchanging
//!   gossip messages over channels. [`engine::sweep`] fans independent
//!   budget/topology grid points across cores.
//!
//! Quick tour (runs as a doctest — the default build is pure Rust now
//! that the XLA path is feature-gated):
//!
//! ```
//! use matcha::graph::paper_figure1_graph;
//! use matcha::matching::decompose;
//! use matcha::budget::optimize_activation_probabilities;
//! use matcha::mixing::optimize_alpha;
//!
//! let g = paper_figure1_graph();
//! let decomp = decompose(&g);                  // Step 1: matchings
//! let probs = optimize_activation_probabilities(&decomp, 0.5); // Step 2
//! let mix = optimize_alpha(&decomp, &probs.probabilities);     // Step 3
//! assert!(mix.rho < 1.0); // Theorem 2: convergence guaranteed
//! ```

// The codebase favors explicit index loops for the numerical kernels
// (mirrors the paper's equations); keep clippy's style lints from
// fighting that in `ci.sh`'s `-D warnings` run.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod benchkit;
pub mod budget;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod engine;
pub mod graph;
pub mod json;
pub mod linalg;
pub mod matching;
pub mod metrics;
pub mod mixing;
pub mod proptest;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sim;
pub mod topology;
