//! # matcha — MATCHA: Matching Decomposition Sampling for Decentralized SGD
//!
//! A production-grade reproduction of *“MATCHA: Speeding Up Decentralized
//! SGD via Matching Decomposition Sampling”* (Wang, Sahu, Yang, Joshi,
//! Kar, 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's contribution: matching
//!   decomposition ([`matching`]), activation-probability optimization
//!   ([`budget`]), mixing-weight optimization and spectral-norm analysis
//!   ([`mixing`]), the random topology scheduler ([`topology`]), the
//!   communication delay model ([`delay`]), a pure-Rust decentralized SGD
//!   simulator ([`sim`]), the **event-driven parallel execution engine**
//!   ([`engine`]), and the NN training coordinator ([`coordinator`]) that
//!   executes AOT-compiled XLA artifacts through `runtime` (gated behind
//!   the `xla` feature — the offline image cannot build the XLA crates).
//! - **L2/L1 (build-time Python, `python/compile/`)** — a flat-parameter
//!   transformer LM and Pallas kernels, lowered once to HLO text in
//!   `artifacts/`; Python is never on the training path.
//!
//! ## The front door: [`experiment`]
//!
//! The crate's public API is the unified experiment pipeline
//! **spec → plan → run → observe**: one typed, serializable
//! [`experiment::ExperimentSpec`] describes a full run (graph, strategy +
//! budget, workload, delay policy, backend, hyperparameters), planning
//! exposes the derived math (matchings, probabilities, α, ρ) before
//! anything executes, and a single [`experiment::run()`] drives every
//! backend, returning one [`experiment::ExperimentResult`]. Specs load
//! from JSON files: `matcha run --spec exp.json`.
//!
//! Quick tour (runs as a doctest — the default build is pure Rust now
//! that the XLA path is feature-gated):
//!
//! ```
//! use matcha::experiment::{self, Backend, ExperimentSpec, ProblemSpec, Strategy};
//!
//! // Declare the whole experiment: MATCHA at half budget on the paper's
//! // Figure-1 graph, a quadratic workload, the event-driven engine.
//! let spec = ExperimentSpec::new("fig1")
//!     .strategy(Strategy::Matcha { budget: 0.5 })
//!     .problem(ProblemSpec::quadratic())
//!     .backend(Backend::EngineSequential)
//!     .lr(0.03)
//!     .iterations(60)
//!     .validated()
//!     .unwrap();
//!
//! // Plan: decompose → probabilities → α (paper §3, steps 1–3).
//! let plan = experiment::plan(&spec).unwrap();
//! assert!(plan.rho < 1.0); // Theorem 2: convergence guaranteed
//!
//! // Run: same entry point for sim / engine / actors / async / cluster
//! // backends.
//! let result = experiment::run(&spec).unwrap();
//! assert!(result.final_loss().is_finite());
//!
//! // The barrier-free async backend reports staleness/idle statistics.
//! let async_spec = spec.clone().backend(Backend::Async { threads: 2, max_staleness: 3 });
//! let async_result = experiment::run(&async_spec).unwrap();
//! assert!(async_result.async_stats.is_some());
//!
//! // The cluster backend runs the shards behind a wire-format transport
//! // and reports per-link bytes-on-wire (loopback here; "tcp" uses real
//! // localhost sockets).
//! let cluster_spec = spec.clone().backend(Backend::Cluster {
//!     shards: 2,
//!     transport: matcha::cluster::TransportKind::Loopback,
//! });
//! let cluster_result = experiment::run(&cluster_spec).unwrap();
//! assert!(cluster_result.cluster_stats.unwrap().total_bytes() > 0);
//!
//! // The spec round-trips through JSON, so it is a loadable artifact.
//! let reloaded = ExperimentSpec::parse(&spec.to_json_string()).unwrap();
//! assert_eq!(reloaded, spec);
//!
//! // Any run can be traced: attach a ring sink and the backends emit
//! // typed events (compute/link spans, mix/barrier markers) that export
//! // to Perfetto-loadable Chrome trace JSON. `matcha run --spec ...
//! // --trace out.json` does exactly this.
//! use matcha::trace::{chrome_trace, validate_chrome_trace, RingSink, Tracer};
//! let mut sink = RingSink::new(4096);
//! let mut tracer = Tracer::attached(&mut sink);
//! let traced = experiment::run_planned_traced(
//!     &spec,
//!     &plan,
//!     &mut experiment::NoopObserver,
//!     &mut tracer,
//! )
//! .unwrap();
//! assert!(!sink.is_empty());
//! let trace_json = chrome_trace(&sink.records(), &traced.snapshot.to_json());
//! validate_chrome_trace(&trace_json.to_string()).unwrap();
//!
//! // A `report` block arms the convergence observatory: the run comes
//! // back with an algorithm-level readout — realized activation counts
//! // audited against the designed p_j, windowed consensus contraction
//! // vs the predicted ρ, and the error-runtime frontier on the paper's
//! // fig-4 axes. `matcha report --spec ...` renders the same snapshot
//! // as a self-contained report.
//! use matcha::experiment::ReportSpec;
//! let audited = experiment::run(&spec.clone().report(ReportSpec { window: 2 })).unwrap();
//! let observatory = audited.observatory.unwrap();
//! assert_eq!(observatory.rounds, 60);
//! assert_eq!(observatory.ledger.designed, plan.probabilities);
//! assert_eq!(observatory.ledger.realized.len(), plan.probabilities.len());
//! assert!(!observatory.frontier.is_empty());
//! ```
//!
//! ## Execution backends
//!
//! The backends share one **arena-backed** step/mix kernel: all worker
//! iterates live in a contiguous [`state::StateMatrix`] (one row per
//! worker), scratch comes from once-per-run pools (including TopK
//! compression's magnitude buffer), and the gossip fold
//! ([`state::MixKernel`], bound to run semantics by [`sim::kernel`])
//! runs in place with zero per-message heap allocation — asserted under
//! a counting allocator in `benches/hotpath.rs`. The row primitives the
//! fold is built from ([`state::simd`]) dispatch to AVX2 when the CPU
//! has it, bit-for-bit identical to the scalar fallback
//! (`MATCHA_NO_SIMD=1` forces scalar). Every backend therefore agrees
//! **bit-for-bit** per seed (pinned against the golden fixtures of
//! `rust/tests/golden.rs`):
//!
//! - [`sim::run_decentralized`] — the sequential reference loop with
//!   closed-form time accounting ([`delay::DelayModel`]).
//! - [`engine::run_engine`] — a discrete-event engine (event queue at
//!   per-link granularity, [`engine::DelayPolicy`] time models for
//!   stragglers / heterogeneous links / link failures) whose parallel
//!   mode multiplexes the workers over a bounded pool of OS threads.
//!   [`engine::sweep`] fans independent budget/topology grid points
//!   across cores, streaming each finished point through an
//!   [`experiment::Observer`].
//! - [`gossip::run_async`] — the **barrier-free** asynchronous gossip
//!   runtime (`backend: "async"` in a spec): every worker advances on
//!   its own virtual clock, exchanges are AD-PSGD-style pairwise
//!   averages with per-edge model-version tracking and staleness-damped
//!   mixing, bounded by a configurable `max_staleness`. At staleness 0
//!   it degrades to the synchronous kernel bit-for-bit; with
//!   [`gossip::UNBOUNDED_STALENESS`] (`"max_staleness": null`) the gate
//!   is off entirely — pure AD-PSGD; under stragglers it beats barrier
//!   mode in both virtual and wall-clock time
//!   (`benches/async_vs_barrier.rs`).
//! - [`cluster::run_cluster`] — the **multi-node** cluster runtime
//!   (`backend: "cluster"`): workers partitioned over
//!   transport-separated shards, phase commands serialized through a
//!   versioned length-prefixed wire format ([`cluster::wire`]), carried
//!   by an in-memory loopback or a real TCP transport with per-link
//!   byte accounting ([`cluster::transport`]). Mix frames suppress rows
//!   whose peer lives on the receiving shard ([`cluster::wire::MixLocalRef`]
//!   resolves them from the shard's own pre-mix segment) and are folded
//!   zero-copy straight out of the received frame bytes. The loopback
//!   cluster is bit-for-bit equal to the actors backend per seed; the
//!   TCP cluster runs the same schedule over localhost sockets
//!   (`rust/tests/cluster.rs`, `benches/cluster_transport.rs`).
//! - [`node::run_remote`] — the **deployment** shape of the cluster
//!   runtime: standalone shard-node daemons (`matcha shard-node
//!   --listen ADDR`) serve shards in their own processes, and a remote
//!   coordinator (`"transport": {"tcp": ["host:port", ...]}` in a spec)
//!   drives them with a **pipelined**, reconnect-tolerant command
//!   stream — same schedule, same fold arithmetic, bit-for-bit equal to
//!   the in-process backends (`rust/tests/node.rs`,
//!   `benches/node_pipeline.rs`).
//!
//! Direct use of the lower layers ([`matching`], [`budget`], [`mixing`],
//! hand-built [`sim::RunConfig`]s, `coordinator::plan_*`) remains
//! supported as the **legacy path** for specialized harnesses; new code
//! should speak [`experiment`] specs.

// The codebase favors explicit index loops for the numerical kernels
// (mirrors the paper's equations); keep clippy's style lints from
// fighting that in `ci.sh`'s `-D warnings` run.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod benchkit;
pub mod budget;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod engine;
pub mod experiment;
pub mod gossip;
pub mod graph;
pub mod json;
pub mod linalg;
pub mod matching;
pub mod metrics;
pub mod mixing;
pub mod node;
pub mod proptest;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sim;
pub mod state;
pub mod topology;
pub mod trace;
