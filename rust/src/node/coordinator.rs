//! The remote coordinator: replay a MATCHA schedule against standalone
//! shard-node daemons with pipelined, reconnect-tolerant commands.
//!
//! Structure mirrors the in-process cluster driver — the engine's own
//! barrier loop over an [`Executor`] that serializes phases into wire
//! frames — but the executor is **pipelined**: commands stream ahead of
//! their replies, bounded by [`RemoteOptions::window`] in-flight frames
//! per link. The dependency analysis that makes this safe:
//!
//! - A `Step` command needs nothing from the coordinator's arena — the
//!   daemon steps its own workers from its own RNG streams. Steps are
//!   sent without waiting.
//! - A `Mix` command's staged rows are read from the coordinator's arena
//!   *post-step*, and a routed peer row may be owned by **any** shard —
//!   so every in-flight reply must be folded back before staging. That
//!   drain ([`PipelinedExec::sync`]) is the pipeline's only
//!   synchronization point: one round-trip wait per mixing iteration
//!   instead of two, and none at all across communication-free rounds.
//! - [`Executor::flush`] (called by the drive loop at metric-record
//!   points) also drains, so pipelining never changes what observers and
//!   recorders see.
//!
//! Identical frames in identical order per link, identical fold
//! arithmetic on the daemon — `window` is pure latency hiding and every
//! setting is bit-for-bit equal to the in-process cluster backend.
//!
//! ## Reconnect-with-resume
//!
//! Each link keeps its unacknowledged frames in a replay buffer. When a
//! connection dies (I/O error or [`crate::cluster::WireError::TimedOut`]
//! from the configured deadline), the coordinator re-dials the daemon
//! and aligns against its `Resume { done, states, .. }` handshake using
//! the invariant `acked ≤ done ≤ sent`:
//!
//! - `done − acked` pending frames were executed but their replies were
//!   lost — dropped from the buffer, with the resumed states applied to
//!   the arena in their place.
//! - `sent − done` pending frames never reached the daemon — re-sent in
//!   order.
//! - `done < acked` means the daemon lost its session (restarted), and
//!   `done > sent` means it serves some other coordinator's session:
//!   both are hard errors, never silent corruption.
//!
//! Every command executes exactly once, so a run that survives a
//! reconnect is bit-for-bit the run that never dropped. Reconnects are
//! observable as [`TraceEvent::Reconnect`] and the
//! [`Counter::Reconnects`] metric.
//!
//! ## Telemetry harvest
//!
//! With a [`TelemetryCollector`] attached, the coordinator pulls every
//! daemon's telemetry (drained trace ring + cumulative registry +
//! session health) right after dialing, at every [`Executor::flush`]
//! sync barrier, and once more before shutdown. Pulls only happen on a
//! drained link (`acked == sent`), so the snapshot is deterministically
//! the next inbound frame; they never enter the pending/replay
//! machinery, and their wire traffic is tracked per link and excluded
//! from the run's [`ClusterStats`] — the run's results and its byte
//! accounting are bit-for-bit identical with telemetry on or off.

use crate::cluster::driver::PlanReplay;
use crate::cluster::{
    check_proto, ClusterResult, ClusterStats, LinkStats, TcpTransport, Transport, TransportKind,
    WireError, WireMeta, WireMsg,
};
use crate::engine::runner::{drive, route_per_worker, stage_shard_messages, Executor};
use crate::engine::{parse_policy, DelayPolicy};
use crate::experiment::{
    build_problem, plan, Backend, BuiltProblem, ExperimentSpec, NoopObserver, Observer, Plan,
};
use crate::gossip::{shard_workers, RoundPlan};
use crate::graph::Graph;
use crate::sim::{Problem, RunConfig};
use crate::state::StateMatrix;
use crate::trace::{Counter, NodeTelemetry, TelemetryCollector, TraceEvent, Tracer};
use std::collections::VecDeque;
use std::net::TcpStream;
use std::time::Duration;

/// Tuning of the remote coordinator's connection handling. The defaults
/// suit localhost and LAN deployments; every setting produces identical
/// results — only latency tolerance changes.
#[derive(Clone, Debug)]
pub struct RemoteOptions {
    /// Maximum in-flight (sent, unacknowledged) commands per link,
    /// clamped to at least 1. `1` degenerates to the in-process driver's
    /// strict request/reply protocol.
    pub window: usize,
    /// Read/write deadline per link in milliseconds (`0` = no deadline).
    /// A daemon silent past the deadline surfaces as the typed
    /// [`WireError::TimedOut`] and triggers a reconnect; a handshake
    /// is always bounded (5 s when no deadline is configured) so a
    /// silent stray listener cannot hang a run.
    pub io_timeout_ms: u64,
    /// Dial attempts per reconnect before the run aborts with an error.
    pub reconnect_attempts: u32,
    /// Pause between successive dial attempts, in milliseconds.
    pub reconnect_delay_ms: u64,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        RemoteOptions {
            window: 4,
            io_timeout_ms: 30_000,
            reconnect_attempts: 3,
            reconnect_delay_ms: 50,
        }
    }
}

/// Field-wise sum of two link-stat snapshots: how a link's retired
/// connections and its live one combine into the link's total traffic.
fn add_stats(a: LinkStats, b: LinkStats) -> LinkStats {
    LinkStats {
        frames_sent: a.frames_sent + b.frames_sent,
        bytes_sent: a.bytes_sent + b.bytes_sent,
        frames_received: a.frames_received + b.frames_received,
        bytes_received: a.bytes_received + b.bytes_received,
        intra_bytes: a.intra_bytes + b.intra_bytes,
    }
}

/// What a daemon reported in its `Resume` handshake frame.
struct ResumeInfo {
    done: u64,
    dim: u32,
    states: Vec<f64>,
}

/// Dial one daemon and run the `Assign → Hello → Resume` handshake.
/// The handshake is always deadline-bounded; the steady-state timeout
/// from `opts` is armed before returning.
fn dial_shard(
    addr: &str,
    shard: usize,
    shards: usize,
    spec_json: &str,
    opts: &RemoteOptions,
) -> Result<(TcpTransport, ResumeInfo), String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let mut tx = TcpTransport::new(stream).map_err(|e| format!("{addr}: {e}"))?;
    let handshake = Duration::from_millis(match opts.io_timeout_ms {
        0 => 5_000,
        ms => ms,
    });
    tx.set_io_timeout(Some(handshake)).map_err(|e| format!("{addr}: {e}"))?;
    let mut scratch = Vec::new();
    let assign = WireMsg::Assign {
        shard: shard as u32,
        shards: shards as u32,
        spec_json: spec_json.to_string(),
    };
    tx.send_msg(&assign, &mut scratch).map_err(|e| format!("{addr}: assign: {e}"))?;
    let mut body = Vec::new();
    match tx.recv_msg(&mut body).map_err(|e| format!("{addr}: handshake: {e}"))? {
        WireMsg::Hello { shard: announced, proto } => {
            check_proto(proto).map_err(|e| format!("{addr}: {e}"))?;
            if announced as usize != shard {
                return Err(format!(
                    "{addr}: daemon announced shard {announced}, expected {shard}"
                ));
            }
        }
        WireMsg::VersionReject { supported } => {
            return Err(format!(
                "{addr}: daemon rejected our protocol (it speaks version {supported})"
            ));
        }
        other => return Err(format!("{addr}: handshake expected Hello, got {other:?}")),
    }
    let resume = match tx.recv_msg(&mut body).map_err(|e| format!("{addr}: resume: {e}"))? {
        WireMsg::Resume { done, steps: _, folded: _, dim, states } => {
            ResumeInfo { done, dim, states }
        }
        other => return Err(format!("{addr}: handshake expected Resume, got {other:?}")),
    };
    let steady = match opts.io_timeout_ms {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    tx.set_io_timeout(steady).map_err(|e| format!("{addr}: {e}"))?;
    Ok((tx, resume))
}

/// One coordinator↔daemon link: the live transport, the exactly-once
/// command accounting, and the replay buffer for reconnects.
struct RemoteLink {
    addr: String,
    tx: TcpTransport,
    /// Encoded frames sent but not yet acknowledged, oldest first — what
    /// reconnect-with-resume replays.
    pending: VecDeque<Vec<u8>>,
    /// Commands sent over the link's lifetime (all connections).
    sent: u64,
    /// Commands whose `States` reply was received and applied.
    acked: u64,
    /// Traffic accumulated by this link's retired connections; the live
    /// connection's counters are added on top, so per-phase deltas stay
    /// monotone across reconnects.
    stats_base: LinkStats,
    /// Mix rows suppressed on this link (peer lived on the daemon's own
    /// shard, so the row was omitted from the `MixLocal` frame); folded
    /// into the savings ledger [`LinkStats::intra_bytes`] after the run.
    intra_rows: u64,
    /// Wire traffic spent on telemetry pulls over this link's lifetime —
    /// subtracted from the final stats so a telemetry-enabled run
    /// reports exactly the traffic of the run itself.
    tele_stats: LinkStats,
}

/// Exchange one draining `TelemetryPull` on a quiescent link.
fn exchange_pull(
    link: &mut RemoteLink,
    scratch: &mut Vec<u8>,
    body: &mut Vec<u8>,
) -> Result<NodeTelemetry, WireError> {
    link.tx.send_msg(&WireMsg::TelemetryPull { drain: true }, scratch)?;
    match link.tx.recv_msg(body)? {
        WireMsg::TelemetrySnapshot { telemetry } => Ok(telemetry),
        other => {
            Err(WireError::Inconsistent(format!("expected TelemetrySnapshot, got {other:?}")))
        }
    }
}

/// Harvest one daemon's telemetry over its live link and fold it into
/// the collector. The caller must have drained the link
/// (`acked == sent`) so the snapshot is deterministically the next
/// inbound frame. The exchange's own wire traffic is accumulated into
/// the link's `tele_stats` (even on failure — sent bytes are sent) so
/// the run's stats can exclude it. Transport failures are returned for
/// the caller to decide between reconnecting and skipping: pulls are
/// observational and are never replayed.
fn pull_link_telemetry(
    link: &mut RemoteLink,
    s: usize,
    collector: &mut TelemetryCollector,
    coord_wall_now_ns: u64,
    scratch: &mut Vec<u8>,
    body: &mut Vec<u8>,
) -> Result<(), WireError> {
    debug_assert_eq!(link.acked, link.sent, "telemetry pulls need a drained link");
    let before = add_stats(link.stats_base, link.tx.stats());
    // The link's run-only traffic so far: everything minus what earlier
    // pulls cost (progress reporting only).
    let run_bytes = (before.bytes_sent + before.bytes_received)
        .saturating_sub(link.tele_stats.bytes_sent + link.tele_stats.bytes_received);
    let outcome = exchange_pull(link, scratch, body);
    let after = add_stats(link.stats_base, link.tx.stats());
    link.tele_stats = add_stats(link.tele_stats, after.delta(&before));
    let telemetry = outcome?;
    collector.absorb(s, telemetry, coord_wall_now_ns, run_bytes);
    Ok(())
}

/// The coordinator's link fleet plus the first unrecoverable failure.
/// Owned by the run entry point and borrowed by the executor, so the
/// links survive [`drive`] consuming the executor — the entry point
/// still needs them for the shutdown frames and the final stats.
struct RemoteState {
    links: Vec<RemoteLink>,
    failure: Option<String>,
}

/// The pipelined wire executor (see the module docs for the dependency
/// analysis). The [`Executor`] trait cannot return errors, so transport
/// failures that survive reconnection poison the executor instead:
/// [`drive`] checks [`Executor::poisoned`] each iteration and stops
/// replaying, and the entry point turns the recorded failure into `Err`.
struct PipelinedExec<'a> {
    state: &'a mut RemoteState,
    opts: &'a RemoteOptions,
    spec_json: &'a str,
    workers: usize,
    dim: usize,
    window: usize,
    /// Per-worker `(matching, u, v)` routes of the current round, shared
    /// with the in-process executors via [`route_per_worker`].
    per: Vec<Vec<(usize, usize, usize)>>,
    /// Recycled encode / decode / staging buffers. (The replay buffer
    /// still clones each sent frame — an accepted cost on a
    /// transport-bound path, and the price of resumability.)
    scratch: Vec<u8>,
    body: Vec<u8>,
    msgs: Vec<WireMeta>,
    staging: Vec<f64>,
    /// Per-link combined-stats snapshot at each phase start, for the
    /// per-phase wire-traffic deltas.
    prev_stats: Vec<LinkStats>,
    /// When present, every flush barrier also harvests each daemon's
    /// telemetry into this collector.
    collector: Option<&'a mut TelemetryCollector>,
}

impl<'a> PipelinedExec<'a> {
    fn new(
        state: &'a mut RemoteState,
        opts: &'a RemoteOptions,
        spec_json: &'a str,
        workers: usize,
        dim: usize,
        collector: Option<&'a mut TelemetryCollector>,
    ) -> Self {
        let shards = state.links.len();
        PipelinedExec {
            state,
            opts,
            spec_json,
            workers,
            dim,
            window: opts.window.max(1),
            per: (0..workers).map(|_| Vec::new()).collect(),
            scratch: Vec::new(),
            body: Vec::new(),
            msgs: Vec::new(),
            staging: Vec::new(),
            prev_stats: vec![LinkStats::default(); shards],
            collector,
        }
    }

    /// The link's total traffic: retired connections plus the live one.
    fn combined(&self, s: usize) -> LinkStats {
        let link = &self.state.links[s];
        add_stats(link.stats_base, link.tx.stats())
    }

    fn snapshot_stats(&mut self) {
        for s in 0..self.state.links.len() {
            let combined = self.combined(s);
            self.prev_stats[s] = combined;
        }
    }

    /// Fold the phase's per-link traffic into the registry and emit the
    /// frame-traffic markers, exactly as the in-process driver does.
    fn account_traffic(&mut self, tracer: &mut Tracer<'_>) {
        for s in 0..self.state.links.len() {
            let delta = self.combined(s).delta(&self.prev_stats[s]);
            tracer.count(Counter::WireFramesSent, delta.frames_sent);
            tracer.count(Counter::WireBytesSent, delta.bytes_sent);
            tracer.count(Counter::WireFramesReceived, delta.frames_received);
            tracer.count(Counter::WireBytesReceived, delta.bytes_received);
            tracer.emit(TraceEvent::FrameSent { link: s, bytes: delta.bytes_sent });
            tracer.emit(TraceEvent::FrameReceived { link: s, bytes: delta.bytes_received });
        }
    }

    /// Copy one shard's reply (or resume) states into the arena rows it
    /// owns.
    fn apply_states(&self, s: usize, states: &[f64], xs: &mut StateMatrix) -> Result<(), String> {
        let d = self.dim;
        let shards = self.state.links.len();
        let slots = shard_workers(s, shards, self.workers).count();
        if states.len() != slots * d {
            return Err(format!(
                "remote link {s}: states carry {} values, expected {} ({slots} workers × dim {d})",
                states.len(),
                slots * d
            ));
        }
        for (slot, w) in shard_workers(s, shards, self.workers).enumerate() {
            xs.row_mut(w).copy_from_slice(&states[slot * d..(slot + 1) * d]);
        }
        Ok(())
    }

    /// Receive and apply the oldest outstanding reply on link `s`,
    /// reconnecting through failures. A successful reconnect may resume
    /// past every outstanding command (their replies are folded in via
    /// the Resume states), in which case there is nothing left to
    /// receive and this returns immediately.
    fn recv_one(
        &mut self,
        s: usize,
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    ) -> Result<(), String> {
        loop {
            {
                let link = &self.state.links[s];
                if link.acked >= link.sent {
                    return Ok(());
                }
            }
            match self.state.links[s].tx.recv_msg(&mut self.body) {
                Ok(WireMsg::States { shard, dim, states }) => {
                    if shard as usize != s {
                        return Err(format!(
                            "remote link {s}: reply announced shard {shard}"
                        ));
                    }
                    if dim as usize != self.dim {
                        return Err(format!(
                            "remote link {s}: reply dim {dim}, expected {}",
                            self.dim
                        ));
                    }
                    self.apply_states(s, &states, xs)?;
                    let link = &mut self.state.links[s];
                    link.acked += 1;
                    link.pending.pop_front();
                    return Ok(());
                }
                Ok(WireMsg::VersionReject { supported }) => {
                    return Err(format!(
                        "remote link {s} ({}): daemon speaks protocol version {supported}",
                        self.state.links[s].addr
                    ));
                }
                Ok(other) => {
                    return Err(format!(
                        "remote link {s}: expected States reply, got {other:?}"
                    ));
                }
                Err(e) => self.reconnect(s, xs, tracer, &e)?,
            }
        }
    }

    /// Ship the frame in `self.scratch` on link `s`, waiting for acks
    /// only when the in-flight window is full, and record it in the
    /// replay buffer.
    fn send_cmd(
        &mut self,
        s: usize,
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    ) -> Result<(), String> {
        while self.state.links[s].pending.len() >= self.window {
            self.recv_one(s, xs, tracer)?;
        }
        loop {
            match self.state.links[s].tx.send(&self.scratch) {
                Ok(()) => {
                    let link = &mut self.state.links[s];
                    link.pending.push_back(self.scratch.clone());
                    link.sent += 1;
                    return Ok(());
                }
                Err(e) => self.reconnect(s, xs, tracer, &e)?,
            }
        }
    }

    /// Drain every link to `acked == sent`: the arena is authoritative
    /// when this returns.
    fn sync(&mut self, xs: &mut StateMatrix, tracer: &mut Tracer<'_>) -> Result<(), String> {
        for s in 0..self.state.links.len() {
            while self.state.links[s].acked < self.state.links[s].sent {
                self.recv_one(s, xs, tracer)?;
            }
        }
        Ok(())
    }

    /// Re-establish link `s` after `cause` killed its connection: retire
    /// the old connection's stats, re-dial with the same assignment,
    /// align on the daemon's `Resume`, and replay what it never saw.
    fn reconnect(
        &mut self,
        s: usize,
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
        cause: &WireError,
    ) -> Result<(), String> {
        let shards = self.state.links.len();
        {
            let link = &mut self.state.links[s];
            link.stats_base = add_stats(link.stats_base, link.tx.stats());
            // Force the daemon's read on the old connection to fail so a
            // merely-silent (not closed) link frees the daemon to accept
            // our re-dial; harmless when the connection is already dead.
            let _ = link.tx.stream().shutdown(std::net::Shutdown::Both);
        }
        let addr = self.state.links[s].addr.clone();
        let attempts = self.opts.reconnect_attempts.max(1);
        let mut last = String::from("no attempt made");
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(self.opts.reconnect_delay_ms));
            }
            let (tx, resume) = match dial_shard(&addr, s, shards, self.spec_json, self.opts) {
                Ok(dialed) => dialed,
                Err(e) => {
                    last = e;
                    continue;
                }
            };
            let (acked, sent) = {
                let link = &self.state.links[s];
                (link.acked, link.sent)
            };
            // The resume invariant: acked ≤ done ≤ sent. Anything else
            // is a session mismatch that no replay can repair.
            if resume.done < acked {
                return Err(format!(
                    "remote link {s} ({addr}): daemon resumed at {} processed commands but \
                     {acked} replies were already applied — it lost its session (restarted?); \
                     the run cannot be resumed",
                    resume.done
                ));
            }
            if resume.done > sent {
                return Err(format!(
                    "remote link {s} ({addr}): daemon reports {} processed commands but only \
                     {sent} were ever sent on this link — it is serving a stale session from \
                     another coordinator",
                    resume.done
                ));
            }
            if resume.dim as usize != self.dim {
                return Err(format!(
                    "remote link {s} ({addr}): resume dim {}, expected {}",
                    resume.dim, self.dim
                ));
            }
            // Commands the daemon executed whose replies died with the
            // old connection: drop their frames and take their combined
            // effect from the resumed states instead.
            {
                let link = &mut self.state.links[s];
                link.tx = tx;
                for _ in link.acked..resume.done {
                    link.pending.pop_front();
                }
                link.acked = resume.done;
            }
            self.apply_states(s, &resume.states, xs)?;
            // Replay everything still in flight, oldest first. A replay
            // failure retires this connection and tries again.
            let mut replay_err = None;
            {
                let link = &mut self.state.links[s];
                for frame in &link.pending {
                    if let Err(e) = link.tx.send(frame) {
                        replay_err = Some(e);
                        break;
                    }
                }
            }
            if let Some(e) = replay_err {
                last = format!("{addr}: replay: {e}");
                let link = &mut self.state.links[s];
                link.stats_base = add_stats(link.stats_base, link.tx.stats());
                let _ = link.tx.stream().shutdown(std::net::Shutdown::Both);
                continue;
            }
            let resumed = self.state.links[s].pending.len() as u64;
            tracer.emit(TraceEvent::Reconnect { link: s, resumed });
            tracer.count(Counter::Reconnects, 1);
            return Ok(());
        }
        Err(format!(
            "remote link {s} ({addr}): connection failed ({cause}) and reconnect did not \
             recover after {attempts} attempts: {last}"
        ))
    }

    fn try_step(
        &mut self,
        lr: f64,
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    ) -> Result<(), String> {
        self.snapshot_stats();
        self.scratch.clear();
        WireMsg::Step { lr }.encode(&mut self.scratch);
        for s in 0..self.state.links.len() {
            self.send_cmd(s, xs, tracer)?;
        }
        // Every worker steps exactly once per phase; counted at send
        // time so the totals match the in-process backends under
        // pipelining and reconnects (commands never re-execute).
        tracer.count(Counter::ShardSteps, self.workers as u64);
        self.account_traffic(tracer);
        Ok(())
    }

    fn try_mix(
        &mut self,
        k: usize,
        alpha: f64,
        matchings: &[Graph],
        activated: &[usize],
        dead: &[(usize, usize)],
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    ) -> Result<(), String> {
        self.snapshot_stats();
        // The staged rows are read out of the arena post-step, and a
        // routed peer row may be owned by any shard: every in-flight
        // reply must land first. The pipeline's one synchronization
        // point.
        self.sync(xs, tracer)?;
        route_per_worker(&mut self.per, matchings, activated, dead);
        let shards = self.state.links.len();
        for s in 0..shards {
            let mut msgs = std::mem::take(&mut self.msgs);
            let mut staging = std::mem::take(&mut self.staging);
            stage_shard_messages(
                s,
                shards,
                self.workers,
                &self.per,
                xs,
                &mut msgs,
                &mut staging,
                &mut self.state.links[s].intra_rows,
                // Suppress local-peer rows: the daemon resolves them
                // from its own pre-mix segment, so they never cross the
                // wire (same protocol as the in-process cluster driver).
                true,
                |slot, j, u, v| WireMeta {
                    slot: slot as u32,
                    matching: j as u32,
                    u: u as u32,
                    v: v as u32,
                },
            );
            // Staged-message count decided at routing time — identical
            // totals to the reply-side accounting of the actor pool.
            tracer.count(Counter::ShardMsgsFolded, msgs.len() as u64);
            let msg = WireMsg::MixLocal {
                k: k as u64,
                alpha,
                shard: s as u32,
                shards: shards as u32,
                dim: self.dim as u32,
                msgs,
                staging,
            };
            self.scratch.clear();
            msg.encode(&mut self.scratch);
            self.send_cmd(s, xs, tracer)?;
            let WireMsg::MixLocal { msgs, staging, .. } = msg else { unreachable!() };
            self.msgs = msgs;
            self.staging = staging;
        }
        self.account_traffic(tracer);
        Ok(())
    }

    /// Pull every daemon's telemetry at a quiescent point (the caller
    /// just synced, so every link is drained). A pull that dies with
    /// its connection goes through the normal reconnect path and is
    /// then *skipped* — pulls are observational, never replayed, and
    /// the next barrier harvests the daemon's (cumulative) registry
    /// again.
    fn harvest(&mut self, xs: &mut StateMatrix, tracer: &mut Tracer<'_>) -> Result<(), String> {
        if self.collector.is_none() {
            return Ok(());
        }
        for s in 0..self.state.links.len() {
            let wall = tracer.wall_now_ns();
            let res = match self.collector.as_deref_mut() {
                Some(collector) => pull_link_telemetry(
                    &mut self.state.links[s],
                    s,
                    collector,
                    wall,
                    &mut self.scratch,
                    &mut self.body,
                ),
                None => Ok(()),
            };
            if let Err(e) = res {
                self.reconnect(s, xs, tracer, &e)?;
            }
        }
        Ok(())
    }
}

impl Executor for PipelinedExec<'_> {
    fn step(&mut self, _k: usize, lr: f64, xs: &mut StateMatrix, tracer: &mut Tracer<'_>) {
        if self.state.failure.is_some() {
            return;
        }
        if let Err(e) = self.try_step(lr, xs, tracer) {
            self.state.failure = Some(e);
        }
    }

    fn mix(
        &mut self,
        k: usize,
        alpha: f64,
        matchings: &[Graph],
        activated: &[usize],
        dead: &[(usize, usize)],
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    ) {
        if self.state.failure.is_some() {
            return;
        }
        if let Err(e) = self.try_mix(k, alpha, matchings, activated, dead, xs, tracer) {
            self.state.failure = Some(e);
        }
    }

    fn flush(&mut self, xs: &mut StateMatrix, tracer: &mut Tracer<'_>) {
        if self.state.failure.is_some() {
            return;
        }
        if let Err(e) = self.sync(xs, tracer) {
            self.state.failure = Some(e);
            return;
        }
        if let Err(e) = self.harvest(xs, tracer) {
            self.state.failure = Some(e);
        }
    }

    fn poisoned(&self) -> bool {
        self.state.failure.is_some()
    }
}

// ---------------------------------------------------------------------
// The run entry points
// ---------------------------------------------------------------------

/// Run the spec against its listed shard-node daemons. Equivalent to
/// [`run_remote_observed`] with a no-op observer. The spec's backend
/// must be `cluster` with the remote transport
/// (`{"tcp": ["host:port", ...]}`, one address per shard, in shard
/// order); the daemons must already be listening.
pub fn run_remote(
    spec: &ExperimentSpec,
    opts: &RemoteOptions,
) -> Result<ClusterResult, String> {
    run_remote_observed(spec, opts, &mut NoopObserver)
}

/// [`run_remote`] with streaming observation (callbacks run on the
/// coordinator thread, exactly as in every other backend).
pub fn run_remote_observed(
    spec: &ExperimentSpec,
    opts: &RemoteOptions,
    observer: &mut dyn Observer,
) -> Result<ClusterResult, String> {
    run_remote_traced(spec, opts, observer, &mut Tracer::disabled())
}

/// [`run_remote_observed`] with trace emission: the engine loop's spans
/// plus the wire-traffic markers and [`TraceEvent::Reconnect`] events
/// flow through `tracer`.
pub fn run_remote_traced(
    spec: &ExperimentSpec,
    opts: &RemoteOptions,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> Result<ClusterResult, String> {
    let exp_plan = plan(spec)?;
    run_remote_planned_traced(spec, &exp_plan, opts, observer, tracer)
}

/// [`run_remote_traced`] with a precomputed plan — what the unified
/// spec runner ([`crate::experiment::run()`]) dispatches to when a spec
/// names a remote cluster backend.
pub(crate) fn run_remote_planned_traced(
    spec: &ExperimentSpec,
    exp_plan: &Plan,
    opts: &RemoteOptions,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> Result<ClusterResult, String> {
    run_remote_planned_telemetry(spec, exp_plan, opts, observer, tracer, None)
}

/// [`run_remote_planned_traced`] plus distributed-telemetry harvesting:
/// with a collector, every daemon's trace ring, registry and health are
/// pulled after dialing, at each flush barrier, and before shutdown.
pub(crate) fn run_remote_planned_telemetry(
    spec: &ExperimentSpec,
    exp_plan: &Plan,
    opts: &RemoteOptions,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
    collector: Option<&mut TelemetryCollector>,
) -> Result<ClusterResult, String> {
    let (shards, addrs) = match &spec.backend {
        Backend::Cluster { shards, transport: TransportKind::Remote { addrs } } => {
            (*shards, addrs.as_slice())
        }
        other => {
            return Err(format!(
                "remote coordinator: the spec backend must be a cluster with node \
                 addresses ({{\"tcp\": [\"host:port\", ...]}}), got {other:?}"
            ));
        }
    };
    let cfg = exp_plan.run_config(spec)?;
    let m = exp_plan.graph.num_nodes();
    if shards > m {
        return Err(format!(
            "remote cluster: {shards} node addresses for a {m}-worker graph — each \
             daemon hosts at least one worker, so list at most {m} nodes"
        ));
    }
    let mut sampler = exp_plan.sampler(spec.sampler_seed.unwrap_or(spec.seed));
    let mut policy =
        parse_policy(&spec.policy, &exp_plan.graph, &cfg).map_err(|e| format!("policy: {e}"))?;
    let matchings = &exp_plan.decomposition.matchings;
    // The apriori schedule, materialized once and replayed — daemons
    // never sample topology; the coordinator owns the whole schedule.
    let round_plan = RoundPlan::generate(sampler.as_mut(), matchings, cfg.iterations);
    let spec_json = spec.to_json_string();
    let problem = build_problem(spec, m);
    match &problem {
        BuiltProblem::Quad(p) => drive_remote(
            p, matchings, &round_plan, policy.as_mut(), &cfg, shards, addrs, &spec_json, opts,
            observer, tracer, collector,
        ),
        BuiltProblem::Logreg(p) => drive_remote(
            p, matchings, &round_plan, policy.as_mut(), &cfg, shards, addrs, &spec_json, opts,
            observer, tracer, collector,
        ),
    }
}

/// Connect the link fleet, drive the schedule through the pipelined
/// executor, shut the daemons' sessions down, and assemble the stats.
fn drive_remote<P: Problem + ?Sized>(
    problem: &P,
    matchings: &[Graph],
    round_plan: &RoundPlan,
    policy: &mut dyn DelayPolicy,
    cfg: &RunConfig,
    shards: usize,
    addrs: &[String],
    spec_json: &str,
    opts: &RemoteOptions,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
    mut collector: Option<&mut TelemetryCollector>,
) -> Result<ClusterResult, String> {
    let m = problem.num_workers();
    let d = problem.dim();
    debug_assert_eq!(shards, addrs.len(), "validated: one address per shard");

    let mut links = Vec::with_capacity(shards);
    for (s, addr) in addrs.iter().enumerate() {
        let (tx, resume) =
            dial_shard(addr, s, shards, spec_json, opts).map_err(|e| format!("remote cluster: {e}"))?;
        // A fresh run must start from a fresh session: a daemon that is
        // mid-session belongs to some other (possibly dead) coordinator,
        // and silently adopting its state would corrupt the trajectory.
        if resume.done != 0 {
            return Err(format!(
                "remote cluster: daemon at {addr} is mid-session ({} commands already \
                 processed) — restart it (or let its run finish) before starting a new one",
                resume.done
            ));
        }
        if resume.dim as usize != d {
            return Err(format!(
                "remote cluster: daemon at {addr} serves dim {} but this run has dim {d}",
                resume.dim
            ));
        }
        links.push(RemoteLink {
            addr: addr.clone(),
            tx,
            pending: VecDeque::new(),
            sent: 0,
            acked: 0,
            stats_base: LinkStats::default(),
            intra_rows: 0,
            tele_stats: LinkStats::default(),
        });
    }

    let mut state = RemoteState { links, failure: None };
    let mut scratch = Vec::new();
    let mut body = Vec::new();
    // The opening harvest: fixes each daemon's wall-clock offset while
    // the timelines are as close as they will ever be, and surfaces the
    // fleet's health before the first command. Best-effort — a failed
    // pull surfaces on the first real frame and reconnects there.
    if let Some(c) = collector.as_deref_mut() {
        for (s, link) in state.links.iter_mut().enumerate() {
            let wall = tracer.wall_now_ns();
            if let Err(e) = pull_link_telemetry(link, s, c, wall, &mut scratch, &mut body) {
                eprintln!("remote cluster: opening telemetry pull on link {s}: {e}");
            }
        }
    }
    let exec = PipelinedExec::new(&mut state, opts, spec_json, m, d, collector.as_deref_mut());
    let mut replay = PlanReplay { plan: round_plan };
    let result = drive(problem, matchings, &mut replay, policy, cfg, exec, observer, tracer);

    if let Some(e) = state.failure.take() {
        return Err(e);
    }
    // The closing harvest: whatever the ring collected since the last
    // flush barrier, plus final health, before the sessions end.
    if let Some(c) = collector.as_deref_mut() {
        for (s, link) in state.links.iter_mut().enumerate() {
            let wall = tracer.wall_now_ns();
            if let Err(e) = pull_link_telemetry(link, s, c, wall, &mut scratch, &mut body) {
                eprintln!("remote cluster: closing telemetry pull on link {s}: {e}");
            }
        }
    }
    for link in &mut state.links {
        // Best-effort: a daemon dying between its last ack and the
        // shutdown frame does not invalidate the finished run.
        let _ = link.tx.send_msg(&WireMsg::Shutdown, &mut scratch);
    }
    let stats = ClusterStats {
        transport: TransportKind::Remote { addrs: addrs.to_vec() },
        per_link: state
            .links
            .iter()
            .map(|link| {
                // Telemetry traffic is excluded: the reported stats are
                // the run's own frames, identical with telemetry off.
                let mut ls = add_stats(link.stats_base, link.tx.stats()).delta(&link.tele_stats);
                // Each suppressed local-peer row would have carried
                // 8·dim payload bytes — the savings realized by the
                // MixLocal frames on this link.
                ls.intra_bytes = link.intra_rows * 8 * d as u64;
                ls
            })
            .collect(),
    };
    Ok(ClusterResult {
        run: result.run,
        dropped_links: result.dropped_links,
        events: result.events,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_pipeline_with_bounded_io() {
        let opts = RemoteOptions::default();
        assert!(opts.window > 1, "pipelining on by default");
        assert!(opts.io_timeout_ms > 0, "deadlines armed by default");
        assert!(opts.reconnect_attempts >= 1);
    }

    #[test]
    fn stats_addition_is_fieldwise() {
        let a = LinkStats {
            frames_sent: 1,
            bytes_sent: 10,
            frames_received: 2,
            bytes_received: 20,
            intra_bytes: 3,
        };
        let b = LinkStats {
            frames_sent: 4,
            bytes_sent: 40,
            frames_received: 5,
            bytes_received: 50,
            intra_bytes: 6,
        };
        let sum = add_stats(a, b);
        assert_eq!(sum.frames_sent, 5);
        assert_eq!(sum.bytes_sent, 50);
        assert_eq!(sum.frames_received, 7);
        assert_eq!(sum.bytes_received, 70);
        assert_eq!(sum.intra_bytes, 9);
        // Retire-then-add round-trips: (a + b) − b == a.
        assert_eq!(sum.delta(&b), a);
    }

    #[test]
    fn non_remote_backends_are_rejected() {
        let spec = ExperimentSpec::new("ring:4")
            .problem(crate::experiment::ProblemSpec::quadratic())
            .iterations(5);
        let err = run_remote(&spec, &RemoteOptions::default()).unwrap_err();
        assert!(err.contains("node addresses"), "got: {err}");
    }
}
