//! Standalone shard-node daemons and the pipelined remote coordinator:
//! the cluster runtime's deployment shape.
//!
//! The in-process cluster backend ([`crate::cluster`]) spawns its own
//! shard threads and dials itself over loopback pipes or localhost
//! sockets — one process, one lifetime. This module splits that topology
//! into real processes:
//!
//! ```text
//!   host A                  host B                     host C
//!   matcha run --spec ...   matcha shard-node          matcha shard-node
//!   (remote coordinator) ──▶  --listen B:7701  ──┐       --listen C:7701
//!          │                 (shard 0 daemon)    │      (shard 1 daemon)
//!          └────────────────────────────────────────────────▶
//! ```
//!
//! - [`run_daemon`] ([`crate::cli`]: `matcha shard-node --listen ADDR`)
//!   is the server side: it accepts a coordinator connection, receives
//!   an `Assign` frame naming its shard and carrying the full
//!   [`crate::experiment::ExperimentSpec`] as JSON, deterministically
//!   rebuilds the workload from that spec (same seed derivations as
//!   every in-process backend), and serves phase commands against its
//!   own [`crate::engine::actor::ActorShard`] — the identical fold
//!   arithmetic, so remote runs stay **bit-for-bit** equal to the
//!   in-process backends per seed.
//! - [`run_remote`] is the client side: a coordinator that connects to
//!   pre-existing daemons listed in the spec's backend
//!   (`"transport": {"tcp": ["host:port", ...]}`), replays the
//!   materialized [`crate::gossip::RoundPlan`] schedule through the
//!   engine's own drive loop, and reports the standard
//!   [`crate::cluster::ClusterResult`].
//!
//! Two properties distinguish this coordinator from the in-process one:
//!
//! **Pipelining.** The in-process driver is strictly request/reply: every
//! phase waits for every shard. Over real links that pays one round-trip
//! of latency per phase — two per mixing iteration. The remote
//! coordinator instead streams commands ahead of the replies, bounded by
//! [`RemoteOptions::window`]: `Step` commands carry no data dependency
//! and are sent without waiting; a `Mix` only requires that every
//! in-flight reply has been folded back into the coordinator's arena
//! (its staged rows read other shards' post-step states). The schedule
//! and arithmetic are untouched — `window: 1` degenerates to the
//! unpipelined protocol and every window produces identical results.
//!
//! **Reconnect-with-resume.** Daemons keep their session (shard state
//! plus a processed-command counter) when a connection dies. A
//! coordinator that loses a link re-dials, re-sends `Assign`, and the
//! daemon answers `Hello` + `Resume { done, states, .. }`; the
//! coordinator drops the pending frames the daemon already executed
//! (applying the resumed states in their place — their replies died with
//! the old socket), replays the rest, and continues the schedule.
//! Commands are executed exactly once, so the trajectory is unchanged —
//! pinned by `rust/tests/node.rs`, which injects connection drops
//! mid-run and asserts bit-for-bit parity with the loopback cluster.
//!
//! **Distributed telemetry.** Every daemon runs under an attached
//! tracer and answers `TelemetryPull` wire frames with a
//! [`crate::trace::NodeTelemetry`] snapshot — live health via
//! [`query_status`] (`matcha status ADDR`), and full trace/metric
//! harvests the coordinator folds into a
//! [`crate::trace::TelemetryCollector`] for merged per-process Chrome
//! traces and daemon-authoritative aggregate metrics.

mod coordinator;
mod daemon;

pub(crate) use coordinator::{run_remote_planned_telemetry, run_remote_planned_traced};
pub use coordinator::{run_remote, run_remote_observed, run_remote_traced, RemoteOptions};
pub(crate) use daemon::listen_and_serve;
pub use daemon::{query_status, run_daemon, DaemonOptions};
