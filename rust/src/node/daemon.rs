//! The shard-node daemon: one process serving one shard of a remote
//! cluster run.
//!
//! A daemon is started with nothing but a listen address
//! (`matcha shard-node --listen ADDR`); everything else arrives over the
//! wire. The first coordinator connection opens with an `Assign` frame
//! naming the daemon's shard and carrying the full experiment spec as
//! JSON, and the daemon rebuilds the workload from it — the same
//! `spec → plan → run_config → problem` path and the same seed
//! derivations every in-process backend uses, then the shared
//! [`ActorShard::for_partition`] construction. Identical inputs,
//! identical arithmetic: a remote run is bit-for-bit the in-process run.
//!
//! ## Session lifecycle
//!
//! The daemon's unit of state is a **session**: the shard's iterates plus
//! a `done` counter of fully processed commands. Connections are
//! ephemeral; sessions are not.
//!
//! - A dropped connection (coordinator crash, network fault, timeout)
//!   leaves the session intact. The daemon falls back to accepting, and
//!   a coordinator that re-dials with the same `Assign` gets a
//!   `Hello` + `Resume { done, states, .. }` handshake telling it
//!   exactly where the session stands — the basis of the coordinator's
//!   reconnect-with-resume (commands are executed exactly once: a frame
//!   is either fully processed before `done` moves, or never seen).
//! - A `Shutdown` frame ends the session cleanly: with
//!   [`DaemonOptions::once`] the daemon exits, otherwise it resets to a
//!   fresh session and waits for the next run (how a bench or test
//!   reuses one daemon fleet across many runs).
//! - A connection assigning a different shard, shard count or spec than
//!   the live session is rejected (logged, dropped) — a daemon serves
//!   one assignment per lifetime-until-reset.
//!
//! ## Telemetry
//!
//! Every daemon runs its workload under a real [`Tracer`] (a
//! [`RingSink`] plus the always-on metric registry), emitting
//! compute/mix spans around each command it executes. A
//! `TelemetryPull` frame — in-band on the command link, as the first
//! frame of a fresh connection, or on a side connection polled between
//! commands — is answered with a [`NodeTelemetry`] snapshot: session
//! health (shard, rounds, reconnects survived, uptime, ring drops),
//! the cumulative registry, and (on draining pulls) the ring's
//! records. Pulls never advance `done`, never enter the replay
//! machinery, and work even before the first `Assign` arrives, which
//! is what makes `matcha status ADDR` answer against an idle daemon.

use crate::cluster::driver::phase_cmd_from_wire;
use crate::cluster::wire::{peek_tag, MixLocalRef, TAG_MIX_LOCAL};
use crate::cluster::{TcpTransport, Transport, WireMsg, PROTO_VERSION};
use crate::engine::actor::{ActorShard, MixBatch};
use crate::experiment::{build_problem, plan, BuiltProblem, ExperimentSpec, DEFAULT_REPORT_WINDOW};
use crate::sim::kernel::{init_iterates, worker_streams};
use crate::sim::{Problem, RunConfig};
use crate::trace::{
    Counter, NodeTelemetry, Observatory, ObservatoryConfig, RingSink, TraceEvent, Tracer,
    UNASSIGNED_SHARD,
};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// How long an accepted connection gets to produce its `Assign` frame
/// before the daemon gives up on it and keeps accepting — a silent stray
/// connection must not wedge the accept loop.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Read/write deadline on a mid-session status connection: a stalled
/// `matcha status` client must not wedge the command loop for long.
const STATUS_TIMEOUT: Duration = Duration::from_millis(800);

/// Trace-ring capacity when the assigned spec carries no trace block
/// (the daemon always runs under an attached tracer so `matcha status`
/// and coordinator harvests have something to report).
const FALLBACK_RING_CAPACITY: usize = 4096;

/// Behavior knobs of [`run_daemon`].
#[derive(Clone, Debug)]
pub struct DaemonOptions {
    /// Exit after the first clean `Shutdown` instead of resetting the
    /// session and waiting for the next coordinator. The CI smoke runs
    /// daemons with `--once` so the processes terminate on their own.
    pub once: bool,
    /// Read/write deadline on the coordinator connection, in
    /// milliseconds; `0` keeps the connection fully blocking (a daemon
    /// happily waits for work). When set, a coordinator silent past the
    /// deadline drops the connection — the session survives for the
    /// reconnect.
    pub io_timeout_ms: u64,
    /// Fault injection for the reconnect tests: drop the coordinator
    /// connection once, after this many commands have been processed
    /// over the daemon's lifetime. Never set in production.
    pub drop_after: Option<u64>,
}

impl Default for DaemonOptions {
    fn default() -> Self {
        DaemonOptions { once: false, io_timeout_ms: 0, drop_after: None }
    }
}

/// What one accepted connection turned out to be.
enum Admission {
    /// A coordinator assignment: the link (steady-state timeout already
    /// applied) plus the assigned shard, shard count and spec JSON.
    Assigned(TcpTransport, u32, u32, String),
    /// A `matcha status` query; it was answered and the connection is
    /// done. The caller just keeps accepting.
    StatusHandled,
}

/// Accept one connection and read its first frame. An `Assign` is the
/// normal handshake; a `TelemetryPull` is answered from `status` and
/// the connection closed. The handshake runs under a short deadline;
/// afterwards an assigned connection switches to the configured
/// steady-state timeout. Any failure rejects only this connection.
fn accept_assign(
    listener: &TcpListener,
    opts: &DaemonOptions,
    status: &mut dyn FnMut(bool) -> NodeTelemetry,
) -> Result<Admission, String> {
    let (stream, peer) = listener.accept().map_err(|e| format!("shard-node: accept: {e}"))?;
    let mut link = TcpTransport::new(stream).map_err(|e| format!("shard-node: {peer}: {e}"))?;
    link.set_io_timeout(Some(HANDSHAKE_TIMEOUT))
        .map_err(|e| format!("shard-node: {peer}: {e}"))?;
    let mut body = Vec::new();
    match link.recv_msg(&mut body) {
        Ok(WireMsg::Assign { shard, shards, spec_json }) => {
            let steady = match opts.io_timeout_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            };
            link.set_io_timeout(steady).map_err(|e| format!("shard-node: {peer}: {e}"))?;
            Ok(Admission::Assigned(link, shard, shards, spec_json))
        }
        Ok(WireMsg::TelemetryPull { drain }) => {
            let mut scratch = Vec::new();
            let reply = WireMsg::TelemetrySnapshot { telemetry: status(drain) };
            link.send_msg(&reply, &mut scratch)
                .map_err(|e| format!("shard-node: {peer}: status reply: {e}"))?;
            Ok(Admission::StatusHandled)
        }
        Ok(other) => Err(format!("shard-node: {peer}: handshake expected Assign, got {other:?}")),
        Err(e) => Err(format!("shard-node: {peer}: handshake: {e}")),
    }
}

/// The idle-daemon health answer: no shard, no session, just uptime.
fn idle_telemetry(started: &Instant) -> NodeTelemetry {
    NodeTelemetry {
        shard: UNASSIGNED_SHARD,
        uptime_ms: started.elapsed().as_millis() as u64,
        ..NodeTelemetry::default()
    }
}

/// Build the live-session telemetry answer. `drain` empties the trace
/// ring into the reply (the ring's cumulative drop count survives).
fn session_telemetry(
    tracer: &mut Tracer<'_>,
    observatory: &Observatory,
    shard: u32,
    rounds_done: u64,
    reconnects: u64,
    drain: bool,
) -> NodeTelemetry {
    let wall = tracer.wall_now_ns();
    NodeTelemetry {
        shard,
        rounds_done,
        reconnects,
        uptime_ms: wall / 1_000_000,
        ring_dropped: tracer.sink_dropped(),
        wall_now_ns: wall,
        records: if drain { tracer.drain_sink() } else { Vec::new() },
        registry: tracer.registry.clone(),
        observatory: observatory.health(),
    }
}

/// Serve `matcha status` queries that arrive while a session is live:
/// between commands the daemon drains the listener non-blockingly and
/// answers first-frame `TelemetryPull`s on the side. Anything else —
/// including an `Assign` racing the live coordinator link — is dropped
/// with a log line rather than admitted mid-session.
fn poll_status_conns(
    listener: &TcpListener,
    shard_id: usize,
    status: &mut dyn FnMut(bool) -> NodeTelemetry,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(accepted) => accepted,
            Err(_) => break, // WouldBlock: no one waiting, back to work
        };
        if let Err(e) = answer_side_conn(stream, status) {
            eprintln!("shard-node {shard_id}: side connection from {peer} dropped: {e}");
        }
    }
    let _ = listener.set_nonblocking(false);
}

/// Answer one side connection's `TelemetryPull` (anything else errors).
fn answer_side_conn(
    stream: TcpStream,
    status: &mut dyn FnMut(bool) -> NodeTelemetry,
) -> Result<(), String> {
    stream.set_nonblocking(false).map_err(|e| e.to_string())?;
    let mut link = TcpTransport::new(stream).map_err(|e| e.to_string())?;
    link.set_io_timeout(Some(STATUS_TIMEOUT)).map_err(|e| e.to_string())?;
    let mut body = Vec::new();
    match link.recv_msg(&mut body).map_err(|e| e.to_string())? {
        WireMsg::TelemetryPull { drain } => {
            let mut scratch = Vec::new();
            let reply = WireMsg::TelemetrySnapshot { telemetry: status(drain) };
            link.send_msg(&reply, &mut scratch).map_err(|e| e.to_string())
        }
        other => Err(format!("mid-session frame must be TelemetryPull, got {other:?}")),
    }
}

/// One-shot health query against a daemon at `addr`: connect, send a
/// non-draining `TelemetryPull`, read the snapshot back. Works against
/// an idle daemon (pre-assign), between sessions, and mid-session (the
/// daemon polls for side connections between commands). The
/// `matcha status` client.
pub fn query_status(addr: &str, timeout_ms: u64) -> Result<NodeTelemetry, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("status: connect {addr}: {e}"))?;
    let mut link = TcpTransport::new(stream).map_err(|e| format!("status: {addr}: {e}"))?;
    let timeout = match timeout_ms {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    link.set_io_timeout(timeout).map_err(|e| format!("status: {addr}: {e}"))?;
    let mut scratch = Vec::new();
    link.send_msg(&WireMsg::TelemetryPull { drain: false }, &mut scratch)
        .map_err(|e| format!("status: {addr}: send: {e}"))?;
    let mut body = Vec::new();
    match link.recv_msg(&mut body) {
        Ok(WireMsg::TelemetrySnapshot { telemetry }) => Ok(telemetry),
        Ok(other) => Err(format!("status: {addr}: expected TelemetrySnapshot, got {other:?}")),
        Err(e) => Err(format!("status: {addr}: {e}")),
    }
}

/// Serve one shard forever (or until a `Shutdown` under
/// [`DaemonOptions::once`]). Binds to nothing itself — the caller owns
/// the listener, so tests can bind port 0 and read the ephemeral
/// address before spawning the daemon.
///
/// The first connection's `Assign` fixes the daemon's shard, shard count
/// and spec; an unparseable or inconsistent first assignment is fatal
/// (`Err`), because the daemon cannot know what to serve. Later
/// connections must repeat the same assignment and are merely rejected
/// when they do not. Status queries are answered at any point without
/// disturbing the lifecycle.
pub fn run_daemon(listener: TcpListener, opts: &DaemonOptions) -> Result<(), String> {
    let started = Instant::now();
    let (link, shard, shards, spec_json) = loop {
        let mut idle = |_drain: bool| idle_telemetry(&started);
        match accept_assign(&listener, opts, &mut idle)? {
            Admission::Assigned(link, shard, shards, spec_json) => {
                break (link, shard, shards, spec_json)
            }
            Admission::StatusHandled => continue,
        }
    };
    if shards == 0 || shard >= shards {
        return Err(format!("shard-node: assigned bogus shard {shard} of {shards}"));
    }
    let spec = ExperimentSpec::parse(&spec_json)
        .map_err(|e| format!("shard-node: assigned spec: {e}"))?;
    let exp_plan = plan(&spec).map_err(|e| format!("shard-node: plan: {e}"))?;
    let cfg = exp_plan.run_config(&spec).map_err(|e| format!("shard-node: {e}"))?;
    let m = exp_plan.graph.num_nodes();
    if shards as usize > m {
        return Err(format!(
            "shard-node: assigned {shards} shards over a {m}-worker graph \
             (each shard needs at least one worker)"
        ));
    }
    let ring_capacity = spec
        .trace
        .as_ref()
        .filter(|t| t.telemetry)
        .map(|t| t.telemetry_capacity)
        .unwrap_or(FALLBACK_RING_CAPACITY);
    let problem = build_problem(&spec, m);
    // The daemon mirrors the run's designed activation schedule from the
    // assigned spec alone: the sampler is deterministic in the spec
    // seeds, so the matchings the coordinator will drive each round are
    // reproducible here without any extra protocol.
    let mut sampler = exp_plan.sampler(spec.sampler_seed.unwrap_or(spec.seed));
    let activated: Vec<Vec<usize>> =
        (0..cfg.iterations).map(|k| sampler.round(k).activated).collect();
    let obs_cfg = ObservatoryConfig {
        designed: exp_plan.probabilities.clone(),
        matchings: exp_plan.decomposition.matchings.iter().map(|g| g.edges().to_vec()).collect(),
        rho: exp_plan.rho,
        workers: m,
        window: spec.report.as_ref().map_or(DEFAULT_REPORT_WINDOW, |r| r.window),
    };
    let sid = shard as usize;
    let n = shards as usize;
    match &problem {
        BuiltProblem::Quad(p) => serve(
            &listener,
            p,
            &cfg,
            m,
            sid,
            n,
            &spec_json,
            link,
            opts,
            ring_capacity,
            obs_cfg,
            activated,
        ),
        BuiltProblem::Logreg(p) => serve(
            &listener,
            p,
            &cfg,
            m,
            sid,
            n,
            &spec_json,
            link,
            opts,
            ring_capacity,
            obs_cfg,
            activated,
        ),
    }
}

/// Consensus distance of the daemon's local state segment: the mean
/// squared distance of its rows from their own mean. A local stand-in
/// for the global consensus distance — enough for the observatory's
/// windowed decay rate, which only needs a ratio of the same quantity
/// at two record points.
fn local_consensus(states: &[f64], d: usize) -> f64 {
    let rows = states.len() / d.max(1);
    if rows == 0 {
        return 0.0;
    }
    let mut mean = vec![0.0; d];
    for r in 0..rows {
        for (j, mj) in mean.iter_mut().enumerate() {
            *mj += states[r * d + j];
        }
    }
    for mj in mean.iter_mut() {
        *mj /= rows as f64;
    }
    let mut acc = 0.0;
    for r in 0..rows {
        for (j, &mj) in mean.iter().enumerate() {
            let diff = states[r * d + j] - mj;
            acc += diff * diff;
        }
    }
    acc / rows as f64
}

/// What span to emit around one phase command's execution.
enum DaemonSpan {
    Step,
    Mix { k: usize, msgs: usize },
}

/// The daemon's serve loop, generic over the workload: session state
/// outlives connections, connections come and go.
fn serve<P: Problem + ?Sized>(
    listener: &TcpListener,
    problem: &P,
    cfg: &RunConfig,
    m: usize,
    shard_id: usize,
    shards: usize,
    spec_json: &str,
    first: TcpTransport,
    opts: &DaemonOptions,
    ring_capacity: usize,
    obs_cfg: ObservatoryConfig,
    activated: Vec<Vec<usize>>,
) -> Result<(), String> {
    let d = problem.dim();
    // The same initial arena and gradient streams every backend derives
    // from the run seed — the daemon's slice of them is its session.
    let xs0 = init_iterates(cfg.seed, m, d);
    let rngs = worker_streams(cfg.seed, m);
    let fresh = || {
        ActorShard::for_partition(
            problem,
            cfg.compression.clone(),
            cfg.seed,
            shard_id,
            shards,
            &xs0,
            &rngs,
        )
    };

    // Session state: the shard plus exactly-once command accounting.
    // `done`/`steps`/`folded` describe the current session (reset on
    // Shutdown); `lifetime` counts across sessions for fault injection.
    let mut shard = fresh();
    let (mut done, mut steps, mut folded) = (0u64, 0u64, 0u64);
    let mut lifetime = 0u64;
    let mut dropped_once = false;

    // Telemetry: the tracer (ring + registry) spans the daemon's whole
    // life; session health (`rounds`/`reconnects`/`k_step`) resets with
    // the session, the registry never does.
    let mut ring = RingSink::new(ring_capacity);
    let mut tracer = Tracer::attached(&mut ring);
    let (mut rounds, mut reconnects, mut k_step) = (0u64, 0u64, 0u64);
    // The observatory is always armed daemon-side (it is what makes
    // `matcha status` answer with a drift/contraction one-liner); like
    // the session it resets on Shutdown.
    let mut observatory = Observatory::enabled(obs_cfg.clone());

    let mut scratch = Vec::new();
    let mut body = Vec::new();
    let mut ret: Vec<f64> = Vec::new();
    let mut batch = MixBatch::default();

    let mut conn = Some(first);
    loop {
        let mut link = match conn.take() {
            Some(link) => link,
            None => {
                let admission = accept_assign(listener, opts, &mut |drain| {
                    session_telemetry(
                        &mut tracer,
                        &observatory,
                        shard_id as u32,
                        rounds,
                        reconnects,
                        drain,
                    )
                });
                let (link, a_shard, a_shards, a_spec) = match admission {
                    Ok(Admission::Assigned(link, a_shard, a_shards, a_spec)) => {
                        (link, a_shard, a_shards, a_spec)
                    }
                    Ok(Admission::StatusHandled) => continue,
                    Err(e) => {
                        eprintln!("{e}");
                        continue;
                    }
                };
                if a_shard as usize != shard_id
                    || a_shards as usize != shards
                    || a_spec != spec_json
                {
                    eprintln!(
                        "shard-node {shard_id}: rejected connection assigning shard \
                         {a_shard}/{a_shards} with a different spec (serving \
                         {shard_id}/{shards})"
                    );
                    continue;
                }
                link
            }
        };

        // Announce ourselves and where the session stands. A resuming
        // coordinator diffs `done` against its own ack counter and
        // replays exactly the frames the previous connection lost; the
        // states carry the combined effect of every command whose reply
        // died with that connection.
        let hello = WireMsg::Hello { shard: shard_id as u32, proto: PROTO_VERSION };
        if let Err(e) = link.send_msg(&hello, &mut scratch) {
            eprintln!("shard-node {shard_id}: hello: {e}");
            continue;
        }
        let resume = WireMsg::Resume {
            done,
            steps,
            folded,
            dim: d as u32,
            states: shard.states().to_vec(),
        };
        if let Err(e) = link.send_msg(&resume, &mut scratch) {
            eprintln!("shard-node {shard_id}: resume: {e}");
            continue;
        }

        // Command loop on this connection. Any exit other than a
        // Shutdown drops the link and falls back to accepting with the
        // session intact — counted as a survived reconnect below.
        let mut clean_shutdown = false;
        loop {
            poll_status_conns(listener, shard_id, &mut |drain| {
                session_telemetry(
                    &mut tracer,
                    &observatory,
                    shard_id as u32,
                    rounds,
                    reconnects,
                    drain,
                )
            });
            let inject_drop = !dropped_once && matches!(opts.drop_after, Some(n) if lifetime >= n);
            if inject_drop {
                dropped_once = true;
                eprintln!(
                    "shard-node {shard_id}: fault injection: dropping connection after \
                     {lifetime} commands"
                );
                break;
            }
            if let Err(e) = link.recv_into(&mut body) {
                eprintln!("shard-node {shard_id}: connection lost: {e}");
                break;
            }
            let (span, reply) = if peek_tag(&body) == Ok(TAG_MIX_LOCAL) {
                // Zero-copy mix: the frame is decoded as a borrowed view
                // and its rows folded straight out of the receive buffer
                // — never materialized into an owned phase command.
                let frame = match MixLocalRef::decode(&body) {
                    Ok(frame) => frame,
                    Err(e) => {
                        eprintln!("shard-node {shard_id}: bad command: {e}");
                        break;
                    }
                };
                let span = DaemonSpan::Mix { k: frame.k as usize, msgs: frame.msg_count() };
                match shard.mix_from_frame(&frame, std::mem::take(&mut ret)) {
                    Ok(reply) => (Some(span), reply),
                    Err(e) => {
                        eprintln!("shard-node {shard_id}: bad command: {e}");
                        break;
                    }
                }
            } else {
                let msg = match WireMsg::decode(&body) {
                    Ok(msg) => msg,
                    Err(e) => {
                        eprintln!("shard-node {shard_id}: connection lost: {e}");
                        break;
                    }
                };
                // What to trace around this command, captured before the
                // frame is consumed by the command conversion.
                let span = match &msg {
                    WireMsg::Step { .. } => Some(DaemonSpan::Step),
                    WireMsg::Mix { k, msgs, .. } => {
                        Some(DaemonSpan::Mix { k: *k as usize, msgs: msgs.len() })
                    }
                    _ => None,
                };
                let cmd = match msg {
                    WireMsg::Shutdown => {
                        if opts.once {
                            return Ok(());
                        }
                        // Session over: forget it and wait for the next run.
                        shard = fresh();
                        (done, steps, folded) = (0, 0, 0);
                        (rounds, reconnects, k_step) = (0, 0, 0);
                        observatory = Observatory::enabled(obs_cfg.clone());
                        clean_shutdown = true;
                        break;
                    }
                    WireMsg::TelemetryPull { drain } => {
                        // In-band harvest: answered without touching `done`
                        // — never part of the exactly-once command stream.
                        let telemetry = session_telemetry(
                            &mut tracer,
                            &observatory,
                            shard_id as u32,
                            rounds,
                            reconnects,
                            drain,
                        );
                        let reply = WireMsg::TelemetrySnapshot { telemetry };
                        if let Err(e) = link.send_msg(&reply, &mut scratch) {
                            eprintln!("shard-node {shard_id}: telemetry reply: {e}");
                            break;
                        }
                        continue;
                    }
                    WireMsg::VersionReject { supported } => {
                        eprintln!(
                            "shard-node {shard_id}: coordinator rejected our protocol \
                             (it speaks version {supported})"
                        );
                        break;
                    }
                    msg => match phase_cmd_from_wire(msg, d, &mut batch, &mut ret) {
                        Ok(cmd) => cmd,
                        Err(e) => {
                            eprintln!("shard-node {shard_id}: bad command: {e}");
                            break;
                        }
                    },
                };
                if let Some(DaemonSpan::Step) = span {
                    tracer.set_now(k_step as f64);
                    tracer.emit(TraceEvent::ComputeBegin { worker: shard_id, k: k_step as usize });
                }
                (span, shard.handle(cmd))
            };
            match span {
                Some(DaemonSpan::Step) => {
                    tracer.emit(TraceEvent::ComputeEnd { worker: shard_id, k: k_step as usize });
                    k_step += 1;
                }
                Some(DaemonSpan::Mix { k, msgs }) => {
                    tracer.set_now(k as f64);
                    tracer.emit(TraceEvent::MixApplied { k, activated: msgs });
                    tracer.emit(TraceEvent::RoundBarrier { k });
                    rounds = k as u64 + 1;
                    // Commands are exactly-once per session, so the
                    // ledger can never double-count a round across
                    // reconnects.
                    if let Some(acts) = activated.get(k) {
                        observatory.on_round(acts, &[]);
                    }
                    if (k + 1) % cfg.record_every == 0 || k + 1 == cfg.iterations {
                        let c = local_consensus(shard.states(), d);
                        observatory.on_record(k + 1, k as f64 + 1.0, 0.0, f64::NAN, c);
                    }
                }
                None => {}
            }
            tracer.count(Counter::ShardSteps, reply.steps);
            tracer.count(Counter::ShardMsgsFolded, reply.folded);
            // Exactly-once accounting: the command is fully applied
            // before `done` moves, and `done` moves before the reply
            // ships — a connection can die at any point without the
            // counter misrepresenting the session.
            done += 1;
            lifetime += 1;
            steps += reply.steps;
            folded += reply.folded;
            if let Some(b) = reply.batch {
                batch = b;
            }
            let msg =
                WireMsg::States { shard: shard_id as u32, dim: d as u32, states: reply.states };
            if let Err(e) = link.send_msg(&msg, &mut scratch) {
                eprintln!("shard-node {shard_id}: reply: {e}");
                break;
            }
            let WireMsg::States { states, .. } = msg else { unreachable!() };
            ret = states;
        }
        if !clean_shutdown {
            reconnects += 1;
            tracer.count(Counter::Reconnects, 1);
        }
    }
}

/// Bind `addr` and serve: the `matcha shard-node` entry point. Split
/// from [`run_daemon`] so tests can pre-bind an ephemeral port.
pub(crate) fn listen_and_serve(addr: &str, opts: &DaemonOptions) -> Result<(), String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("shard-node: bind {addr}: {e}"))?;
    let local = listener
        .local_addr()
        .map_err(|e| format!("shard-node: listener address: {e}"))?;
    eprintln!("shard-node: listening on {local}");
    run_daemon(listener, opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_are_persistent_and_unbounded() {
        let opts = DaemonOptions::default();
        assert!(!opts.once);
        assert_eq!(opts.io_timeout_ms, 0);
        assert!(opts.drop_after.is_none());
    }

    #[test]
    fn first_connection_must_open_with_assign() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
        let addr = listener.local_addr().unwrap();
        let dial = std::thread::spawn(move || {
            let mut tx = TcpTransport::new(TcpStream::connect(addr).expect("connect")).unwrap();
            let mut scratch = Vec::new();
            // A Hello where an Assign belongs: the daemon must reject
            // the handshake instead of serving.
            tx.send_msg(&WireMsg::Hello { shard: 0, proto: PROTO_VERSION }, &mut scratch)
                .unwrap();
        });
        let err = run_daemon(listener, &DaemonOptions::default()).unwrap_err();
        assert!(err.contains("expected Assign"), "got: {err}");
        dial.join().unwrap();
    }

    #[test]
    fn bogus_shard_assignment_is_fatal() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
        let addr = listener.local_addr().unwrap();
        let dial = std::thread::spawn(move || {
            let mut tx = TcpTransport::new(TcpStream::connect(addr).expect("connect")).unwrap();
            let mut scratch = Vec::new();
            let assign = WireMsg::Assign { shard: 5, shards: 2, spec_json: String::from("{}") };
            tx.send_msg(&assign, &mut scratch).unwrap();
        });
        let err = run_daemon(listener, &DaemonOptions::default()).unwrap_err();
        assert!(err.contains("bogus shard"), "got: {err}");
        dial.join().unwrap();
    }

    #[test]
    fn idle_daemon_answers_status_then_still_requires_assign() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind");
        let addr = listener.local_addr().unwrap();
        let daemon = std::thread::spawn(move || run_daemon(listener, &DaemonOptions::default()));
        let snap = query_status(&addr.to_string(), 2_000).expect("status");
        assert_eq!(snap.shard, UNASSIGNED_SHARD);
        assert_eq!(snap.rounds_done, 0);
        assert_eq!(snap.reconnects, 0);
        assert!(snap.records.is_empty());
        // The status query consumed a connection without consuming the
        // daemon: a bogus Assign on the next connection is still the
        // fatal first assignment.
        let mut tx = TcpTransport::new(TcpStream::connect(addr).expect("connect")).unwrap();
        let mut scratch = Vec::new();
        let assign = WireMsg::Assign { shard: 5, shards: 2, spec_json: String::from("{}") };
        tx.send_msg(&assign, &mut scratch).unwrap();
        let err = daemon.join().unwrap().unwrap_err();
        assert!(err.contains("bogus shard"), "got: {err}");
    }
}
