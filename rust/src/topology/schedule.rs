//! Apriori communication schedules.
//!
//! The paper (§1, §3): "the communication schedule (i.e., the sequence of
//! sparse subgraphs) of MATCHA can be obtained apriori. There is no
//! additional runtime overhead during training." A [`Schedule`] is that
//! pregenerated sequence plus the mixing weight α; it can be saved to /
//! loaded from JSON so leaders can distribute it to workers before
//! training starts.

use super::{Round, TopologySampler};
use crate::json::Json;

/// A materialized communication schedule: `rounds[k]` lists the matchings
/// activated at iteration `k`; `alpha` is the mixing weight.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    pub alpha: f64,
    pub num_matchings: usize,
    pub rounds: Vec<Round>,
}

impl Schedule {
    /// Generate `steps` rounds from a sampler.
    pub fn generate<S: TopologySampler>(
        sampler: &mut S,
        alpha: f64,
        num_matchings: usize,
        steps: usize,
    ) -> Schedule {
        let rounds = (0..steps).map(|k| sampler.round(k)).collect();
        Schedule { alpha, num_matchings, rounds }
    }

    /// Total communication units over the whole schedule (unit-delay
    /// model: one unit per activated matching).
    pub fn total_comm_units(&self) -> usize {
        self.rounds.iter().map(|r| r.comm_units()).sum()
    }

    /// Average communication units per iteration.
    pub fn mean_comm_units(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.total_comm_units() as f64 / self.rounds.len() as f64
    }

    /// Empirical activation frequency of each matching.
    pub fn activation_frequencies(&self) -> Vec<f64> {
        let mut counts = vec![0usize; self.num_matchings];
        for r in &self.rounds {
            for &j in &r.activated {
                counts[j] += 1;
            }
        }
        counts
            .into_iter()
            .map(|c| c as f64 / self.rounds.len().max(1) as f64)
            .collect()
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("alpha", Json::Num(self.alpha)),
            ("num_matchings", Json::Num(self.num_matchings as f64)),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::Arr(
                                r.activated.iter().map(|&j| Json::Num(j as f64)).collect(),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse from JSON produced by [`Schedule::to_json`].
    pub fn from_json(j: &Json) -> Result<Schedule, String> {
        let alpha = j
            .get("alpha")
            .and_then(Json::as_f64)
            .ok_or("schedule: missing 'alpha'")?;
        let num_matchings = j
            .get("num_matchings")
            .and_then(Json::as_usize)
            .ok_or("schedule: missing 'num_matchings'")?;
        let rounds_json = j
            .get("rounds")
            .and_then(Json::as_array)
            .ok_or("schedule: missing 'rounds'")?;
        let mut rounds = Vec::with_capacity(rounds_json.len());
        for (k, r) in rounds_json.iter().enumerate() {
            let ids = r
                .as_array()
                .ok_or_else(|| format!("schedule: round {k} not an array"))?;
            let mut activated = Vec::with_capacity(ids.len());
            for id in ids {
                let j = id
                    .as_usize()
                    .ok_or_else(|| format!("schedule: bad matching id in round {k}"))?;
                if j >= num_matchings {
                    return Err(format!("schedule: matching id {j} out of range"));
                }
                activated.push(j);
            }
            rounds.push(Round { activated });
        }
        Ok(Schedule { alpha, num_matchings, rounds })
    }

    /// Save to a file as JSON.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load from a JSON file.
    pub fn load(path: &std::path::Path) -> Result<Schedule, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        Schedule::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{MatchaSampler, PeriodicSampler};

    #[test]
    fn generate_and_stats() {
        let mut s = MatchaSampler::new(vec![1.0, 0.0, 0.5], 9);
        let sched = Schedule::generate(&mut s, 0.3, 3, 2000);
        let freqs = sched.activation_frequencies();
        assert!((freqs[0] - 1.0).abs() < 1e-12);
        assert!(freqs[1].abs() < 1e-12);
        assert!((freqs[2] - 0.5).abs() < 0.05);
        assert!((sched.mean_comm_units() - 1.5).abs() < 0.05);
    }

    #[test]
    fn json_roundtrip() {
        let mut s = PeriodicSampler::new(4, 3);
        let sched = Schedule::generate(&mut s, 0.21, 4, 10);
        let j = sched.to_json();
        let back = Schedule::from_json(&j).unwrap();
        assert_eq!(back, sched);
    }

    #[test]
    fn file_roundtrip() {
        let mut s = MatchaSampler::new(vec![0.7, 0.3], 1);
        let sched = Schedule::generate(&mut s, 0.4, 2, 25);
        let dir = std::env::temp_dir().join("matcha_schedule_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sched.json");
        sched.save(&path).unwrap();
        let back = Schedule::load(&path).unwrap();
        assert_eq!(back, sched);
    }

    #[test]
    fn from_json_rejects_out_of_range_ids() {
        let j = Json::parse(r#"{"alpha":0.1,"num_matchings":2,"rounds":[[0,5]]}"#).unwrap();
        assert!(Schedule::from_json(&j).is_err());
    }
}
