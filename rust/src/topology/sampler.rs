//! Activation strategies: MATCHA's independent Bernoulli sampling plus
//! the paper's comparators (vanilla, periodic, single-matching).

use super::Round;
use crate::rng::Rng;

/// A strategy that decides, per iteration, which matchings communicate.
pub trait TopologySampler {
    /// Activated matchings for iteration `k` (0-based).
    fn round(&mut self, k: usize) -> Round;
    /// Expected communication units per iteration (Σ over matchings of
    /// the long-run activation frequency).
    fn expected_comm_units(&self) -> f64;
    /// Human-readable strategy name for logs/benches.
    fn name(&self) -> &'static str;
}

impl TopologySampler for Box<dyn TopologySampler> {
    fn round(&mut self, k: usize) -> Round {
        (**self).round(k)
    }

    fn expected_comm_units(&self) -> f64 {
        (**self).expected_comm_units()
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// MATCHA: matching `j` activates i.i.d. Bernoulli(p_j) each iteration
/// (paper Step 2/3).
pub struct MatchaSampler {
    probs: Vec<f64>,
    rng: Rng,
}

impl MatchaSampler {
    pub fn new(probs: Vec<f64>, seed: u64) -> Self {
        for &p in &probs {
            assert!((0.0..=1.0).contains(&p), "probability {p} out of range");
        }
        MatchaSampler { probs, rng: Rng::new(seed) }
    }
}

impl TopologySampler for MatchaSampler {
    fn round(&mut self, _k: usize) -> Round {
        let mut activated = Vec::new();
        for (j, &p) in self.probs.iter().enumerate() {
            if self.rng.bernoulli(p) {
                activated.push(j);
            }
        }
        Round { activated }
    }

    fn expected_comm_units(&self) -> f64 {
        self.probs.iter().sum()
    }

    fn name(&self) -> &'static str {
        "matcha"
    }
}

/// Adaptive-budget MATCHA (the paper's §6 future direction, after its
/// ref [34]): the communication budget — and therefore the optimized
/// activation probabilities — changes across training phases (e.g. spend
/// more budget early while consensus matters most, decay later).
///
/// Phases are `(start_iteration, probabilities)` with strictly increasing
/// starts; iteration `k` uses the last phase with `start ≤ k`.
pub struct AdaptiveMatchaSampler {
    phases: Vec<(usize, Vec<f64>)>,
    rng: Rng,
}

impl AdaptiveMatchaSampler {
    pub fn new(phases: Vec<(usize, Vec<f64>)>, seed: u64) -> Self {
        assert!(!phases.is_empty(), "need at least one phase");
        assert_eq!(phases[0].0, 0, "first phase must start at iteration 0");
        for w in phases.windows(2) {
            assert!(w[0].0 < w[1].0, "phase starts must increase");
            assert_eq!(w[0].1.len(), w[1].1.len(), "phase prob lengths differ");
        }
        for (_, probs) in &phases {
            for &p in probs {
                assert!((0.0..=1.0).contains(&p));
            }
        }
        AdaptiveMatchaSampler { phases, rng: Rng::new(seed) }
    }

    /// Build from a budget schedule `(start_iter, cb)` by solving problem
    /// (4) per phase. Returns the sampler and a single conservative
    /// mixing weight: the minimum of the per-phase optimal α's (each
    /// phase's ρ(α) is convex with ρ < 1 on (0, 2α*_phase), and
    /// min_phase α* lies in that interval for every phase, so ρ < 1 holds
    /// throughout training).
    pub fn from_budget_schedule(
        decomp: &crate::matching::MatchingDecomposition,
        schedule: &[(usize, f64)],
        seed: u64,
    ) -> (Self, f64) {
        use crate::budget::optimize_activation_probabilities;
        use crate::mixing::optimize_alpha;
        assert!(!schedule.is_empty());
        let mut phases = Vec::with_capacity(schedule.len());
        let mut alpha = f64::INFINITY;
        for &(start, cb) in schedule {
            let probs = optimize_activation_probabilities(decomp, cb);
            let mix = optimize_alpha(decomp, &probs.probabilities);
            alpha = alpha.min(mix.alpha);
            phases.push((start, probs.probabilities));
        }
        (Self::new(phases, seed), alpha)
    }

    fn probs_at(&self, k: usize) -> &[f64] {
        let idx = self
            .phases
            .iter()
            .rposition(|&(start, _)| start <= k)
            .expect("first phase starts at 0");
        &self.phases[idx].1
    }
}

impl TopologySampler for AdaptiveMatchaSampler {
    fn round(&mut self, k: usize) -> Round {
        let mut activated = Vec::new();
        // Borrow-split: copy the phase probabilities cheaply (M is tiny).
        let probs: Vec<f64> = self.probs_at(k).to_vec();
        for (j, &p) in probs.iter().enumerate() {
            if self.rng.bernoulli(p) {
                activated.push(j);
            }
        }
        Round { activated }
    }

    fn expected_comm_units(&self) -> f64 {
        // Long-run expectation is phase-dependent; report the final phase.
        self.phases.last().unwrap().1.iter().sum()
    }

    fn name(&self) -> &'static str {
        "adaptive-matcha"
    }
}

/// Vanilla DecenSGD: every matching, every iteration.
pub struct VanillaSampler {
    m: usize,
}

impl VanillaSampler {
    pub fn new(num_matchings: usize) -> Self {
        VanillaSampler { m: num_matchings }
    }
}

impl TopologySampler for VanillaSampler {
    fn round(&mut self, _k: usize) -> Round {
        Round { activated: (0..self.m).collect() }
    }

    fn expected_comm_units(&self) -> f64 {
        self.m as f64
    }

    fn name(&self) -> &'static str {
        "vanilla"
    }
}

/// Periodic DecenSGD (P-DecenSGD, paper §3): the *whole* base topology is
/// activated every `period` iterations, nothing in between. At period
/// `⌈1/CB⌉` its budget matches MATCHA's CB.
pub struct PeriodicSampler {
    m: usize,
    period: usize,
}

impl PeriodicSampler {
    pub fn new(num_matchings: usize, period: usize) -> Self {
        assert!(period >= 1);
        PeriodicSampler { m: num_matchings, period }
    }

    /// Construct from a communication budget: period = round(1/CB).
    pub fn from_budget(num_matchings: usize, cb: f64) -> Self {
        assert!(cb > 0.0 && cb <= 1.0);
        let period = (1.0 / cb).round().max(1.0) as usize;
        Self::new(num_matchings, period)
    }
}

impl TopologySampler for PeriodicSampler {
    fn round(&mut self, k: usize) -> Round {
        if (k + 1) % self.period == 0 {
            Round { activated: (0..self.m).collect() }
        } else {
            Round { activated: vec![] }
        }
    }

    fn expected_comm_units(&self) -> f64 {
        self.m as f64 / self.period as f64
    }

    fn name(&self) -> &'static str {
        "periodic"
    }
}

/// Single-matching variant (paper §3 "Extension to Other Design
/// Choices"): exactly one matching per iteration, drawn with probability
/// proportional to the activation probabilities.
pub struct SingleMatchingSampler {
    weights: Vec<f64>,
    rng: Rng,
}

impl SingleMatchingSampler {
    pub fn new(weights: Vec<f64>, seed: u64) -> Self {
        assert!(weights.iter().any(|&w| w > 0.0), "need a positive weight");
        SingleMatchingSampler { weights, rng: Rng::new(seed) }
    }
}

impl TopologySampler for SingleMatchingSampler {
    fn round(&mut self, _k: usize) -> Round {
        let j = self.rng.weighted_choice(&self.weights);
        Round { activated: vec![j] }
    }

    fn expected_comm_units(&self) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "single-matching"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matcha_activation_frequencies_match_probs() {
        let probs = vec![0.9, 0.5, 0.1];
        let mut s = MatchaSampler::new(probs.clone(), 42);
        let iters = 20_000;
        let mut counts = vec![0usize; 3];
        for k in 0..iters {
            for j in s.round(k).activated {
                counts[j] += 1;
            }
        }
        for j in 0..3 {
            let freq = counts[j] as f64 / iters as f64;
            assert!(
                (freq - probs[j]).abs() < 0.02,
                "matching {j}: freq {freq} vs p {}",
                probs[j]
            );
        }
    }

    #[test]
    fn matcha_is_deterministic_per_seed() {
        let mut a = MatchaSampler::new(vec![0.5, 0.5], 7);
        let mut b = MatchaSampler::new(vec![0.5, 0.5], 7);
        for k in 0..100 {
            assert_eq!(a.round(k), b.round(k));
        }
    }

    #[test]
    fn adaptive_switches_phases() {
        let mut s = AdaptiveMatchaSampler::new(
            vec![(0, vec![1.0, 1.0]), (100, vec![0.0, 1.0]), (200, vec![0.0, 0.0])],
            5,
        );
        for k in 0..100 {
            assert_eq!(s.round(k).activated, vec![0, 1], "k={k}");
        }
        for k in 100..200 {
            assert_eq!(s.round(k).activated, vec![1], "k={k}");
        }
        for k in 200..250 {
            assert!(s.round(k).activated.is_empty(), "k={k}");
        }
    }

    #[test]
    fn adaptive_from_budget_schedule_is_feasible() {
        use crate::graph::paper_figure1_graph;
        use crate::matching::decompose;
        let d = decompose(&paper_figure1_graph());
        let (s, alpha) =
            AdaptiveMatchaSampler::from_budget_schedule(&d, &[(0, 0.8), (500, 0.2)], 3);
        assert!(alpha > 0.0);
        assert_eq!(s.phases.len(), 2);
        // Early phase spends more than late phase.
        let early: f64 = s.phases[0].1.iter().sum();
        let late: f64 = s.phases[1].1.iter().sum();
        assert!(early > late);
    }

    #[test]
    #[should_panic(expected = "phase starts must increase")]
    fn adaptive_rejects_bad_phase_order() {
        AdaptiveMatchaSampler::new(vec![(0, vec![0.5]), (0, vec![0.5])], 1);
    }

    #[test]
    fn vanilla_always_everything() {
        let mut s = VanillaSampler::new(4);
        for k in 0..10 {
            assert_eq!(s.round(k).activated, vec![0, 1, 2, 3]);
        }
        assert_eq!(s.expected_comm_units(), 4.0);
    }

    #[test]
    fn periodic_fires_on_schedule() {
        let mut s = PeriodicSampler::new(3, 4);
        let fired: Vec<bool> = (0..12).map(|k| !s.round(k).activated.is_empty()).collect();
        // Fires at k = 3, 7, 11 (every 4th iteration).
        assert_eq!(
            fired,
            vec![false, false, false, true, false, false, false, true, false, false, false, true]
        );
        assert!((s.expected_comm_units() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn periodic_from_budget() {
        let s = PeriodicSampler::from_budget(5, 0.25);
        assert_eq!(s.period, 4);
        let s2 = PeriodicSampler::from_budget(5, 1.0);
        assert_eq!(s2.period, 1);
    }

    #[test]
    fn single_matching_draws_one() {
        let mut s = SingleMatchingSampler::new(vec![1.0, 2.0, 1.0], 3);
        let mut counts = vec![0usize; 3];
        for k in 0..8000 {
            let r = s.round(k);
            assert_eq!(r.activated.len(), 1);
            counts[r.activated[0]] += 1;
        }
        // Middle matching should be drawn ~2x as often.
        let ratio = counts[1] as f64 / (counts[0] + counts[2]) as f64;
        assert!((ratio - 1.0).abs() < 0.15, "ratio {ratio}");
    }
}
