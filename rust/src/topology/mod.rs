//! Random topology sequence generation (Step 3 of MATCHA) and the
//! benchmark activation strategies.
//!
//! A [`TopologySampler`] produces, per iteration, the set of activated
//! matchings and the corresponding mixing matrix `W⁽ᵏ⁾ = I − α Σ B_j L_j`.
//! The paper emphasizes that the whole sequence can be generated
//! **apriori** — [`Schedule`] materializes it up front, can be serialized
//! to JSON, and is what the training coordinator executes (zero runtime
//! scheduling overhead, exactly as claimed in §1).

mod sampler;
mod schedule;

pub use sampler::*;
pub use schedule::*;

use crate::linalg::Mat;

/// One iteration's communication plan.
#[derive(Clone, Debug, PartialEq)]
pub struct Round {
    /// Indices of activated matchings (into the decomposition).
    pub activated: Vec<usize>,
}

impl Round {
    /// Number of sequential matching communications this round costs
    /// under the unit-delay model.
    pub fn comm_units(&self) -> usize {
        self.activated.len()
    }
}

/// Build the mixing matrix `W = I − α Σ_{j∈activated} L_j`.
pub fn mixing_matrix(laplacians: &[Mat], activated: &[usize], alpha: f64) -> Mat {
    assert!(!laplacians.is_empty());
    let n = laplacians[0].rows();
    let mut w = Mat::eye(n);
    for &j in activated {
        w.axpy(-alpha, &laplacians[j]);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;

    #[test]
    fn mixing_matrix_identity_when_nothing_activated() {
        let d = decompose(&paper_figure1_graph());
        let w = mixing_matrix(&d.laplacians(), &[], 0.3);
        assert!(w.max_abs_diff(&Mat::eye(8)) < 1e-12);
    }

    #[test]
    fn mixing_matrix_doubly_stochastic_any_subset() {
        let d = decompose(&paper_figure1_graph());
        let laps = d.laplacians();
        for subset in [vec![0], vec![0, 1], (0..d.len()).collect::<Vec<_>>()] {
            let w = mixing_matrix(&laps, &subset, 0.2);
            assert!(w.is_doubly_stochastic(1e-9));
            assert!(w.is_symmetric(1e-9));
        }
    }
}
