//! Reusable scratch-state pools: allocate once per run, reuse every
//! iteration, never allocate in the mixing hot path.

use super::arena::StateMatrix;

/// Per-run scratch for the step/mix kernels: the per-worker delta
/// accumulators of the simultaneous gossip fold, the per-edge difference
/// message, and the gradient buffer. One `DeltaPool` is allocated at run
/// start and threaded through every iteration — the historical code
/// allocated the gradient with the runner and the deltas with a separate
/// `GossipScratch`; this pool is their single arena-backed replacement.
pub struct DeltaPool {
    /// `workers × dim` delta accumulators (`Δ_w` of the gossip fold).
    deltas: StateMatrix,
    /// One edge's difference message `x_v − x_u` (post-compression).
    diff: Vec<f64>,
    /// One worker's stochastic-gradient scratch.
    grad: Vec<f64>,
    /// TopK compression's magnitude-sort scratch
    /// ([`crate::sim::Compression::compress_with`]) — preallocated here
    /// so compressing an edge message never touches the heap.
    comp: Vec<f64>,
}

impl DeltaPool {
    /// Scratch for `workers` workers of dimension `dim`.
    pub fn new(workers: usize, dim: usize) -> DeltaPool {
        DeltaPool {
            deltas: StateMatrix::zeros(workers, dim),
            diff: vec![0.0; dim],
            grad: vec![0.0; dim],
            comp: Vec::with_capacity(dim),
        }
    }

    /// The gradient scratch buffer (for [`crate::sim::kernel::local_sgd_step`]).
    pub fn grad_mut(&mut self) -> &mut [f64] {
        &mut self.grad
    }

    /// Split borrow of the delta arena, the diff buffer and the
    /// compression scratch — the three pieces the gossip fold writes
    /// concurrently.
    pub(crate) fn fold_scratch(&mut self) -> (&mut StateMatrix, &mut [f64], &mut Vec<f64>) {
        (&mut self.deltas, &mut self.diff, &mut self.comp)
    }

    /// Read access to the delta accumulators (the apply step).
    pub(crate) fn deltas(&self) -> &StateMatrix {
        &self.deltas
    }
}

/// A grow-only row pool with a free list: fixed-width rows borrowed for a
/// while (a round snapshot, a staged per-edge contribution, a metrics
/// snapshot) and recycled instead of freed. The asynchronous gossip
/// runtime keeps every transient model-sized buffer here, so its steady
/// state performs no per-message heap allocation: `alloc` only touches
/// the heap while the pool is still growing toward the run's peak
/// concurrency.
pub struct SnapshotPool {
    data: Vec<f64>,
    dim: usize,
    rows: usize,
    free_rows: Vec<usize>,
}

impl SnapshotPool {
    /// An empty pool of `dim`-wide rows.
    pub fn new(dim: usize) -> SnapshotPool {
        SnapshotPool { data: Vec::new(), dim, rows: 0, free_rows: Vec::new() }
    }

    /// Borrow a row (contents unspecified until written).
    pub fn alloc(&mut self) -> usize {
        if let Some(r) = self.free_rows.pop() {
            r
        } else {
            self.rows += 1;
            self.data.resize(self.rows * self.dim, 0.0);
            self.rows - 1
        }
    }

    /// Borrow a row initialized to a copy of `src` (`src.len() == dim`).
    pub fn alloc_from(&mut self, src: &[f64]) -> usize {
        let r = self.alloc();
        self.row_mut(r).copy_from_slice(src);
        r
    }

    /// Return a row to the free list.
    pub fn release(&mut self, r: usize) {
        debug_assert!(!self.free_rows.contains(&r), "double release of row {r}");
        self.free_rows.push(r);
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Row `r` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Rows currently lent out.
    pub fn in_use(&self) -> usize {
        self.rows - self.free_rows.len()
    }

    /// Peak row count reached so far (the pool never shrinks).
    pub fn capacity_rows(&self) -> usize {
        self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_pool_shapes() {
        let mut p = DeltaPool::new(4, 3);
        assert_eq!(p.grad_mut().len(), 3);
        let (deltas, diff, comp) = p.fold_scratch();
        assert_eq!(deltas.rows(), 4);
        assert_eq!(deltas.dim(), 3);
        assert_eq!(diff.len(), 3);
        assert!(comp.capacity() >= 3, "compression scratch preallocated");
    }

    #[test]
    fn snapshot_pool_recycles_rows() {
        let mut p = SnapshotPool::new(2);
        let a = p.alloc_from(&[1.0, 2.0]);
        let b = p.alloc_from(&[3.0, 4.0]);
        assert_ne!(a, b);
        assert_eq!(p.row(a), &[1.0, 2.0]);
        assert_eq!(p.in_use(), 2);
        p.release(a);
        assert_eq!(p.in_use(), 1);
        let c = p.alloc();
        assert_eq!(c, a, "freed row must be reused before growing");
        assert_eq!(p.capacity_rows(), 2);
        p.release(b);
        p.release(c);
        assert_eq!(p.in_use(), 0);
    }
}
