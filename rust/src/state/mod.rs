//! Contiguous model-state arena shared by every execution backend.
//!
//! MATCHA's per-iteration cost is dominated by the gossip mix
//! `X ← X + α Σ_j (−L_j) X`, and the memory layout of that step — not
//! the math — decides real-world throughput. This module owns the
//! layout:
//!
//! - [`StateMatrix`] ([`arena`]) — all worker iterates in one contiguous
//!   row-major `workers × dim` buffer, with typed [`RowRef`] / [`RowMut`]
//!   views and split-borrow row access.
//! - [`DeltaPool`] / [`SnapshotPool`] ([`pool`]) — once-per-run scratch:
//!   delta accumulators, edge-message and gradient buffers, and a
//!   recycled row pool for the async runtime's transient snapshots.
//! - [`MixKernel`] ([`kernel`]) — the edge-wise gossip fold applied in
//!   place over arena rows, plus the per-worker staged fold the actor
//!   shards use.
//! - [`simd`] — the vectorized (AVX2, runtime-detected, scalar-fallback)
//!   element loops the kernel dispatches to, bit-for-bit identical to
//!   the scalar arithmetic, with [`RowSource`] abstracting host rows vs
//!   rows borrowed straight from a received wire frame.
//!
//! Every execution layer runs on this module: the sequential simulator
//! ([`crate::sim`]), both engine executors ([`crate::engine`]), and the
//! barrier-free gossip runtime ([`crate::gossip`]). The refactor changed
//! representation only — message formation, fold order and apply order
//! are untouched — so all backends remain bit-for-bit equal to the
//! pre-arena trajectories per seed (`rust/tests/golden.rs` pins them
//! against golden fixtures, generated on first run and committed
//! thereafter). The payoff is zero per-message heap
//! allocation in the mixing hot path (measured by `benches/hotpath.rs`,
//! `BENCH_state.json`) and a memory footprint that scales to thousands
//! of workers × large `dim`.

pub mod arena;
pub mod kernel;
pub mod pool;
pub mod simd;

pub use arena::{RowMut, RowRef, StateMatrix};
pub use kernel::MixKernel;
pub use pool::{DeltaPool, SnapshotPool};
pub use simd::{simd_active, RowSource};
