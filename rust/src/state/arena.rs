//! The contiguous model-state arena.
//!
//! [`StateMatrix`] stores all `rows` worker iterates in **one** row-major
//! `rows × dim` buffer. Every execution backend (sequential simulator,
//! event-driven engine, actor pool, asynchronous gossip runtime) keeps its
//! iterates — and its scratch state — in arenas instead of `Vec<Vec<f64>>`,
//! which buys:
//!
//! - one allocation per run instead of one per worker (and none at all in
//!   the mixing hot path — see [`super::DeltaPool`]),
//! - cache-friendly row-major traversal for the gossip fold,
//! - a single place for later performance work (SIMD chunking,
//!   compression staging, multi-node sharding) to land.
//!
//! The arena changes the *representation* only: row accessors hand out
//! exactly the `&[f64]` / `&mut [f64]` slices the kernels always operated
//! on, in the same iteration order, so trajectories are bit-for-bit
//! identical to the historical `Vec<Vec<f64>>` code (enforced by
//! `rust/tests/golden.rs`).

use crate::rng::Rng;

/// All worker iterates of a run in one contiguous row-major buffer.
///
/// Row `w` is worker `w`'s iterate `x_w ∈ R^dim`. Use [`StateMatrix::row`]
/// / [`StateMatrix::row_mut`] for raw slices, [`StateMatrix::view`] /
/// [`StateMatrix::view_mut`] for typed views that remember their row
/// index, and [`StateMatrix::pair`] to read two distinct rows at once
/// (the edge-wise gossip access pattern).
#[derive(Clone, Debug, PartialEq)]
pub struct StateMatrix {
    data: Vec<f64>,
    rows: usize,
    dim: usize,
}

impl StateMatrix {
    /// A `rows × dim` arena of zeros.
    pub fn zeros(rows: usize, dim: usize) -> StateMatrix {
        StateMatrix { data: vec![0.0; rows * dim], rows, dim }
    }

    /// The common initial point: every worker starts from the same random
    /// iterate (Theorem 1 starts all workers at the same point). Exactly
    /// the historical `init_iterates` derivation: `0.01 · N(0,1)` per
    /// coordinate from `Rng::new(seed)`.
    pub fn init(seed: u64, rows: usize, dim: usize) -> StateMatrix {
        let mut rng = Rng::new(seed);
        let x0: Vec<f64> = (0..dim).map(|_| 0.01 * rng.normal()).collect();
        let mut m = StateMatrix::zeros(rows, dim);
        for r in 0..rows {
            m.row_mut(r).copy_from_slice(&x0);
        }
        m
    }

    /// Build an arena from per-worker vectors (tests, compatibility).
    /// All vectors must share one length.
    pub fn from_vecs(xs: &[Vec<f64>]) -> StateMatrix {
        let rows = xs.len();
        let dim = if rows == 0 { 0 } else { xs[0].len() };
        let mut m = StateMatrix::zeros(rows, dim);
        for (r, x) in xs.iter().enumerate() {
            m.row_mut(r).copy_from_slice(x);
        }
        m
    }

    /// Copy out as per-worker vectors (serialization, compatibility).
    pub fn to_vecs(&self) -> Vec<Vec<f64>> {
        self.iter_rows().map(|r| r.to_vec()).collect()
    }

    /// Number of rows (workers).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row length (parameter dimension `d`).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Row `r` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Typed read view of row `r` (carries the row index).
    #[inline]
    pub fn view(&self, r: usize) -> RowRef<'_> {
        RowRef { row: r, data: self.row(r) }
    }

    /// Typed write view of row `r` (carries the row index).
    #[inline]
    pub fn view_mut(&mut self, r: usize) -> RowMut<'_> {
        let dim = self.dim;
        RowMut { row: r, data: &mut self.data[r * dim..(r + 1) * dim] }
    }

    /// Two distinct rows at once — the gossip kernel reads both endpoints
    /// of an edge from the pre-mix state. Panics if `u == v`.
    #[inline]
    pub fn pair(&self, u: usize, v: usize) -> (&[f64], &[f64]) {
        assert_ne!(u, v, "pair: rows must be distinct");
        (self.row(u), self.row(v))
    }

    /// Two distinct mutable rows at once (split borrow). Panics if
    /// `u == v`.
    pub fn pair_mut(&mut self, u: usize, v: usize) -> (&mut [f64], &mut [f64]) {
        assert_ne!(u, v, "pair_mut: rows must be distinct");
        let dim = self.dim;
        let (lo, hi) = (u.min(v), u.max(v));
        let (head, tail) = self.data.split_at_mut(hi * dim);
        let lo_row = &mut head[lo * dim..(lo + 1) * dim];
        let hi_row = &mut tail[..dim];
        if u < v {
            (lo_row, hi_row)
        } else {
            (hi_row, lo_row)
        }
    }

    /// Iterate rows in worker order.
    #[inline]
    pub fn iter_rows(&self) -> std::slice::ChunksExact<'_, f64> {
        self.data.chunks_exact(self.dim)
    }

    /// Iterate mutable rows in worker order.
    #[inline]
    pub fn iter_rows_mut(&mut self) -> std::slice::ChunksExactMut<'_, f64> {
        self.data.chunks_exact_mut(self.dim)
    }

    /// The whole arena as one flat slice (row-major).
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// The whole arena as one flat mutable slice (row-major).
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Set every element to `v` (delta-accumulator reset).
    pub fn fill(&mut self, v: f64) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Mean iterate x̄ = (1/rows) Σ x_w, in the same accumulation order
    /// as the historical `sim::mean_iterate` (bit-for-bit).
    pub fn mean(&self) -> Vec<f64> {
        let mut mean = vec![0.0; self.dim];
        for x in self.iter_rows() {
            for (a, &b) in mean.iter_mut().zip(x) {
                *a += b;
            }
        }
        for a in mean.iter_mut() {
            *a /= self.rows as f64;
        }
        mean
    }

    /// Consensus distance `(1/rows) Σ_w ‖x_w − x̄‖²` (paper eq. 62), same
    /// accumulation order as the historical `sim::consensus_distance`.
    pub fn consensus_distance(&self) -> f64 {
        let mean = self.mean();
        self.iter_rows()
            .map(|x| x.iter().zip(&mean).map(|(a, b)| (a - b) * (a - b)).sum::<f64>())
            .sum::<f64>()
            / self.rows as f64
    }
}

/// A typed read-only view of one arena row: derefs to `&[f64]` and
/// remembers which worker it belongs to.
#[derive(Clone, Copy)]
pub struct RowRef<'a> {
    row: usize,
    data: &'a [f64],
}

impl<'a> RowRef<'a> {
    /// The worker (row) index this view points at.
    pub fn index(&self) -> usize {
        self.row
    }

    /// The underlying slice with the view's lifetime.
    pub fn as_slice(&self) -> &'a [f64] {
        self.data
    }
}

impl std::ops::Deref for RowRef<'_> {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        self.data
    }
}

/// A typed mutable view of one arena row: derefs to `&mut [f64]` and
/// remembers which worker it belongs to.
pub struct RowMut<'a> {
    row: usize,
    data: &'a mut [f64],
}

impl RowMut<'_> {
    /// The worker (row) index this view points at.
    pub fn index(&self) -> usize {
        self.row
    }
}

impl std::ops::Deref for RowMut<'_> {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        self.data
    }
}

impl std::ops::DerefMut for RowMut<'_> {
    fn deref_mut(&mut self) -> &mut [f64] {
        self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_contiguous_and_ordered() {
        let mut m = StateMatrix::zeros(3, 2);
        for r in 0..3 {
            for c in 0..2 {
                m.row_mut(r)[c] = (r * 2 + c) as f64;
            }
        }
        assert_eq!(m.as_slice(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(m.row(1), &[2.0, 3.0]);
        let rows: Vec<&[f64]> = m.iter_rows().collect();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2], &[4.0, 5.0]);
    }

    #[test]
    fn init_matches_historical_derivation() {
        // Same RNG recipe as the old `init_iterates`: one x0, replicated.
        let m = StateMatrix::init(3, 5, 8);
        let mut rng = Rng::new(3);
        let x0: Vec<f64> = (0..8).map(|_| 0.01 * rng.normal()).collect();
        for r in 0..5 {
            assert_eq!(m.row(r), &x0[..]);
        }
        assert_eq!(m, StateMatrix::init(3, 5, 8));
    }

    #[test]
    fn pair_mut_splits_either_orientation() {
        let mut m = StateMatrix::from_vecs(&[vec![1.0], vec![2.0], vec![3.0]]);
        {
            let (a, b) = m.pair_mut(0, 2);
            assert_eq!((a[0], b[0]), (1.0, 3.0));
            a[0] = 10.0;
            b[0] = 30.0;
        }
        {
            let (a, b) = m.pair_mut(2, 0);
            assert_eq!((a[0], b[0]), (30.0, 10.0));
        }
    }

    #[test]
    fn mean_and_consensus_match_vec_helpers() {
        let xs = vec![vec![1.0, 0.0], vec![3.0, 0.0]];
        let m = StateMatrix::from_vecs(&xs);
        assert_eq!(m.mean(), crate::sim::mean_iterate(&xs));
        assert_eq!(m.consensus_distance(), crate::sim::consensus_distance(&xs));
        assert_eq!(m.to_vecs(), xs);
    }

    // -- contract coverage: the panicking paths -----------------------

    #[test]
    #[should_panic(expected = "pair: rows must be distinct")]
    fn pair_rejects_identical_rows() {
        let m = StateMatrix::zeros(3, 2);
        let _ = m.pair(1, 1);
    }

    #[test]
    #[should_panic(expected = "pair_mut: rows must be distinct")]
    fn pair_mut_rejects_identical_rows() {
        let mut m = StateMatrix::zeros(3, 2);
        let _ = m.pair_mut(2, 2);
    }

    #[test]
    #[should_panic]
    fn row_out_of_range_panics() {
        let m = StateMatrix::zeros(2, 3);
        let _ = m.row(2);
    }

    #[test]
    #[should_panic]
    fn row_mut_out_of_range_panics() {
        let mut m = StateMatrix::zeros(2, 3);
        let _ = m.row_mut(5);
    }

    #[test]
    #[should_panic]
    fn pair_mut_out_of_range_panics() {
        let mut m = StateMatrix::zeros(2, 3);
        let _ = m.pair_mut(0, 2);
    }

    #[test]
    fn views_carry_their_index() {
        let mut m = StateMatrix::zeros(2, 3);
        {
            let mut v = m.view_mut(1);
            assert_eq!(v.index(), 1);
            v[0] = 7.0;
        }
        let v = m.view(1);
        assert_eq!(v.index(), 1);
        assert_eq!(v.as_slice()[0], 7.0);
        assert_eq!(&*v, &[7.0, 0.0, 0.0]);
    }
}
