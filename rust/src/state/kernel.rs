//! The arena-backed gossip mix kernel.
//!
//! [`MixKernel`] performs the simultaneous gossip step
//! `X ← X + α Σ_{j∈activated} (−L_j^live) X` edge-wise, **in place** over
//! [`StateMatrix`] rows, with all scratch coming from a once-per-run
//! [`DeltaPool`] — zero heap allocation per message, per edge, or per
//! iteration. The arithmetic (message formation, fold order, final apply)
//! is exactly the historical `sim::kernel::apply_gossip`, so every
//! backend built on this kernel reproduces the pre-arena trajectories
//! bit-for-bit (`rust/tests/golden.rs`).
//!
//! Two entry points cover the two execution shapes:
//!
//! - [`MixKernel::apply`] — the full-state fold used by the sequential
//!   simulator and the engine's in-process executor: one pass over every
//!   activated edge, reading both endpoint rows from the pre-mix arena.
//! - [`MixKernel::fold_worker`] — one worker's fold from routed peer-row
//!   messages, used by the actor shards (per-shard staging buffers) —
//!   same accumulation order per worker as the full-state fold.

use super::arena::StateMatrix;
use super::pool::DeltaPool;
use super::simd::{self, RowSource};
use crate::graph::Graph;
use crate::sim::kernel::edge_diff_message_src;
use crate::sim::Compression;

/// The gossip-mix context of one run: the run seed (per-edge compression
/// RNG derivation) and the optional message compression. Copy-cheap;
/// construct it once per run next to the [`DeltaPool`].
#[derive(Clone, Copy)]
pub struct MixKernel<'a> {
    seed: u64,
    compression: Option<&'a Compression>,
}

impl<'a> MixKernel<'a> {
    pub fn new(seed: u64, compression: Option<&'a Compression>) -> MixKernel<'a> {
        MixKernel { seed, compression }
    }

    /// Apply one simultaneous gossip step in place over the arena:
    /// `X ← X + α Σ_{j∈activated} (−L_j^live) X`, where `L_j^live` omits
    /// links listed in `dead` (failure injection; canonical `u < v`
    /// orientation). Edge traversal, message formation and fold order are
    /// the shared global (activation, edge) order every backend uses.
    pub fn apply(
        &self,
        xs: &mut StateMatrix,
        matchings: &[Graph],
        activated: &[usize],
        alpha: f64,
        dead: Option<&[(usize, usize)]>,
        k: usize,
        pool: &mut DeltaPool,
    ) {
        if activated.is_empty() {
            return;
        }
        {
            let (deltas, diff, comp) = pool.fold_scratch();
            deltas.fill(0.0);
            for &j in activated {
                for &(u, v) in matchings[j].edges() {
                    if let Some(dead) = dead {
                        if dead.contains(&(u, v)) {
                            continue;
                        }
                    }
                    // Read both endpoints from the pre-mix state; the
                    // deltas arena keeps the update simultaneous.
                    let (xu, xv) = xs.pair(u, v);
                    edge_diff_message_src(
                        RowSource::Host(xu),
                        RowSource::Host(xv),
                        diff,
                        self.compression,
                        comp,
                        self.seed,
                        k,
                        j,
                        u,
                        v,
                    );
                    simd::acc_add(deltas.row_mut(u), diff);
                    simd::acc_sub(deltas.row_mut(v), diff);
                }
            }
        }
        for (x, dv) in xs.iter_rows_mut().zip(pool.deltas().iter_rows()) {
            simd::axpy(x, alpha, dv);
        }
    }

    /// Fold one worker's gossip mix from routed peer messages: for each
    /// `(matching, u, v, peer_row)` in global (activation, edge) order,
    /// form the canonical diff (`x_v − x_u`, this worker on the `u` side
    /// iff `worker == u`), accumulate `±diff` into `delta`, then apply
    /// `x += α·Δ` — the per-worker projection of [`MixKernel::apply`].
    /// An empty message iterator still applies the zero delta, matching
    /// the full-state kernel on non-incident workers of an active round.
    ///
    /// Peer rows are [`RowSource`]s: host staging slices in the actor
    /// mode, or rows borrowed directly from a received wire frame in the
    /// cluster/daemon zero-copy decode path. `comp` is the recycled TopK
    /// compression scratch ([`super::pool::DeltaPool`] keeps one; the
    /// actor shards keep their own).
    #[allow(clippy::too_many_arguments)]
    pub fn fold_worker<'m, I>(
        &self,
        worker: usize,
        x: &mut [f64],
        msgs: I,
        k: usize,
        alpha: f64,
        diff: &mut [f64],
        delta: &mut [f64],
        comp: &mut Vec<f64>,
    ) where
        I: IntoIterator<Item = (usize, usize, usize, RowSource<'m>)>,
    {
        delta.iter_mut().for_each(|v| *v = 0.0);
        for (j, u, v, peer) in msgs {
            self.fold_msg(worker, x, j, u, v, peer, k, diff, delta, comp);
        }
        Self::apply_delta(x, alpha, delta);
    }

    /// Fold one routed message into `delta`: the per-message body of
    /// [`MixKernel::fold_worker`], split out so the streaming wire-frame
    /// fold ([`crate::engine`]'s `ActorShard::mix_from_frame`) can drive
    /// it without materializing a message list.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fold_msg(
        &self,
        worker: usize,
        x: &[f64],
        j: usize,
        u: usize,
        v: usize,
        peer: RowSource<'_>,
        k: usize,
        diff: &mut [f64],
        delta: &mut [f64],
        comp: &mut Vec<f64>,
    ) {
        if worker == u {
            edge_diff_message_src(
                RowSource::Host(x),
                peer,
                diff,
                self.compression,
                comp,
                self.seed,
                k,
                j,
                u,
                v,
            );
            simd::acc_add(delta, diff);
        } else {
            edge_diff_message_src(
                peer,
                RowSource::Host(x),
                diff,
                self.compression,
                comp,
                self.seed,
                k,
                j,
                u,
                v,
            );
            simd::acc_sub(delta, diff);
        }
    }

    /// The final `x += α·Δ` of a per-worker fold.
    pub(crate) fn apply_delta(x: &mut [f64], alpha: f64, delta: &[f64]) {
        simd::axpy(x, alpha, delta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::rng::Rng;

    fn random_state(m: usize, dim: usize, seed: u64) -> StateMatrix {
        let mut rng = Rng::new(seed);
        let mut xs = StateMatrix::zeros(m, dim);
        for r in 0..m {
            for x in xs.row_mut(r).iter_mut() {
                *x = rng.normal();
            }
        }
        xs
    }

    #[test]
    fn apply_preserves_worker_mean() {
        let d = decompose(&paper_figure1_graph());
        let mut xs = random_state(8, 6, 9);
        let before = xs.mean();
        let activated: Vec<usize> = (0..d.len()).collect();
        let mut pool = DeltaPool::new(8, 6);
        MixKernel::new(5, None).apply(&mut xs, &d.matchings, &activated, 0.31, None, 0, &mut pool);
        for (a, b) in before.iter().zip(&xs.mean()) {
            assert!((a - b).abs() < 1e-12, "mean drifted: {a} vs {b}");
        }
    }

    #[test]
    fn fold_worker_matches_full_state_apply() {
        let d = decompose(&paper_figure1_graph());
        let (m, dim, alpha, k, seed) = (8usize, 5usize, 0.21, 3usize, 9u64);
        let xs = random_state(m, dim, 4);
        let activated: Vec<usize> = (0..d.len()).collect();

        let mut reference = xs.clone();
        let mut pool = DeltaPool::new(m, dim);
        let kernel = MixKernel::new(seed, None);
        kernel.apply(&mut reference, &d.matchings, &activated, alpha, None, k, &mut pool);

        let mut diff = vec![0.0; dim];
        let mut delta = vec![0.0; dim];
        let mut comp = Vec::new();
        // One preallocated row reused across workers — the harness does
        // no per-worker allocation, so what's exercised is the kernel.
        let mut x = vec![0.0; dim];
        for w in 0..m {
            let mut msgs: Vec<(usize, usize, usize, RowSource<'_>)> = Vec::new();
            for &j in &activated {
                for &(u, v) in d.matchings[j].edges() {
                    if u == w {
                        msgs.push((j, u, v, RowSource::Host(xs.row(v))));
                    } else if v == w {
                        msgs.push((j, u, v, RowSource::Host(xs.row(u))));
                    }
                }
            }
            x.copy_from_slice(xs.row(w));
            kernel.fold_worker(w, &mut x, msgs, k, alpha, &mut diff, &mut delta, &mut comp);
            assert_eq!(&x[..], reference.row(w), "worker {w} diverged");
        }
    }

    #[test]
    fn fold_worker_from_wire_rows_is_bit_identical() {
        // Peer rows borrowed as little-endian frame bytes must fold
        // exactly like their host twins — the zero-copy decode contract.
        let d = decompose(&paper_figure1_graph());
        let (m, dim, alpha, k, seed) = (8usize, 5usize, 0.21, 3usize, 9u64);
        let xs = random_state(m, dim, 4);
        let activated: Vec<usize> = (0..d.len()).collect();
        let comp_cfg = crate::sim::Compression::TopK { frac: 0.6 };
        let kernel = MixKernel::new(seed, Some(&comp_cfg));

        let wire: Vec<Vec<u8>> = (0..m)
            .map(|w| xs.row(w).iter().flat_map(|x| x.to_le_bytes()).collect())
            .collect();
        let mut diff = vec![0.0; dim];
        let mut delta = vec![0.0; dim];
        let mut comp = Vec::new();
        let mut host_x = vec![0.0; dim];
        let mut wire_x = vec![0.0; dim];
        for w in 0..m {
            let mut host_msgs: Vec<(usize, usize, usize, RowSource<'_>)> = Vec::new();
            let mut wire_msgs: Vec<(usize, usize, usize, RowSource<'_>)> = Vec::new();
            for &j in &activated {
                for &(u, v) in d.matchings[j].edges() {
                    if u == w || v == w {
                        let peer = if u == w { v } else { u };
                        host_msgs.push((j, u, v, RowSource::Host(xs.row(peer))));
                        wire_msgs.push((j, u, v, RowSource::Wire(&wire[peer])));
                    }
                }
            }
            host_x.copy_from_slice(xs.row(w));
            wire_x.copy_from_slice(xs.row(w));
            kernel.fold_worker(w, &mut host_x, host_msgs, k, alpha, &mut diff, &mut delta, &mut comp);
            kernel.fold_worker(w, &mut wire_x, wire_msgs, k, alpha, &mut diff, &mut delta, &mut comp);
            for (a, b) in host_x.iter().zip(&wire_x) {
                assert_eq!(a.to_bits(), b.to_bits(), "worker {w} wire fold diverged");
            }
        }
    }

    #[test]
    fn dead_links_drop_out_of_the_fold() {
        let d = decompose(&paper_figure1_graph());
        let j0 = (0..d.len())
            .find(|&j| d.matchings[j].edges().len() >= 2)
            .expect("fig1 decomposition has a multi-link matching");
        let (u, v) = d.matchings[j0].edges()[0];
        let xs0 = random_state(8, 3, 4);
        let mut with_dead = xs0.clone();
        let mut pool = DeltaPool::new(8, 3);
        MixKernel::new(1, None).apply(
            &mut with_dead,
            &d.matchings,
            &[j0],
            0.2,
            Some(&[(u, v)]),
            0,
            &mut pool,
        );
        assert_eq!(with_dead.row(u), xs0.row(u));
        assert_eq!(with_dead.row(v), xs0.row(v));
        let moved = d.matchings[j0]
            .edges()
            .iter()
            .filter(|&&e| e != (u, v))
            .any(|&(a, _)| with_dead.row(a) != xs0.row(a));
        assert!(moved, "live links should still exchange");
    }
}
