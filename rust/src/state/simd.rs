//! SIMD-chunked primitive loops of the gossip-mix hot path.
//!
//! Four element-wise loops dominate the fold in [`super::kernel`]: the
//! edge difference `diff = x_v − x_u`, the `±diff` accumulation into a
//! per-worker delta, and the final `x += α·Δ` apply. Each is provided in
//! two bit-for-bit identical flavors:
//!
//! - a portable scalar loop ([`scalar`]), and
//! - an AVX2 version ([`avx2`], x86_64 only) that processes four `f64`
//!   lanes per instruction with unaligned loads/stores.
//!
//! **Bit-for-bit by construction**: every lane of the vector versions
//! performs exactly the same single IEEE-754 operation on exactly the
//! same operands as the scalar loop — lane `i` only ever combines
//! element `i` of each input. There are no horizontal reductions, no
//! FMA contraction (`mul` then `add`, two roundings, exactly like the
//! scalar `alpha * d` then `+=`), and no reassociation — so the SIMD
//! path reproduces the scalar trajectories exactly and the golden
//! fixtures (`rust/tests/golden.rs`) hold with SIMD on or off. The
//! property tests below assert equality across shapes that straddle the
//! 4-lane width.
//!
//! Dispatch is decided once per process ([`simd_active`]): AVX2 must be
//! detected at runtime, and the `MATCHA_NO_SIMD` environment variable
//! (any non-empty value other than `0`) forces the scalar fallback —
//! the escape hatch CI uses to keep the fallback path covered.
//!
//! [`RowSource`] abstracts where a peer row lives: host `f64` memory, or
//! the little-endian bytes of a received wire frame
//! ([`crate::cluster::wire::MixLocalRef`]). The zero-copy decode path
//! folds straight out of the receive buffer — IEEE-754 bit patterns are
//! reinterpreted, never re-rounded, so a wire row folds bit-identically
//! to its host twin.

use std::sync::OnceLock;

/// Where one model row's `f64`s live: host memory, or borrowed
/// little-endian bytes of a received frame body (`len = 8 × dim`).
#[derive(Clone, Copy)]
pub enum RowSource<'a> {
    /// A row in host memory (an arena segment, a staging buffer).
    Host(&'a [f64]),
    /// A row borrowed from a wire frame as raw little-endian `f64`
    /// bytes — the zero-copy decode path of [`crate::cluster::wire`].
    Wire(&'a [u8]),
}

impl RowSource<'_> {
    /// Row length in elements.
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            RowSource::Host(a) => a.len(),
            RowSource::Wire(b) => b.len() / 8,
        }
    }

    /// True when the row holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Should the `MATCHA_NO_SIMD` value force the scalar fold? Any
/// non-empty value other than `0` counts as "yes". Pure function of the
/// raw variable so the policy is unit-testable without mutating the
/// process environment (the cached [`simd_active`] reads it once).
pub(crate) fn scalar_forced(val: Option<&std::ffi::OsStr>) -> bool {
    match val {
        None => false,
        Some(v) => !v.is_empty() && v != std::ffi::OsStr::new("0"),
    }
}

/// Whether the vectorized kernels are in use: AVX2 detected at runtime
/// and not disabled via `MATCHA_NO_SIMD`. Decided once per process.
pub fn simd_active() -> bool {
    static ACTIVE: OnceLock<bool> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            !scalar_forced(std::env::var_os("MATCHA_NO_SIMD").as_deref())
                && is_x86_feature_detected!("avx2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// `out[i] = xv[i] − xu[i]` — the canonical edge difference message.
#[inline]
pub(crate) fn diff_rows(xu: RowSource<'_>, xv: RowSource<'_>, out: &mut [f64]) {
    assert_eq!(xu.len(), out.len(), "xu row width mismatch");
    assert_eq!(xv.len(), out.len(), "xv row width mismatch");
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2 support at runtime; the
        // length asserts above bound every pointer offset.
        unsafe { avx2::diff_rows(xu, xv, out) };
        return;
    }
    scalar::diff_rows(xu, xv, out);
}

/// `acc[i] += src[i]` — fold a diff into the `u`-side delta.
#[inline]
pub(crate) fn acc_add(acc: &mut [f64], src: &[f64]) {
    assert_eq!(acc.len(), src.len(), "delta/diff width mismatch");
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2; lengths asserted equal.
        unsafe { avx2::acc_add(acc, src) };
        return;
    }
    scalar::acc_add(acc, src);
}

/// `acc[i] -= src[i]` — fold a diff into the `v`-side delta.
#[inline]
pub(crate) fn acc_sub(acc: &mut [f64], src: &[f64]) {
    assert_eq!(acc.len(), src.len(), "delta/diff width mismatch");
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2; lengths asserted equal.
        unsafe { avx2::acc_sub(acc, src) };
        return;
    }
    scalar::acc_sub(acc, src);
}

/// `x[i] += alpha * delta[i]` — the final per-row apply (two roundings:
/// multiply, then add — never fused, matching the historical scalar
/// arithmetic exactly).
#[inline]
pub(crate) fn axpy(x: &mut [f64], alpha: f64, delta: &[f64]) {
    assert_eq!(x.len(), delta.len(), "row/delta width mismatch");
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2; lengths asserted equal.
        unsafe { avx2::axpy(x, alpha, delta) };
        return;
    }
    scalar::axpy(x, alpha, delta);
}

/// Portable scalar loops — the reference semantics (and the
/// `MATCHA_NO_SIMD` / non-x86 path).
pub(crate) mod scalar {
    use super::RowSource;

    /// Element `i` of a row, decoding wire bytes as little-endian f64.
    #[inline(always)]
    fn at(src: RowSource<'_>, i: usize) -> f64 {
        match src {
            RowSource::Host(a) => a[i],
            RowSource::Wire(b) => {
                f64::from_le_bytes(b[i * 8..i * 8 + 8].try_into().expect("8-byte f64"))
            }
        }
    }

    pub fn diff_rows(xu: RowSource<'_>, xv: RowSource<'_>, out: &mut [f64]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = at(xv, i) - at(xu, i);
        }
    }

    pub fn acc_add(acc: &mut [f64], src: &[f64]) {
        for (a, &b) in acc.iter_mut().zip(src.iter()) {
            *a += b;
        }
    }

    pub fn acc_sub(acc: &mut [f64], src: &[f64]) {
        for (a, &b) in acc.iter_mut().zip(src.iter()) {
            *a -= b;
        }
    }

    pub fn axpy(x: &mut [f64], alpha: f64, delta: &[f64]) {
        for (xi, &di) in x.iter_mut().zip(delta.iter()) {
            *xi += alpha * di;
        }
    }
}

/// AVX2 loops: four f64 lanes per instruction, unaligned loads/stores,
/// scalar remainder. Callers must have verified AVX2 support and that
/// all rows share one length.
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use super::RowSource;
    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd,
        _mm256_storeu_pd, _mm256_sub_pd,
    };

    /// Four lanes starting at element `i`. Wire bytes are loaded
    /// unaligned and reinterpreted — x86 is little-endian, so the bit
    /// patterns are exactly the host f64s.
    #[inline(always)]
    unsafe fn load4(src: RowSource<'_>, i: usize) -> __m256d {
        match src {
            RowSource::Host(a) => _mm256_loadu_pd(a.as_ptr().add(i)),
            RowSource::Wire(b) => _mm256_loadu_pd(b.as_ptr().add(i * 8).cast::<f64>()),
        }
    }

    /// Scalar remainder element `i`.
    #[inline(always)]
    fn load1(src: RowSource<'_>, i: usize) -> f64 {
        match src {
            RowSource::Host(a) => a[i],
            RowSource::Wire(b) => {
                f64::from_le_bytes(b[i * 8..i * 8 + 8].try_into().expect("8-byte f64"))
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn diff_rows(xu: RowSource<'_>, xv: RowSource<'_>, out: &mut [f64]) {
        let n = out.len();
        let mut i = 0;
        while i + 4 <= n {
            let d = _mm256_sub_pd(load4(xv, i), load4(xu, i));
            _mm256_storeu_pd(out.as_mut_ptr().add(i), d);
            i += 4;
        }
        while i < n {
            out[i] = load1(xv, i) - load1(xu, i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn acc_add(acc: &mut [f64], src: &[f64]) {
        let n = acc.len();
        let mut i = 0;
        while i + 4 <= n {
            let s = _mm256_add_pd(
                _mm256_loadu_pd(acc.as_ptr().add(i)),
                _mm256_loadu_pd(src.as_ptr().add(i)),
            );
            _mm256_storeu_pd(acc.as_mut_ptr().add(i), s);
            i += 4;
        }
        while i < n {
            acc[i] += src[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn acc_sub(acc: &mut [f64], src: &[f64]) {
        let n = acc.len();
        let mut i = 0;
        while i + 4 <= n {
            let s = _mm256_sub_pd(
                _mm256_loadu_pd(acc.as_ptr().add(i)),
                _mm256_loadu_pd(src.as_ptr().add(i)),
            );
            _mm256_storeu_pd(acc.as_mut_ptr().add(i), s);
            i += 4;
        }
        while i < n {
            acc[i] -= src[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(x: &mut [f64], alpha: f64, delta: &[f64]) {
        let a = _mm256_set1_pd(alpha);
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            // mul then add — two roundings, exactly the scalar
            // `*xi += alpha * di`. An FMA here would round once and
            // break bit-for-bit parity with the fixtures.
            let scaled = _mm256_mul_pd(a, _mm256_loadu_pd(delta.as_ptr().add(i)));
            let s = _mm256_add_pd(_mm256_loadu_pd(x.as_ptr().add(i)), scaled);
            _mm256_storeu_pd(x.as_mut_ptr().add(i), s);
            i += 4;
        }
        while i < n {
            x[i] += alpha * delta[i];
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::ffi::OsStr;

    fn random_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn le_bytes(v: &[f64]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    #[test]
    fn env_gate_policy() {
        assert!(!scalar_forced(None));
        assert!(!scalar_forced(Some(OsStr::new(""))));
        assert!(!scalar_forced(Some(OsStr::new("0"))));
        assert!(scalar_forced(Some(OsStr::new("1"))));
        assert!(scalar_forced(Some(OsStr::new("true"))));
        assert!(scalar_forced(Some(OsStr::new("yes"))));
    }

    #[test]
    fn wire_rows_decode_like_host_rows() {
        let mut rng = Rng::new(21);
        for n in [1usize, 3, 4, 5, 8, 13] {
            let xu = random_vec(&mut rng, n);
            let xv = random_vec(&mut rng, n);
            let (bu, bv) = (le_bytes(&xu), le_bytes(&xv));
            let mut host = vec![0.0; n];
            let mut wire = vec![0.0; n];
            scalar::diff_rows(RowSource::Host(&xu), RowSource::Host(&xv), &mut host);
            scalar::diff_rows(RowSource::Wire(&bu), RowSource::Wire(&bv), &mut wire);
            for (a, b) in host.iter().zip(&wire) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(RowSource::Wire(&bu).len(), n);
        }
    }

    #[test]
    fn dispatch_matches_scalar_bit_for_bit() {
        // Whatever path simd_active() picked, the public wrappers must
        // agree with the scalar reference exactly.
        let mut rng = Rng::new(77);
        for n in [1usize, 2, 3, 4, 5, 7, 8, 11, 16, 19, 50] {
            let xu = random_vec(&mut rng, n);
            let xv = random_vec(&mut rng, n);
            let mut got = vec![0.0; n];
            let mut want = vec![0.0; n];
            diff_rows(RowSource::Host(&xu), RowSource::Host(&xv), &mut got);
            scalar::diff_rows(RowSource::Host(&xu), RowSource::Host(&xv), &mut want);
            assert_eq!(bits(&got), bits(&want), "diff_rows n={n}");

            let base = random_vec(&mut rng, n);
            let (mut ga, mut wa) = (base.clone(), base.clone());
            acc_add(&mut ga, &got);
            scalar::acc_add(&mut wa, &want);
            assert_eq!(bits(&ga), bits(&wa), "acc_add n={n}");

            let (mut gs, mut ws) = (base.clone(), base.clone());
            acc_sub(&mut gs, &got);
            scalar::acc_sub(&mut ws, &want);
            assert_eq!(bits(&gs), bits(&ws), "acc_sub n={n}");

            let (mut gx, mut wx) = (base.clone(), base);
            axpy(&mut gx, 0.31, &got);
            scalar::axpy(&mut wx, 0.31, &want);
            assert_eq!(bits(&gx), bits(&wx), "axpy n={n}");
        }
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The MATCHA_NO_SIMD ≡ SIMD contract: vector and scalar modules
    /// agree bit-for-bit on every op, every source combination, and
    /// shapes that straddle the 4-lane width — so forcing the scalar
    /// path can never change a trajectory.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_matches_scalar_bit_for_bit_across_shapes() {
        if !is_x86_feature_detected!("avx2") {
            return; // nothing to compare on this machine
        }
        let mut rng = Rng::new(0x51d);
        for n in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 11, 12, 15, 16, 17, 19, 31, 32, 50, 64] {
            let xu = random_vec(&mut rng, n);
            let xv = random_vec(&mut rng, n);
            let (bu, bv) = (le_bytes(&xu), le_bytes(&xv));
            let combos: [(RowSource<'_>, RowSource<'_>); 4] = [
                (RowSource::Host(&xu), RowSource::Host(&xv)),
                (RowSource::Host(&xu), RowSource::Wire(&bv)),
                (RowSource::Wire(&bu), RowSource::Host(&xv)),
                (RowSource::Wire(&bu), RowSource::Wire(&bv)),
            ];
            for (i, &(a, b)) in combos.iter().enumerate() {
                let mut want = vec![0.0; n];
                let mut got = vec![0.0; n];
                scalar::diff_rows(a, b, &mut want);
                // SAFETY: avx2 presence checked above; lengths match.
                unsafe { avx2::diff_rows(a, b, &mut got) };
                assert_eq!(bits(&got), bits(&want), "diff combo {i} n={n}");
            }
            let diff = {
                let mut d = vec![0.0; n];
                scalar::diff_rows(RowSource::Host(&xu), RowSource::Host(&xv), &mut d);
                d
            };
            let base = random_vec(&mut rng, n);
            let (mut ga, mut wa) = (base.clone(), base.clone());
            unsafe { avx2::acc_add(&mut ga, &diff) };
            scalar::acc_add(&mut wa, &diff);
            assert_eq!(bits(&ga), bits(&wa), "acc_add n={n}");
            let (mut gs, mut ws) = (base.clone(), base.clone());
            unsafe { avx2::acc_sub(&mut gs, &diff) };
            scalar::acc_sub(&mut ws, &diff);
            assert_eq!(bits(&gs), bits(&ws), "acc_sub n={n}");
            for alpha in [0.21, -0.75, 1.0 / 3.0] {
                let (mut gx, mut wx) = (base.clone(), base.clone());
                unsafe { avx2::axpy(&mut gx, alpha, &diff) };
                scalar::axpy(&mut wx, alpha, &diff);
                assert_eq!(bits(&gx), bits(&wx), "axpy n={n} alpha={alpha}");
            }
        }
    }
}
