//! The plan stage: spec → derived mathematical artifacts, before any run.
//!
//! Planning owns the paper's three-step pipeline (decompose the base
//! graph into matchings, optimize the activation probabilities under the
//! communication budget, optimize the mixing weight α) and exposes every
//! derived quantity — matchings, probabilities, λ₂, α, ρ — so callers can
//! inspect an experiment's convergence characteristics without running
//! it. This is the layer that absorbed the `coordinator::plan_*` helpers;
//! those remain as thin legacy wrappers.

use super::spec::{ExperimentSpec, Strategy};
use crate::budget::{expected_laplacian, optimize_activation_probabilities};
use crate::delay::DelayModel;
use crate::graph::{algebraic_connectivity, lambda2_of, Graph};
use crate::matching::{decompose, MatchingDecomposition};
use crate::mixing::{
    optimize_alpha, optimize_alpha_from_laplacians, optimize_alpha_periodic, vanilla_design,
};
use crate::sim::RunConfig;
use crate::topology::{
    MatchaSampler, PeriodicSampler, Schedule, SingleMatchingSampler, TopologySampler,
    VanillaSampler,
};

/// Everything derived from a spec before execution: the resolved graph,
/// its matching decomposition, per-matching activation probabilities (or
/// draw weights for the single-matching strategy), λ₂ of the expected
/// topology, the mixing weight α and the spectral norm ρ (Theorem 2:
/// ρ < 1 guarantees convergence).
#[derive(Clone, Debug)]
pub struct Plan {
    pub graph: Graph,
    pub decomposition: MatchingDecomposition,
    /// Per-matching activation probabilities. For
    /// [`Strategy::SingleMatching`] these are the normalized draw weights
    /// (Σ = 1); for [`Strategy::Vanilla`] all ones; for
    /// [`Strategy::Periodic`] the budget replicated.
    pub probabilities: Vec<f64>,
    /// λ₂ of the expected activated Laplacian.
    pub lambda2: f64,
    /// Optimized mixing weight α.
    pub alpha: f64,
    /// Spectral norm ρ of `E[WᵀW] − J` at α.
    pub rho: f64,
    /// The strategy this plan was derived for (drives sampler choice).
    pub strategy: Strategy,
}

/// Derive the full plan for a spec (validates the spec first). The cheap
/// half of [`crate::experiment::run()`] — `matcha run --spec f --dry-run`
/// stops here.
pub fn plan(spec: &ExperimentSpec) -> Result<Plan, String> {
    let graph = spec.validate_resolving()?;
    Plan::for_graph(graph, spec.strategy)
}

impl Plan {
    /// Plan a strategy directly on a graph object (the spec-free entry
    /// point used by harnesses that generate graphs programmatically).
    pub fn for_graph(graph: Graph, strategy: Strategy) -> Result<Plan, String> {
        if graph.num_nodes() < 2 || graph.num_edges() == 0 {
            return Err("graph: need at least 2 nodes and 1 edge".into());
        }
        if !graph.is_connected() {
            return Err("graph: base topology must be connected".into());
        }
        if let Some(cb) = strategy.budget() {
            if !cb.is_finite() || cb <= 0.0 || cb > 1.0 {
                return Err(format!("strategy: budget {cb} out of (0, 1]"));
            }
        }
        let decomposition = decompose(&graph);
        let m = decomposition.len();
        let (probabilities, lambda2, design) = match strategy {
            Strategy::Matcha { budget } => {
                let probs = optimize_activation_probabilities(&decomposition, budget);
                let mix = optimize_alpha(&decomposition, &probs.probabilities);
                (probs.probabilities, probs.lambda2, mix)
            }
            Strategy::Vanilla => {
                let design = vanilla_design(&graph.laplacian());
                (vec![1.0; m], algebraic_connectivity(&graph), design)
            }
            Strategy::Periodic { budget } => {
                let design = optimize_alpha_periodic(&graph.laplacian(), budget);
                (vec![budget; m], budget * algebraic_connectivity(&graph), design)
            }
            Strategy::SingleMatching { budget } => {
                // Draw weights ∝ the optimized Bernoulli probabilities.
                let probs = optimize_activation_probabilities(&decomposition, budget);
                let total: f64 = probs.probabilities.iter().sum();
                let q: Vec<f64> = probs.probabilities.iter().map(|p| p / total).collect();
                let laps = decomposition.laplacians();
                let lbar = expected_laplacian(&laps, &q);
                // Single-matching law: E[L²] = Σ qⱼ Lⱼ² = 2L̄ (matching
                // Laplacians satisfy Lⱼ² = 2Lⱼ), and the generic
                // optimizer expects E[L²] = L̄² + 2L̃ — so L̃ = L̄ − L̄²/2.
                let mut ltilde = lbar.clone();
                let lbar2 = lbar.matmul(&lbar);
                ltilde.axpy(-0.5, &lbar2);
                let design = optimize_alpha_from_laplacians(&lbar, &ltilde);
                (q, lambda2_of(&lbar), design)
            }
        };
        Ok(Plan {
            graph,
            decomposition,
            probabilities,
            lambda2,
            alpha: design.alpha,
            rho: design.rho,
            strategy,
        })
    }

    /// Expected communication units per iteration, Σ over matchings of
    /// the long-run activation frequency.
    pub fn expected_comm_units(&self) -> f64 {
        match self.strategy {
            Strategy::SingleMatching { .. } => 1.0,
            _ => self.probabilities.iter().sum(),
        }
    }

    /// The activation sampler realizing this plan's strategy.
    pub fn sampler(&self, seed: u64) -> Box<dyn TopologySampler> {
        match self.strategy {
            Strategy::Matcha { .. } => {
                Box::new(MatchaSampler::new(self.probabilities.clone(), seed))
            }
            Strategy::Vanilla => Box::new(VanillaSampler::new(self.decomposition.len())),
            Strategy::Periodic { budget } => {
                Box::new(PeriodicSampler::from_budget(self.decomposition.len(), budget))
            }
            Strategy::SingleMatching { .. } => {
                Box::new(SingleMatchingSampler::new(self.probabilities.clone(), seed))
            }
        }
    }

    /// Pregenerate an apriori activation schedule (paper §1: zero runtime
    /// scheduling overhead).
    pub fn schedule(&self, steps: usize, seed: u64) -> Schedule {
        let mut sampler = self.sampler(seed);
        Schedule::generate(&mut sampler, self.alpha, self.decomposition.len(), steps)
    }

    /// Assemble the runner configuration for this plan from a spec's
    /// hyperparameters (the spec-driven replacement for hand-built
    /// `RunConfig` literals, which are now a legacy path).
    pub fn run_config(&self, spec: &ExperimentSpec) -> Result<RunConfig, String> {
        Ok(RunConfig {
            lr: spec.lr,
            lr_decay: spec.lr_decay,
            lr_decay_every: spec.lr_decay_every,
            iterations: spec.iterations,
            record_every: spec.record_every.unwrap_or_else(|| (spec.iterations / 50).max(1)),
            alpha: self.alpha,
            compute_units: spec.compute_units,
            delay: DelayModel::parse(&spec.delay).map_err(|e| format!("delay: {e}"))?,
            compression: spec.compression.clone(),
            latency_floor: spec.latency_floor,
            seed: spec.seed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;

    #[test]
    fn plan_matches_legacy_pipeline_for_matcha() {
        let g = paper_figure1_graph();
        let plan = Plan::for_graph(g.clone(), Strategy::Matcha { budget: 0.5 }).unwrap();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.5);
        let mix = optimize_alpha(&d, &probs.probabilities);
        assert_eq!(plan.probabilities, probs.probabilities);
        assert_eq!(plan.lambda2, probs.lambda2);
        assert_eq!(plan.alpha, mix.alpha);
        assert_eq!(plan.rho, mix.rho);
    }

    #[test]
    fn all_strategies_plan_with_rho_below_one() {
        let g = paper_figure1_graph();
        for strategy in [
            Strategy::Matcha { budget: 0.4 },
            Strategy::Vanilla,
            Strategy::Periodic { budget: 0.4 },
            Strategy::SingleMatching { budget: 0.4 },
        ] {
            let plan = Plan::for_graph(g.clone(), strategy).unwrap();
            assert!(plan.rho < 1.0, "{}: rho {}", strategy.name(), plan.rho);
            assert!(plan.alpha > 0.0 && plan.alpha.is_finite(), "{}", strategy.name());
            assert!(plan.lambda2 > 0.0, "{}", strategy.name());
        }
    }

    #[test]
    fn single_matching_weights_normalize() {
        let g = paper_figure1_graph();
        let plan = Plan::for_graph(g, Strategy::SingleMatching { budget: 0.3 }).unwrap();
        let total: f64 = plan.probabilities.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "Σq = {total}");
        assert_eq!(plan.expected_comm_units(), 1.0);
    }

    #[test]
    fn plan_rejects_bad_inputs() {
        let g = paper_figure1_graph();
        assert!(Plan::for_graph(g.clone(), Strategy::Matcha { budget: 0.0 }).is_err());
        assert!(Plan::for_graph(g, Strategy::Matcha { budget: 2.0 }).is_err());
        let disconnected = Graph::new(4, &[(0, 1), (2, 3)]);
        assert!(Plan::for_graph(disconnected, Strategy::Vanilla).is_err());
    }

    #[test]
    fn schedule_generation_matches_sampler_stream() {
        let g = paper_figure1_graph();
        let plan = Plan::for_graph(g, Strategy::Matcha { budget: 0.5 }).unwrap();
        let sched = plan.schedule(100, 3);
        assert_eq!(sched.rounds.len(), 100);
        let mut sampler = plan.sampler(3);
        for (k, round) in sched.rounds.iter().enumerate() {
            assert_eq!(round.activated, sampler.round(k).activated, "round {k}");
        }
    }
}
