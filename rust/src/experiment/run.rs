//! Unified execution: one `run()` for every backend, one result type.
//!
//! [`run()`] takes a validated [`ExperimentSpec`], derives the [`Plan`],
//! builds the workload and sampler with the same seed derivations the
//! legacy entry points used (so spec-driven runs reproduce
//! [`crate::sim::run_decentralized`] and the engine's analytic mode
//! **bit-for-bit** per seed — enforced by `rust/tests/experiment.rs`),
//! and dispatches on the backend. [`ExperimentResult`] supersedes the
//! `RunResult`/`EngineResult` split: engine-only counters are zero on the
//! sim backend.

use super::observer::{NoopObserver, Observer};
use super::plan::{plan, Plan};
use super::spec::{Backend, ExperimentSpec, ProblemSpec};
use crate::cluster::{run_cluster_traced, ClusterConfig, ClusterStats, TransportKind};
use crate::engine::{parse_policy, run_engine_traced, sweep_parallel_streaming, EngineConfig};
use crate::gossip::{run_async_traced, AsyncConfig, AsyncStats};
use crate::json::Json;
use crate::metrics::Recorder;
use crate::rng::Rng;
use crate::sim::{
    run_decentralized_traced, LogisticProblem, LogisticSpec, QuadraticProblem, RunResult,
};
use crate::state::StateMatrix;
use crate::trace::{
    chrome_trace_merged, write_trace, MetricsSnapshot, Observatory, ObservatoryConfig,
    ObservatorySnapshot, PidTrack, RingSink, TelemetryCollector, TraceFormat, TraceRecord, Tracer,
};

/// The unified outcome of a spec-driven run: plan-derived quantities,
/// the metric series, and summary statistics from whichever backend
/// executed it.
pub struct ExperimentResult {
    /// Mixing weight the run used.
    pub alpha: f64,
    /// Spectral norm of the activation design (Theorem 2).
    pub rho: f64,
    /// λ₂ of the expected activated topology.
    pub lambda2: f64,
    /// Number of matchings in the decomposition.
    pub num_matchings: usize,
    /// All recorded metric series (`loss_vs_iter`, `loss_vs_time`, ...).
    pub metrics: Recorder,
    /// Final averaged iterate x̄.
    pub final_mean: Vec<f64>,
    /// Every worker's final iterate, straight from the run's state arena
    /// (one row per worker). `Some` for single runs; [`run_sweep`] drops
    /// each grid point's arena (keeping only `final_mean`) so a large
    /// sweep does not retain one full `workers × dim` matrix per point.
    pub final_states: Option<StateMatrix>,
    /// Total virtual time elapsed.
    pub total_time: f64,
    /// Total communication units spent.
    pub total_comm_units: f64,
    /// Links dropped by failure injection (0 on the sim backend).
    pub dropped_links: usize,
    /// Discrete events processed (0 on the sim backend).
    pub events: u64,
    /// Per-worker staleness / idle-time statistics; `Some` only for the
    /// async backend.
    pub async_stats: Option<AsyncStats>,
    /// Per-link bytes-on-wire statistics; `Some` only for the cluster
    /// backend.
    pub cluster_stats: Option<ClusterStats>,
    /// The unified counter/histogram snapshot read out of the run's
    /// [`crate::trace::Tracer`] registry — same schema on every
    /// backend, zeros where a metric does not apply.
    pub snapshot: MetricsSnapshot,
    /// The algorithm-level observatory readout; `Some` only when the
    /// spec enables it with a `report` block.
    pub observatory: Option<ObservatorySnapshot>,
}

impl ExperimentResult {
    /// Final training loss (NaN if the run recorded nothing).
    pub fn final_loss(&self) -> f64 {
        self.metrics.last("loss_vs_iter").unwrap_or(f64::NAN)
    }

    /// One-line JSON summary (what `matcha sweep` streams per point).
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("final_loss", num_or_null(self.final_loss())),
            ("total_time", num_or_null(self.total_time)),
            ("comm_units", num_or_null(self.total_comm_units)),
            ("alpha", num_or_null(self.alpha)),
            ("rho", num_or_null(self.rho)),
            ("dropped_links", Json::Num(self.dropped_links as f64)),
            ("events", Json::Num(self.events as f64)),
            (
                "mean_staleness",
                match &self.async_stats {
                    Some(s) => Json::Num(s.mean_staleness()),
                    None => Json::Null,
                },
            ),
            (
                // Bytes actually shipped over shard links. Mix rows whose
                // peer lives on the receiving shard are suppressed at the
                // sender (`MixLocal`), so this already reflects the
                // intra-shard savings.
                "wire_bytes",
                match &self.cluster_stats {
                    Some(s) => Json::Num(s.remote_bytes() as f64),
                    None => Json::Null,
                },
            ),
            (
                // Payload bytes the suppression avoided shipping: rows a
                // naive protocol would have staged for local peers. The
                // headline number for the zero-copy/suppression work.
                "suppressed_bytes",
                match &self.cluster_stats {
                    Some(s) => Json::Num(s.suppressed_bytes() as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    fn from_sim(plan: &Plan, r: RunResult) -> ExperimentResult {
        ExperimentResult {
            alpha: plan.alpha,
            rho: plan.rho,
            lambda2: plan.lambda2,
            num_matchings: plan.decomposition.len(),
            metrics: r.metrics,
            final_mean: r.final_mean,
            final_states: Some(r.final_states),
            total_time: r.total_time,
            total_comm_units: r.total_comm_units,
            dropped_links: 0,
            events: 0,
            async_stats: None,
            cluster_stats: None,
            snapshot: MetricsSnapshot::default(),
            observatory: None,
        }
    }

    fn from_engine(plan: &Plan, r: crate::engine::EngineResult) -> ExperimentResult {
        ExperimentResult {
            alpha: plan.alpha,
            rho: plan.rho,
            lambda2: plan.lambda2,
            num_matchings: plan.decomposition.len(),
            metrics: r.run.metrics,
            final_mean: r.run.final_mean,
            final_states: Some(r.run.final_states),
            total_time: r.run.total_time,
            total_comm_units: r.run.total_comm_units,
            dropped_links: r.dropped_links,
            events: r.events,
            async_stats: None,
            cluster_stats: None,
            snapshot: MetricsSnapshot::default(),
            observatory: None,
        }
    }

    fn from_async(plan: &Plan, r: crate::gossip::AsyncResult) -> ExperimentResult {
        ExperimentResult {
            alpha: plan.alpha,
            rho: plan.rho,
            lambda2: plan.lambda2,
            num_matchings: plan.decomposition.len(),
            metrics: r.run.metrics,
            final_mean: r.run.final_mean,
            final_states: Some(r.run.final_states),
            total_time: r.run.total_time,
            total_comm_units: r.run.total_comm_units,
            dropped_links: r.dropped_links,
            events: r.events,
            async_stats: Some(r.stats),
            cluster_stats: None,
            snapshot: MetricsSnapshot::default(),
            observatory: None,
        }
    }

    fn from_cluster(plan: &Plan, r: crate::cluster::ClusterResult) -> ExperimentResult {
        ExperimentResult {
            alpha: plan.alpha,
            rho: plan.rho,
            lambda2: plan.lambda2,
            num_matchings: plan.decomposition.len(),
            metrics: r.run.metrics,
            final_mean: r.run.final_mean,
            final_states: Some(r.run.final_states),
            total_time: r.run.total_time,
            total_comm_units: r.run.total_comm_units,
            dropped_links: r.dropped_links,
            events: r.events,
            async_stats: None,
            cluster_stats: Some(r.stats),
            snapshot: MetricsSnapshot::default(),
            observatory: None,
        }
    }
}

fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// The materialized workload. Crate-visible (not public): external
/// callers talk specs; the shard-node daemon and remote coordinator
/// ([`crate::node`]) rebuild the identical workload from the spec JSON
/// carried in the `Assign` handshake frame.
pub(crate) enum BuiltProblem {
    Quad(QuadraticProblem),
    Logreg(LogisticProblem),
}

pub(crate) fn build_problem(spec: &ExperimentSpec, num_workers: usize) -> BuiltProblem {
    match &spec.problem {
        ProblemSpec::Quadratic { dim, hetero, noise_std, seed } => {
            // `None` derives the run seed exactly as the legacy CLI did.
            let mut rng = Rng::new(seed.unwrap_or(spec.seed ^ 0x9a9a));
            BuiltProblem::Quad(QuadraticProblem::generate(
                num_workers,
                *dim,
                *hetero,
                *noise_std,
                &mut rng,
            ))
        }
        ProblemSpec::Logistic { non_iid, separation, seed } => {
            BuiltProblem::Logreg(LogisticProblem::generate(LogisticSpec {
                num_workers,
                non_iid: *non_iid,
                separation: *separation,
                seed: seed.unwrap_or(spec.seed ^ 0x10f),
                ..LogisticSpec::default()
            }))
        }
    }
}

/// Run the experiment described by `spec`. Equivalent to
/// [`run_observed`] with a no-op observer.
pub fn run(spec: &ExperimentSpec) -> Result<ExperimentResult, String> {
    run_observed(spec, &mut NoopObserver)
}

/// Run the experiment, streaming progress through `observer`.
pub fn run_observed(
    spec: &ExperimentSpec,
    observer: &mut dyn Observer,
) -> Result<ExperimentResult, String> {
    let plan = plan(spec)?;
    run_planned(spec, &plan, observer)
}

/// [`run_observed`] plus optional live progress: with `progress` set on
/// a remote cluster spec, every telemetry harvest prints a per-shard
/// `progress: shard S round R (...)` line to stderr. On every other
/// backend (or with `progress` false) this is exactly [`run_observed`].
pub fn run_with_progress(
    spec: &ExperimentSpec,
    observer: &mut dyn Observer,
    progress: bool,
) -> Result<ExperimentResult, String> {
    let plan = plan(spec)?;
    run_planned_progress(spec, &plan, observer, progress)
}

/// Run with a precomputed plan (lets callers plan once and reuse — the
/// sweep driver and `--dry-run` both lean on this split).
///
/// When the spec carries a `trace` block, the run records events into a
/// ring sink of the requested capacity and writes the trace file when
/// it finishes; otherwise this is [`run_planned_traced`] with a
/// disabled tracer (metrics still accumulate into
/// [`ExperimentResult::snapshot`]).
pub fn run_planned(
    spec: &ExperimentSpec,
    plan: &Plan,
    observer: &mut dyn Observer,
) -> Result<ExperimentResult, String> {
    run_planned_progress(spec, plan, observer, false)
}

/// A collector when this run harvests daemon telemetry: remote cluster
/// backend, and either the trace block left `telemetry` on (its
/// default) or the caller asked for live `--progress` lines.
fn telemetry_collector(spec: &ExperimentSpec, progress: bool) -> Option<TelemetryCollector> {
    let shards = match &spec.backend {
        Backend::Cluster { shards, transport: TransportKind::Remote { .. } } => *shards,
        _ => return None,
    };
    if !spec.trace.as_ref().map_or(progress, |t| t.telemetry || progress) {
        return None;
    }
    let mut collector = TelemetryCollector::new(shards);
    if progress {
        collector.enable_progress();
    }
    Some(collector)
}

/// [`run_planned`] with the telemetry/progress policy applied: builds
/// the collector when the spec warrants one, runs, and writes the trace
/// file — a merged per-process Chrome export when daemon telemetry was
/// harvested, the plain single-process export otherwise.
pub(crate) fn run_planned_progress(
    spec: &ExperimentSpec,
    plan: &Plan,
    observer: &mut dyn Observer,
    progress: bool,
) -> Result<ExperimentResult, String> {
    let mut collector = telemetry_collector(spec, progress);
    match &spec.trace {
        Some(ts) => {
            let mut sink = RingSink::new(ts.capacity);
            let result = {
                let mut tracer = Tracer::attached(&mut sink);
                run_planned_telemetry(spec, plan, observer, &mut tracer, collector.as_mut())?
            };
            let dropped = sink.dropped() + collector.as_ref().map_or(0, |c| c.dropped_total());
            let other = trace_side_data(&result, dropped);
            let path = std::path::Path::new(&ts.path);
            let records = sink.records();
            match (&collector, ts.format) {
                // Merged multi-process export: coordinator pid 0 on its
                // virtual timeline, one wall-clock pid per daemon.
                (Some(c), TraceFormat::Chrome) => write_merged_trace(path, &records, c, &other)?,
                // JSONL stays a single stream: the coordinator's records.
                _ => write_trace(path, ts.format, &records, &other)?,
            }
            Ok(result)
        }
        None => {
            run_planned_telemetry(spec, plan, observer, &mut Tracer::disabled(), collector.as_mut())
        }
    }
}

/// The `otherData` payload attached to Chrome exports: the run's
/// counter/histogram snapshot, a per-series summary of the metric
/// recorder, and how many records the producing ring(s) overwrote
/// (coordinator sink plus every harvested daemon ring) — non-zero means
/// the export is truncated at the source, which `matcha trace-check`
/// warns about.
fn trace_side_data(result: &ExperimentResult, dropped_records: u64) -> Json {
    let mut series = Vec::new();
    for (name, s) in result.metrics.summaries() {
        series.push((name, s.to_json()));
    }
    Json::obj(vec![
        ("metrics", result.snapshot.to_json()),
        ("series", Json::obj(series)),
        ("dropped_records", Json::Num(dropped_records as f64)),
    ])
}

/// Write the distributed-telemetry Chrome export: the coordinator's
/// records as `pid` 0 on its deterministic virtual timeline, and each
/// harvested daemon ring as `pid s + 1` placed by wall clock through
/// the epoch offset fixed at that shard's first pull.
fn write_merged_trace(
    path: &std::path::Path,
    coordinator: &[TraceRecord],
    collector: &TelemetryCollector,
    other_data: &Json,
) -> Result<(), String> {
    let mut tracks = Vec::with_capacity(1 + collector.shard_count());
    tracks.push(PidTrack {
        pid: 0,
        name: "coordinator".into(),
        records: coordinator,
        wall_offset_ns: None,
    });
    for s in 0..collector.shard_count() {
        tracks.push(PidTrack {
            pid: s + 1,
            name: format!("shard {s}"),
            records: collector.records(s),
            wall_offset_ns: Some(collector.wall_offset_ns(s)),
        });
    }
    let text = chrome_trace_merged(&tracks, other_data).to_string();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("trace: cannot create {}: {e}", parent.display()))?;
        }
    }
    std::fs::write(path, text).map_err(|e| format!("trace: cannot write {}: {e}", path.display()))
}

/// Run with a precomputed plan, emitting events and metrics through
/// `tracer`. The result's [`ExperimentResult::snapshot`] is read out of
/// the tracer's registry when the backend returns.
pub fn run_planned_traced(
    spec: &ExperimentSpec,
    plan: &Plan,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> Result<ExperimentResult, String> {
    run_planned_telemetry(spec, plan, observer, tracer, None)
}

/// [`run_planned_traced`] plus distributed-telemetry harvesting: with a
/// collector, the remote coordinator pulls every daemon's trace ring,
/// registry and health over the wire, and the result's snapshot becomes
/// the daemon-authoritative aggregate instead of the coordinator's own
/// estimates. Ignored (and irrelevant) on every non-remote backend.
pub(crate) fn run_planned_telemetry(
    spec: &ExperimentSpec,
    plan: &Plan,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
    mut collector: Option<&mut TelemetryCollector>,
) -> Result<ExperimentResult, String> {
    // The observatory is armed before any backend dispatch so every
    // path — including the remote coordinator, whose hooks fire on this
    // side of the wire — feeds the same ledger and windows.
    if let Some(report) = &spec.report {
        tracer.observatory = Observatory::enabled(ObservatoryConfig {
            designed: plan.probabilities.clone(),
            matchings: plan.decomposition.matchings.iter().map(|g| g.edges().to_vec()).collect(),
            rho: plan.rho,
            workers: plan.graph.num_nodes(),
            window: report.window,
        });
    }
    // Remote cluster runs talk to pre-existing shard-node daemons; the
    // pipelined coordinator in `crate::node` owns that path end to end
    // (its own dial/handshake/reconnect lifecycle, same engine loop).
    if let Backend::Cluster { transport: TransportKind::Remote { .. }, .. } = &spec.backend {
        let r = crate::node::run_remote_planned_telemetry(
            spec,
            plan,
            &crate::node::RemoteOptions::default(),
            observer,
            tracer,
            collector.as_deref_mut(),
        )?;
        let mut result = ExperimentResult::from_cluster(plan, r);
        result.snapshot = match &collector {
            Some(c) => MetricsSnapshot::from_registry(&c.aggregate(&tracer.registry)),
            None => MetricsSnapshot::from_registry(&tracer.registry),
        };
        result.observatory = tracer.observatory.snapshot();
        return Ok(result);
    }
    let cfg = plan.run_config(spec)?;
    let mut sampler = plan.sampler(spec.sampler_seed.unwrap_or(spec.seed));
    let problem = build_problem(spec, plan.graph.num_nodes());
    let matchings = &plan.decomposition.matchings;

    let mut result = match &spec.backend {
        Backend::SimReference => {
            let r = match &problem {
                BuiltProblem::Quad(p) => {
                    run_decentralized_traced(p, matchings, &mut sampler, &cfg, observer, tracer)
                }
                BuiltProblem::Logreg(p) => {
                    run_decentralized_traced(p, matchings, &mut sampler, &cfg, observer, tracer)
                }
            };
            ExperimentResult::from_sim(plan, r)
        }
        Backend::EngineSequential | Backend::EngineActors { .. } => {
            let threads = match spec.backend {
                Backend::EngineActors { threads } => threads,
                _ => 1,
            };
            let mut policy = parse_policy(&spec.policy, &plan.graph, &cfg)
                .map_err(|e| format!("policy: {e}"))?;
            let engine_cfg = EngineConfig { run: cfg, threads };
            let r = match &problem {
                BuiltProblem::Quad(p) => run_engine_traced(
                    p,
                    matchings,
                    &mut sampler,
                    policy.as_mut(),
                    &engine_cfg,
                    observer,
                    tracer,
                ),
                BuiltProblem::Logreg(p) => run_engine_traced(
                    p,
                    matchings,
                    &mut sampler,
                    policy.as_mut(),
                    &engine_cfg,
                    observer,
                    tracer,
                ),
            };
            ExperimentResult::from_engine(plan, r)
        }
        Backend::Async { threads, max_staleness } => {
            let mut policy = parse_policy(&spec.policy, &plan.graph, &cfg)
                .map_err(|e| format!("policy: {e}"))?;
            let async_cfg =
                AsyncConfig { run: cfg, threads: *threads, max_staleness: *max_staleness };
            let r = match &problem {
                BuiltProblem::Quad(p) => run_async_traced(
                    p,
                    matchings,
                    &mut sampler,
                    policy.as_mut(),
                    &async_cfg,
                    observer,
                    tracer,
                ),
                BuiltProblem::Logreg(p) => run_async_traced(
                    p,
                    matchings,
                    &mut sampler,
                    policy.as_mut(),
                    &async_cfg,
                    observer,
                    tracer,
                ),
            };
            ExperimentResult::from_async(plan, r)
        }
        Backend::Cluster { shards, transport } => {
            let mut policy = parse_policy(&spec.policy, &plan.graph, &cfg)
                .map_err(|e| format!("policy: {e}"))?;
            let cluster_cfg =
                ClusterConfig { run: cfg, shards: *shards, transport: transport.clone() };
            let r = match &problem {
                BuiltProblem::Quad(p) => run_cluster_traced(
                    p,
                    matchings,
                    &mut sampler,
                    policy.as_mut(),
                    &cluster_cfg,
                    observer,
                    tracer,
                )?,
                BuiltProblem::Logreg(p) => run_cluster_traced(
                    p,
                    matchings,
                    &mut sampler,
                    policy.as_mut(),
                    &cluster_cfg,
                    observer,
                    tracer,
                )?,
            };
            ExperimentResult::from_cluster(plan, r)
        }
    };
    result.snapshot = MetricsSnapshot::from_registry(&tracer.registry);
    result.observatory = tracer.observatory.snapshot();
    Ok(result)
}

/// Sweep the spec's strategy over a budget grid, fanning points across
/// `threads` OS threads. Each point is an independent spec-driven run;
/// `observer.on_point` fires on the calling thread **as each point
/// finishes** (completion order), and the full results come back in
/// input order.
///
/// Per-point execution is kept single-threaded: since thread counts
/// never change results on any backend, a multi-threaded point backend
/// (`actors`, or `async` with `threads > 1`) is demoted to its
/// sequential equivalent instead of nesting a worker pool inside every
/// fanned-out point.
pub fn run_sweep(
    base: &ExperimentSpec,
    budgets: &[f64],
    threads: usize,
    observer: &mut dyn Observer,
) -> Result<Vec<(f64, ExperimentResult)>, String> {
    if budgets.is_empty() {
        return Err("sweep: need at least one budget".into());
    }
    let mut base = base.clone();
    match base.backend {
        Backend::EngineActors { .. } => base.backend = Backend::EngineSequential,
        // The cluster backend's per-point results are identical to the
        // sequential engine's; sweeps do not need a shard fleet (or, for
        // the remote transport, a daemon fleet) per point.
        Backend::Cluster { .. } => base.backend = Backend::EngineSequential,
        Backend::Async { threads: t, max_staleness } if t > 1 => {
            base.backend = Backend::Async { threads: 1, max_staleness };
        }
        _ => {}
    }
    let base = &base;
    // Validate and plan every grid point up front: errors surface before
    // any thread spawns, and the decompose → probabilities → α work is
    // not repeated inside the workers.
    let mut points: Vec<(ExperimentSpec, Plan)> = Vec::with_capacity(budgets.len());
    for &cb in budgets {
        let spec = base.clone().with_budget(cb);
        let point_plan = plan(&spec)?;
        points.push((spec, point_plan));
    }
    let results = sweep_parallel_streaming(
        &points,
        threads,
        // Per-point arenas are dropped right away: a sweep keeps summary
        // statistics and series, not one workers × dim matrix per point.
        |_i, point| {
            run_planned(&point.0, &point.1, &mut NoopObserver).map(|mut r| {
                r.final_states = None;
                r
            })
        },
        |i, r| {
            if let Ok(res) = r {
                observer.on_point(i, res);
            }
        },
    );
    let mut out = Vec::with_capacity(results.len());
    for (r, &cb) in results.into_iter().zip(budgets) {
        out.push((cb, r?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::Strategy;

    fn quick_spec() -> ExperimentSpec {
        ExperimentSpec::new("ring:6")
            .problem(ProblemSpec::quadratic())
            .strategy(Strategy::Matcha { budget: 0.5 })
            .lr(0.03)
            .iterations(60)
            .record_every(20)
            .seed(9)
    }

    #[test]
    fn sim_and_engine_backends_agree_bit_for_bit() {
        let sim = run(&quick_spec()).unwrap();
        let engine = run(&quick_spec().backend(Backend::EngineSequential)).unwrap();
        assert_eq!(sim.final_mean, engine.final_mean);
        assert_eq!(sim.final_states, engine.final_states);
        assert!(sim.final_states.is_some(), "single runs expose the final arena");
        assert_eq!(sim.total_time, engine.total_time);
        assert_eq!(sim.total_comm_units, engine.total_comm_units);
        assert_eq!(sim.events, 0);
        assert!(engine.events > 0);
    }

    #[test]
    fn actors_single_thread_matches_sequential_engine() {
        // threads >= 1 is accepted for the actors backend; one thread
        // must reproduce the sequential engine exactly.
        let seq = run(&quick_spec().backend(Backend::EngineSequential)).unwrap();
        let act = run(&quick_spec().backend(Backend::EngineActors { threads: 1 })).unwrap();
        assert_eq!(act.final_mean, seq.final_mean);
        assert_eq!(act.final_states, seq.final_states);
        assert_eq!(act.total_time, seq.total_time);
    }

    #[test]
    fn cluster_loopback_matches_actors_bit_for_bit() {
        use crate::cluster::TransportKind;
        let act = run(&quick_spec().backend(Backend::EngineActors { threads: 2 })).unwrap();
        let clu = run(&quick_spec()
            .backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }))
        .unwrap();
        assert_eq!(clu.final_mean, act.final_mean);
        assert_eq!(clu.final_states, act.final_states);
        assert_eq!(clu.total_time, act.total_time);
        assert_eq!(clu.total_comm_units, act.total_comm_units);
        let stats = clu.cluster_stats.expect("cluster stats present");
        assert_eq!(stats.per_link.len(), 2);
        assert!(stats.total_bytes() > 0);
        let j = clu.summary_json();
        assert!(j.get("wire_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("suppressed_bytes").unwrap().as_f64().is_some());
        assert!(act.cluster_stats.is_none());
    }

    #[test]
    fn unbounded_async_backend_is_deterministic() {
        let spec = quick_spec().policy("straggler:0:4.0").backend(Backend::Async {
            threads: 2,
            max_staleness: crate::gossip::UNBOUNDED_STALENESS,
        });
        let a = run(&spec).unwrap();
        let b = run(&spec).unwrap();
        assert_eq!(a.final_mean, b.final_mean);
        assert_eq!(a.total_time, b.total_time);
        assert!(a.final_loss().is_finite());
    }

    #[test]
    fn async_backend_at_staleness_zero_matches_sim_bit_for_bit() {
        let sim = run(&quick_spec()).unwrap();
        let spec = quick_spec().backend(Backend::Async { threads: 2, max_staleness: 0 });
        let asy = run(&spec).unwrap();
        assert_eq!(sim.final_mean, asy.final_mean);
        let stats = asy.async_stats.expect("async stats present");
        assert_eq!(stats.max_staleness(), 0);
        assert!(asy.events > 0);
    }

    #[test]
    fn async_backend_reports_staleness_in_summary() {
        let spec = quick_spec()
            .policy("straggler:0:4.0")
            .backend(Backend::Async { threads: 1, max_staleness: 3 });
        let res = run(&spec).unwrap();
        let j = res.summary_json();
        assert!(j.get("mean_staleness").unwrap().as_f64().is_some());
        let stats = res.async_stats.expect("stats");
        assert!(stats.max_staleness() <= 3);
        assert_eq!(stats.per_worker.len(), 6);
    }

    #[test]
    fn async_observer_sees_iterations_and_records() {
        struct Counting {
            iterations: usize,
            records: usize,
        }
        impl Observer for Counting {
            fn on_iteration(&mut self, _k: usize, _time: f64, _comm: f64) {
                self.iterations += 1;
            }
            fn on_record(&mut self, _k: usize, _time: f64, metrics: &Recorder) {
                self.records += 1;
                assert!(!metrics.get("loss_vs_iter").is_empty());
            }
        }
        let spec = quick_spec().backend(Backend::Async { threads: 2, max_staleness: 2 });
        let mut obs = Counting { iterations: 0, records: 0 };
        run_observed(&spec, &mut obs).unwrap();
        assert_eq!(obs.iterations, 60);
        assert_eq!(obs.records, 1 + 60 / 20);
    }

    #[test]
    fn observer_sees_iterations_and_records() {
        struct Counting {
            iterations: usize,
            records: usize,
            last_time: f64,
        }
        impl Observer for Counting {
            fn on_iteration(&mut self, _k: usize, time: f64, _comm: f64) {
                self.iterations += 1;
                assert!(time >= self.last_time);
                self.last_time = time;
            }
            fn on_record(&mut self, _k: usize, _time: f64, metrics: &Recorder) {
                self.records += 1;
                assert!(!metrics.get("loss_vs_iter").is_empty());
            }
        }
        let mut obs = Counting { iterations: 0, records: 0, last_time: 0.0 };
        run_observed(&quick_spec(), &mut obs).unwrap();
        assert_eq!(obs.iterations, 60);
        // Initial record + one per record_every stride.
        assert_eq!(obs.records, 1 + 60 / 20);
    }

    #[test]
    fn sweep_streams_every_point() {
        struct Points(Vec<usize>);
        impl Observer for Points {
            fn on_point(&mut self, index: usize, result: &ExperimentResult) {
                assert!(result.total_time > 0.0);
                self.0.push(index);
            }
        }
        let base = quick_spec().backend(Backend::EngineSequential);
        let budgets = [0.3, 0.6, 1.0];
        let mut obs = Points(Vec::new());
        let results = run_sweep(&base, &budgets, 2, &mut obs).unwrap();
        assert_eq!(results.len(), 3);
        for (_, r) in &results {
            assert!(r.final_states.is_none(), "sweeps drop per-point arenas");
        }
        let mut seen = obs.0.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2], "every point must stream exactly once");
        // Results in input order regardless of completion order.
        for ((cb, _), expect) in results.iter().zip(&budgets) {
            assert_eq!(cb, expect);
        }
    }

    #[test]
    fn sweep_demotes_multithreaded_point_backends() {
        // Thread counts never change results, so an actors-backend base
        // sweeps via the sequential engine instead of nesting pools.
        let base = quick_spec().backend(Backend::EngineActors { threads: 8 });
        let results = run_sweep(&base, &[0.5], 2, &mut NoopObserver).unwrap();
        assert_eq!(results.len(), 1);
        let seq = run(&quick_spec().backend(Backend::EngineSequential)).unwrap();
        assert_eq!(results[0].1.final_mean, seq.final_mean);
    }

    #[test]
    fn summary_json_is_parseable() {
        let res = run(&quick_spec()).unwrap();
        let j = res.summary_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert!(parsed.get("final_loss").unwrap().as_f64().is_some());
    }

    #[test]
    fn sweep_line_schema_is_uniform_across_backends() {
        // Non-cluster, non-async backends pin `wire_bytes` and
        // `mean_staleness` to null, so every sweep JSON line carries
        // the same keys regardless of backend.
        let sim = run(&quick_spec()).unwrap().summary_json();
        assert_eq!(sim.get("wire_bytes"), Some(&Json::Null));
        assert_eq!(sim.get("suppressed_bytes"), Some(&Json::Null));
        assert_eq!(sim.get("mean_staleness"), Some(&Json::Null));
        for key in ["final_loss", "total_time", "comm_units", "alpha", "rho"] {
            assert!(sim.get(key).is_some(), "missing {key}");
        }
        let eng = run(&quick_spec().backend(Backend::EngineSequential)).unwrap().summary_json();
        assert_eq!(eng.get("wire_bytes"), Some(&Json::Null));
    }

    #[test]
    fn snapshot_rides_on_every_backend() {
        use crate::cluster::TransportKind;
        use crate::trace::Counter;
        let sim = run(&quick_spec()).unwrap();
        assert_eq!(sim.snapshot.counter(Counter::MixRounds), 60);
        assert!(sim.snapshot.counter(Counter::ComputeEvents) > 0);
        assert_eq!(sim.snapshot.wire_bytes(), 0);
        let clu = run(&quick_spec()
            .backend(Backend::Cluster { shards: 2, transport: TransportKind::Loopback }))
        .unwrap();
        assert!(clu.snapshot.wire_bytes() > 0, "cluster runs account wire traffic");
        assert!(clu.snapshot.counter(Counter::ShardSteps) > 0);
    }

    #[test]
    fn traced_run_records_events_and_snapshot() {
        use crate::trace::Counter;
        let spec = quick_spec().backend(Backend::EngineSequential);
        let pl = plan(&spec).unwrap();
        let mut sink = RingSink::new(65_536);
        let mut tracer = Tracer::attached(&mut sink);
        let res = run_planned_traced(&spec, &pl, &mut NoopObserver, &mut tracer).unwrap();
        drop(tracer);
        assert_eq!(res.snapshot.counter(Counter::MixRounds), 60);
        assert!(!sink.is_empty());
        assert_eq!(sink.dropped(), 0);
    }

    #[test]
    fn spec_trace_block_writes_chrome_trace() {
        use crate::experiment::spec::TraceSpec;
        use crate::trace::{validate_chrome_trace, Counter, TraceFormat};
        let path = std::env::temp_dir().join("matcha_run_planned_trace.json");
        let spec = quick_spec().trace(TraceSpec {
            path: path.to_string_lossy().into_owned(),
            format: TraceFormat::Chrome,
            capacity: 8192,
            telemetry: true,
            telemetry_capacity: 8192,
        });
        let res = run(&spec).unwrap();
        assert!(res.snapshot.counter(Counter::ComputeEvents) > 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let check = validate_chrome_trace(&text).unwrap();
        assert!(check.events > 0);
        assert!(text.contains("otherData"), "metric summaries attach to the export");
        // A ring that never overflowed advertises zero dropped records.
        assert_eq!(check.dropped, Some(0));
        std::fs::remove_file(&path).ok();
    }
}
