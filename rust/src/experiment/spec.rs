//! The typed experiment specification: one declarative, serializable
//! description of a full MATCHA run.
//!
//! An [`ExperimentSpec`] names everything a run needs — the base graph,
//! the activation strategy and its communication budget, the workload,
//! the delay policy, the execution backend, and the run hyperparameters —
//! and is the single input to [`crate::experiment::plan()`] and
//! [`crate::experiment::run()`]. Specs are built fluently in code or loaded
//! from JSON files (`matcha run --spec exp.json`), with cross-field
//! validation in both directions and an exact JSON round-trip
//! (`parse(to_json_string(s)) == s`).

use crate::cluster::TransportKind;
use crate::graph::{parse_graph_spec, Graph};
use crate::json::Json;
use crate::sim::Compression;
use crate::trace::TraceFormat;
use std::collections::BTreeMap;

/// Where the base communication topology comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphSource {
    /// A generator spec string (`fig1`, `ring:8`, `er:16:8:303`, ...) in
    /// the [`parse_graph_spec`] grammar.
    Spec(String),
    /// An explicit graph (e.g. a measured cluster topology). JSON form:
    /// `{"nodes": 8, "edges": [[0,1], [1,2], ...]}`.
    Explicit(Graph),
}

impl GraphSource {
    /// Materialize the graph, validating connectivity (the paper requires
    /// a connected base topology).
    pub fn resolve(&self) -> Result<Graph, String> {
        let g = match self {
            GraphSource::Spec(s) => parse_graph_spec(s).map_err(|e| format!("graph: {e}"))?,
            GraphSource::Explicit(g) => g.clone(),
        };
        if g.num_nodes() < 2 || g.num_edges() == 0 {
            return Err("graph: need at least 2 nodes and 1 edge".into());
        }
        if !g.is_connected() {
            return Err("graph: base topology must be connected".into());
        }
        Ok(g)
    }
}

/// The activation strategy: which matchings communicate each iteration
/// (paper §3 and its comparators).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// MATCHA: independent Bernoulli activation with optimized
    /// probabilities at communication budget `budget ∈ (0, 1]`.
    Matcha { budget: f64 },
    /// Vanilla DecenSGD: every matching, every iteration.
    Vanilla,
    /// P-DecenSGD: the whole base topology every `⌈1/budget⌉` rounds.
    Periodic { budget: f64 },
    /// Exactly one matching per round, drawn ∝ the optimized
    /// probabilities at `budget` (paper §3 "Extension to Other Design
    /// Choices").
    SingleMatching { budget: f64 },
}

impl Strategy {
    /// The communication budget, if this strategy has one.
    pub fn budget(&self) -> Option<f64> {
        match self {
            Strategy::Matcha { budget }
            | Strategy::Periodic { budget }
            | Strategy::SingleMatching { budget } => Some(*budget),
            Strategy::Vanilla => None,
        }
    }

    /// The same strategy at a different budget (no-op for `Vanilla`).
    /// This is what the sweep driver maps over a budget grid.
    pub fn with_budget(self, cb: f64) -> Strategy {
        match self {
            Strategy::Matcha { .. } => Strategy::Matcha { budget: cb },
            Strategy::Periodic { .. } => Strategy::Periodic { budget: cb },
            Strategy::SingleMatching { .. } => Strategy::SingleMatching { budget: cb },
            Strategy::Vanilla => Strategy::Vanilla,
        }
    }

    /// Short name for logs and JSON (`matcha`, `vanilla`, `periodic`,
    /// `single`).
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Matcha { .. } => "matcha",
            Strategy::Vanilla => "vanilla",
            Strategy::Periodic { .. } => "periodic",
            Strategy::SingleMatching { .. } => "single",
        }
    }
}

/// The optimization workload.
#[derive(Clone, Debug, PartialEq)]
pub enum ProblemSpec {
    /// Distributed noisy quadratic with a known optimum.
    Quadratic {
        /// Parameter dimension.
        dim: usize,
        /// How far apart the workers' local optima are (0 = IID).
        hetero: f64,
        /// Gradient noise standard deviation.
        noise_std: f64,
        /// Generation seed; `None` derives `run.seed ^ 0x9a9a` (the
        /// historical CLI derivation, kept for parity).
        seed: Option<u64>,
    },
    /// Synthetic logistic regression with train/test splits.
    Logistic {
        /// Shard skew: 0 = IID, 1 = strongly non-IID.
        non_iid: f64,
        /// Class-mean separation (higher = easier).
        separation: f64,
        /// Generation seed; `None` derives `run.seed ^ 0x10f`.
        seed: Option<u64>,
    },
}

impl ProblemSpec {
    /// The default quadratic workload (dim 20, hetero 1.0, noise 0.2).
    pub fn quadratic() -> ProblemSpec {
        ProblemSpec::Quadratic { dim: 20, hetero: 1.0, noise_std: 0.2, seed: None }
    }

    /// The default logistic-regression workload (IID shards).
    pub fn logistic() -> ProblemSpec {
        ProblemSpec::Logistic { non_iid: 0.0, separation: 1.5, seed: None }
    }

    /// Short name for logs and JSON (`quad`, `logreg`).
    pub fn name(&self) -> &'static str {
        match self {
            ProblemSpec::Quadratic { .. } => "quad",
            ProblemSpec::Logistic { .. } => "logreg",
        }
    }
}

/// Which execution path runs the DecenSGD recursion. All backends share
/// the step/mix kernel (`sim::kernel`); the barrier backends agree
/// bit-for-bit per seed under the analytic delay policy, and the async
/// backend joins them at `max_staleness = 0`.
#[derive(Clone, Debug, PartialEq)]
pub enum Backend {
    /// The sequential reference simulator with closed-form time
    /// accounting ([`crate::sim::run_decentralized`]).
    SimReference,
    /// The event-driven engine, in-process sequential executor.
    EngineSequential,
    /// The event-driven engine's bounded actor pool: all workers
    /// multiplexed over `min(threads, workers)` OS threads. `threads`
    /// must be >= 1; the pool never changes results, only wall-clock
    /// (one thread degenerates to the sequential engine).
    EngineActors { threads: usize },
    /// The barrier-free asynchronous gossip runtime
    /// ([`crate::gossip::run_async`]): per-worker virtual clocks,
    /// staleness-aware pairwise mixing bounded by `max_staleness`
    /// (0 reproduces the synchronous kernel exactly;
    /// [`crate::gossip::UNBOUNDED_STALENESS`] — JSON
    /// `"max_staleness": null` — removes the bound entirely, the
    /// throughput-oriented AD-PSGD mode), gradient steps on a bounded
    /// pool of `threads` OS threads.
    Async { threads: usize, max_staleness: usize },
    /// The multi-node cluster runtime ([`crate::cluster::run_cluster`]):
    /// workers partitioned over `shards` transport-separated shard
    /// nodes, phase commands serialized through the versioned wire
    /// format. `loopback` is deterministic and bit-for-bit equal to the
    /// actors backend per seed; `tcp` runs the same schedule over real
    /// localhost sockets; `{"tcp": ["host:port", ...]}` connects to
    /// standalone shard-node daemons ([`crate::node`]), one shard per
    /// listed address. Shard count never changes results.
    Cluster { shards: usize, transport: TransportKind },
}

impl Backend {
    /// Short name for logs and JSON (`sim`, `engine`, `actors`, `async`,
    /// `cluster`).
    pub fn name(&self) -> &'static str {
        match self {
            Backend::SimReference => "sim",
            Backend::EngineSequential => "engine",
            Backend::EngineActors { .. } => "actors",
            Backend::Async { .. } => "async",
            Backend::Cluster { .. } => "cluster",
        }
    }
}

/// Default trace ring capacity when the spec's `trace` block omits it.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// Default per-daemon trace ring capacity for remote runs when the
/// spec's `trace` block omits `telemetry_capacity`. Smaller than the
/// coordinator's ring: each daemon's records cross the wire at every
/// harvest, so the ring only has to cover one harvest interval.
pub const DEFAULT_TELEMETRY_CAPACITY: usize = 8_192;

/// Where and how a run writes its event trace. JSON form:
/// `{"path": "out.json", "format": "chrome" | "jsonl",
/// "capacity": 65536, "telemetry": true, "telemetry_capacity": 8192}`
/// (everything but `path` optional).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpec {
    /// Output file path.
    pub path: String,
    /// Export format (defaults to Chrome trace-event JSON).
    pub format: TraceFormat,
    /// Ring-buffer capacity in records; when a run emits more, the
    /// oldest records are dropped.
    pub capacity: usize,
    /// For remote cluster runs: harvest every daemon's telemetry
    /// (trace ring + metrics + health) and merge it into the export as
    /// one Chrome `pid` track per shard. On by default; results are
    /// bit-for-bit identical either way. Ignored by in-process
    /// backends.
    pub telemetry: bool,
    /// Per-daemon trace ring capacity for remote runs.
    pub telemetry_capacity: usize,
}

/// Default contraction-window size (record samples per window) when the
/// spec's `report` block omits it.
pub const DEFAULT_REPORT_WINDOW: usize = 8;

/// Enables the run's algorithm-level observatory
/// ([`crate::trace::Observatory`]): activation ledger, contraction
/// windows, error-runtime frontier and straggler audit, harvested onto
/// [`super::ExperimentResult::observatory`]. JSON form:
/// `{"report": {"window": 8}}` (`window` optional).
#[derive(Clone, Debug, PartialEq)]
pub struct ReportSpec {
    /// Record samples per tumbling contraction window (≥ 2). A window
    /// closes — and [`super::Observer::on_window`] fires — every
    /// `window` record points.
    pub window: usize,
}

impl Default for ReportSpec {
    fn default() -> ReportSpec {
        ReportSpec { window: DEFAULT_REPORT_WINDOW }
    }
}

/// A complete, declarative description of one experiment. See the module
/// docs for the JSON schema; every field except `graph` has a default.
///
/// Build fluently and finish with [`ExperimentSpec::validated`]:
///
/// ```
/// use matcha::experiment::{Backend, ExperimentSpec, ProblemSpec, Strategy};
/// let spec = ExperimentSpec::new("ring:6")
///     .strategy(Strategy::Matcha { budget: 0.5 })
///     .problem(ProblemSpec::quadratic())
///     .backend(Backend::EngineSequential)
///     .iterations(50)
///     .validated()
///     .unwrap();
/// assert_eq!(spec.strategy.name(), "matcha");
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec {
    pub graph: GraphSource,
    pub strategy: Strategy,
    pub problem: ProblemSpec,
    /// Delay model spec in the [`crate::delay::DelayModel::parse`]
    /// grammar: `unit` | `maxdeg` | `stochastic:lo:hi`.
    pub delay: String,
    /// Engine delay-policy spec in the [`crate::engine::parse_policy`]
    /// grammar: `analytic` | `hetero:SEED` | `straggler:W:F` |
    /// `flaky:P`. The sim backend supports only `analytic`.
    pub policy: String,
    pub backend: Backend,
    /// Learning rate η.
    pub lr: f64,
    /// Step decay: multiply lr by `lr_decay` every `lr_decay_every`
    /// iterations (`lr_decay = 1.0` disables).
    pub lr_decay: f64,
    pub lr_decay_every: usize,
    /// Total iterations K.
    pub iterations: usize,
    /// Metric recording stride; `None` = `max(iterations / 50, 1)`.
    pub record_every: Option<usize>,
    /// Computation time per iteration in delay units.
    pub compute_units: f64,
    /// Optional gossip-message compression.
    pub compression: Option<Compression>,
    /// Handshake-latency floor for the compression time factor.
    pub latency_floor: f64,
    /// Run seed: gradient noise, batch sampling, delay draws.
    pub seed: u64,
    /// Topology-sampler seed; `None` = `seed`. Overridable so legacy
    /// harnesses that seeded the sampler independently stay bit-exact.
    pub sampler_seed: Option<u64>,
    /// Optional event-trace output (`None` = tracing disabled; metric
    /// counters still accumulate).
    pub trace: Option<TraceSpec>,
    /// Optional algorithm-level observatory (`None` = disabled; the
    /// record path stays allocation-free).
    pub report: Option<ReportSpec>,
}

impl ExperimentSpec {
    /// A spec on a generator graph with every other field defaulted
    /// (MATCHA at CB 0.5, logistic regression, analytic policy, the
    /// reference simulator, 1000 iterations).
    pub fn new(graph_spec: &str) -> ExperimentSpec {
        Self::on_source(GraphSource::Spec(graph_spec.to_string()))
    }

    /// A spec on an explicit graph object.
    pub fn on_graph(graph: Graph) -> ExperimentSpec {
        Self::on_source(GraphSource::Explicit(graph))
    }

    fn on_source(graph: GraphSource) -> ExperimentSpec {
        ExperimentSpec {
            graph,
            strategy: Strategy::Matcha { budget: 0.5 },
            problem: ProblemSpec::logistic(),
            delay: "unit".to_string(),
            policy: "analytic".to_string(),
            backend: Backend::SimReference,
            lr: 0.05,
            lr_decay: 1.0,
            lr_decay_every: usize::MAX,
            iterations: 1000,
            record_every: None,
            compute_units: 1.0,
            compression: None,
            latency_floor: 0.05,
            seed: 0,
            sampler_seed: None,
            trace: None,
            report: None,
        }
    }

    // ---- fluent builder --------------------------------------------------

    pub fn strategy(mut self, s: Strategy) -> Self {
        self.strategy = s;
        self
    }

    pub fn problem(mut self, p: ProblemSpec) -> Self {
        self.problem = p;
        self
    }

    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    pub fn delay(mut self, d: &str) -> Self {
        self.delay = d.to_string();
        self
    }

    pub fn policy(mut self, p: &str) -> Self {
        self.policy = p.to_string();
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }

    pub fn lr_decay(mut self, factor: f64, every: usize) -> Self {
        self.lr_decay = factor;
        self.lr_decay_every = every;
        self
    }

    pub fn iterations(mut self, k: usize) -> Self {
        self.iterations = k;
        self
    }

    pub fn record_every(mut self, every: usize) -> Self {
        self.record_every = Some(every);
        self
    }

    pub fn compute_units(mut self, units: f64) -> Self {
        self.compute_units = units;
        self
    }

    pub fn compression(mut self, c: Compression) -> Self {
        self.compression = Some(c);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn sampler_seed(mut self, seed: u64) -> Self {
        self.sampler_seed = Some(seed);
        self
    }

    /// Attach an event-trace output to the run.
    pub fn trace(mut self, t: TraceSpec) -> Self {
        self.trace = Some(t);
        self
    }

    /// Enable the algorithm-level observatory (drift ledger,
    /// contraction windows, frontier, audit).
    pub fn report(mut self, r: ReportSpec) -> Self {
        self.report = Some(r);
        self
    }

    /// Replace the strategy's communication budget (sweep helper).
    pub fn with_budget(mut self, cb: f64) -> Self {
        self.strategy = self.strategy.with_budget(cb);
        self
    }

    /// Builder terminator: validate and return the spec.
    pub fn validated(self) -> Result<ExperimentSpec, String> {
        self.validate()?;
        Ok(self)
    }

    // ---- validation ------------------------------------------------------

    /// Cross-field validation. Every rejection message names the field it
    /// is about (`graph:`, `strategy:`, `run:`, ...).
    pub fn validate(&self) -> Result<(), String> {
        self.validate_resolving().map(|_| ())
    }

    /// [`ExperimentSpec::validate`], returning the resolved graph so
    /// callers that need it next don't resolve twice (generator specs
    /// like `er:M:D:SEED` run a seed search on every resolve).
    pub fn validate_resolving(&self) -> Result<Graph, String> {
        let g = self.graph.resolve()?;
        if let Some(cb) = self.strategy.budget() {
            if !cb.is_finite() || cb <= 0.0 || cb > 1.0 {
                return Err(format!("strategy: budget {cb} out of (0, 1]"));
            }
        }
        match &self.problem {
            ProblemSpec::Quadratic { dim, hetero, noise_std, .. } => {
                if *dim == 0 {
                    return Err("problem: quadratic dim must be >= 1".into());
                }
                if !hetero.is_finite() || *hetero < 0.0 {
                    return Err(format!("problem: quadratic hetero {hetero} must be >= 0"));
                }
                if !noise_std.is_finite() || *noise_std < 0.0 {
                    return Err(format!("problem: quadratic noise_std {noise_std} must be >= 0"));
                }
            }
            ProblemSpec::Logistic { non_iid, separation, .. } => {
                if !non_iid.is_finite() || !(0.0..=1.0).contains(non_iid) {
                    return Err(format!("problem: logreg non_iid {non_iid} out of [0, 1]"));
                }
                if !separation.is_finite() || *separation <= 0.0 {
                    return Err(format!("problem: logreg separation {separation} must be > 0"));
                }
            }
        }
        let delay = crate::delay::DelayModel::parse(&self.delay)
            .map_err(|e| format!("delay: {e}"))?;
        if !self.lr.is_finite() || self.lr <= 0.0 {
            return Err(format!("run: lr {} must be positive", self.lr));
        }
        if !self.lr_decay.is_finite() || self.lr_decay <= 0.0 || self.lr_decay > 1.0 {
            return Err(format!("run: lr_decay {} out of (0, 1]", self.lr_decay));
        }
        if self.lr_decay_every == 0 {
            return Err("run: lr_decay_every must be >= 1".into());
        }
        if self.iterations == 0 {
            return Err("run: iterations must be >= 1".into());
        }
        if self.record_every == Some(0) {
            return Err("run: record_every must be >= 1".into());
        }
        if !self.compute_units.is_finite() || self.compute_units < 0.0 {
            return Err(format!("run: compute_units {} must be >= 0", self.compute_units));
        }
        if !self.latency_floor.is_finite() || self.latency_floor < 0.0 {
            return Err(format!("run: latency_floor {} must be >= 0", self.latency_floor));
        }
        // Seeds ride through JSON as f64 numbers; at or beyond 2^53 they
        // silently lose precision and break the exact round-trip. The
        // bound is strict (`>=`) so a written value that the JSON parser
        // already rounded *down to* 2^53 is still caught here.
        const MAX_JSON_SEED: u64 = 1 << 53;
        for (name, seed) in [
            ("run: seed", Some(self.seed)),
            ("run: sampler_seed", self.sampler_seed),
            (
                "problem: seed",
                match &self.problem {
                    ProblemSpec::Quadratic { seed, .. } | ProblemSpec::Logistic { seed, .. } => {
                        *seed
                    }
                },
            ),
        ] {
            if let Some(s) = seed {
                if s >= MAX_JSON_SEED {
                    return Err(format!(
                        "{name} {s} is not below 2^53 and cannot round-trip through JSON"
                    ));
                }
            }
        }
        match &self.compression {
            Some(Compression::TopK { frac }) => {
                if !frac.is_finite() || *frac <= 0.0 || *frac > 1.0 {
                    return Err(format!("run: compression top-k frac {frac} out of (0, 1]"));
                }
            }
            Some(Compression::Quantize { bits }) => {
                if *bits == 0 || *bits > 32 {
                    return Err(format!("run: compression quantize bits {bits} out of [1, 32]"));
                }
            }
            None => {}
        }
        if let Backend::EngineActors { threads } = self.backend {
            if threads == 0 {
                return Err(format!(
                    "backend: actors needs threads >= 1 (got {threads}); \
                     a one-thread pool is valid and matches the sequential \
                     engine bit-for-bit"
                ));
            }
        }
        if let Backend::Async { threads, max_staleness } = self.backend {
            if threads == 0 {
                return Err("backend: async needs threads >= 1".into());
            }
            if matches!(delay, crate::delay::DelayModel::MaxDegree) {
                return Err(
                    "backend: the async runtime needs a link-granular delay model; \
                     'maxdeg' has no per-link schedule (use delay 'unit' or \
                     'stochastic:lo:hi')"
                        .into(),
                );
            }
            // Bounded values must survive the JSON number round-trip;
            // the unbounded sentinel serializes as `null` instead.
            if max_staleness != crate::gossip::UNBOUNDED_STALENESS
                && max_staleness as u64 >= (1 << 53)
            {
                return Err(format!(
                    "backend: max_staleness {max_staleness} is not below 2^53 and cannot \
                     round-trip through JSON (use null for the unbounded AD-PSGD mode)"
                ));
            }
        }
        if let Backend::Cluster { shards, .. } = self.backend {
            if shards == 0 {
                return Err(
                    "backend: cluster needs shards >= 1 (a one-shard cluster is valid \
                     and matches the in-process backends bit-for-bit)"
                        .into(),
                );
            }
        }
        if let Backend::Cluster { shards, transport: TransportKind::Remote { addrs } } =
            &self.backend
        {
            if addrs.is_empty() {
                return Err(
                    "backend: remote transport needs at least one \"host:port\" node address"
                        .into(),
                );
            }
            if addrs.iter().any(|a| a.is_empty()) {
                return Err("backend: remote node addresses must be non-empty strings".into());
            }
            if *shards != addrs.len() {
                return Err(format!(
                    "backend: remote cluster lists {} node addresses but shards = {shards} \
                     (each listed shard-node daemon hosts exactly one shard; drop 'shards' \
                     to default it to the address count)",
                    addrs.len()
                ));
            }
        }
        if let Some(trace) = &self.trace {
            if trace.path.is_empty() {
                return Err("trace: path must be non-empty".into());
            }
            if trace.capacity == 0 {
                return Err("trace: capacity must be >= 1".into());
            }
            if trace.telemetry_capacity == 0 {
                return Err("trace: telemetry_capacity must be >= 1".into());
            }
        }
        if let Some(report) = &self.report {
            // A window needs two samples for a decay rate.
            if report.window < 2 {
                return Err("report: window must be >= 2".into());
            }
        }
        // The policy grammar needs the graph and the run config, so
        // validate it with a probe config mirroring what the run builds.
        let probe = crate::sim::RunConfig {
            delay,
            compute_units: self.compute_units,
            seed: self.seed,
            ..crate::sim::RunConfig::default()
        };
        crate::engine::parse_policy(&self.policy, &g, &probe)
            .map_err(|e| format!("policy: {e}"))?;
        if self.backend == Backend::SimReference && self.policy != "analytic" {
            return Err(format!(
                "policy: the sim backend supports only 'analytic' (got '{}'); \
                 pick an engine backend for '{}'",
                self.policy, self.policy
            ));
        }
        Ok(g)
    }

    // ---- JSON ------------------------------------------------------------

    /// Serialize to a [`Json`] value (compact, round-trips exactly).
    pub fn to_json(&self) -> Json {
        let graph = match &self.graph {
            GraphSource::Spec(s) => Json::Str(s.clone()),
            GraphSource::Explicit(g) => Json::obj(vec![
                ("nodes", Json::Num(g.num_nodes() as f64)),
                (
                    "edges",
                    Json::Arr(
                        g.edges()
                            .iter()
                            .map(|&(u, v)| {
                                Json::Arr(vec![Json::Num(u as f64), Json::Num(v as f64)])
                            })
                            .collect(),
                    ),
                ),
            ]),
        };
        let mut strategy = vec![("kind", Json::Str(self.strategy.name().into()))];
        if let Some(cb) = self.strategy.budget() {
            strategy.push(("budget", Json::Num(cb)));
        }
        let problem = match &self.problem {
            ProblemSpec::Quadratic { dim, hetero, noise_std, seed } => {
                let mut p = vec![
                    ("kind", Json::Str("quad".into())),
                    ("dim", Json::Num(*dim as f64)),
                    ("hetero", Json::Num(*hetero)),
                    ("noise_std", Json::Num(*noise_std)),
                ];
                if let Some(s) = seed {
                    p.push(("seed", Json::Num(*s as f64)));
                }
                p
            }
            ProblemSpec::Logistic { non_iid, separation, seed } => {
                let mut p = vec![
                    ("kind", Json::Str("logreg".into())),
                    ("non_iid", Json::Num(*non_iid)),
                    ("separation", Json::Num(*separation)),
                ];
                if let Some(s) = seed {
                    p.push(("seed", Json::Num(*s as f64)));
                }
                p
            }
        };
        let mut backend = vec![("kind", Json::Str(self.backend.name().into()))];
        match &self.backend {
            Backend::EngineActors { threads } => {
                backend.push(("threads", Json::Num(*threads as f64)));
            }
            Backend::Async { threads, max_staleness } => {
                backend.push(("threads", Json::Num(*threads as f64)));
                // The unbounded AD-PSGD sentinel round-trips as `null`
                // (the usize value itself cannot survive a JSON number).
                backend.push((
                    "max_staleness",
                    if *max_staleness == crate::gossip::UNBOUNDED_STALENESS {
                        Json::Null
                    } else {
                        Json::Num(*max_staleness as f64)
                    },
                ));
            }
            Backend::Cluster { shards, transport } => {
                backend.push(("shards", Json::Num(*shards as f64)));
                // The in-process transports serialize as bare names; the
                // remote transport carries its node list as an object so
                // `parse(to_json()) == self` stays exact.
                backend.push((
                    "transport",
                    match transport {
                        TransportKind::Remote { addrs } => Json::obj(vec![(
                            "tcp",
                            Json::Arr(addrs.iter().map(|a| Json::Str(a.clone())).collect()),
                        )]),
                        named => Json::Str(named.name().into()),
                    },
                ));
            }
            _ => {}
        }
        let mut run = vec![
            ("lr", Json::Num(self.lr)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("compute_units", Json::Num(self.compute_units)),
            ("latency_floor", Json::Num(self.latency_floor)),
            ("seed", Json::Num(self.seed as f64)),
        ];
        if self.lr_decay != 1.0 {
            run.push(("lr_decay", Json::Num(self.lr_decay)));
        }
        if self.lr_decay_every != usize::MAX {
            run.push(("lr_decay_every", Json::Num(self.lr_decay_every as f64)));
        }
        if let Some(every) = self.record_every {
            run.push(("record_every", Json::Num(every as f64)));
        }
        if let Some(s) = self.sampler_seed {
            run.push(("sampler_seed", Json::Num(s as f64)));
        }
        match &self.compression {
            Some(Compression::TopK { frac }) => run.push((
                "compression",
                Json::obj(vec![("kind", Json::Str("topk".into())), ("frac", Json::Num(*frac))]),
            )),
            Some(Compression::Quantize { bits }) => run.push((
                "compression",
                Json::obj(vec![
                    ("kind", Json::Str("quantize".into())),
                    ("bits", Json::Num(*bits as f64)),
                ]),
            )),
            None => {}
        }
        let mut top = vec![
            ("graph", graph),
            ("strategy", Json::obj(strategy)),
            ("problem", Json::obj(problem)),
            ("delay", Json::Str(self.delay.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("backend", Json::obj(backend)),
            ("run", Json::obj(run)),
        ];
        if let Some(trace) = &self.trace {
            // Every field is emitted so the round-trip is exact even
            // when they match the parse defaults.
            top.push((
                "trace",
                Json::obj(vec![
                    ("path", Json::Str(trace.path.clone())),
                    ("format", Json::Str(trace.format.name().into())),
                    ("capacity", Json::Num(trace.capacity as f64)),
                    ("telemetry", Json::Bool(trace.telemetry)),
                    ("telemetry_capacity", Json::Num(trace.telemetry_capacity as f64)),
                ]),
            ));
        }
        if let Some(report) = &self.report {
            // `window` is always emitted so the round-trip is exact even
            // when it matches the parse default.
            top.push(("report", Json::obj(vec![("window", Json::Num(report.window as f64))])));
        }
        Json::obj(top)
    }

    /// Compact JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a spec from JSON text and validate it.
    pub fn parse(text: &str) -> Result<ExperimentSpec, String> {
        let json = Json::parse(text).map_err(|e| format!("spec: {e}"))?;
        let spec = Self::from_json(&json)?;
        spec.validate()?;
        Ok(spec)
    }

    /// Load and validate a spec file.
    pub fn load(path: &std::path::Path) -> Result<ExperimentSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("spec: cannot read {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Write the spec as JSON.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json_string())
    }

    /// Build a spec from parsed JSON. Structural errors only; semantic
    /// checks live in [`ExperimentSpec::validate`]. Unknown keys are
    /// rejected at every level.
    pub fn from_json(json: &Json) -> Result<ExperimentSpec, String> {
        let obj = json.as_object().ok_or("spec: top level must be an object")?;
        known_keys(
            obj,
            "spec",
            &[
                "graph", "strategy", "problem", "delay", "policy", "backend", "run", "trace",
                "report",
            ],
        )?;

        let graph = match obj.get("graph") {
            None => return Err("spec: missing required key 'graph'".into()),
            Some(Json::Str(s)) => GraphSource::Spec(s.clone()),
            Some(g) => GraphSource::Explicit(parse_explicit_graph(g)?),
        };
        let mut spec = Self::on_source(graph);

        if let Some(s) = obj.get("strategy") {
            spec.strategy = parse_strategy(s)?;
        }
        if let Some(p) = obj.get("problem") {
            spec.problem = parse_problem(p)?;
        }
        if let Some(d) = obj.get("delay") {
            spec.delay = d
                .as_str()
                .ok_or("delay: must be a string (unit | maxdeg | stochastic:lo:hi)")?
                .to_string();
        }
        if let Some(p) = obj.get("policy") {
            spec.policy = p.as_str().ok_or("policy: must be a string")?.to_string();
        }
        if let Some(b) = obj.get("backend") {
            spec.backend = parse_backend(b)?;
        }
        if let Some(r) = obj.get("run") {
            parse_run_params(r, &mut spec)?;
        }
        if let Some(t) = obj.get("trace") {
            spec.trace = Some(parse_trace(t)?);
        }
        if let Some(r) = obj.get("report") {
            spec.report = Some(parse_report(r)?);
        }
        Ok(spec)
    }
}

fn parse_report(json: &Json) -> Result<ReportSpec, String> {
    let obj = json.as_object().ok_or("report: must be {\"window\": N} (window optional)")?;
    known_keys(obj, "report", &["window"])?;
    let window = get_usize(obj, "report", "window", DEFAULT_REPORT_WINDOW)?;
    Ok(ReportSpec { window })
}

fn parse_trace(json: &Json) -> Result<TraceSpec, String> {
    let obj = json
        .as_object()
        .ok_or("trace: must be {\"path\": \"...\", \"format\": ..., \"capacity\": ...}")?;
    known_keys(obj, "trace", &["path", "format", "capacity", "telemetry", "telemetry_capacity"])?;
    let path = obj
        .get("path")
        .and_then(Json::as_str)
        .ok_or("trace: missing required string 'path'")?
        .to_string();
    let format = match obj.get("format") {
        None => TraceFormat::Chrome,
        Some(f) => {
            let name = f.as_str().ok_or("trace: 'format' must be a string")?;
            TraceFormat::parse(name).map_err(|e| format!("trace: {e}"))?
        }
    };
    let capacity = get_usize(obj, "trace", "capacity", DEFAULT_TRACE_CAPACITY)?;
    let telemetry = match obj.get("telemetry") {
        None => true,
        Some(v) => v.as_bool().ok_or("trace: 'telemetry' must be a boolean")?,
    };
    let telemetry_capacity =
        get_usize(obj, "trace", "telemetry_capacity", DEFAULT_TELEMETRY_CAPACITY)?;
    Ok(TraceSpec { path, format, capacity, telemetry, telemetry_capacity })
}

fn known_keys(obj: &BTreeMap<String, Json>, ctx: &str, known: &[&str]) -> Result<(), String> {
    for k in obj.keys() {
        if !known.contains(&k.as_str()) {
            return Err(format!("{ctx}: unknown key '{k}'"));
        }
    }
    Ok(())
}

fn get_f64(
    obj: &BTreeMap<String, Json>,
    ctx: &str,
    key: &str,
    default: f64,
) -> Result<f64, String> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| format!("{ctx}: '{key}' must be a number")),
    }
}

fn get_usize(
    obj: &BTreeMap<String, Json>,
    ctx: &str,
    key: &str,
    default: usize,
) -> Result<usize, String> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| format!("{ctx}: '{key}' must be a non-negative integer")),
    }
}

fn get_seed(obj: &BTreeMap<String, Json>, ctx: &str, key: &str) -> Result<Option<u64>, String> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_usize()
            .map(|s| Some(s as u64))
            .ok_or_else(|| format!("{ctx}: '{key}' must be a non-negative integer")),
    }
}

fn parse_explicit_graph(json: &Json) -> Result<Graph, String> {
    let obj = json
        .as_object()
        .ok_or("graph: must be a spec string or {\"nodes\": N, \"edges\": [[u,v],...]}")?;
    known_keys(obj, "graph", &["nodes", "edges"])?;
    let nodes = get_usize(obj, "graph", "nodes", 0)?;
    if nodes < 2 {
        return Err("graph: 'nodes' must be >= 2".into());
    }
    let edges_json = obj
        .get("edges")
        .and_then(Json::as_array)
        .ok_or("graph: 'edges' must be an array of [u, v] pairs")?;
    let mut edges = Vec::with_capacity(edges_json.len());
    for e in edges_json {
        let pair = e.as_array().filter(|a| a.len() == 2).ok_or("graph: each edge must be [u, v]")?;
        let u = pair[0].as_usize().ok_or("graph: edge endpoints must be integers")?;
        let v = pair[1].as_usize().ok_or("graph: edge endpoints must be integers")?;
        if u == v {
            return Err(format!("graph: self-loop [{u}, {v}] not allowed"));
        }
        if u >= nodes || v >= nodes {
            return Err(format!("graph: edge [{u}, {v}] out of range for {nodes} nodes"));
        }
        edges.push((u, v));
    }
    Ok(Graph::new(nodes, &edges))
}

fn parse_strategy(json: &Json) -> Result<Strategy, String> {
    // Allow the shorthand `"strategy": "vanilla"` only for kinds without
    // parameters — a budgeted kind written as a bare string would
    // otherwise run at an unstated default budget.
    if let Some(kind) = json.as_str() {
        if matches!(kind, "matcha" | "periodic" | "single") {
            return Err(format!(
                "strategy: '{kind}' needs a budget — use \
                 {{\"kind\": \"{kind}\", \"budget\": CB}}"
            ));
        }
        return strategy_from(kind, 0.5);
    }
    let obj = json.as_object().ok_or("strategy: must be a string or an object with 'kind'")?;
    known_keys(obj, "strategy", &["kind", "budget"])?;
    let kind = obj
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("strategy: missing string key 'kind'")?;
    let budget = match obj.get("budget") {
        None => None,
        Some(v) => Some(v.as_f64().ok_or("strategy: 'budget' must be a number")?),
    };
    match kind {
        "vanilla" => {
            if budget.is_some() {
                return Err("strategy: vanilla takes no budget".into());
            }
            Ok(Strategy::Vanilla)
        }
        "matcha" | "periodic" | "single" => {
            let cb = budget
                .ok_or_else(|| format!("strategy: '{kind}' needs a numeric 'budget'"))?;
            strategy_from(kind, cb)
        }
        other => Err(format!(
            "strategy: unknown kind '{other}' (expected matcha | vanilla | periodic | single)"
        )),
    }
}

fn strategy_from(kind: &str, budget: f64) -> Result<Strategy, String> {
    match kind {
        "matcha" => Ok(Strategy::Matcha { budget }),
        "vanilla" => Ok(Strategy::Vanilla),
        "periodic" => Ok(Strategy::Periodic { budget }),
        "single" => Ok(Strategy::SingleMatching { budget }),
        other => Err(format!(
            "strategy: unknown kind '{other}' (expected matcha | vanilla | periodic | single)"
        )),
    }
}

fn parse_problem(json: &Json) -> Result<ProblemSpec, String> {
    if let Some(kind) = json.as_str() {
        return match kind {
            "quad" => Ok(ProblemSpec::quadratic()),
            "logreg" => Ok(ProblemSpec::logistic()),
            other => Err(format!("problem: unknown kind '{other}' (expected quad | logreg)")),
        };
    }
    let obj = json.as_object().ok_or("problem: must be a string or an object with 'kind'")?;
    let kind = obj
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("problem: missing string key 'kind'")?;
    match kind {
        "quad" => {
            known_keys(obj, "problem", &["kind", "dim", "hetero", "noise_std", "seed"])?;
            Ok(ProblemSpec::Quadratic {
                dim: get_usize(obj, "problem", "dim", 20)?,
                hetero: get_f64(obj, "problem", "hetero", 1.0)?,
                noise_std: get_f64(obj, "problem", "noise_std", 0.2)?,
                seed: get_seed(obj, "problem", "seed")?,
            })
        }
        "logreg" => {
            known_keys(obj, "problem", &["kind", "non_iid", "separation", "seed"])?;
            Ok(ProblemSpec::Logistic {
                non_iid: get_f64(obj, "problem", "non_iid", 0.0)?,
                separation: get_f64(obj, "problem", "separation", 1.5)?,
                seed: get_seed(obj, "problem", "seed")?,
            })
        }
        other => Err(format!("problem: unknown kind '{other}' (expected quad | logreg)")),
    }
}

fn parse_backend(json: &Json) -> Result<Backend, String> {
    if let Some(kind) = json.as_str() {
        return match kind {
            "sim" => Ok(Backend::SimReference),
            "engine" => Ok(Backend::EngineSequential),
            "actors" => {
                Err("backend: 'actors' needs {\"kind\": \"actors\", \"threads\": N}".into())
            }
            "cluster" => Err(
                "backend: 'cluster' needs {\"kind\": \"cluster\", \"shards\": N, \
                 \"transport\": \"loopback\" | \"tcp\" | {\"tcp\": [\"host:port\", ...]}}"
                    .into(),
            ),
            "async" => Ok(Backend::Async {
                threads: 1,
                max_staleness: crate::gossip::DEFAULT_MAX_STALENESS,
            }),
            other => Err(format!(
                "backend: unknown kind '{other}' \
                 (expected sim | engine | actors | async | cluster)"
            )),
        };
    }
    let obj = json.as_object().ok_or("backend: must be a string or an object with 'kind'")?;
    let kind = obj
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("backend: missing string key 'kind'")?;
    match kind {
        "sim" | "engine" | "actors" => known_keys(obj, "backend", &["kind", "threads"])?,
        "async" => known_keys(obj, "backend", &["kind", "threads", "max_staleness"])?,
        "cluster" => known_keys(obj, "backend", &["kind", "shards", "transport"])?,
        _ => {}
    }
    match kind {
        "sim" => Ok(Backend::SimReference),
        "engine" => Ok(Backend::EngineSequential),
        "actors" => Ok(Backend::EngineActors { threads: get_usize(obj, "backend", "threads", 2)? }),
        "async" => Ok(Backend::Async {
            threads: get_usize(obj, "backend", "threads", 1)?,
            // `null` selects the unbounded AD-PSGD mode; a number is the
            // version-drift bound.
            max_staleness: match obj.get("max_staleness") {
                None => crate::gossip::DEFAULT_MAX_STALENESS,
                Some(Json::Null) => crate::gossip::UNBOUNDED_STALENESS,
                Some(v) => v.as_usize().ok_or(
                    "backend: 'max_staleness' must be a non-negative integer or null \
                     (null = unbounded AD-PSGD mode)",
                )?,
            },
        }),
        "cluster" => {
            let transport = match obj.get("transport") {
                None => TransportKind::Loopback,
                Some(v) => parse_transport(v)?,
            };
            // A remote cluster hosts exactly one shard per listed daemon,
            // so the shard count defaults to the address count.
            let default_shards = match &transport {
                TransportKind::Remote { addrs } => addrs.len().max(1),
                _ => 2,
            };
            Ok(Backend::Cluster {
                shards: get_usize(obj, "backend", "shards", default_shards)?,
                transport,
            })
        }
        other => Err(format!(
            "backend: unknown kind '{other}' \
             (expected sim | engine | actors | async | cluster)"
        )),
    }
}

/// Parse a cluster `transport` value: a bare name (`loopback` | `tcp`)
/// or the remote object form `{"tcp": ["host:port", ...]}` naming the
/// shard-node daemons to connect to.
fn parse_transport(json: &Json) -> Result<TransportKind, String> {
    if let Some(name) = json.as_str() {
        return TransportKind::parse(name).map_err(|e| format!("backend: {e}"));
    }
    let obj = json.as_object().ok_or(
        "backend: 'transport' must be \"loopback\" | \"tcp\" | \
         {\"tcp\": [\"host:port\", ...]}",
    )?;
    known_keys(obj, "backend: transport", &["tcp"])?;
    let arr = obj.get("tcp").and_then(Json::as_array).ok_or(
        "backend: remote transport needs a \"tcp\" array of \"host:port\" node addresses",
    )?;
    let mut addrs = Vec::with_capacity(arr.len());
    for a in arr {
        addrs.push(
            a.as_str()
                .ok_or("backend: remote node addresses must be \"host:port\" strings")?
                .to_string(),
        );
    }
    Ok(TransportKind::Remote { addrs })
}

fn parse_run_params(json: &Json, spec: &mut ExperimentSpec) -> Result<(), String> {
    let obj = json.as_object().ok_or("run: must be an object")?;
    known_keys(
        obj,
        "run",
        &[
            "lr",
            "lr_decay",
            "lr_decay_every",
            "iterations",
            "record_every",
            "compute_units",
            "latency_floor",
            "seed",
            "sampler_seed",
            "compression",
        ],
    )?;
    spec.lr = get_f64(obj, "run", "lr", spec.lr)?;
    spec.lr_decay = get_f64(obj, "run", "lr_decay", spec.lr_decay)?;
    spec.lr_decay_every = get_usize(obj, "run", "lr_decay_every", spec.lr_decay_every)?;
    spec.iterations = get_usize(obj, "run", "iterations", spec.iterations)?;
    if obj.contains_key("record_every") {
        spec.record_every = Some(get_usize(obj, "run", "record_every", 1)?);
    }
    spec.compute_units = get_f64(obj, "run", "compute_units", spec.compute_units)?;
    spec.latency_floor = get_f64(obj, "run", "latency_floor", spec.latency_floor)?;
    spec.seed = get_seed(obj, "run", "seed")?.unwrap_or(spec.seed);
    spec.sampler_seed = get_seed(obj, "run", "sampler_seed")?;
    if let Some(c) = obj.get("compression") {
        spec.compression = Some(parse_compression(c)?);
    }
    Ok(())
}

fn parse_compression(json: &Json) -> Result<Compression, String> {
    let obj = json.as_object().ok_or("run: 'compression' must be an object with 'kind'")?;
    let kind = obj
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("run: compression missing string key 'kind'")?;
    match kind {
        "topk" => {
            known_keys(obj, "run: compression", &["kind", "frac"])?;
            Ok(Compression::TopK { frac: get_f64(obj, "run: compression", "frac", 0.25)? })
        }
        "quantize" => {
            known_keys(obj, "run: compression", &["kind", "bits"])?;
            let bits = get_usize(obj, "run: compression", "bits", 8)?;
            if bits > u32::MAX as usize {
                return Err("run: compression bits out of range".into());
            }
            Ok(Compression::Quantize { bits: bits as u32 })
        }
        other => Err(format!(
            "run: unknown compression kind '{other}' (expected topk | quantize)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_validate() {
        let spec = ExperimentSpec::new("fig1").validated().unwrap();
        assert_eq!(spec.strategy, Strategy::Matcha { budget: 0.5 });
        assert_eq!(spec.backend, Backend::SimReference);
        assert_eq!(spec.policy, "analytic");
    }

    #[test]
    fn async_backend_roundtrips_and_validates() {
        let spec = ExperimentSpec::new("ring:8")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::Async { threads: 4, max_staleness: 7 })
            .iterations(20)
            .validated()
            .unwrap();
        let text = spec.to_json_string();
        assert!(text.contains("max_staleness"), "{text}");
        let back = ExperimentSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
        // Bare string shorthand picks the defaults.
        let short = ExperimentSpec::parse(r#"{"graph": "fig1", "backend": "async"}"#).unwrap();
        assert_eq!(
            short.backend,
            Backend::Async { threads: 1, max_staleness: crate::gossip::DEFAULT_MAX_STALENESS }
        );
    }

    #[test]
    fn async_backend_rejects_maxdeg_delay_and_zero_threads() {
        let err = ExperimentSpec::new("fig1")
            .problem(ProblemSpec::quadratic())
            .delay("maxdeg")
            .backend(Backend::Async { threads: 2, max_staleness: 4 })
            .validate()
            .unwrap_err();
        assert!(err.contains("link-granular"), "{err}");
        let err = ExperimentSpec::new("fig1")
            .backend(Backend::Async { threads: 0, max_staleness: 4 })
            .validate()
            .unwrap_err();
        assert!(err.contains("threads"), "{err}");
    }

    #[test]
    fn cluster_backend_roundtrips_and_validates() {
        for transport in [TransportKind::Loopback, TransportKind::Tcp] {
            let spec = ExperimentSpec::new("ring:8")
                .problem(ProblemSpec::quadratic())
                .backend(Backend::Cluster { shards: 3, transport: transport.clone() })
                .iterations(20)
                .validated()
                .unwrap();
            let text = spec.to_json_string();
            assert!(text.contains("cluster") && text.contains(transport.name()), "{text}");
            assert_eq!(ExperimentSpec::parse(&text).unwrap(), spec);
        }
        // Transport defaults to loopback when omitted.
        let short = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "cluster", "shards": 2}}"#,
        )
        .unwrap();
        assert_eq!(
            short.backend,
            Backend::Cluster { shards: 2, transport: TransportKind::Loopback }
        );
    }

    #[test]
    fn remote_cluster_backend_roundtrips_and_defaults_shards() {
        let addrs = vec!["10.0.0.1:7701".to_string(), "10.0.0.2:7701".to_string()];
        let spec = ExperimentSpec::new("ring:8")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::Cluster {
                shards: 2,
                transport: TransportKind::Remote { addrs: addrs.clone() },
            })
            .iterations(20)
            .validated()
            .unwrap();
        let text = spec.to_json_string();
        assert!(text.contains("10.0.0.1:7701"), "{text}");
        assert_eq!(ExperimentSpec::parse(&text).unwrap(), spec);
        // Omitting 'shards' defaults it to one shard per listed daemon.
        let short = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "cluster",
                "transport": {"tcp": ["a:1", "b:2", "c:3"]}}}"#,
        )
        .unwrap();
        assert_eq!(
            short.backend,
            Backend::Cluster {
                shards: 3,
                transport: TransportKind::Remote {
                    addrs: vec!["a:1".into(), "b:2".into(), "c:3".into()],
                },
            }
        );
    }

    #[test]
    fn cluster_backend_rejects_bad_forms() {
        let err = ExperimentSpec::parse(r#"{"graph": "fig1", "backend": "cluster"}"#).unwrap_err();
        assert!(err.contains("shards"), "{err}");
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "cluster", "transport": "carrier"}}"#,
        )
        .unwrap_err();
        assert!(err.contains("transport"), "{err}");
        let err = ExperimentSpec::new("fig1")
            .backend(Backend::Cluster { shards: 0, transport: TransportKind::Loopback })
            .validate()
            .unwrap_err();
        assert!(err.contains("shards >= 1"), "{err}");
        // Remote forms: wrong object key, non-string address, empty node
        // list, and a shard count that disagrees with the address list.
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "cluster",
                "transport": {"udp": ["a:1"]}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown key 'udp'"), "{err}");
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "cluster", "transport": {"tcp": [7]}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("host:port"), "{err}");
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "cluster", "transport": {"tcp": []}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("at least one"), "{err}");
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "cluster", "shards": 3,
                "transport": {"tcp": ["a:1", "b:2"]}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("2 node addresses but shards = 3"), "{err}");
    }

    #[test]
    fn unbounded_staleness_roundtrips_as_null() {
        let spec = ExperimentSpec::new("ring:8")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::Async {
                threads: 2,
                max_staleness: crate::gossip::UNBOUNDED_STALENESS,
            })
            .iterations(20)
            .validated()
            .unwrap();
        let text = spec.to_json_string();
        assert!(text.contains("\"max_staleness\":null"), "{text}");
        assert_eq!(ExperimentSpec::parse(&text).unwrap(), spec);
        // Explicit null in hand-written JSON selects the unbounded mode.
        let parsed = ExperimentSpec::parse(
            r#"{"graph": "fig1", "backend": {"kind": "async", "threads": 1,
                "max_staleness": null}}"#,
        )
        .unwrap();
        assert_eq!(
            parsed.backend,
            Backend::Async { threads: 1, max_staleness: crate::gossip::UNBOUNDED_STALENESS }
        );
        // A bounded value at or beyond 2^53 cannot round-trip and is
        // rejected with a pointer at the null spelling.
        let err = ExperimentSpec::new("fig1")
            .backend(Backend::Async { threads: 1, max_staleness: (1 << 53) + 1 })
            .validate()
            .unwrap_err();
        assert!(err.contains("2^53") && err.contains("null"), "{err}");
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        let spec = ExperimentSpec::new("ring:8")
            .strategy(Strategy::Periodic { budget: 0.25 })
            .problem(ProblemSpec::Quadratic {
                dim: 24,
                hetero: 4.0,
                noise_std: 1.0,
                seed: Some(88),
            })
            .delay("stochastic:0.5:2.0")
            .policy("straggler:0:3.0")
            .backend(Backend::EngineActors { threads: 8 })
            .lr(0.04)
            .lr_decay(0.5, 200)
            .iterations(300)
            .record_every(25)
            .compute_units(0.2)
            .compression(Compression::TopK { frac: 0.25 })
            .seed(7)
            .sampler_seed(31)
            .trace(TraceSpec {
                path: "out/trace.json".into(),
                format: TraceFormat::Jsonl,
                capacity: 1024,
                telemetry: false,
                telemetry_capacity: 512,
            })
            .report(ReportSpec { window: 4 });
        let text = spec.to_json_string();
        let back = ExperimentSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn report_block_parses_defaults_and_validates() {
        let spec = ExperimentSpec::parse(r#"{"graph": "fig1", "report": {}}"#).unwrap();
        assert_eq!(spec.report, Some(ReportSpec { window: DEFAULT_REPORT_WINDOW }));

        let spec =
            ExperimentSpec::parse(r#"{"graph": "fig1", "report": {"window": 3}}"#).unwrap();
        assert_eq!(spec.report, Some(ReportSpec { window: 3 }));

        // Absent block means disabled.
        assert_eq!(ExperimentSpec::parse(r#"{"graph": "fig1"}"#).unwrap().report, None);

        let err =
            ExperimentSpec::parse(r#"{"graph": "fig1", "report": {"window": 1}}"#).unwrap_err();
        assert!(err.contains("report: window must be >= 2"), "{err}");
        let err = ExperimentSpec::parse(r#"{"graph": "fig1", "report": 8}"#).unwrap_err();
        assert!(err.contains("report"), "{err}");
        let err = ExperimentSpec::new("fig1")
            .report(ReportSpec { window: 0 })
            .validate()
            .unwrap_err();
        assert!(err.contains("report: window"), "{err}");
    }

    #[test]
    fn trace_block_parses_defaults_and_validates() {
        let spec = ExperimentSpec::parse(
            r#"{"graph": "fig1", "trace": {"path": "t.json"}}"#,
        )
        .unwrap();
        let trace = spec.trace.expect("trace block parsed");
        assert_eq!(trace.path, "t.json");
        assert_eq!(trace.format, TraceFormat::Chrome);
        assert_eq!(trace.capacity, DEFAULT_TRACE_CAPACITY);
        assert!(trace.telemetry, "distributed telemetry defaults on");
        assert_eq!(trace.telemetry_capacity, DEFAULT_TELEMETRY_CAPACITY);

        let spec = ExperimentSpec::parse(
            r#"{"graph": "fig1",
                "trace": {"path": "t.json", "telemetry": false, "telemetry_capacity": 64}}"#,
        )
        .unwrap();
        let trace = spec.trace.expect("trace block parsed");
        assert!(!trace.telemetry);
        assert_eq!(trace.telemetry_capacity, 64);

        let err = ExperimentSpec::parse(r#"{"graph": "fig1", "trace": {}}"#).unwrap_err();
        assert!(err.contains("path"), "{err}");
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "trace": {"path": "t", "format": "pprof"}}"#,
        )
        .unwrap_err();
        assert!(err.contains("format"), "{err}");
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "trace": {"path": "t", "telemetry": 3}}"#,
        )
        .unwrap_err();
        assert!(err.contains("telemetry"), "{err}");
        let base_trace = || TraceSpec {
            path: "t".into(),
            format: TraceFormat::Chrome,
            capacity: 16,
            telemetry: true,
            telemetry_capacity: 16,
        };
        let err = ExperimentSpec::new("fig1")
            .trace(TraceSpec { path: String::new(), ..base_trace() })
            .validate()
            .unwrap_err();
        assert!(err.contains("trace: path"), "{err}");
        let err = ExperimentSpec::new("fig1")
            .trace(TraceSpec { capacity: 0, ..base_trace() })
            .validate()
            .unwrap_err();
        assert!(err.contains("trace: capacity"), "{err}");
        let err = ExperimentSpec::new("fig1")
            .trace(TraceSpec { telemetry_capacity: 0, ..base_trace() })
            .validate()
            .unwrap_err();
        assert!(err.contains("trace: telemetry_capacity"), "{err}");
    }

    #[test]
    fn explicit_graph_roundtrip() {
        let g = crate::graph::ring(5);
        let spec = ExperimentSpec::on_graph(g.clone())
            .problem(ProblemSpec::quadratic())
            .iterations(10);
        let back = ExperimentSpec::parse(&spec.to_json_string()).unwrap();
        assert_eq!(back.graph, GraphSource::Explicit(g));
    }

    #[test]
    fn rejects_unknown_keys_everywhere() {
        for (text, needle) in [
            (r#"{"graph": "fig1", "bogus": 1}"#, "unknown key 'bogus'"),
            (r#"{"graph": "fig1", "strategy": {"kind": "matcha", "x": 1}}"#, "unknown key 'x'"),
            (r#"{"graph": "fig1", "run": {"warp": 9}}"#, "unknown key 'warp'"),
            (
                r#"{"graph": "fig1", "trace": {"path": "t", "color": "red"}}"#,
                "unknown key 'color'",
            ),
            (r#"{"graph": "fig1", "report": {"depth": 2}}"#, "unknown key 'depth'"),
        ] {
            let err = ExperimentSpec::parse(text).unwrap_err();
            assert!(err.contains(needle), "{text}: {err}");
        }
    }

    #[test]
    fn validate_rejects_each_bad_field_with_its_name() {
        let base = || ExperimentSpec::new("fig1").problem(ProblemSpec::quadratic());
        let cases: Vec<(ExperimentSpec, &str)> = vec![
            (ExperimentSpec::new("warp:9"), "graph"),
            (base().strategy(Strategy::Matcha { budget: 0.0 }), "strategy"),
            (base().strategy(Strategy::Matcha { budget: 1.5 }), "strategy"),
            (base().lr(0.0), "run: lr"),
            (base().iterations(0), "run: iterations"),
            (base().record_every(0), "run: record_every"),
            (base().delay("warp"), "delay"),
            (base().policy("warp"), "policy"),
            (base().policy("straggler:99:2.0"), "policy"),
            (
                base().delay("maxdeg").policy("flaky:0.2").backend(Backend::EngineSequential),
                "policy",
            ),
            (base().policy("flaky:0.2"), "policy"),
            (base().backend(Backend::EngineActors { threads: 0 }), "backend"),
            (
                base().compression(Compression::TopK { frac: 0.0 }),
                "run: compression",
            ),
        ];
        for (spec, needle) in cases {
            let err = spec.validate().unwrap_err();
            assert!(err.contains(needle), "expected '{needle}' in: {err}");
        }
    }

    #[test]
    fn actors_backend_accepts_a_single_thread() {
        // The shared pool handles one thread fine (and matches the
        // sequential engine bit-for-bit), so threads >= 1 validates.
        ExperimentSpec::new("fig1")
            .problem(ProblemSpec::quadratic())
            .backend(Backend::EngineActors { threads: 1 })
            .validated()
            .unwrap();
    }

    #[test]
    fn sim_backend_accepts_engine_policies_only_on_engine() {
        let spec = ExperimentSpec::new("fig1")
            .policy("hetero:3")
            .backend(Backend::EngineSequential);
        spec.validate().unwrap();
    }

    #[test]
    fn with_budget_maps_over_strategies() {
        assert_eq!(
            Strategy::Matcha { budget: 0.5 }.with_budget(0.2),
            Strategy::Matcha { budget: 0.2 }
        );
        assert_eq!(Strategy::Vanilla.with_budget(0.2), Strategy::Vanilla);
    }

    #[test]
    fn shorthand_strings_parse() {
        let spec = ExperimentSpec::parse(
            r#"{"graph": "fig1", "strategy": "vanilla", "problem": "quad", "backend": "engine"}"#,
        )
        .unwrap();
        assert_eq!(spec.strategy, Strategy::Vanilla);
        assert_eq!(spec.problem, ProblemSpec::quadratic());
        assert_eq!(spec.backend, Backend::EngineSequential);
    }

    #[test]
    fn budgeted_strategy_shorthand_is_rejected() {
        for kind in ["matcha", "periodic", "single"] {
            let text = format!(r#"{{"graph": "fig1", "strategy": "{kind}"}}"#);
            let err = ExperimentSpec::parse(&text).unwrap_err();
            assert!(err.contains("needs a budget"), "{kind}: {err}");
        }
    }

    #[test]
    fn seeds_at_or_beyond_2_53_are_rejected() {
        let err = ExperimentSpec::new("fig1").seed(u64::MAX).validate().unwrap_err();
        assert!(err.contains("2^53"), "{err}");
        let err = ExperimentSpec::new("fig1")
            .sampler_seed(1 << 60)
            .validate()
            .unwrap_err();
        assert!(err.contains("sampler_seed"), "{err}");
        // 2^53 itself is rejected: a JSON integer just above it rounds
        // down to exactly 2^53 during parsing, so allowing the boundary
        // would let that silent rounding through.
        assert!(ExperimentSpec::new("fig1").seed(1 << 53).validate().is_err());
        // The largest exactly-representable seed is fine.
        ExperimentSpec::new("fig1").seed((1 << 53) - 1).validate().unwrap();
    }

    #[test]
    fn object_strategy_requires_explicit_budget() {
        let err = ExperimentSpec::parse(r#"{"graph": "fig1", "strategy": {"kind": "periodic"}}"#)
            .unwrap_err();
        assert!(err.contains("needs a numeric 'budget'"), "{err}");
        let err = ExperimentSpec::parse(
            r#"{"graph": "fig1", "strategy": {"kind": "vanilla", "budget": 0.2}}"#,
        )
        .unwrap_err();
        assert!(err.contains("vanilla takes no budget"), "{err}");
    }
}
