//! Streaming observation of running experiments.
//!
//! An [`Observer`] receives callbacks while an experiment executes —
//! after every iteration, at every metrics record, and (from the sweep
//! driver) as each grid point finishes — instead of waiting for the final
//! result object. All callbacks default to no-ops, so implementors
//! override only what they consume. The sim and engine loops invoke the
//! same callbacks at the same points, so an observer is
//! backend-agnostic.

use crate::metrics::Recorder;
use crate::trace::WindowStats;

use super::run::ExperimentResult;

/// Callbacks fired while a run (or sweep) is in flight. Iteration and
/// record callbacks arrive on the thread driving the run; sweep point
/// callbacks arrive on the thread that called the sweep, in completion
/// order (not input order).
pub trait Observer {
    /// After iteration `k` (1-based) completes: current virtual time and
    /// cumulative communication units.
    fn on_iteration(&mut self, _k: usize, _time: f64, _comm_units: f64) {}

    /// After a metrics row is recorded at iteration `k` (including the
    /// initial `k = 0` record). `metrics` is the recorder so far.
    fn on_record(&mut self, _k: usize, _time: f64, _metrics: &Recorder) {}

    /// A sweep grid point finished: `index` is its position in the input
    /// grid.
    fn on_point(&mut self, _index: usize, _result: &ExperimentResult) {}

    /// The run's [`crate::trace::Observatory`] closed a contraction
    /// window: realized consensus decay rate vs the plan's predicted ρ,
    /// plus the current activation drift score. Fires only when the
    /// spec enables the observatory (a `report` block) and the run has
    /// enough record samples to fill a window.
    fn on_window(&mut self, _w: &WindowStats) {}
}

/// The do-nothing observer; what the non-observed entry points use.
pub struct NoopObserver;

impl Observer for NoopObserver {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_methods_are_noops() {
        use crate::experiment::{run, ExperimentSpec, ProblemSpec};
        let mut obs = NoopObserver;
        obs.on_iteration(1, 2.0, 3.0);
        obs.on_record(1, 2.0, &Recorder::new());
        let result = run(&ExperimentSpec::new("fig1")
            .problem(ProblemSpec::quadratic())
            .iterations(5))
        .unwrap();
        obs.on_point(0, &result);
    }
}
