//! The unified experiment API: **spec → plan → run → observe**.
//!
//! This layer is the crate's front door. MATCHA's contribution is a
//! *pipeline* — decompose the base topology into matchings, optimize the
//! activation probabilities under a communication budget, optimize the
//! mixing weight, then run DecenSGD (paper §3, steps 1–3) — and this
//! module exposes that pipeline as four composable stages instead of the
//! per-call-site wiring the CLI, benches and examples used to carry:
//!
//! - **Spec** ([`ExperimentSpec`]) — a typed, validated, serializable
//!   description of a full run: graph source, strategy
//!   (`matcha | vanilla | periodic | single`) and budget, workload
//!   (`quad | logreg`), delay model and policy (stragglers, heterogeneous
//!   links, link failures), execution backend
//!   (`sim | engine | actors | async | cluster` — `async` is the
//!   barrier-free asynchronous gossip runtime of [`crate::gossip`],
//!   `cluster` the transport-separated multi-node runtime of
//!   [`crate::cluster`]), and run hyperparameters. Build fluently or
//!   load from JSON (`matcha run --spec exp.json`).
//! - **Plan** ([`Plan`], [`plan()`]) — the decompose → probabilities → α
//!   math, exposing matchings, λ₂, α and ρ before anything executes
//!   (`--dry-run` stops here). Absorbs the legacy `coordinator::plan_*`
//!   helpers.
//! - **Run** ([`run()`], [`run_observed`], [`run_sweep`]) — one entry point
//!   for every backend, returning one [`ExperimentResult`] (superseding
//!   the `RunResult` / `EngineResult` split). Spec-driven runs reproduce
//!   the legacy entry points bit-for-bit per seed.
//! - **Observe** ([`Observer`]) — streaming callbacks per iteration, per
//!   metrics record, and per finished sweep grid point.
//!
//! ```
//! use matcha::experiment::{self, Backend, ExperimentSpec, ProblemSpec, Strategy};
//!
//! let spec = ExperimentSpec::new("fig1")
//!     .strategy(Strategy::Matcha { budget: 0.5 })
//!     .problem(ProblemSpec::quadratic())
//!     .backend(Backend::EngineSequential)
//!     .lr(0.03)
//!     .iterations(50)
//!     .validated()
//!     .unwrap();
//!
//! let plan = experiment::plan(&spec).unwrap();
//! assert!(plan.rho < 1.0); // Theorem 2: convergence guaranteed
//!
//! let result = experiment::run(&spec).unwrap();
//! assert!(result.total_time > 0.0);
//! assert!(result.final_loss().is_finite());
//! ```

mod observer;
mod plan;
mod run;
mod spec;

pub use observer::{NoopObserver, Observer};
pub use plan::{plan, Plan};
pub(crate) use run::{build_problem, run_planned_progress, BuiltProblem};
pub use run::{
    run, run_observed, run_planned, run_planned_traced, run_sweep, run_with_progress,
    ExperimentResult,
};
pub use spec::{
    Backend, ExperimentSpec, GraphSource, ProblemSpec, ReportSpec, Strategy, TraceSpec,
    DEFAULT_REPORT_WINDOW, DEFAULT_TELEMETRY_CAPACITY, DEFAULT_TRACE_CAPACITY,
};
